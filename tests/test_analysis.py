"""proxlint (repro.analysis) test suite.

Three layers:

* **per-rule fixtures** — one positive + one negative source blob per rule,
  where the positive encodes the historical bug pattern the rule exists to
  prevent (PR5 static-args, PR6 wall-clock flush timeout, PR8 getattr
  config shims), so a rule that silently stops firing breaks its fixture;
* **machinery** — inline suppressions, baseline round-trip + stale
  detection, the CLI gate exit codes;
* **the tier-1 gate itself** — the pytest bridge runs the full suite over
  ``src/`` + ``benchmarks/`` against the checked-in baseline and reports
  every non-baselined finding as an individual test failure named
  ``path:line [rule]``.
"""
import dataclasses
import textwrap
from pathlib import Path

import pytest

from repro.analysis.baseline import Baseline, BaselineEntry
from repro.analysis.engine import FileContext, check_source
from repro.analysis import pytest_bridge
from repro.analysis.rules import ALL_RULES, get_rule
from repro.analysis.rules.config_compat import ConfigForwardCompatRule
from repro.analysis.rules.dtype_hygiene import DtypeHygieneRule
from repro.analysis.rules.jit_static_args import JitStaticArgsRule
from repro.analysis.rules.metric_names import MetricNameLiteralsRule
from repro.analysis.rules.monotonic_clock import MonotonicClockRule
from repro.analysis.rules.plan_hashability import PlanHashabilityRule
from repro.analysis.rules.tracer_leak import TracerLeakRule
from repro.analysis.rules.unreferenced import UnreferencedModuleRule

_REPO = Path(__file__).resolve().parent.parent


def _check(src, rule, rel="src/repro/serve/fixture.py"):
    return check_source(textwrap.dedent(src), rel=rel, rules=[rule()])


# ---------------------------------------------------------------------------
# jit-static-args (the PR5 bug: distributed_search_kernel's axis-name
# strings were threaded into the traced body without static_argnames)
# ---------------------------------------------------------------------------

_PR5_POSITIVE = """
    import jax
    from functools import partial

    @partial(jax.jit, static_argnames=("mode",))
    def kernel(x, mode, data_axis="data"):
        if data_axis == "data":
            return x + 1
        return x
"""


def test_jit_static_args_fires_on_pr5_pattern():
    found = _check(_PR5_POSITIVE, JitStaticArgsRule)
    assert [f.rule for f in found] == ["jit-static-args"]
    assert "data_axis" in found[0].message


def test_jit_static_args_silent_on_fixed_code():
    fixed = _PR5_POSITIVE.replace('("mode",)', '("mode", "data_axis")')
    assert _check(fixed, JitStaticArgsRule) == []


def test_jit_static_args_allows_is_none_pytree_checks():
    src = """
        import jax

        @jax.jit
        def f(x, mask=None):
            if mask is None:
                return x
            return x * mask
    """
    assert _check(src, JitStaticArgsRule) == []


# ---------------------------------------------------------------------------
# plan-hashability (QueryPlan.cache_key batching identity: a frozen
# dataclass with a list field constructs fine and explodes at first hash)
# ---------------------------------------------------------------------------

def test_plan_hashability_fires_on_list_field():
    src = """
        from dataclasses import dataclass
        from typing import List, Optional

        @dataclass(frozen=True)
        class PlanKey:
            k: int
            tags: Optional[List[str]] = None
    """
    found = _check(src, PlanHashabilityRule)
    assert [f.rule for f in found] == ["plan-hashability"]
    assert "tags" in found[0].message


def test_plan_hashability_silent_on_tuple_fields():
    src = """
        from dataclasses import dataclass
        from typing import Optional, Tuple

        @dataclass(frozen=True)
        class PlanKey:
            k: int
            tags: Optional[Tuple[str, ...]] = None
    """
    assert _check(src, PlanHashabilityRule) == []


# ---------------------------------------------------------------------------
# monotonic-clock (the PR6 bug: the serving engine measured queue wait with
# time.time(); an NTP step turned the flush timeout into an instant flush)
# ---------------------------------------------------------------------------

_CLOCK_POSITIVE = """
    import time

    def flush_due(t_submit):
        return time.time() - t_submit
"""


def test_monotonic_clock_fires_in_serve_tree():
    found = _check(_CLOCK_POSITIVE, MonotonicClockRule)
    assert [f.rule for f in found] == ["monotonic-clock"]


def test_monotonic_clock_silent_on_perf_counter():
    fixed = _CLOCK_POSITIVE.replace("time.time()", "time.perf_counter()")
    assert _check(fixed, MonotonicClockRule) == []


def test_monotonic_clock_scoped_to_latency_trees():
    # wall-clock timestamps outside serve/obs/plan/benchmarks are fine
    assert _check(_CLOCK_POSITIVE, MonotonicClockRule,
                  rel="src/repro/core/fixture.py") == []


# ---------------------------------------------------------------------------
# metric-name-literals (obs registry cells are keyed by name — a dynamic
# name is an unbounded-cardinality leak)
# ---------------------------------------------------------------------------

def test_metric_names_fire_on_fstring():
    src = """
        def report(metrics, tenant):
            metrics.counter(f"requests_{tenant}", 1)
    """
    found = _check(src, MetricNameLiteralsRule)
    assert [f.rule for f in found] == ["metric-name-literals"]


def test_metric_names_allow_literals_and_constants():
    src = """
        LATENCY = "serve_latency_us"

        def report(metrics, tenant, us):
            metrics.counter("requests_total", 1, tenant=tenant)
            metrics.observe(LATENCY, us)
    """
    assert _check(src, MetricNameLiteralsRule) == []


# ---------------------------------------------------------------------------
# config-forward-compat (the PR8 contract: upgrade_config at the boundary,
# never per-site getattr defaults)
# ---------------------------------------------------------------------------

def test_config_compat_fires_on_getattr_shim():
    src = """
        def width(cfg):
            return int(getattr(cfg, "beam_width", 1))
    """
    found = _check(src, ConfigForwardCompatRule)
    assert [f.rule for f in found] == ["config-forward-compat"]
    assert "beam_width" in found[0].message


def test_config_compat_allows_capability_probes_and_direct_reads():
    src = """
        from repro.configs.base import upgrade_config

        def width(cfg, index):
            attrs = getattr(index, "attributes", None)   # not config-shaped
            return upgrade_config(cfg).beam_width, attrs
    """
    assert _check(src, ConfigForwardCompatRule) == []


# ---------------------------------------------------------------------------
# tracer-leak (Python control flow on traced values concretizes the tracer)
# ---------------------------------------------------------------------------

def test_tracer_leak_fires_on_python_branch():
    src = """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            y = jnp.sum(x)
            if y > 0:
                return y
            return -y
    """
    found = _check(src, TracerLeakRule)
    assert [f.rule for f in found] == ["tracer-leak"]


def test_tracer_leak_silent_on_where_shape_and_none_checks():
    src = """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x, m=None):
            y = jnp.sum(x, axis=-1)
            if m is None:          # pytree-structural: fine
                m = jnp.ones_like(y)
            if y.shape[0] > 1:     # static shape read: fine
                y = y[:1]
            return jnp.where(y > 0, y * m[:1], -y)
    """
    assert _check(src, TracerLeakRule) == []


# ---------------------------------------------------------------------------
# dtype-hygiene (int32 node ids, no float64 into the kernel tree)
# ---------------------------------------------------------------------------

def test_dtype_hygiene_fires_in_core_tree():
    src = """
        import numpy as np

        def build(n, dists):
            ids = np.arange(n)
            wide = dists.astype(np.float64)
            return ids, wide
    """
    found = _check(src, DtypeHygieneRule, rel="src/repro/core/fixture.py")
    assert [f.rule for f in found] == ["dtype-hygiene"] * 2
    assert "ids" in found[0].message and "float64" in found[1].message


def test_dtype_hygiene_silent_on_int32_ids_and_f32():
    src = """
        import numpy as np

        def build(n, dists):
            ids = np.arange(n, dtype=np.int32)
            return ids, dists.astype(np.float32)
    """
    assert _check(src, DtypeHygieneRule,
                  rel="src/repro/core/fixture.py") == []


# ---------------------------------------------------------------------------
# unreferenced-module (dead-code audit over the static import graph)
# ---------------------------------------------------------------------------

def _ctx(rel, src):
    return FileContext(rel, rel, textwrap.dedent(src))


def _project_findings(rule, ctxs):
    rule.universe_dirs = ()          # fixture: no tests/ universe on disk
    return list(rule.check_project(ctxs))


def test_unreferenced_module_flags_dead_src_module():
    found = _project_findings(UnreferencedModuleRule(), [
        _ctx("benchmarks/bench.py", "import repro.alpha\n"),   # root
        _ctx("src/repro/alpha.py", "from repro.beta import X\n"),
        _ctx("src/repro/beta.py", "X = 1\n"),
        _ctx("src/repro/gamma.py", "Y = 2\n"),                 # dead
    ])
    assert [f.path for f in found] == ["src/repro/gamma.py"]
    # module-granularity baseline identity, stable under content edits
    assert found[0].line_text == "module:repro.gamma"


def test_unreferenced_module_exempts_cli_entry_points():
    found = _project_findings(UnreferencedModuleRule(), [
        _ctx("src/repro/tool.py",
             'if __name__ == "__main__":\n    print("hi")\n'),
    ])
    assert found == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_line_suppression():
    src = """
        import time
        t0 = time.time()  # proxlint: disable=monotonic-clock
        t1 = time.time()
    """
    found = _check(src, MonotonicClockRule)
    assert [f.line for f in found] == [4]   # only the unsuppressed line


def test_file_suppression():
    src = """
        # proxlint: disable-file=monotonic-clock
        import time
        t0 = time.time()
        t1 = time.time()
    """
    assert _check(src, MonotonicClockRule) == []


# ---------------------------------------------------------------------------
# baseline round-trip + stale detection
# ---------------------------------------------------------------------------

def test_baseline_round_trip_and_stale(tmp_path):
    findings = _check(_CLOCK_POSITIVE, MonotonicClockRule)
    assert findings

    bl = Baseline.from_findings(findings)
    assert all(e.justification == "TODO: justify or fix" for e in bl.entries)
    bl = Baseline([dataclasses.replace(e, justification="intentional: test")
                   for e in bl.entries])
    path = tmp_path / "baseline.json"
    bl.save(str(path))
    loaded = Baseline.load(str(path))
    assert [e.key for e in loaded.entries] == [e.key for e in bl.entries]
    assert loaded.entries[0].justification == "intentional: test"

    # covered: same findings -> no new, no stale
    new, covered, stale = loaded.split(findings)
    assert not new and not stale and covered == findings

    # the flagged line changes -> the entry goes stale (debt cannot
    # outlive the code it excused)
    fixed = _check(_CLOCK_POSITIVE.replace("time.time()",
                                           "time.perf_counter()"),
                   MonotonicClockRule)
    new, covered, stale = loaded.split(fixed)
    assert not new and not covered and stale == loaded.entries

    # --update-baseline carries surviving justifications over
    again = Baseline.from_findings(findings, old=loaded)
    assert again.entries[0].justification == "intentional: test"


# ---------------------------------------------------------------------------
# CLI gate (exit codes CI relies on)
# ---------------------------------------------------------------------------

def test_cli_gate_exit_codes(tmp_path, monkeypatch, capsys):
    from repro.analysis.__main__ import main

    assert main(["check", "--list-rules"]) == 0

    pkg = tmp_path / "src" / "repro" / "serve"
    pkg.mkdir(parents=True)
    bad = pkg / "engine.py"
    bad.write_text("import time\nt0 = time.time()\n")
    monkeypatch.chdir(tmp_path)

    assert main(["check", "src"]) == 1                    # new findings
    assert main(["check", "--update-baseline", "src"]) == 0
    assert main(["check", "src"]) == 0                    # baselined
    out = capsys.readouterr().out
    assert "0 error(s)" in out

    bad.write_text("import time\nt0 = time.perf_counter()\n")
    assert main(["check", "src"]) == 1                    # stale entries


# ---------------------------------------------------------------------------
# pytest bridge: one failure per finding, named path:line [rule]
# ---------------------------------------------------------------------------

def test_bridge_reports_individual_failures_with_location(tmp_path):
    pkg = tmp_path / "src" / "repro" / "serve"
    pkg.mkdir(parents=True)
    (pkg / "engine.py").write_text("import time\nt0 = time.time()\n")

    report = pytest_bridge.run([str(tmp_path / "src")], root=str(tmp_path))
    params = dict(pytest_bridge.finding_params(report))
    key = "src/repro/serve/engine.py:2 [monotonic-clock]"
    assert key in params                       # one param per finding
    assert "src/repro/serve/engine.py:2" in params[key]   # file:line in msg
    assert "monotonic-clock" in params[key]


def test_bridge_clean_tree_collects_sentinel(tmp_path):
    pkg = tmp_path / "src" / "repro" / "serve"
    pkg.mkdir(parents=True)
    (pkg / "engine.py").write_text("import time\nt0 = time.perf_counter()\n")
    bench = tmp_path / "benchmarks"
    bench.mkdir()
    (bench / "bench.py").write_text("import repro.serve.engine\n")

    report = pytest_bridge.run(
        [str(tmp_path / "src"), str(bench)], root=str(tmp_path))
    assert pytest_bridge.finding_params(report) == [(pytest_bridge.CLEAN,
                                                     None)]


# ---------------------------------------------------------------------------
# registry sanity
# ---------------------------------------------------------------------------

def test_rule_registry_is_complete():
    ids = [cls.id for cls in ALL_RULES]
    assert len(ids) == len(set(ids))
    for rule_id in ids:
        assert get_rule(rule_id).id == rule_id
    with pytest.raises(KeyError):
        get_rule("no-such-rule")


# ---------------------------------------------------------------------------
# THE tier-1 gate: src/ + benchmarks/ against the checked-in baseline.
# Each non-baselined finding (and each stale baseline entry) fails as its
# own test, named path:line [rule].
# ---------------------------------------------------------------------------

_report = pytest_bridge.run(
    [str(_REPO / "src"), str(_REPO / "benchmarks")], root=str(_REPO),
    baseline_path=str(_REPO / "proxlint.baseline.json"))
_PARAMS = pytest_bridge.finding_params(_report)


@pytest.mark.parametrize("loc,message", _PARAMS, ids=[p[0] for p in _PARAMS])
def test_repo_is_proxlint_clean(loc, message):
    if message is not None:
        pytest.fail(message)
