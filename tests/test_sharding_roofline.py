"""Sharding rule resolution + HLO cost parser correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shard_lib
from repro.launch.mesh import make_mesh
from repro.roofline import hlo_parse
from repro.roofline.analysis import collective_bytes


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def test_logical_to_spec_divisibility():
    # kv=1 (MQA) can't shard over model=16 -> replicated on that dim
    big = shard_lib.abstract_mesh((16, 16), ("data", "model"))
    spec = shard_lib.logical_to_spec(("embed", "kv"), shape=(64, 1), mesh=big)
    assert spec == P("data", None)
    spec = shard_lib.logical_to_spec(("embed", "kv"), shape=(64, 32), mesh=big)
    assert spec == P("data", "model")
    # dim not divisible by the data axis either -> fully replicated
    spec = shard_lib.logical_to_spec(("embed", "kv"), shape=(33, 1), mesh=big)
    assert spec == P(None, None)


def test_param_shardings_tree(mesh):
    specs = {"w": ("embed", "heads"), "b": ("embed",)}
    shapes = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32),
              "b": jax.ShapeDtypeStruct((8,), jnp.float32)}
    sh = shard_lib.param_shardings(specs, shapes, mesh)
    assert sh["w"].spec == P("data", "model")
    assert sh["b"].spec == P("data")


def test_hlo_parser_scan_trip_counts():
    def scanned(a):
        def body(c, _):
            return c @ a, None
        c, _ = jax.lax.scan(body, a, None, length=10)
        return c

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    text = jax.jit(scanned).lower(x).compile().as_text()
    cost = hlo_parse.analyze_text(text)
    expected = 10 * 2 * 128 ** 3
    assert abs(cost.flops - expected) / expected < 0.01


def test_hlo_parser_nested_scans():
    def nested(a):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ a, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        c, _ = jax.lax.scan(outer, a, None, length=3)
        return c

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    text = jax.jit(nested).lower(x).compile().as_text()
    cost = hlo_parse.analyze_text(text)
    expected = 15 * 2 * 64 ** 3
    assert abs(cost.flops - expected) / expected < 0.01


def test_collective_regex():
    fake = """
  %ag = f32[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = bf16[64]{0} all-reduce(%y), to_apply=%sum
  %rs = f32[4,4]{1,0} reduce-scatter(%z), dimensions={0}
"""
    got = collective_bytes(fake)
    assert got["all-gather"] == 8 * 128 * 4
    assert got["all-reduce"] == 64 * 2
    assert got["reduce-scatter"] == 16 * 4
