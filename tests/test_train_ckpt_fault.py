"""Training loop convergence, checkpoint round-trip, fault injection,
microbatch-accumulation equivalence, data-pipeline determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck
from repro.configs import get_smoke_config
from repro.distributed.fault import FaultConfig, FaultTolerantLoop
from repro.launch.mesh import make_mesh
from repro.models.model import build_model
from repro.train.data import DataConfig, batch_for_step
from repro.train.loop import init_train_state, make_train_step
from repro.train.optimizer import AdamW


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg, q_chunk=64)
    opt = AdamW(lr=1e-3, warmup_steps=10, total_steps=200)
    state, specs = init_train_state(model, opt, jax.random.PRNGKey(0))
    mesh = make_mesh((1, 1), ("data", "model"))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=65, global_batch=8,
                      copy_period=16)
    return cfg, model, opt, state, mesh, dcfg


def test_loss_decreases(setup):
    cfg, model, opt, state, mesh, dcfg = setup
    ts, _ = make_train_step(model, opt, mesh, microbatches=2)
    ts = jax.jit(ts)
    losses = []
    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in batch_for_step(dcfg, step).items()}
        state, m = ts(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_microbatch_equivalence(setup):
    cfg, model, opt, state, mesh, dcfg = setup
    batch = {k: jnp.asarray(v) for k, v in batch_for_step(dcfg, 0).items()}
    ts1, _ = make_train_step(model, opt, mesh, microbatches=1)
    ts4, _ = make_train_step(model, opt, mesh, microbatches=4)
    s1, m1 = jax.jit(ts1)(state, batch)
    s4, m4 = jax.jit(ts4)(state, batch)
    # losses averaged over microbatches equal the full-batch loss
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-2
    # parameters after the step are close (fp32 accumulation, bf16 params)
    p1 = jax.tree_util.tree_leaves(s1.params)
    p4 = jax.tree_util.tree_leaves(s4.params)
    for a, b in zip(p1, p4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.1, atol=0.05)


def test_data_pipeline_deterministic():
    dcfg = DataConfig(vocab_size=1000, seq_len=33, global_batch=4)
    a = batch_for_step(dcfg, 7)
    b = batch_for_step(dcfg, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_for_step(dcfg, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_checkpoint_roundtrip_and_gc(setup):
    cfg, model, opt, state, mesh, dcfg = setup
    with tempfile.TemporaryDirectory() as d:
        for step in (1, 2, 3, 4):
            ck.save_checkpoint(d, step, state, keep=2)
        assert ck.latest_step(d) == 4
        dirs = [x for x in os.listdir(d) if x.startswith("step_")]
        assert len(dirs) == 2  # gc keeps last 2
        restored, step, _ = ck.restore_checkpoint(d, state,
                                                  validate_digests=True)
        assert step == 4
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fault_recovery_deterministic(setup):
    """A NaN fault mid-run rolls back + skips; the run completes and the
    final step count is exact."""
    cfg, model, opt, state, mesh, dcfg = setup
    ts, _ = make_train_step(model, opt, mesh)
    ts = jax.jit(ts)
    fails = {"n": 0}

    def step_fn(st, step):
        if step == 6 and fails["n"] == 0:
            fails["n"] += 1
            return st, {"loss": float("nan"), "grad_norm": 1.0}
        batch = {k: jnp.asarray(v) for k, v in batch_for_step(dcfg, step).items()}
        st, m = ts(st, batch)
        return st, {k: float(v) for k, v in m.items()}

    with tempfile.TemporaryDirectory() as d:
        loop = FaultTolerantLoop(step_fn, state,
                                 FaultConfig(ckpt_dir=d, ckpt_every=3,
                                             async_ckpt=False))
        loop.run(10)
        assert loop.restarts == 1
        assert loop.step >= 10


def test_elastic_restore_different_mesh(setup):
    """Checkpoint written under one mesh restores onto another shape —
    topology independence (logical-spec manifest)."""
    cfg, model, opt, state, mesh, dcfg = setup
    from repro.distributed.fault import elastic_restore
    _, specs = model.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ck.save_checkpoint(d, 5, state.params)
        new_mesh = make_mesh((1, 1), ("data", "model"))
        restored, step, _ = elastic_restore(d, state.params, new_mesh, specs)
        assert step == 5
        for a, b in zip(jax.tree_util.tree_leaves(state.params),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
