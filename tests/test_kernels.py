"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops


RNG = np.random.default_rng(0)


@pytest.mark.parametrize("q,m,c,dsub", [(1, 8, 64, 2), (8, 16, 256, 4),
                                        (4, 32, 256, 3), (2, 25, 128, 4)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_pq_adt_sweep(q, m, c, dsub, metric):
    qs = jnp.asarray(RNG.standard_normal((q, m * dsub)), jnp.float32)
    cents = jnp.asarray(RNG.standard_normal((m, c, dsub)), jnp.float32)
    got = ops.pq_adt(qs, cents, metric)
    want = ops.pq_adt_ref(qs, cents, metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,m,c", [(1, 8, 16), (37, 16, 64), (300, 32, 256)])
def test_pq_lookup_sweep(n, m, c):
    codes = jnp.asarray(RNG.integers(0, c, (n, m)), jnp.uint8)
    adt = jnp.asarray(RNG.standard_normal((m, c)), jnp.float32)
    got = ops.pq_lookup(codes, adt)
    want = ops.pq_lookup_ref(codes, adt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("q,l", [(1, 32), (5, 64), (16, 256)])
def test_bitonic_sweep(q, l):
    keys = jnp.asarray(RNG.standard_normal((q, l)), jnp.float32)
    vals = jnp.asarray(RNG.integers(0, 1 << 20, (q, l)), jnp.int32)
    gk, gv = ops.bitonic_sort_pairs(keys, vals)
    wk, wv = ops.bitonic_sort_pairs_ref(keys, vals)
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(wk))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 64, 128]))
def test_bitonic_property(seed, l):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.standard_normal((3, l)), jnp.float32)
    vals = jnp.asarray(np.tile(np.arange(l, dtype=np.int32), (3, 1)))
    gk, gv = ops.bitonic_sort_pairs(keys, vals)
    gk, gv = np.asarray(gk), np.asarray(gv)
    assert (np.diff(gk, axis=1) >= 0).all()
    # payload is the inverse permutation: gathering keys by it reproduces gk
    orig = np.asarray(keys)
    np.testing.assert_array_equal(
        np.take_along_axis(orig, gv, axis=1), gk
    )


@pytest.mark.parametrize("q,k,d", [(1, 16, 32), (6, 64, 128), (3, 128, 96)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_l2_rerank_sweep(q, k, d, metric):
    qs = jnp.asarray(RNG.standard_normal((q, d)), jnp.float32)
    cands = jnp.asarray(RNG.standard_normal((q, k, d)), jnp.float32)
    got = ops.l2_rerank(qs, cands, metric)
    want = ops.l2_rerank_ref(qs, cands, metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_bf16_lookup_tolerance():
    """bf16 ADT path (serving dtype) stays within bf16 epsilon of f32."""
    codes = jnp.asarray(RNG.integers(0, 256, (64, 32)), jnp.uint8)
    adt = jnp.asarray(RNG.standard_normal((32, 256)), jnp.float32)
    got32 = np.asarray(ops.pq_lookup(codes, adt))
    got16 = np.asarray(ops.pq_lookup(codes, adt.astype(jnp.bfloat16).astype(jnp.float32)))
    np.testing.assert_allclose(got32, got16, rtol=0.05, atol=0.3)
