"""3D NAND device + accelerator simulator sanity (paper design points)."""
import dataclasses

import pytest

from repro.nand.device import NandConfig
from repro.nand.engine import EngineConfig
from repro.nand.simulator import WorkloadTrace, simulate


@pytest.fixture(scope="module")
def nand():
    return NandConfig()


def test_proxima_core_design_point(nand):
    assert nand.read_latency_ns() < 300          # §IV-C
    assert 100 <= nand.page_bytes <= 160         # ~128 B granularity
    gb = nand.capacity_bits / 1e9
    assert 400 <= gb <= 520                      # ~432 Gb


def test_ssd_class_pages_are_slow(nand):
    # Fig 9: large pages + many blocks -> 10^4+ ns
    assert nand.read_latency_ns(page_bytes=8192, n_block=1024) > 1e4


def test_one_shot_hot_access(nand):
    """Reading a co-located hot record costs ONE activation + transfer,
    far below separate activations (§IV-E)."""
    hot_bytes = 2256
    one_shot = nand.access_latency_ns(hot_bytes)
    separate = 2 * nand.read_latency_ns()
    assert one_shot < separate


@pytest.fixture(scope="module")
def trace():
    return WorkloadTrace(hops=40, pq=200, acc=60, hot_hops=20, free_pq=100,
                         rounds=40, dim=128, r_degree=64, index_bits=22,
                         pq_bits=256)


def test_queue_scaling_monotone(trace):
    prev_qps, prev_util = 0.0, 0.0
    for nq in (32, 64, 128, 256):
        r = simulate(trace, n_queues=nq)
        assert r.qps > prev_qps
        assert r.core_utilization >= prev_util
        prev_qps, prev_util = r.qps, r.core_utilization


def test_queue_efficiency_declines(trace):
    r32 = simulate(trace, n_queues=32)
    r512 = simulate(trace, n_queues=512)
    assert r512.qps_per_watt < r32.qps_per_watt   # paper Fig 16


def test_hot_nodes_help(trace):
    cold = dataclasses.replace(trace, hot_hops=0.0, free_pq=0.0)
    r_hot = simulate(trace)
    r_cold = simulate(cold)
    assert r_hot.qps > r_cold.qps
    assert r_hot.latency_us < r_cold.latency_us


def test_pq_beats_accurate_traversal():
    pq = WorkloadTrace(hops=40, pq=200, acc=60, rounds=40, dim=128,
                       r_degree=64, index_bits=22, pq_bits=256)
    acc = WorkloadTrace(hops=75, pq=0, acc=240, rounds=75, dim=128,
                        r_degree=64, index_bits=32, pq_bits=0, use_pq=False)
    r_pq, r_acc = simulate(pq), simulate(acc)
    assert r_pq.qps > 1.5 * r_acc.qps             # paper Fig 13: ~2x
    assert r_pq.qps_per_watt > r_acc.qps_per_watt


def test_access_bound_breakdown(trace):
    cold = dataclasses.replace(trace, hot_hops=0.0, free_pq=0.0)
    r = simulate(cold)
    assert r.breakdown["nand_access"] > 0.6       # paper Fig 15: ~80%
