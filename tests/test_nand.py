"""3D NAND device + accelerator simulator sanity (paper design points)."""
import dataclasses

import pytest

from repro.nand.device import NandConfig
from repro.nand.engine import EngineConfig
from repro.nand.simulator import (
    UpdateTrace, WorkloadTrace, simulate, simulate_mixed, simulate_updates,
)


@pytest.fixture(scope="module")
def nand():
    return NandConfig()


def test_proxima_core_design_point(nand):
    assert nand.read_latency_ns() < 300          # §IV-C
    assert 100 <= nand.page_bytes <= 160         # ~128 B granularity
    gb = nand.capacity_bits / 1e9
    assert 400 <= gb <= 520                      # ~432 Gb


def test_ssd_class_pages_are_slow(nand):
    # Fig 9: large pages + many blocks -> 10^4+ ns
    assert nand.read_latency_ns(page_bytes=8192, n_block=1024) > 1e4


def test_one_shot_hot_access(nand):
    """Reading a co-located hot record costs ONE activation + transfer,
    far below separate activations (§IV-E)."""
    hot_bytes = 2256
    one_shot = nand.access_latency_ns(hot_bytes)
    separate = 2 * nand.read_latency_ns()
    assert one_shot < separate


@pytest.fixture(scope="module")
def trace():
    return WorkloadTrace(hops=40, pq=200, acc=60, hot_hops=20, free_pq=100,
                         rounds=40, dim=128, r_degree=64, index_bits=22,
                         pq_bits=256)


def test_queue_scaling_monotone(trace):
    prev_qps, prev_util = 0.0, 0.0
    for nq in (32, 64, 128, 256):
        r = simulate(trace, n_queues=nq)
        assert r.qps > prev_qps
        assert r.core_utilization >= prev_util
        prev_qps, prev_util = r.qps, r.core_utilization


def test_queue_efficiency_declines(trace):
    r32 = simulate(trace, n_queues=32)
    r512 = simulate(trace, n_queues=512)
    assert r512.qps_per_watt < r32.qps_per_watt   # paper Fig 16


def test_hot_nodes_help(trace):
    cold = dataclasses.replace(trace, hot_hops=0.0, free_pq=0.0)
    r_hot = simulate(trace)
    r_cold = simulate(cold)
    assert r_hot.qps > r_cold.qps
    assert r_hot.latency_us < r_cold.latency_us


def test_pq_beats_accurate_traversal():
    pq = WorkloadTrace(hops=40, pq=200, acc=60, rounds=40, dim=128,
                       r_degree=64, index_bits=22, pq_bits=256)
    acc = WorkloadTrace(hops=75, pq=0, acc=240, rounds=75, dim=128,
                        r_degree=64, index_bits=32, pq_bits=0, use_pq=False)
    r_pq, r_acc = simulate(pq), simulate(acc)
    assert r_pq.qps > 1.5 * r_acc.qps             # paper Fig 13: ~2x
    assert r_pq.qps_per_watt > r_acc.qps_per_watt


def test_access_bound_breakdown(trace):
    cold = dataclasses.replace(trace, hot_hops=0.0, free_pq=0.0)
    r = simulate(cold)
    assert r.breakdown["nand_access"] > 0.6       # paper Fig 15: ~80%


# ---------------------------------------------------------------------------
# Program/erase model + streaming updates
# ---------------------------------------------------------------------------

def test_program_erase_dwarf_reads(nand):
    """NAND asymmetry: a page program is orders of magnitude slower than the
    Proxima core's sub-300ns read; a block erase slower still."""
    read = nand.read_latency_ns()
    prog = nand.program_latency_ns(nand.page_bytes)
    erase = nand.erase_latency_ns(1)
    assert prog > 50 * read
    assert erase > 10 * prog
    assert nand.program_energy_pj(nand.page_bytes) > nand.access_energy_pj(
        nand.page_bytes
    )


def test_write_amplification_vs_consolidation_fraction():
    """Delta-buffered updates: WA ~ (1+f)/f — consolidating more often
    (smaller delta fraction) costs more rewrites per logical byte."""
    was = []
    for f in (0.1, 0.25, 0.5):
        u = UpdateTrace(insert_rate=1e4, consolidate_fraction=f)
        r = simulate_updates(u)
        assert r.write_amplification >= 1.0
        assert abs(r.write_amplification - (1.0 + f) / f) < 0.05
        was.append(r.write_amplification)
    assert was[0] > was[1] > was[2]


def test_update_throughput_and_endurance():
    u = UpdateTrace(insert_rate=1e4, delete_rate=2e3)
    r = simulate_updates(u)
    assert r.update_throughput_per_s > 1e4        # sustains the offered rate
    assert 0.0 < r.program_busy_fraction < 1.0
    assert r.program_energy_pj_per_insert > 0
    assert r.erase_energy_pj_per_insert > 0
    assert r.endurance_years > 1.0                # SLC at 10k inserts/s
    # 10x the write rate -> ~10x less lifetime
    r10 = simulate_updates(dataclasses.replace(u, insert_rate=1e5,
                                               delete_rate=2e4))
    assert r10.endurance_years < r.endurance_years / 5


def test_mixed_trace_degrades_reads(trace):
    """Program/erase traffic steals core time from the read path."""
    prev_qps = float("inf")
    for rate in (1e3, 3e4, 1e5):
        u = UpdateTrace(insert_rate=rate, delete_rate=0.2 * rate)
        m = simulate_mixed(trace, u)
        assert m.qps < prev_qps
        prev_qps = m.qps
        assert m.update.write_amplification > 1.0
        assert m.total_power_w > m.read.power_w
    base = simulate(trace)
    assert prev_qps < base.qps


# ------------------------------------------------------------ double buffer
def test_double_buffer_shortens_round_and_latency(trace):
    """With a double-buffered page buffer the round's critical path is
    max(read, score) instead of read + score: per-round latency and total
    latency drop, the saved overlap is positive, and the busy-time figures
    (utilization, power) are untouched — overlap hides latency, it does not
    reduce work."""
    seq = simulate(trace)
    db = simulate(trace, nand=NandConfig(double_buffer=True))
    assert seq.overlap_saved_us == 0.0
    assert db.overlap_saved_us > 0.0
    assert db.round_latency_us < seq.round_latency_us
    assert db.latency_us < seq.latency_us
    assert db.qps > seq.qps
    assert db.core_utilization == pytest.approx(seq.core_utilization)
    # overlap buys throughput, not free energy: watts rise with the modeled
    # QPS while per-query energy only improves by the static share now
    # amortized over more queries
    assert db.power_w > seq.power_w
    assert db.power_w / db.qps <= seq.power_w / seq.qps


def test_double_buffer_single_round_saves_nothing():
    """One traversal round has no next round to overlap with."""
    t = WorkloadTrace(hops=2, pq=40, acc=10, hot_hops=0, free_pq=0,
                      rounds=1, dim=128, r_degree=64, index_bits=22,
                      pq_bits=256)
    db = simulate(t, nand=NandConfig(double_buffer=True))
    assert db.overlap_saved_us == 0.0


def test_double_buffer_metrics_exported(trace):
    """The round/overlap figures reach the observability name space."""
    m = simulate(trace, nand=NandConfig(double_buffer=True)).metrics()
    assert m["nand_round_latency_us"] > 0.0
    assert m["nand_overlap_saved_us"] > 0.0
    m_seq = simulate(trace).metrics()
    assert m_seq["nand_overlap_saved_us"] == 0.0
    assert m_seq["nand_round_latency_us"] > m["nand_round_latency_us"]
