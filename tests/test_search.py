"""Algorithm 1 behaviour: JAX search vs the Python reference oracle, recall
targets, and the paper's claimed effects (ET cuts hops; beta-rerank recovers
PQ casualties; PQ+rerank ~ exact traversal at far fewer accurate dists)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import SearchConfig
from repro.core import recall_at_k, search, search_reference


def _run(idx, cfg):
    return search(idx.corpus(), idx.dataset.queries, cfg, idx.dataset.metric)


def test_matches_reference_oracle(tiny_index):
    idx = tiny_index
    cfg = idx.config.search
    res = _run(idx, cfg)
    ids = np.asarray(res.ids)
    agree = 0
    n = 8
    for i in range(n):
        rid, _, cnt = search_reference(
            idx.graph.adjacency, idx.graph.degrees, idx.codes,
            idx._search_base(), idx.codebook.centroids,
            idx.graph.entry_point, idx.dataset.queries[i], cfg,
            idx.dataset.metric, hot_count=idx.hot_count,
        )
        agree += len(set(rid.tolist()) & set(ids[i].tolist()))
    assert agree / (n * cfg.k) > 0.9  # Bloom FPs may cause rare divergence


def test_recall_and_counters(tiny_index):
    idx = tiny_index
    res = _run(idx, idx.config.search)
    rec = recall_at_k(np.asarray(res.ids), idx.dataset.gt, 10)
    assert rec > 0.8, f"recall {rec}"
    assert np.asarray(res.n_hops).mean() > 3
    assert np.asarray(res.n_pq).mean() > np.asarray(res.n_acc).mean(), \
        "PQ traversal should do most distance work with cheap PQ distances"


def test_early_termination_cuts_hops(tiny_index):
    idx = tiny_index
    no_et = dataclasses.replace(idx.config.search, early_termination=False)
    et = idx.config.search
    r_no = _run(idx, no_et)
    r_et = _run(idx, et)
    rec_no = recall_at_k(np.asarray(r_no.ids), idx.dataset.gt, 10)
    rec_et = recall_at_k(np.asarray(r_et.ids), idx.dataset.gt, 10)
    assert np.asarray(r_et.n_hops).mean() < np.asarray(r_no.n_hops).mean()
    assert rec_et >= rec_no - 0.05  # ~equal recall (paper §III-D)


def test_beta_rerank_monotone_cost(tiny_index):
    idx = tiny_index
    accs = []
    for beta in (1.0, 1.1, 1.5):
        cfg = dataclasses.replace(idx.config.search, beta=beta)
        accs.append(float(np.asarray(_run(idx, cfg).n_acc).mean()))
    assert accs[0] <= accs[1] <= accs[2]


def test_pq_vs_exact_traversal(tiny_index):
    idx = tiny_index
    exact = dataclasses.replace(idx.config.search, use_pq=False,
                                early_termination=False)
    pq = idx.config.search
    r_ex = _run(idx, exact)
    r_pq = _run(idx, pq)
    rec_ex = recall_at_k(np.asarray(r_ex.ids), idx.dataset.gt, 10)
    rec_pq = recall_at_k(np.asarray(r_pq.ids), idx.dataset.gt, 10)
    assert rec_pq >= rec_ex - 0.1
    # the paper's core claim: far fewer accurate distance computations
    assert (np.asarray(r_pq.n_acc).mean()
            < 0.6 * np.asarray(r_ex.n_acc).mean())


def test_rerank_improves_over_raw_pq(tiny_index):
    idx = tiny_index
    no_rr = dataclasses.replace(idx.config.search, rerank=False,
                                early_termination=False)
    rr = dataclasses.replace(idx.config.search, early_termination=False)
    rec_no = recall_at_k(np.asarray(_run(idx, no_rr).ids), idx.dataset.gt, 10)
    rec_rr = recall_at_k(np.asarray(_run(idx, rr).ids), idx.dataset.gt, 10)
    assert rec_rr >= rec_no


def test_hot_node_counters(tiny_index):
    idx = tiny_index
    assert idx.hot_count > 0
    res = _run(idx, idx.config.search)
    # reordered graph: entry point is id 0 => expansions start hot
    assert np.asarray(res.n_hot_hops).mean() > 0
    assert np.asarray(res.n_free_pq).mean() > 0


def test_pallas_path_equivalence(tiny_index):
    idx = tiny_index
    cfg = dataclasses.replace(idx.config.search, list_size=32, t_init=8)
    plain = _run(idx, cfg)
    pall = _run(idx, dataclasses.replace(cfg, use_pallas=True))
    a = np.sort(np.asarray(plain.ids), 1)
    b = np.sort(np.asarray(pall.ids), 1)
    assert (a == b).mean() > 0.95
