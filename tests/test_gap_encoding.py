"""Gap-encoding round-trip (hypothesis property) + compression accounting."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.gap_encoding import gap_decode, gap_encode, gap_stats


@settings(max_examples=40, deadline=None)
@given(
    st.integers(2, 80),    # n vertices
    st.integers(1, 12),    # degree
    st.integers(0, 2**31 - 1),
)
def test_roundtrip_property(n, r, seed):
    rng = np.random.default_rng(seed)
    adj = rng.integers(0, n, size=(n, r)).astype(np.int32)
    enc = gap_encode(adj)
    dec = gap_decode(enc)
    np.testing.assert_array_equal(np.sort(adj.astype(np.int64), 1), dec)


def test_bit_width_scales_with_n():
    rng = np.random.default_rng(0)
    widths = []
    for n in (100, 10000, 1000000):
        adj = rng.integers(0, n, size=(64, 16)).astype(np.int32)
        widths.append(gap_encode(adj).bit_width)
    assert widths[0] < widths[1] < widths[2]
    assert widths[2] <= 26  # paper: 1M-scale graphs need <= 20-26 bits


def test_compression_vs_32bit():
    rng = np.random.default_rng(1)
    adj = rng.integers(0, 100000, size=(1000, 32)).astype(np.int32)
    s = gap_stats(adj)
    assert s["encoded_bytes"] < s["raw_bytes"]
    assert s["compression_ratio"] >= 0.19  # paper: >=19%


def test_sorted_duplicates_pad():
    """Padding (repeated last neighbour) encodes as zero deltas."""
    adj = np.asarray([[5, 9, 9, 9], [1, 2, 3, 3]], dtype=np.int32)
    enc = gap_encode(adj)
    dec = gap_decode(enc)
    np.testing.assert_array_equal(dec, [[5, 9, 9, 9], [1, 2, 3, 3]])
