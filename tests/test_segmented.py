"""Segmented out-of-core build: single-segment bit-identity with the legacy
monolithic pipeline (the CI equivalence gate), calibrated-beta reordering
invariance, reservoir/streaming-kNN correctness, cross-segment stitching
quality, direct-to-tile serving, and per-segment storage/NAND accounting.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (
    DatasetConfig, GraphConfig, PQConfig, ProximaConfig, SearchConfig,
)
from repro.core import pq as pq_mod
from repro.core.dataset import (
    ArraySegmentSource, exact_knn, exact_knn_stream, make_dataset,
    recall_at_k,
)
from repro.core.index import build_index, build_index_monolithic
from repro.core.search import graph_search
from repro.core.segmented import build_segmented, reservoir_sample
from repro.nand.simulator import BuildTrace, simulate_build


def _cfg(n=220, dim=32, hot=0.05, seed=0):
    return ProximaConfig(
        dataset=DatasetConfig(name="sift-like", num_base=n, num_queries=12,
                              dim=dim, num_clusters=6, cluster_std=0.3,
                              seed=seed),
        pq=PQConfig(num_subvectors=8, num_centroids=16, kmeans_iters=4),
        graph=GraphConfig(max_degree=12, build_list_size=24, alpha=1.2),
        search=SearchConfig(k=10, list_size=32, t_init=8, t_step=4,
                            repetition_rate=3, beta=1.06),
        hot_node_fraction=hot,
    )


# --------------------------------------------------------------------------
# single-segment equivalence: build_segmented(S=1).to_flat() IS the legacy
# monolithic build, artifact for artifact.  CI runs this file's
# "equivalence" selection as the segmented-build gate.
# --------------------------------------------------------------------------

def test_single_segment_equivalence_monolithic():
    cfg = _cfg()
    ds = make_dataset(cfg.dataset)
    mono = build_index_monolithic(cfg, dataset=ds, reorder_samples=8,
                                  calibrate=True)
    seg = build_segmented(cfg, dataset=ds, reorder_samples=8, calibrate=True,
                          segment_size=0)
    assert seg.num_segments == 1 and seg.stitch is None
    flat = seg.to_flat()

    np.testing.assert_array_equal(flat.graph.adjacency, mono.graph.adjacency)
    np.testing.assert_array_equal(flat.graph.degrees, mono.graph.degrees)
    assert flat.graph.entry_point == mono.graph.entry_point
    np.testing.assert_array_equal(flat.codes, mono.codes)
    np.testing.assert_array_equal(flat.dataset.base, mono.dataset.base)
    np.testing.assert_array_equal(flat.dataset.gt, mono.dataset.gt)
    np.testing.assert_array_equal(flat.codebook.centroids,
                                  mono.codebook.centroids)
    np.testing.assert_array_equal(flat.reordering.perm, mono.reordering.perm)
    assert flat.reordering.hot_count == mono.reordering.hot_count
    assert flat.calibrated_beta == mono.calibrated_beta
    assert (flat.gap.encoded_bytes if flat.gap else 0) == \
           (mono.gap.encoded_bytes if mono.gap else 0)


def test_build_index_wrapper_equivalence(tiny_proxima_cfg, tiny_index):
    # build_index is now the thin build_segmented(...).to_flat() wrapper; the
    # session fixture (built through the wrapper) must match a direct
    # monolithic build on the shared fixture config.
    mono = build_index_monolithic(tiny_proxima_cfg, reorder_samples=24)
    np.testing.assert_array_equal(tiny_index.graph.adjacency,
                                  mono.graph.adjacency)
    np.testing.assert_array_equal(tiny_index.codes, mono.codes)
    np.testing.assert_array_equal(tiny_index.reordering.perm,
                                  mono.reordering.perm)


# --------------------------------------------------------------------------
# calibrated beta is invariant to visit-frequency reordering (regression:
# the calibrator used to see reordered codes against UN-reordered encoder
# input, silently mis-pairing every sampled row)
# --------------------------------------------------------------------------

def test_calibrated_beta_invariant_to_reordering():
    # n <= calibrate_beta's num_samples/num_targets, so calibration covers
    # every (code, vector) pair and the quantile is over the same multiset
    # regardless of row order -> betas must be EXACTLY equal.
    cfg_hot = _cfg(hot=0.05)
    cfg_cold = dataclasses.replace(cfg_hot, hot_node_fraction=0.0)
    ds = make_dataset(cfg_hot.dataset)
    hot = build_index(cfg_hot, dataset=ds, reorder_samples=8, calibrate=True)
    cold = build_index(cfg_cold, dataset=ds, reorder_samples=8,
                       calibrate=True)
    assert hot.reordering is not None and cold.reordering is None
    assert hot.calibrated_beta == cold.calibrated_beta


def test_calibrate_beta_permutation_invariant_pairs():
    # the unit-level property behind the regression above: permuting rows of
    # (codes, base) TOGETHER leaves beta unchanged when sampling covers n.
    rng = np.random.default_rng(0)
    base = rng.standard_normal((96, 16)).astype(np.float32)
    cfg = PQConfig(num_subvectors=4, num_centroids=8, kmeans_iters=4)
    cb = pq_mod.train_pq(base, cfg, "l2")
    codes = np.asarray(pq_mod.encode(jnp.asarray(base),
                                     jnp.asarray(cb.centroids)))
    perm = np.random.default_rng(1).permutation(96)
    b0 = pq_mod.calibrate_beta(cb, codes, base,
                               np.random.default_rng(2), 96, 96)
    b1 = pq_mod.calibrate_beta(cb, codes[perm], base[perm],
                               np.random.default_rng(3), 96, 96)
    assert b0 == b1


# --------------------------------------------------------------------------
# streaming primitives
# --------------------------------------------------------------------------

def test_reservoir_sample_small_stream_is_identity():
    rng = np.random.default_rng(0)
    base = rng.standard_normal((100, 8)).astype(np.float32)
    src = ArraySegmentSource(base, segment_size=30)
    assert src.num_segments == 4
    np.testing.assert_array_equal(reservoir_sample(src, 100), base)
    np.testing.assert_array_equal(reservoir_sample(src, 1000), base)


def test_reservoir_sample_uniform_membership():
    rng = np.random.default_rng(0)
    base = np.arange(500, dtype=np.float32)[:, None] * np.ones(4, np.float32)
    src = ArraySegmentSource(base, segment_size=64)
    sample = reservoir_sample(src, 50, seed=7)
    assert sample.shape == (50, 4)
    ids = sample[:, 0].astype(int)
    assert np.all((ids >= 0) & (ids < 500))
    assert len(np.unique(ids)) == 50           # no duplicate rows
    # deterministic for a fixed seed
    np.testing.assert_array_equal(sample, reservoir_sample(src, 50, seed=7))


def test_exact_knn_stream_matches_flat():
    rng = np.random.default_rng(0)
    base = rng.standard_normal((300, 12)).astype(np.float32)
    queries = rng.standard_normal((9, 12)).astype(np.float32)
    src = ArraySegmentSource(base, segment_size=70)
    for metric in ("l2", "ip"):
        got = exact_knn_stream(queries, src, 10, metric)
        want = exact_knn(queries, base, 10, metric)
        np.testing.assert_array_equal(np.sort(got, 1), np.sort(want, 1))


# --------------------------------------------------------------------------
# multi-segment: stitching quality and direct-to-tile serving
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def seg_cfg():
    return _cfg(n=1200, dim=32)


@pytest.fixture(scope="module")
def seg_ds(seg_cfg):
    return make_dataset(seg_cfg.dataset)


@pytest.fixture(scope="module")
def seg4(seg_cfg, seg_ds):
    return build_segmented(seg_cfg, dataset=seg_ds, reorder_samples=8,
                           segment_size=300)


@pytest.mark.slow
def test_stitched_graph_connected_and_navigable(seg4, seg_cfg, seg_ds):
    assert seg4.num_segments == 4
    assert seg4.stitch.cross_edges > 0
    assert seg4.stitch.patched_rows > 0
    flat = seg4.to_flat()
    adj, deg = flat.graph.adjacency, flat.graph.degrees
    n = adj.shape[0]
    # BFS from the entry point must reach every vertex (stitching turned
    # four disjoint block-diagonal graphs into one navigable graph)
    seen = np.zeros(n, bool)
    frontier = [flat.graph.entry_point]
    seen[flat.graph.entry_point] = True
    while frontier:
        nxt = []
        for v in frontier:
            for u in adj[v, : deg[v]]:
                if not seen[u]:
                    seen[u] = True
                    nxt.append(int(u))
        frontier = nxt
    assert seen.all()
    # every segment's row block keeps cross-segment neighbours
    seg_of = np.repeat(np.arange(4), 300)
    valid = np.arange(adj.shape[1])[None, :] < deg[:, None]
    assert ((seg_of[:, None] != seg_of[adj]) & valid).any(axis=1).sum() > 0


@pytest.mark.slow
def test_multi_segment_recall_close_to_flat(seg4, seg_cfg, seg_ds):
    flat = seg4.to_flat()
    mono = build_index_monolithic(seg_cfg, dataset=seg_ds, reorder_samples=8)
    q = jnp.asarray(seg_ds.queries)
    r_seg = recall_at_k(
        np.asarray(graph_search(flat.corpus(), q, seg_cfg.search,
                                seg_ds.metric).ids),
        flat.dataset.gt, 10)
    r_mono = recall_at_k(
        np.asarray(graph_search(mono.corpus(), q, seg_cfg.search,
                                seg_ds.metric).ids),
        mono.dataset.gt, 10)
    # acceptance bar: within 1% of the flat build on the same dataset
    assert r_seg >= r_mono - 0.01


@pytest.mark.slow
def test_segment_tiles_serve_tiled_plan(seg4, seg_ds):
    from repro.plan import Searcher, SearchRequest
    from repro.shard import partition_index

    s = Searcher.open(seg4)
    res = s.search(SearchRequest(queries=seg_ds.queries))
    assert res.plan.kind == "tiled"
    assert res.stats.num_tiles == seg4.num_segments
    perm = seg4.global_perm()
    r = recall_at_k(np.asarray(res.ids), perm[seg_ds.gt], 10)
    assert r >= 0.85

    # partition_index auto-detects a segment-built index and emits the same
    # tiles as tiled_corpus()
    tiled_a, part_a = seg4.tiled_corpus()
    tiled_b, part_b = partition_index(seg4)
    assert part_b.policy == "segments"
    np.testing.assert_array_equal(np.asarray(tiled_a.adjacency),
                                  np.asarray(tiled_b.adjacency))
    np.testing.assert_array_equal(np.asarray(tiled_a.tile_ids),
                                  np.asarray(tiled_b.tile_ids))
    np.testing.assert_array_equal(np.asarray(part_a.tile_sizes),
                                  np.asarray(part_b.tile_sizes))


# --------------------------------------------------------------------------
# accounting: per-segment storage sums and build-time NAND billing
# --------------------------------------------------------------------------

def test_single_segment_index_bytes_matches_flat():
    cfg = _cfg()
    ds = make_dataset(cfg.dataset)
    seg = build_segmented(cfg, dataset=ds, reorder_samples=8, segment_size=0)
    got = seg.index_bytes()
    want = seg.to_flat().index_bytes()
    per = got.pop("per_segment")
    assert len(per) == 1
    assert got == want
    for key, total in got.items():
        assert sum(p[key] for p in per) == total


@pytest.mark.slow
def test_multi_segment_index_bytes_and_build_trace(seg4):
    acct = seg4.index_bytes()
    per = acct["per_segment"]
    assert len(per) == 4
    for key in ("raw_bytes", "index_bytes_gap", "pq_bytes", "total_bytes",
                "hot_repetition_bytes"):
        assert acct[key] == sum(p[key] for p in per)
    assert acct["hot_repetition_bytes"] > 0      # hot prefixes are per-segment

    sim = simulate_build(seg4.build_trace())
    assert sim.write_amplification > 1.0         # stitching re-programs rows
    assert len(sim.per_segment_seconds) == 4
    assert sim.build_seconds > 0 and sim.program_mb > 0


def test_build_trace_billing_without_stitch():
    sim = simulate_build(BuildTrace(segment_sizes=(500,), stitched_rows=0))
    assert sim.write_amplification == 1.0
    assert len(sim.per_segment_seconds) == 1
    assert sim.erase_energy_uj == 0.0
