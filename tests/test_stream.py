"""Streaming mutable-index subsystem: delta graph quality, tombstone
filtering, the end-to-end insert/delete/consolidate acceptance flow, and
the streaming ServingEngine path."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import (
    DatasetConfig, GraphConfig, PQConfig, ProximaConfig, SearchConfig,
    StreamConfig,
)
from repro.core import build_index, exact_knn, recall_at_k, search
from repro.core.dataset import pairwise_dist
from repro.serve.engine import ServingEngine
from repro.stream import DeltaSegment, MutableIndex, search_merged


@pytest.fixture(scope="module")
def stream_cfg():
    return ProximaConfig(
        dataset=DatasetConfig(name="sift-like", num_base=900, num_queries=24,
                              dim=32, num_clusters=10, cluster_std=0.25,
                              seed=3),
        pq=PQConfig(num_subvectors=8, num_centroids=64, kmeans_iters=6),
        graph=GraphConfig(max_degree=16, build_list_size=32, alpha=1.2),
        search=SearchConfig(k=10, list_size=64, t_init=16, t_step=8,
                            repetition_rate=3, beta=1.06),
        stream=StreamConfig(delta_capacity=512, consolidate_fraction=0.6,
                            delta_list_size=32, brute_force_below=32,
                            base_overfetch=16),
        hot_node_fraction=0.03,
    )


@pytest.fixture(scope="module")
def stream_index(stream_cfg):
    return build_index(stream_cfg, reorder_samples=16)


def _perturbed(base, n, rng, scale=0.1):
    picks = base[rng.choice(base.shape[0], n)]
    return (picks + scale * rng.standard_normal(picks.shape)).astype(np.float32)


# ---------------------------------------------------------------------------
# Delta segment
# ---------------------------------------------------------------------------

def test_delta_graph_search_quality():
    """Incremental Vamana over the delta alone stays near-exact."""
    rng = np.random.default_rng(0)
    vecs = rng.standard_normal((150, 16)).astype(np.float32)
    delta = DeltaSegment(
        dim=16, metric="l2", centroids=rng.standard_normal((4, 16, 4)).astype(np.float32),
        graph_cfg=GraphConfig(max_degree=12, build_list_size=24),
        stream_cfg=StreamConfig(delta_capacity=256, delta_list_size=32,
                                brute_force_below=8),
    )
    for v in vecs:
        delta.insert(v)
    hits = 0
    queries = vecs[:20] + 0.01 * rng.standard_normal((20, 16)).astype(np.float32)
    gt = exact_knn(queries, vecs, 5, "l2")
    for q, g in zip(queries, gt):
        ids, dists = delta.search(q, 5)
        assert (np.diff(dists) >= 0).all()
        hits += len(set(ids.tolist()) & set(g.tolist()))
    assert hits / (20 * 5) > 0.9


def test_delta_degrees_capped():
    rng = np.random.default_rng(1)
    delta = DeltaSegment(
        dim=8, metric="l2", centroids=rng.standard_normal((2, 8, 4)).astype(np.float32),
        graph_cfg=GraphConfig(max_degree=6, build_list_size=16),
        stream_cfg=StreamConfig(delta_capacity=128, delta_list_size=16,
                                brute_force_below=4),
    )
    for v in rng.standard_normal((100, 8)).astype(np.float32):
        delta.insert(v)
    assert (delta.degrees[:100] <= 6).all()
    assert (delta.degrees[1:100] >= 1).all()  # every later insert got edges


def test_delta_full_raises():
    rng = np.random.default_rng(2)
    delta = DeltaSegment(
        dim=8, metric="l2", centroids=rng.standard_normal((2, 8, 4)).astype(np.float32),
        graph_cfg=GraphConfig(max_degree=4, build_list_size=8),
        stream_cfg=StreamConfig(delta_capacity=4),
    )
    for v in rng.standard_normal((4, 8)).astype(np.float32):
        delta.insert(v)
    with pytest.raises(RuntimeError):
        delta.insert(np.zeros(8, np.float32))


# ---------------------------------------------------------------------------
# MutableIndex: end-to-end acceptance flow
# ---------------------------------------------------------------------------

def test_streaming_end_to_end(stream_index):
    """Insert >= 20%, delete >= 5%; merged recall@10 against exact kNN of the
    CURRENT corpus stays within 0.05 of a from-scratch rebuild, and
    consolidate() restores single-segment search with equal results."""
    idx = stream_index
    n = idx.dataset.num_base
    mut = MutableIndex(idx)
    rng = np.random.default_rng(7)
    for v in _perturbed(idx.dataset.base, int(0.22 * n), rng):
        mut.insert(v)
    dead = rng.choice(n, int(0.06 * n), replace=False)
    for e in dead:
        assert mut.delete(int(e))
    assert mut.live_count() == n + int(0.22 * n) - int(0.06 * n)

    queries = idx.dataset.queries
    ext_ids, vecs = mut.live_vectors()
    gt = ext_ids[exact_knn(queries, vecs, 10, idx.dataset.metric)]
    merged = search_merged(mut, queries)
    rec_merged = recall_at_k(merged.ids, gt, 10)
    assert not np.isin(merged.ids, dead).any()

    # from-scratch rebuild == what consolidate() produces
    mut.consolidate(reorder_samples=16)
    assert len(mut.delta) == 0 and not mut.tombstones
    rebuilt = search_merged(mut, queries)
    rec_rebuilt = recall_at_k(rebuilt.ids, gt, 10)
    assert rec_merged >= rec_rebuilt - 0.05, (rec_merged, rec_rebuilt)
    assert rec_merged > 0.7

    # single-segment equality: merged path == direct base search via ext ids
    cfg = dataclasses.replace(idx.config.search,
                              k=min(idx.config.search.list_size,
                                    10 + mut.stream_cfg.base_overfetch))
    direct = search(mut.base.corpus(), queries, cfg, idx.dataset.metric)
    direct_ext = mut.ext_base[np.clip(np.asarray(direct.ids), 0, None)]
    np.testing.assert_array_equal(rebuilt.ids, direct_ext[:, :10])


def test_inserted_vector_is_findable(stream_index):
    mut = MutableIndex(stream_index)
    q = stream_index.dataset.queries[0]
    ext = mut.insert(q)                     # exact duplicate of the query
    res = search_merged(mut, q[None])
    assert res.ids[0, 0] == ext
    assert res.dists[0, 0] <= res.dists[0, 1] + 1e-6


def test_deleted_neighbor_is_filtered(stream_index):
    idx = stream_index
    mut = MutableIndex(idx)
    q = idx.dataset.queries[:8]
    before = search_merged(mut, q)
    victim = int(before.ids[0, 0])
    assert mut.delete(victim)
    assert not mut.delete(victim)           # double delete is a no-op
    after = search_merged(mut, q)
    assert victim not in after.ids[0].tolist()
    # remaining results are still sorted + live
    assert (np.diff(after.dists[0][np.isfinite(after.dists[0])]) >= -1e-6).all()


def test_deleted_delta_vectors_dont_crowd_out_live_ones(stream_index):
    """Tombstoned delta vectors must not eat the delta candidate budget:
    a live (slightly farther) delta vector still reaches the merged top-k."""
    mut = MutableIndex(stream_index)
    q = stream_index.dataset.queries[0]
    rng = np.random.default_rng(21)
    dead = [mut.insert(q + 1e-4 * rng.standard_normal(q.shape).astype(np.float32))
            for _ in range(10)]
    live = mut.insert(q + 1e-2 * rng.standard_normal(q.shape).astype(np.float32))
    for e in dead:
        mut.delete(e)
    res = search_merged(mut, q[None])
    assert live in res.ids[0].tolist()
    assert not np.isin(res.ids[0], dead).any()


def test_capacity_overflow_consolidates(stream_index):
    mut = MutableIndex(
        stream_index,
        stream_cfg=StreamConfig(delta_capacity=8, consolidate_fraction=0.9,
                                brute_force_below=4, base_overfetch=8),
    )
    rng = np.random.default_rng(9)
    for v in _perturbed(stream_index.dataset.base, 9, rng):
        mut.insert(v)                       # 9th insert must consolidate
    assert mut.stats["consolidations"] == 1
    assert len(mut.delta) == 1


def test_write_accounting(stream_index):
    mut = MutableIndex(stream_index)
    rng = np.random.default_rng(5)
    for v in _perturbed(stream_index.dataset.base, 20, rng):
        mut.insert(v)
    assert mut.write_amplification() == 1.0   # nothing consolidated yet
    mut.consolidate(reorder_samples=8)
    wa = mut.write_amplification()
    assert wa > 1.0
    assert mut.stats["inserts"] == 20 and mut.stats["consolidations"] == 1


# ---------------------------------------------------------------------------
# Streaming ServingEngine
# ---------------------------------------------------------------------------

def test_engine_streaming_updates_visible(stream_index):
    eng = ServingEngine(MutableIndex(stream_index), batch_size=4, flush_us=0.0)
    q = stream_index.dataset.queries[0]
    ext = eng.insert(q)
    rid = eng.submit(q)
    eng.drain()
    assert eng.done[rid].ids[0] == ext
    assert eng.delete(ext)
    rid2 = eng.submit(q)
    eng.drain()
    assert ext not in eng.done[rid2].ids.tolist()
    assert eng.stats["inserts"] == 1 and eng.stats["deletes"] == 1


def test_engine_consolidates_between_batches(stream_index):
    mut = MutableIndex(
        stream_index,
        stream_cfg=StreamConfig(delta_capacity=256, consolidate_fraction=0.02,
                                brute_force_below=32, base_overfetch=16),
    )
    eng = ServingEngine(mut, batch_size=2, flush_us=0.0)
    rng = np.random.default_rng(13)
    for v in _perturbed(stream_index.dataset.base, 20, rng):
        eng.insert(v)
    eng.submit(stream_index.dataset.queries[0])
    eng.submit(stream_index.dataset.queries[1])
    eng.drain()
    assert eng.stats["consolidations"] >= 1
    assert len(mut.delta) == 0


def test_engine_tracks_capacity_forced_consolidation(stream_index):
    """A full delta forces consolidation inside insert(); the engine's index
    view and consolidation count must follow."""
    mut = MutableIndex(
        stream_index,
        stream_cfg=StreamConfig(delta_capacity=8, consolidate_fraction=0.99,
                                brute_force_below=4, base_overfetch=8),
    )
    eng = ServingEngine(mut, batch_size=2, flush_us=0.0,
                        auto_consolidate=False)
    rng = np.random.default_rng(17)
    for v in _perturbed(stream_index.dataset.base, 9, rng):
        eng.insert(v)
    assert eng.stats["consolidations"] == 1
    assert eng.index is mut.base              # no stale pre-rebuild view


def test_frozen_engine_rejects_updates(tiny_index):
    eng = ServingEngine(tiny_index, batch_size=2, flush_us=0.0)
    with pytest.raises(RuntimeError):
        eng.insert(np.zeros(tiny_index.dataset.dim, np.float32))
    with pytest.raises(RuntimeError):
        eng.delete(0)
