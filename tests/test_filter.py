"""Filtered ANN subsystem: FilterSpec/AttributeStore compilation, the
all-pass bit-identity guarantee, selectivity-adaptive regime choice,
filtered edge cases (empty result / all-pass / tombstone interaction),
per-tile bitmap slices with zero-pass tile skipping, per-request engine
filters batched by hash, NAND predicate-pushdown billing, and the
``upgrade_config`` forward-compat regression guard."""
import dataclasses
import pickle

import numpy as np
import pytest

from repro.configs.base import (
    FilterConfig, ProximaConfig, SearchConfig, StreamConfig, upgrade_config,
)
from repro.core import search, search_reference
from repro.core.dataset import exact_knn, recall_at_k
from repro.filter import (
    AttributeStore, FilterSpec, adapt_search_cfg, attach_attributes,
    bitmap_popcount, encode_categorical, filtered_search, pack_bitmap,
    random_attributes, tile_node_masks, unpack_bitmap,
)


@pytest.fixture(scope="module")
def tiny_store(tiny_index):
    # NOT attached to the shared index — tests pass masks/stores explicitly
    # so the session fixture stays pristine for attribute-free suites
    return random_attributes(tiny_index.dataset.num_base,
                             {"category": 8, "price": 1000}, seed=5)


# ---------------------------------------------------------------------------
# Spec + store units
# ---------------------------------------------------------------------------

def test_spec_compilation_and_composition():
    store = AttributeStore.from_columns({
        "cat": np.asarray([0, 1, 2, 1, 0]),
        "price": np.asarray([10, 20, 30, 40, 50]),
    })
    np.testing.assert_array_equal(
        store.mask(FilterSpec.eq("cat", 1)), [0, 1, 0, 1, 0])
    np.testing.assert_array_equal(
        store.mask(FilterSpec.range("price", 20, 40)), [0, 1, 1, 1, 0])
    np.testing.assert_array_equal(
        store.mask(FilterSpec.range("price", None, 30)), [1, 1, 1, 0, 0])
    np.testing.assert_array_equal(
        store.mask(FilterSpec.isin("cat", [0, 2])), [1, 0, 1, 0, 1])
    both = FilterSpec.eq("cat", 1) & FilterSpec.range("price", 30, None)
    np.testing.assert_array_equal(store.mask(both), [0, 0, 0, 1, 0])
    assert store.mask(FilterSpec()).all()           # empty spec passes all
    assert not store.mask(FilterSpec.isin("cat", [])).any()
    assert store.selectivity(FilterSpec.eq("cat", 0)) == pytest.approx(0.4)
    with pytest.raises(KeyError):
        store.mask(FilterSpec.eq("nope", 1))
    # specs are hashable and equal by value (the engine batches by this)
    assert hash(both) == hash(
        FilterSpec.eq("cat", 1) & FilterSpec.range("price", 30, None))


def test_bitmap_roundtrip_and_store_append():
    rng = np.random.default_rng(0)
    mask = rng.random(77) < 0.3
    bm = pack_bitmap(mask)
    assert bm.dtype == np.uint32
    np.testing.assert_array_equal(unpack_bitmap(bm, 77), mask)
    assert bitmap_popcount(bm) == int(mask.sum())

    store = AttributeStore.from_columns({"f": np.arange(3)})
    assert store.attr_bits == 32
    rid = store.append({"f": 7})
    assert rid == 3 and len(store) == 4
    assert store.append([9]) == 4
    np.testing.assert_array_equal(store.column("f"), [0, 1, 2, 7, 9])
    codes, vocab = encode_categorical(["shoes", "hats", "shoes"])
    np.testing.assert_array_equal(codes, [0, 1, 0])
    assert vocab == {"shoes": 0, "hats": 1}


def test_attach_attributes_validates(tiny_index):
    from repro.serve.engine import ServingEngine

    with pytest.raises(ValueError):
        attach_attributes(tiny_index, random_attributes(3))
    with pytest.raises(ValueError):   # frozen engine validates length too
        ServingEngine(tiny_index, batch_size=4, flush_us=0.0,
                      attributes=random_attributes(3))
    try:
        store = attach_attributes(
            tiny_index, random_attributes(tiny_index.dataset.num_base))
        assert tiny_index.attributes is store
    finally:
        tiny_index.attributes = None   # keep the shared fixture pristine


# ---------------------------------------------------------------------------
# All-pass bit-identity + edge cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("beam", [1, 4])
def test_allpass_filter_bit_identical(tiny_index, tiny_store, beam):
    """An all-pass FilterSpec goes through the masked traversal kernel yet
    returns bit-identical ids AND distances to the unfiltered search, at
    E=1 and E>1 (the acceptance guarantee)."""
    cfg = dataclasses.replace(tiny_index.config.search, beam_width=beam)
    q = tiny_index.dataset.queries[:8]
    metric = tiny_index.dataset.metric
    base = search(tiny_index.corpus(), q, cfg, metric)
    fres = filtered_search(tiny_index.corpus(), q,
                           tiny_store.mask(FilterSpec()), cfg, metric)
    assert fres.mode == "traversal" and fres.selectivity == 1.0
    assert fres.effective == cfg                     # no inflation at s=1
    np.testing.assert_array_equal(np.asarray(base.ids), fres.ids)
    np.testing.assert_array_equal(np.asarray(base.dists), fres.dists)


def test_empty_filter_returns_padding(tiny_index, tiny_store):
    fres = filtered_search(
        tiny_index.corpus(), tiny_index.dataset.queries[:4],
        np.zeros(tiny_index.dataset.num_base, bool),
        tiny_index.config.search, tiny_index.dataset.metric)
    assert fres.mode == "empty"
    assert (fres.ids == -1).all()
    assert np.isinf(fres.dists).all()
    assert int(np.asarray(fres.result.n_hops).sum()) == 0


def test_adaptive_regimes_and_admission(tiny_index, tiny_store):
    """Moderate selectivity -> masked traversal with an inflated frontier;
    high selectivity -> bitmap PQ scan. Both admit only passing nodes and
    clear the 0.9 recall bar against the filtered brute-force oracle."""
    cfg = tiny_index.config.search
    metric = tiny_index.dataset.metric
    q = tiny_index.dataset.queries
    fcfg = FilterConfig()
    for spec, want_mode in [
        (FilterSpec.range("price", 0, 99), "traversal"),   # ~10%
        (FilterSpec.range("price", 0, 14), "scan"),        # ~1.5%
    ]:
        mask = tiny_store.mask(spec)
        fres = filtered_search(tiny_index.corpus(), q, mask, cfg, metric,
                               filter_cfg=fcfg)
        assert fres.mode == want_mode
        got = fres.ids.ravel()
        assert all(mask[i] for i in got if i >= 0)
        pids = np.nonzero(mask)[0]
        k_eff = min(cfg.k, len(pids))
        gt = pids[exact_knn(q, tiny_index.dataset.base[pids], k_eff, metric)]
        assert recall_at_k(fres.ids, gt, k_eff) >= 0.9
    # inflation is pow2-quantized and capped
    eff = adapt_search_cfg(cfg, 0.1, fcfg)
    assert eff.list_size == cfg.list_size * 8
    assert eff.repetition_rate == cfg.repetition_rate + fcfg.relax_repetition
    assert adapt_search_cfg(cfg, 0.5, fcfg).list_size == cfg.list_size * 2


def test_reference_oracle_filtered(tiny_index, tiny_store):
    """search_reference(node_mask=...) returns only passing ids and agrees
    with the masked JAX engine on the large majority of results."""
    idx = tiny_index
    cfg = idx.config.search
    metric = idx.dataset.metric
    mask = tiny_store.mask(FilterSpec.range("price", 0, 199))   # ~20%
    eff = adapt_search_cfg(cfg, float(mask.mean()), FilterConfig())
    fres = filtered_search(idx.corpus(), idx.dataset.queries, mask, cfg,
                           metric)
    overlap = 0.0
    nq = 8
    for i in range(nq):
        ids, dists, _ = search_reference(
            idx.graph.adjacency, idx.graph.degrees, idx.codes,
            idx._search_base(), idx.codebook.centroids,
            idx.graph.entry_point, idx.dataset.queries[i], eff, metric,
            hot_count=idx.hot_count, node_mask=mask,
        )
        got = set(int(v) for v in ids if v >= 0)
        assert all(mask[v] for v in got)
        assert (np.diff(dists[np.isfinite(dists)]) >= -1e-6).all()
        overlap += len(got & set(int(v) for v in fres.ids[i] if v >= 0))
    assert overlap / (nq * cfg.k) >= 0.8


# ---------------------------------------------------------------------------
# Shard layer: per-tile bitmap slices + zero-pass skipping
# ---------------------------------------------------------------------------

def test_sharded_filtered_zero_pass_tiles(tiny_index):
    from repro.shard import partition_index, sharded_search

    idx = tiny_index
    tiled, _ = partition_index(idx, 4, "contiguous")
    hot = idx.hot_count
    mask = np.zeros(idx.dataset.num_base, bool)
    mask[hot + 20: hot + 140] = True     # cold band -> lands on few tiles
    nm = tile_node_masks(tiled.tile_ids, mask)
    counts = nm.sum(1)
    assert (counts == 0).any(), "test premise: at least one zero-pass tile"
    res = sharded_search(tiled, idx.dataset.queries[:6], idx.config.search,
                         idx.dataset.metric, node_masks=nm)
    probed = np.asarray(res.probed)
    hops = np.asarray(res.per_tile.n_hops)
    for p in range(4):
        if counts[p] == 0:               # skipped channel: no work, unprobed
            assert not probed[p].any()
            assert hops[p].sum() == 0
        else:
            assert probed[p].all()
    ids = np.asarray(res.ids)
    assert all(mask[i] for i in ids.ravel() if i >= 0)
    assert (ids[:, 0] >= 0).all()        # passing band still served


# ---------------------------------------------------------------------------
# Stream layer: attributes on insert, filter ∧ tombstone in merged search
# ---------------------------------------------------------------------------

def test_stream_filter_tombstone_interaction(tiny_index):
    from repro.stream import MutableIndex

    idx = tiny_index
    store = random_attributes(idx.dataset.num_base,
                              {"category": 8, "price": 1000}, seed=5)
    mut = MutableIndex(
        idx,
        StreamConfig(delta_capacity=256, consolidate_fraction=0.9,
                     brute_force_below=64, base_overfetch=16),
        attributes=store,
    )
    with pytest.raises(ValueError):
        mut.insert(idx.dataset.queries[0])           # attrs now required
    spec = FilterSpec.range("price", 0, 199)
    rng = np.random.default_rng(2)

    def _vec():
        return (
            idx.dataset.base[rng.integers(0, idx.dataset.num_base)]
            + 0.05 * rng.standard_normal(idx.dataset.dim)
        ).astype(np.float32)

    # group A passes the range spec; group B carries a sentinel price no
    # random base row can have (card 1000 -> values < 1000)
    group_a = [mut.insert(_vec(), attrs={"category": 1, "price": 100})
               for _ in range(8)]
    group_b = [mut.insert(_vec(), attrs={"category": 1, "price": 1500})
               for _ in range(8)]
    # tombstone a few PASSING base nodes, one of A and one of B
    dead = [int(i) for i in np.nonzero(store.mask(spec))[0][:4]]
    dead += [group_a[0], group_b[0]]
    for d in dead:
        assert mut.delete(d)
    res = mut.search(idx.dataset.queries[:8], idx.config.search,
                     filter_spec=spec)
    emask = mut.attributes.mask(spec)
    for i in np.asarray(res.ids).ravel():
        if i >= 0:
            assert emask[i], "non-passing id admitted"
            assert i not in mut.tombstones, "tombstoned id admitted"
    # a filter matching only the delta inserts returns only LIVE inserts —
    # the combined filter ∧ tombstone mask on the delta stream
    res2 = mut.search(idx.dataset.queries[:4], idx.config.search,
                      filter_spec=FilterSpec.eq("price", 1500))
    got = set(int(i) for i in np.asarray(res2.ids).ravel() if i >= 0)
    assert got and got <= set(group_b) - {group_b[0]}
    # empty-result filter through the merged path
    res3 = mut.search(idx.dataset.queries[:4], idx.config.search,
                      filter_spec=FilterSpec.eq("price", 2500))
    assert (np.asarray(res3.ids) == -1).all()


# ---------------------------------------------------------------------------
# Serving engine: per-request filters, batching by filter hash
# ---------------------------------------------------------------------------

def test_engine_filtered_requests(tiny_index, tiny_store):
    from repro.serve.engine import ServingEngine

    idx = tiny_index
    eng = ServingEngine(idx, batch_size=8, flush_us=0.0,
                        attributes=tiny_store)
    q = idx.dataset.queries[:12]
    spec = FilterSpec.range("price", 0, 99)
    mask = tiny_store.mask(spec)
    rids_f = [eng.submit(qq, filter=spec) for qq in q[:6]]
    rids_u = [eng.submit(qq) for qq in q[6:]]
    eng.drain()
    assert eng.stats["filtered_queries"] == 6
    # homogeneous batches: filtered results match the direct filtered path
    direct = filtered_search(idx.corpus(), q[:6], mask, eng.cfg,
                             idx.dataset.metric, filter_cfg=eng.filter_cfg)
    got = np.stack([eng.done[r].ids for r in rids_f])
    np.testing.assert_array_equal(got, direct.ids)
    # unfiltered requests are untouched by the batch split
    base = search(idx.corpus(), q[6:], eng.cfg, idx.dataset.metric)
    got_u = np.stack([eng.done[r].ids for r in rids_u])
    assert (np.sort(got_u, 1) == np.sort(np.asarray(base.ids), 1)).all()
    # an all-pass spec is normalized to the unfiltered batch
    rid = eng.submit(q[0], filter=FilterSpec())
    eng.drain()
    assert eng.done[rid].filter is None
    # filtered submit without a store raises
    bare = ServingEngine(idx, batch_size=4, flush_us=0.0)
    bare.submit(q[0], filter=spec)
    with pytest.raises(RuntimeError):
        bare.drain()


# ---------------------------------------------------------------------------
# NAND predicate pushdown billing
# ---------------------------------------------------------------------------

def test_pushdown_strictly_cheaper_transfer(tiny_index, tiny_store):
    from repro.nand.simulator import filter_comparison, trace_from_search_result

    idx = tiny_index
    spec = FilterSpec.range("price", 0, 99)
    mask = tiny_store.mask(spec)
    fres = filtered_search(idx.corpus(), idx.dataset.queries, mask,
                           idx.config.search, idx.dataset.metric)
    trace = trace_from_search_result(
        fres, dim=idx.dataset.dim, r_degree=idx.graph.max_degree,
        index_bits=idx.gap.bit_width if idx.gap else 32,
        pq_bits=idx.codebook.num_subvectors * 8,
        metric=idx.dataset.metric, attr_bits=tiny_store.attr_bits,
    )
    assert trace.filter_selectivity == pytest.approx(fres.selectivity)
    cmp = filter_comparison(trace)
    push, host = cmp["pushdown"], cmp["host"]
    # the acceptance bar: pushdown bills attribute words as spare-area
    # reads and ships only passing candidates -> strictly less channel
    # transfer energy than host-side filtering of the same trace
    assert push.transfer_pj_per_query < host.transfer_pj_per_query
    assert cmp["transfer_bytes_saved"] > 0
    assert host.traffic_bytes_per_query["attrs"] > 0
    assert push.traffic_bytes_per_query["attrs"] == 0.0
    assert push.traffic_bytes_per_query["pq_codes"] < \
        host.traffic_bytes_per_query["pq_codes"]
    # an unfiltered trace is billed exactly as before (attrs category empty)
    off = trace_from_search_result(
        fres.result, dim=idx.dataset.dim, r_degree=idx.graph.max_degree,
        index_bits=32, pq_bits=256, metric=idx.dataset.metric)
    assert off.filter_mode == "off" and off.attr_bits == 0
    # scan-mode regression: its candidate stream IS the passing subset, so
    # pushdown must not discount it again by the mask selectivity
    scan = filtered_search(idx.corpus(), idx.dataset.queries,
                           tiny_store.mask(FilterSpec.range("price", 0, 14)),
                           idx.config.search, idx.dataset.metric)
    assert scan.mode == "scan"
    scan_trace = trace_from_search_result(
        scan, dim=idx.dataset.dim, r_degree=idx.graph.max_degree,
        index_bits=32, pq_bits=256, metric=idx.dataset.metric,
        attr_bits=tiny_store.attr_bits)
    assert scan_trace.filter_selectivity == 1.0


def test_masked_search_beta_one_no_nan(tiny_index, tiny_store):
    """Regression: with beta=1.0 (used by the fig11/fig13 sweeps) and a
    filter leaving fewer than T passing candidates, the masked margin
    anchor is +inf — the threshold must stay +inf (rerank all passing),
    not go NaN and drop every result."""
    idx = tiny_index
    cfg = dataclasses.replace(idx.config.search, beta=1.0)
    mask = tiny_store.mask(FilterSpec.range("price", 0, 39))   # ~4% passing
    res = search(idx.corpus(), idx.dataset.queries[:6], cfg,
                 idx.dataset.metric, node_mask=np.asarray(mask))
    ids = np.asarray(res.ids)
    assert (ids[:, 0] >= 0).any(), "all results dropped (NaN threshold)"
    assert all(mask[i] for i in ids.ravel() if i >= 0)
    rid, rdists, _ = search_reference(
        idx.graph.adjacency, idx.graph.degrees, idx.codes,
        idx._search_base(), idx.codebook.centroids, idx.graph.entry_point,
        idx.dataset.queries[0], cfg, idx.dataset.metric,
        hot_count=idx.hot_count, node_mask=mask,
    )
    assert (rid >= 0).any() and not np.isnan(rdists).any()


def test_insert_attr_validation_precedes_mutation(tiny_index):
    """Regression: a malformed attrs row must fail BEFORE the vector is
    inserted, or the attribute table desyncs from the external ids."""
    from repro.stream import MutableIndex

    store = random_attributes(tiny_index.dataset.num_base, seed=3)
    mut = MutableIndex(tiny_index, attributes=store)
    before = (len(mut.delta), mut.next_ext, len(store))
    with pytest.raises(KeyError):
        mut.insert(tiny_index.dataset.queries[0], attrs={"typo": 1})
    assert (len(mut.delta), mut.next_ext, len(store)) == before
    ext = mut.insert(tiny_index.dataset.queries[0],
                     attrs={"category": 2, "price": 7})
    assert ext == tiny_index.dataset.num_base and len(store) == ext + 1


# ---------------------------------------------------------------------------
# upgrade_config forward-compat (regression guard for every future field)
# ---------------------------------------------------------------------------

def _strip_fields(cfg: ProximaConfig, names) -> ProximaConfig:
    """Simulate an instance pickled before ``names`` existed: rebuild the
    object with only the remaining attributes set."""
    old = object.__new__(ProximaConfig)
    for f in dataclasses.fields(ProximaConfig):
        if f.name not in names:
            object.__setattr__(old, f.name, getattr(cfg, f.name))
    return old


def test_upgrade_config_fills_missing_fields():
    cfg = ProximaConfig(search=SearchConfig(k=7, list_size=96))
    # a config pickled before FilterConfig existed upgrades with defaults
    old = _strip_fields(cfg, {"filter"})
    assert not hasattr(old, "filter")
    up = upgrade_config(old)
    assert up.filter == FilterConfig()
    assert up.search.k == 7 and up.search.list_size == 96
    # ... and the same holds for EVERY field, one at a time (the guard any
    # future ProximaConfig field inherits for free)
    for f in dataclasses.fields(ProximaConfig):
        up = upgrade_config(_strip_fields(cfg, {f.name}))
        expected = (
            f.default_factory()
            if f.default_factory is not dataclasses.MISSING else f.default
        )
        assert getattr(up, f.name) == expected
        assert upgrade_config(up) is up          # complete -> unchanged
    # pickle round-trip of a stripped instance stays upgradeable
    old = _strip_fields(cfg, {"filter", "shard", "stream"})
    thawed = pickle.loads(pickle.dumps(old))
    up = upgrade_config(thawed)
    assert up.filter == FilterConfig()
    assert up.search.k == 7


def test_sharded_corpus_upgrades_pre_shard_config(tiny_index):
    # ProximaIndex.sharded_corpus goes through upgrade_config rather than a
    # getattr default shim: an index whose config predates ShardConfig (and
    # BuildConfig) still shards with default policy
    import dataclasses as dc

    old_cfg = _strip_fields(tiny_index.config, {"shard", "build"})
    old_index = dc.replace(tiny_index, config=old_cfg)
    tiled, part = old_index.sharded_corpus(num_tiles=2)
    assert part.num_tiles == 2
    ref, _ = tiny_index.sharded_corpus(num_tiles=2)
    assert np.asarray(tiled.adjacency).shape == np.asarray(ref.adjacency).shape
