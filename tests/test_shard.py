"""Shard layer: partitioning invariants, channel-parallel search recall
parity, Pallas-vs-reference cross-tile merge, per-tile counters, and the
channel-parallel NAND model."""
import dataclasses as dc

import numpy as np
import pytest

from repro.core import recall_at_k, search
from repro.nand.simulator import (
    WorkloadTrace,
    simulate,
    simulate_sharded,
    traces_from_sharded_result,
)
from repro.shard import cross_tile_merge, partition_index, sharded_search
from repro.shard.partition import POLICIES


@pytest.fixture(scope="module")
def tiled2(tiny_index):
    return partition_index(tiny_index, 2, "contiguous")


@pytest.fixture(scope="module")
def tiled4(tiny_index):
    return partition_index(tiny_index, 4, "hash")


def test_partition_coverage_all_policies(tiny_index):
    """Every cold vertex lands on exactly one tile; hot vertices are
    replicated on all of them."""
    n = tiny_index.dataset.num_base
    hot = tiny_index.hot_count
    for policy in POLICIES:
        tiled, part = partition_index(tiny_index, 3, policy)
        tid = np.asarray(tiled.tile_ids)
        cold_seen = []
        for p in range(3):
            ids = tid[p][tid[p] >= 0]
            assert len(set(ids.tolist())) == len(ids)     # no dup within tile
            assert set(range(hot)) <= set(ids.tolist())   # hot replica prefix
            assert (ids[:hot] == np.arange(hot)).all()    # ...at the head
            cold_seen.append(set(ids.tolist()) - set(range(hot)))
        union = set().union(*cold_seen)
        assert union == set(range(hot, n))
        for a in range(3):
            for b in range(a + 1, 3):
                assert not (cold_seen[a] & cold_seen[b])
        assert part.tile_sizes.sum() == n + 2 * hot
        assert part.imbalance < 1.5


def test_single_tile_partition_is_identity(tiny_index):
    tiled, part = partition_index(tiny_index, 1, "hash")
    corpus = tiny_index.corpus()
    assert (np.asarray(tiled.adjacency[0]) == np.asarray(corpus.adjacency)).all()
    assert (np.asarray(tiled.tile_ids[0]) == np.arange(tiny_index.dataset.num_base)).all()
    assert int(tiled.entry_points[0]) == int(corpus.entry_point)
    res_s = sharded_search(tiled, tiny_index.dataset.queries,
                           tiny_index.config.search, tiny_index.dataset.metric)
    res_1 = search(corpus, tiny_index.dataset.queries,
                   tiny_index.config.search, tiny_index.dataset.metric)
    assert (np.asarray(res_s.ids) == np.asarray(res_1.ids)).all()


def test_sharded_recall_parity(tiny_index, tiled2, tiled4):
    """P in {1, 2, 4} tiles match single-tile recall within tolerance
    (smaller tiles are searched more exhaustively, so sharded recall is
    usually a bit higher)."""
    idx = tiny_index
    cfg = idx.config.search
    q = idx.dataset.queries
    rec1 = recall_at_k(
        np.asarray(search(idx.corpus(), q, cfg, idx.dataset.metric).ids),
        idx.dataset.gt, 10,
    )
    for tiled, _ in (tiled2, tiled4):
        res = sharded_search(tiled, q, cfg, idx.dataset.metric)
        rec = recall_at_k(np.asarray(res.ids), idx.dataset.gt, 10)
        assert rec >= rec1 - 0.01, f"P={tiled.num_tiles}: {rec} vs {rec1}"


def test_cross_tile_merge_pallas_parity_unit():
    """The merge kernel path and the top_k path agree bit-for-bit, including
    duplicate (replicated hot node) masking."""
    rng = np.random.default_rng(3)
    q, c, k = 7, 24, 6
    ids = rng.integers(0, 40, size=(q, c)).astype(np.int32)
    ids[0, :3] = 5                                  # explicit replicas
    ids[1, 10:] = -1                                # invalid tail
    d = rng.standard_normal((q, c)).astype(np.float32)
    # replicated ids carry identical distances (same base row on every tile)
    for i in range(q):
        for v in np.unique(ids[i][ids[i] >= 0]):
            d[i, ids[i] == v] = d[i, np.argmax(ids[i] == v)]
    ref_ids, ref_d = cross_tile_merge(ids, d, k, use_pallas=False)
    pal_ids, pal_d = cross_tile_merge(ids, d, k, use_pallas=True)
    ref_ids, pal_ids = np.asarray(ref_ids), np.asarray(pal_ids)
    assert (ref_ids == pal_ids).all()
    np.testing.assert_allclose(np.asarray(ref_d), np.asarray(pal_d))
    for i in range(q):                              # no id survives twice
        kept = ref_ids[i][ref_ids[i] >= 0]
        assert len(set(kept.tolist())) == len(kept)


def test_sharded_search_pallas_parity(tiny_index, tiled4):
    """End-to-end: Pallas and jnp paths return identical merged ids on a
    fixed seed (per-tile search parity + cross-tile merge parity)."""
    idx = tiny_index
    tiled, _ = tiled4
    q = idx.dataset.queries[:8]
    res_ref = sharded_search(tiled, q, idx.config.search, idx.dataset.metric)
    cfg_p = dc.replace(idx.config.search, use_pallas=True)
    res_pal = sharded_search(tiled, q, cfg_p, idx.dataset.metric)
    assert (np.asarray(res_ref.ids) == np.asarray(res_pal.ids)).all()


def test_per_tile_counters(tiny_index, tiled4):
    idx = tiny_index
    tiled, _ = tiled4
    q = idx.dataset.queries
    res = sharded_search(tiled, q, idx.config.search, idx.dataset.metric)
    hops = np.asarray(res.per_tile.n_hops)
    assert hops.shape == (4, q.shape[0])
    assert (hops >= 1).all()                        # every tile traversed
    assert (np.asarray(res.per_tile.n_hot_hops) <= hops).all()
    # merged ids are global and within the corpus
    ids = np.asarray(res.ids)
    assert ids.max() < idx.dataset.num_base
    # per-tile traces feed the channel model 1:1
    traces = traces_from_sharded_result(
        res, dim=idx.dataset.dim, r_degree=idx.graph.max_degree,
        index_bits=32, pq_bits=idx.codebook.num_subvectors * 8,
        metric=idx.dataset.metric,
    )
    assert len(traces) == 4
    assert all(t.hops > 0 for t in traces)
    total_hops = sum(t.hops for t in traces)
    assert abs(total_hops - hops.mean(1).sum()) < 1e-6
    # the single-trace helper accepts a sharded result too (total work per
    # query across channels) — what streaming_bench feeds the update model
    from repro.nand.simulator import trace_from_search_result

    agg = trace_from_search_result(
        res, dim=idx.dataset.dim, r_degree=idx.graph.max_degree,
        index_bits=32, pq_bits=idx.codebook.num_subvectors * 8,
        metric=idx.dataset.metric,
    )
    assert abs(agg.hops - total_hops) < 1e-6


def test_simulate_sharded_throughput_scaling():
    """Tiled traces (1/P-size graphs -> shorter traversals) on channel-
    partitioned cores out-serve the single-tile model, and utilization is
    reported per channel."""
    kw = dict(pq=300.0, acc=30.0, rounds=40.0, dim=128, r_degree=64,
              index_bits=24, pq_bits=256)
    single = WorkloadTrace(hops=60.0, **kw)
    base = simulate(single)
    prev_qps = base.qps
    for p in (2, 4, 8):
        kw_p = dict(kw, pq=kw["pq"] / p, acc=kw["acc"] / p,
                    rounds=kw["rounds"] / p)
        tiles = [WorkloadTrace(hops=60.0 / p, **kw_p) for _ in range(p)]
        sim = simulate_sharded(tiles)
        assert len(sim.channel_utilization) == p
        assert sim.qps > prev_qps, f"no scaling at P={p}"
        assert sim.load_imbalance == pytest.approx(1.0)
        prev_qps = sim.qps
    # imbalanced tiles -> straggler latency above the balanced sweep
    kw_4 = dict(kw, pq=kw["pq"] / 4, acc=kw["acc"] / 4, rounds=kw["rounds"] / 4)
    hot_tile = WorkloadTrace(hops=60.0, **kw)       # one channel overloaded
    cold_tile = WorkloadTrace(hops=15.0, **kw_4)
    sim_skew = simulate_sharded([hot_tile] + [cold_tile] * 3)
    assert sim_skew.load_imbalance > 1.5
    bal = simulate_sharded([cold_tile] * 4)
    assert sim_skew.latency_us > bal.latency_us


def test_routed_probing(tiny_index):
    """Cluster-policy routing: probing a query's nearest tiles keeps recall
    close to full fan-out while zeroing the skipped channels' counters."""
    idx = tiny_index
    tiled, _ = partition_index(idx, 4, "cluster")
    q = idx.dataset.queries
    full = sharded_search(tiled, q, idx.config.search, idx.dataset.metric)
    routed = sharded_search(tiled, q, idx.config.search, idx.dataset.metric,
                            probe_tiles=2)
    probed = np.asarray(routed.probed)
    assert probed.shape == (4, q.shape[0])
    assert (probed.sum(0) == 2).all()               # exactly nprobe per query
    assert np.asarray(full.probed).all()
    # skipped lanes billed zero work
    hops = np.asarray(routed.per_tile.n_hops)
    assert (hops[~probed] == 0).all()
    assert (hops[probed] >= 1).all()
    rec_full = recall_at_k(np.asarray(full.ids), idx.dataset.gt, 10)
    rec_routed = recall_at_k(np.asarray(routed.ids), idx.dataset.gt, 10)
    assert rec_routed >= rec_full - 0.15
    # routed channels bill less aggregate work than fan-out
    assert hops.sum() < np.asarray(full.per_tile.n_hops).sum()


def test_mutable_tiled_base(tiny_index):
    """Streaming semantics survive the tiled base: inserts are visible (via
    the global delta), deletes filter, and base results stay correct."""
    from repro.stream.mutable import MutableIndex

    mut = MutableIndex(tiny_index)
    mut.set_num_tiles(2, "hash")
    # a default-constructed engine must NOT clobber the manual tiling
    from repro.serve.engine import ServingEngine

    eng = ServingEngine(mut, batch_size=4, flush_us=0.0)
    assert mut.num_tiles == 2 and mut.shard_policy == "hash"
    assert eng.num_tiles == 2
    q = tiny_index.dataset.queries[:4]
    res = mut.search(q)
    base_direct = search(tiny_index.corpus(), q, tiny_index.config.search,
                         tiny_index.dataset.metric)
    # tiled-base merged search matches the plain base search's top-1
    top1 = np.asarray(base_direct.ids)[:, 0]
    assert (res.ids[:, 0] == top1).mean() >= 0.75
    # a fresh insert is served from the global delta segment
    v = np.asarray(q[0]) + 1e-4
    ext = mut.insert(v)
    res2 = mut.search(v[None])
    assert ext in res2.ids[0]
    assert mut.delete(ext)
    res3 = mut.search(v[None])
    assert ext not in res3.ids[0]
