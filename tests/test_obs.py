"""Observability layer: histogram quantile accuracy, label isolation,
trace-event schema, disabled no-op fast path, recompile watch, and the
engine-level snapshot/trace acceptance contract."""
import json
import math
import warnings

import numpy as np
import pytest

from repro.obs import (
    Histogram, KernelWatch, MetricsRegistry, NULL_OBS, NULL_REGISTRY,
    NULL_SPAN, Observability, RecompileWarning, Tracer,
)


# ---------------------------------------------------------------------------
# Histogram quantiles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["lognormal", "uniform", "exponential"])
@pytest.mark.parametrize("q", [50.0, 95.0, 99.0])
def test_histogram_quantiles_match_numpy(dist, q):
    """Interpolated bucket quantiles track numpy.percentile within the
    bucket-geometry error bound (16 log buckets/decade -> ~8% ratio between
    adjacent edges; allow 12% relative + small absolute slack)."""
    rng = np.random.default_rng(42)
    if dist == "lognormal":
        xs = rng.lognormal(mean=1.0, sigma=1.5, size=20_000)
    elif dist == "uniform":
        xs = rng.uniform(0.1, 500.0, size=20_000)
    else:
        xs = rng.exponential(scale=7.0, size=20_000)
    h = Histogram()
    for v in xs:
        h.observe(v)
    est, exact = h.quantile(q), float(np.percentile(xs, q))
    assert abs(est - exact) <= 0.12 * exact + 1e-6, (dist, q, est, exact)


def test_histogram_exact_tails_and_edge_cases():
    h = Histogram()
    assert math.isnan(h.quantile(50.0))
    for v in (3.0, 5.0, 7.0):
        h.observe(v)
    assert h.quantile(0.0) == pytest.approx(3.0)    # clamped to observed min
    assert h.quantile(100.0) == pytest.approx(7.0)  # clamped to observed max
    assert h.count == 3 and h.mean == pytest.approx(5.0)
    h.observe(float("nan"))                         # ignored, not propagated
    assert h.count == 3
    snap = h.snapshot()
    assert set(snap) == {"count", "sum", "mean", "min", "max",
                         "p50", "p95", "p99"}
    assert snap["min"] == 3.0 and snap["max"] == 7.0


def test_histogram_out_of_range_values():
    """Values beyond the bucket span land in under/overflow slots and the
    quantiles stay finite (clamped to observed extremes)."""
    h = Histogram()
    h.observe(1e-9)     # under the 1e-6 first edge
    h.observe(1e13)     # over the 1e12 last edge
    assert h.count == 2
    assert h.quantile(50.0) >= 1e-9
    assert h.quantile(99.0) <= 1e13


# ---------------------------------------------------------------------------
# Registry semantics
# ---------------------------------------------------------------------------

def test_label_isolation_across_tenants():
    """Tenant A's cells never bleed into tenant B's — counters, gauges and
    histograms are all keyed by the full label set."""
    r = MetricsRegistry()
    for _ in range(3):
        r.counter("queries", tenant="a")
    r.counter("queries", 10.0, tenant="b")
    r.gauge("depth", 5.0, tenant="a")
    r.gauge("depth", 9.0, tenant="b")
    for v in (1.0, 2.0, 3.0):
        r.observe("lat_ms", v, tenant="a")
    r.observe("lat_ms", 1000.0, tenant="b")
    assert r.counter_value("queries", tenant="a") == 3.0
    assert r.counter_value("queries", tenant="b") == 10.0
    assert r.counter_total("queries") == 13.0
    assert r.gauge_value("depth", tenant="a") == 5.0
    assert r.gauge_value("depth", tenant="b") == 9.0
    assert r.histogram("lat_ms", tenant="a").count == 3
    assert r.histogram("lat_ms", tenant="a").vmax == 3.0   # no bleed from b
    assert r.histogram("lat_ms", tenant="b").count == 1
    merged = r.merged_histogram("lat_ms")
    assert merged.count == 4 and merged.vmax == 1000.0


def test_label_order_and_none_normalization():
    r = MetricsRegistry()
    r.counter("c", kind="flat", strategy="none")
    r.counter("c", strategy="none", kind="flat")     # same cell, any order
    r.counter("c", kind="flat", strategy="none", tenant=None)  # None dropped
    assert r.counter_value("c", strategy="none", kind="flat") == 3.0


def test_snapshot_and_json_roundtrip(tmp_path):
    r = MetricsRegistry()
    r.counter("hits", 2.0, tenant="a")
    r.gauge("occupancy", 0.75)
    r.observe("lat_ms", 12.5, kind="flat")
    snap = r.snapshot()
    assert snap["counters"]["hits"] == {"tenant=a": 2.0}
    assert snap["gauges"]["occupancy"] == {"": 0.75}
    assert snap["histograms"]["lat_ms"]["kind=flat"]["count"] == 1
    p = tmp_path / "metrics.json"
    r.to_json(str(p))
    assert json.loads(p.read_text())["counters"]["hits"]["tenant=a"] == 2.0


def test_disabled_registry_is_noop():
    """The disabled fast path records nothing and allocates no cells."""
    r = MetricsRegistry(enabled=False)
    r.counter("c", tenant="a")
    r.gauge("g", 1.0)
    r.observe("h", 2.0)
    assert r.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}
    assert not r._counters and not r._gauges and not r._hists
    assert NULL_REGISTRY.enabled is False
    assert NULL_OBS.enabled is False
    assert NULL_OBS.metrics is NULL_REGISTRY


# ---------------------------------------------------------------------------
# Tracing
# ---------------------------------------------------------------------------

def test_tracer_chrome_trace_schema(tmp_path):
    tr = Tracer()
    with tr.span("batch", kind="flat"):
        with tr.span("kernel-execute"):
            pass
    tr.async_begin("queue-wait", 7)
    tr.async_end("queue-wait", 7)
    tr.instant("consolidate-trigger")
    doc = tr.export(str(tmp_path / "trace.json"))
    loaded = json.loads((tmp_path / "trace.json").read_text())
    assert loaded == json.loads(json.dumps(doc))
    evs = loaded["traceEvents"]
    for e in evs:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] >= 0.0 and e["ts"] >= 0.0
        if e["ph"] in ("b", "e"):
            assert "id" in e
    # sync nesting by time containment: kernel-execute inside batch
    by = {e["name"]: e for e in evs if e["ph"] == "X"}
    b, k = by["batch"], by["kernel-execute"]
    assert b["ts"] <= k["ts"]
    assert k["ts"] + k["dur"] <= b["ts"] + b["dur"] + 1e-6


def test_disabled_tracer_returns_shared_null_span():
    tr = Tracer(enabled=False)
    assert tr.span("x") is NULL_SPAN
    assert tr.span("y", a=1) is NULL_SPAN       # no per-call allocation
    tr.async_begin("q", 1)
    tr.async_end("q", 1)
    tr.instant("i")
    assert tr.events() == []
    with tr.span("z") as sp:
        sp.set(foo=1)                           # safe no-op sink


def test_tracer_clear_keeps_metadata():
    tr = Tracer()
    with tr.span("s"):
        pass
    tr.clear()
    assert all(e["ph"] == "M" for e in tr.events())
    assert len(tr.events()) == 2                # process + thread names


# ---------------------------------------------------------------------------
# Observability bundle resolution
# ---------------------------------------------------------------------------

def test_resolve_accepts_none_bundle_and_obsconfig():
    from repro.configs.base import ObsConfig

    assert Observability.resolve(None) is NULL_OBS
    live = Observability.on()
    assert Observability.resolve(live) is live
    assert Observability.resolve(ObsConfig()) is NULL_OBS   # all-off config
    got = Observability.resolve(ObsConfig(metrics=True, tracing=False,
                                          nand_billing=True))
    assert got.metrics.enabled and not got.tracer.enabled
    assert got.nand_billing
    with pytest.raises(TypeError):
        Observability.resolve(42)


# ---------------------------------------------------------------------------
# Recompile watch
# ---------------------------------------------------------------------------

def test_kernelwatch_warns_on_unexpected_growth():
    r = MetricsRegistry()
    size = {"n": 0}
    w = KernelWatch(r, sources={"k": lambda: size["n"]})
    size["n"] = 2
    w.check(expected_growth=4)                   # within budget: silent
    assert r.counter_total("unexpected_recompiles") == 0
    assert r.gauge_value("jit_cache_growth", kernel="k") == 2
    size["n"] = 9
    with pytest.warns(RecompileWarning, match="compiled 9 new executables"):
        w.check(expected_growth=4)
    assert r.counter_value("unexpected_recompiles", kernel="k") == 5.0
    with warnings.catch_warnings():              # warns once per kernel
        warnings.simplefilter("error", RecompileWarning)
        w.check(expected_growth=4)


# ---------------------------------------------------------------------------
# Engine-level acceptance: snapshot contents + nested trace
# ---------------------------------------------------------------------------

def test_engine_obs_snapshot_and_trace(tiny_index, tmp_path):
    from repro.serve.engine import ServingEngine

    obs = Observability.on(tracing=True, nand_billing=True)
    eng = ServingEngine(tiny_index, batch_size=8, flush_us=0.0, obs=obs)
    for qq in tiny_index.dataset.queries[:12]:
        eng.submit(qq)
    eng.drain()

    snap = obs.metrics.snapshot()
    for name in ("queue_wait_ms", "request_latency_ms", "kernel_execute_ms",
                 "nand_latency_us", "nand_pj_per_query"):
        assert name in snap["histograms"], name
        cell = next(iter(snap["histograms"][name].values()))
        assert cell["count"] > 0
        for p in ("p50", "p95", "p99"):
            assert np.isfinite(cell[p])
    assert "batch_occupancy" in snap["gauges"]
    assert obs.metrics.counter_total("plan_cache_hits") > 0
    assert obs.metrics.counter_total("plan_cache_misses") > 0
    assert obs.metrics.counter_total("nand_billed_queries") == 12
    # histograms are labeled by the serving plan
    labels = next(iter(snap["histograms"]["request_latency_ms"]))
    assert "kind=" in labels and "strategy=" in labels

    doc = obs.tracer.export(str(tmp_path / "trace.json"))
    evs = json.loads((tmp_path / "trace.json").read_text())["traceEvents"]
    assert evs == json.loads(json.dumps(doc["traceEvents"]))
    # every request's async queue-wait opens and closes
    begins = [e["id"] for e in evs if e["ph"] == "b"
              and e["name"] == "queue-wait"]
    ends = [e["id"] for e in evs if e["ph"] == "e"
            and e["name"] == "queue-wait"]
    assert sorted(begins) == sorted(ends) and len(begins) == 12
    # each flush nests batch > batch-assembly / kernel-execute / post-process
    batches = [e for e in evs if e["ph"] == "X" and e["name"] == "batch"]
    assert batches
    for b in batches:
        inner = [e for e in evs if e["ph"] == "X"
                 and e["name"] in ("batch-assembly", "kernel-execute",
                                   "post-process")
                 and b["ts"] - 1e-6 <= e["ts"]
                 and e["ts"] + e["dur"] <= b["ts"] + b["dur"] + 1e-6]
        assert {e["name"] for e in inner} >= {"batch-assembly",
                                              "kernel-execute",
                                              "post-process"}


def test_engine_obs_default_off_records_nothing(tiny_index):
    from repro.serve.engine import ServingEngine

    eng = ServingEngine(tiny_index, batch_size=4, flush_us=0.0)
    assert eng.obs is NULL_OBS
    for qq in tiny_index.dataset.queries[:4]:
        eng.submit(qq)
    eng.drain()
    assert NULL_OBS.metrics.snapshot() == {"counters": {}, "gauges": {},
                                           "histograms": {}}
    assert NULL_OBS.tracer.events() == []


def test_nand_billing_unbillable_execution_counts_not_raises(tiny_index):
    """An execution without NAND geometry (no index handle) records an
    unbilled-batch counter instead of failing the serving path."""
    from repro.obs import record_plan_execution
    from repro.plan import Searcher, SearchRequest

    s = Searcher.open(tiny_index.corpus(), cfg=tiny_index.config.search,
                      metric=tiny_index.dataset.metric)
    res = s.search(SearchRequest(queries=tiny_index.dataset.queries[:4]))
    r = MetricsRegistry()
    sim = record_plan_execution(r, res, index=None)    # geometry unknown
    assert sim is None
    assert r.counter_total("nand_unbilled_batches") == 1
    assert r.counter_total("nand_billed_queries") == 0
    # with geometry the same execution bills cleanly
    sim = record_plan_execution(r, res, index=tiny_index)
    assert sim is not None
    assert r.counter_total("nand_billed_queries") == 4
    assert r.merged_histogram("nand_pj_per_query").count == 1
    # disabled registry: the bridge returns before importing the simulator
    assert record_plan_execution(NULL_REGISTRY, res, index=tiny_index) is None


def test_stream_consolidate_metrics(tiny_index):
    from repro.stream.mutable import MutableIndex

    obs = Observability.on(tracing=True, nand_billing=False)
    mi = MutableIndex(tiny_index)
    mi.obs = obs
    rng = np.random.default_rng(0)
    for _ in range(4):
        mi.insert(rng.standard_normal(tiny_index.dataset.dim)
                  .astype(np.float32))
    mi.consolidate()
    assert obs.metrics.counter_total("stream_inserts") == 4
    assert obs.metrics.counter_total("stream_consolidations") == 1
    assert obs.metrics.histogram("consolidate_ms").count == 1
    assert obs.metrics.gauge_value("delta_fraction") is not None
    names = {e["name"] for e in obs.tracer.events()}
    assert "consolidate" in names
