"""PQ unit + property tests (paper §III-B, Eq. 3)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import PQConfig
from repro.core import pq as pqm
from repro.core.dataset import pairwise_dist


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    return rng.standard_normal((800, 32)).astype(np.float32)


def test_encode_shapes_and_range(data):
    cb = pqm.train_pq(data, PQConfig(num_subvectors=8, num_centroids=16,
                                     kmeans_iters=4))
    codes = np.asarray(pqm.encode(jnp.asarray(data), jnp.asarray(cb.centroids)))
    assert codes.shape == (800, 8)
    assert codes.dtype == np.uint8
    assert codes.max() < 16


def test_quantization_error_decreases_with_centroids(data):
    errs = []
    for c in (4, 16, 64):
        cb = pqm.train_pq(data, PQConfig(num_subvectors=8, num_centroids=c,
                                         kmeans_iters=6))
        codes = np.asarray(pqm.encode(jnp.asarray(data), jnp.asarray(cb.centroids)))
        rec = pqm.decode(codes, cb.centroids)
        errs.append(float(((data - rec) ** 2).sum(-1).mean()))
    assert errs[0] > errs[1] > errs[2]


def test_adt_distance_matches_decoded_distance(data):
    """Eq. 3: sum of ADT lookups == exact distance to the decoded vector."""
    cb = pqm.train_pq(data, PQConfig(num_subvectors=8, num_centroids=16,
                                     kmeans_iters=4))
    codes = pqm.encode(jnp.asarray(data), jnp.asarray(cb.centroids))
    q = jnp.asarray(data[0] + 0.1)
    adt = pqm.compute_adt(q, jnp.asarray(cb.centroids), "l2")
    d_pq = np.asarray(pqm.pq_distance(codes, adt))
    rec = pqm.decode(np.asarray(codes), cb.centroids)
    d_exact = pairwise_dist(np.asarray(q)[None], rec, "l2")[0]
    np.testing.assert_allclose(d_pq, d_exact, rtol=2e-4, atol=2e-4)


def test_adt_ip_metric(data):
    cb = pqm.train_pq(data, PQConfig(num_subvectors=8, num_centroids=16,
                                     kmeans_iters=4), metric="ip")
    codes = pqm.encode(jnp.asarray(data), jnp.asarray(cb.centroids))
    q = jnp.asarray(data[1])
    adt = pqm.compute_adt(q, jnp.asarray(cb.centroids), "ip")
    d_pq = np.asarray(pqm.pq_distance(codes, adt))
    rec = pqm.decode(np.asarray(codes), cb.centroids)
    np.testing.assert_allclose(d_pq, -(rec @ np.asarray(q)), rtol=2e-4,
                               atol=2e-4)


def test_calibrate_beta_reasonable(data):
    cb = pqm.train_pq(data, PQConfig(num_subvectors=8, num_centroids=64,
                                     kmeans_iters=6))
    codes = np.asarray(pqm.encode(jnp.asarray(data), jnp.asarray(cb.centroids)))
    beta = pqm.calibrate_beta(cb, codes, data, np.random.default_rng(0),
                              num_samples=32, num_targets=128)
    assert 1.0 <= beta < 3.0


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(2, 16))
def test_pq_distance_property(m_pow, c):
    """pq_distance == brute-force table lookup for random codes/tables."""
    m = 2 ** (m_pow - 1)
    rng = np.random.default_rng(m * 100 + c)
    adt = rng.standard_normal((m, c)).astype(np.float32)
    codes = rng.integers(0, c, (32, m)).astype(np.uint8)
    got = np.asarray(pqm.pq_distance(jnp.asarray(codes), jnp.asarray(adt)))
    want = adt[np.arange(m)[None, :], codes.astype(int)].sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
