"""Degrade-gracefully shim for ``hypothesis``.

Containers without hypothesis installed previously failed test *collection*
for every property-based module. Importing ``given``/``settings``/``st``
from here instead keeps the real library when present and otherwise
substitutes a fixed-seed example runner: each ``@given`` test is executed
``max_examples`` times with values drawn from a deterministic RNG, so the
property still gets a spread of inputs (just not shrinking or coverage
guidance).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 — mirrors `strategies as st` import style
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=None, unique=False):
            cap = max_size if max_size is not None else min_size + 10

            def draw(rng):
                n = int(rng.integers(min_size, cap + 1))
                if not unique:
                    return [elements.draw(rng) for _ in range(n)]
                out = list(dict.fromkeys(
                    elements.draw(rng) for _ in range(4 * n + 8)
                ))[:n]
                while len(out) < min_size:  # pathological tiny domains
                    v = elements.draw(rng)
                    if v not in out:
                        out.append(v)
                return out

            return _Strategy(draw)

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(0xC0FFEE)
                for _ in range(wrapper._max_examples):
                    fn(*args, *(s.draw(rng) for s in strats), **kwargs)

            # pytest must NOT see the property args as fixtures: drop the
            # __wrapped__ link so inspect.signature reports (*args, **kwargs)
            del wrapper.__wrapped__
            wrapper._max_examples = 10
            wrapper.hypothesis_shim = True
            return wrapper

        return deco

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            if hasattr(fn, "_max_examples"):
                fn._max_examples = max_examples
            return fn

        return deco
