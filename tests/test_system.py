"""End-to-end behaviour tests for the paper's system: the full Proxima
pipeline (PQ + graph + gap + reorder + search + NAND projection) reproduces
the paper's qualitative claims on a synthetic corpus."""
import dataclasses

import numpy as np

from repro.configs.base import SearchConfig
from repro.core import recall_at_k, search
from repro.nand.simulator import simulate, trace_from_search_result


def _trace(idx, res, **kw):
    return trace_from_search_result(
        res, dim=idx.dataset.dim, r_degree=idx.graph.max_degree,
        index_bits=idx.gap.bit_width if idx.gap else 32,
        pq_bits=idx.codebook.num_subvectors * 8, metric=idx.dataset.metric,
        **kw)


def test_paper_claims_pipeline(tiny_index):
    """One flow exercising every §III/§IV-E optimization with the paper's
    directional claims asserted:
      1. PQ traversal + rerank reaches exact-traversal recall with far fewer
         accurate distances (§III-B/C)
      2. early termination cuts expansions at ~equal recall (§III-D)
      3. gap encoding compresses the index >= 19% (§III-E)
      4. hot-node repetition lifts simulated QPS (§IV-E)
    """
    idx = tiny_index
    corpus = idx.corpus()
    q, gt, metric = idx.dataset.queries, idx.dataset.gt, idx.dataset.metric

    exact_cfg = SearchConfig(k=10, list_size=64, use_pq=False,
                             early_termination=False)
    pq_cfg = dataclasses.replace(idx.config.search, early_termination=False)
    et_cfg = idx.config.search

    r_exact = search(corpus, q, exact_cfg, metric)
    r_pq = search(corpus, q, pq_cfg, metric)
    r_et = search(corpus, q, et_cfg, metric)

    rec_exact = recall_at_k(np.asarray(r_exact.ids), gt, 10)
    rec_pq = recall_at_k(np.asarray(r_pq.ids), gt, 10)
    rec_et = recall_at_k(np.asarray(r_et.ids), gt, 10)

    # 1 — recall parity at a fraction of the accurate-distance cost
    assert rec_pq >= rec_exact - 0.1
    assert (np.asarray(r_pq.n_acc).mean()
            < 0.6 * np.asarray(r_exact.n_acc).mean())
    # 2 — ET cuts hops at ~equal recall
    assert np.asarray(r_et.n_hops).mean() < np.asarray(r_pq.n_hops).mean()
    assert rec_et >= rec_pq - 0.05
    # 3 — gap compression
    assert idx.gap.compression_ratio >= 0.19
    # 4 — hot-node repetition helps on the accelerator model
    sim_hot = simulate(_trace(idx, r_et, use_hot=True))
    sim_cold = simulate(_trace(idx, r_et, use_hot=False))
    assert sim_hot.qps > sim_cold.qps
    assert sim_hot.latency_us < sim_cold.latency_us


def test_storage_accounting(tiny_index):
    idx = tiny_index
    b = idx.index_bytes()
    assert b["index_bytes_gap"] < b["index_bytes_uncompressed"]
    assert b["pq_bytes"] == idx.codes.nbytes
    assert b["total_bytes"] > 0
    # hot-node repetition is billed: hot prefix x degree x code bytes
    # (tiny_index builds with hot_node_fraction > 0)
    assert idx.hot_count > 0
    assert b["hot_repetition_bytes"] == \
        idx.hot_count * idx.graph.max_degree * idx.codes.shape[1]
    assert b["total_bytes"] == (b["raw_bytes"] + b["index_bytes_gap"]
                                + b["pq_bytes"] + b["hot_repetition_bytes"])


def test_storage_accounting_gap_disabled(tiny_index):
    # with gap encoding off, the index column falls back to the raw
    # 4-byte-per-slot adjacency and no compression is claimed
    idx = dataclasses.replace(tiny_index, gap=None)
    b = idx.index_bytes()
    n, r = idx.graph.adjacency.shape
    assert b["index_bytes_gap"] == b["index_bytes_uncompressed"] == n * r * 4
    assert b["total_bytes"] == (b["raw_bytes"] + n * r * 4 + b["pq_bytes"]
                                + b["hot_repetition_bytes"])
