"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step on CPU, asserting output shapes + no NaNs;
plus decode/forward consistency for one arch per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import build_model


def _batch(cfg, b=2, s=16, rng=None):
    rng = rng or jax.random.PRNGKey(0)
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["frontend"] = 0.1 * jnp.ones(
            (b, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.family == "encdec":
        batch["frontend"] = 0.1 * jnp.ones((b, s, cfg.frontend_dim),
                                           jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, q_chunk=64, ssm_chunk=8)
    params, specs = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss {loss}"
    # one grad step worth of grads is finite
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.vdot(x, x)) for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, q_chunk=64, ssm_chunk=8)
    params, _ = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    batch = _batch(cfg, b, s)
    del batch["labels"]
    logits, cache = model.prefill(params, batch, max_len=s + 8 + cfg.frontend_tokens)
    assert logits.shape == (b, 1, cfg.vocab_size)
    lg, cache2 = model.decode_step(params, cache, jnp.zeros((b, 1), jnp.int32))
    assert lg.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    assert int(cache2.length) == int(cache.length) + 1


@pytest.mark.parametrize("arch", [
    "stablelm-1.6b",        # dense
    "mixtral-8x22b",        # moe + swa
    "paligemma-3b",         # vlm prefix
    "zamba2-1.2b",          # hybrid
    "seamless-m4t-medium",  # encdec
    "falcon-mamba-7b",      # ssm
])
def test_decode_matches_teacher_forcing(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, q_chunk=64, ssm_chunk=8, moe_capacity=50.0)
    params, _ = model.init(jax.random.PRNGKey(1))
    b, s = 2, 12
    rng = jax.random.PRNGKey(2)
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["frontend"] = 0.1 * jnp.ones(
            (b, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.family == "encdec":
        batch["frontend"] = 0.1 * jnp.ones((b, s - 1, cfg.frontend_dim),
                                           jnp.float32)
    if cfg.family == "encdec":
        enc_out, enc_pos = model._encode(params, batch["frontend"])
        x, pos, pre = model._embed_inputs(params, batch)
        h, _, _ = model._decoder_stack(params, x, pos, enc_out=enc_out,
                                       enc_positions=enc_pos)
    else:
        x, pos, pre = model._embed_inputs(params, batch)
        h, _, _ = model._decoder_stack(params, x, pos, prefix_len=pre)
    full = np.asarray(model._logits(params, h), np.float32)
    pb = dict(batch)
    pb["tokens"] = toks[:, : s - 1]
    _, cache = model.prefill(params, pb, max_len=s + 4 + cfg.frontend_tokens)
    lg, _ = model.decode_step(params, cache, toks[:, s - 1 : s])
    off = cfg.frontend_tokens if cfg.family == "vlm" else 0
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32), full[:, s - 1 + off],
        rtol=2e-2, atol=2e-2,
    )


@pytest.mark.parametrize("arch", [
    "stablelm-1.6b", "mixtral-8x22b", "granite-moe-3b-a800m",
    "falcon-mamba-7b", "zamba2-1.2b",
])
def test_chunked_prefill_matches_full_forward(arch):
    """Segmented prefill (§Perf P1) must reproduce the single-shot logits
    (exact for attention/MoE; SSM chunk-boundary reassociation < 5e-2)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg, q_chunk=512, ssm_chunk=8, moe_capacity=50.0)
    params, _ = model.init(jax.random.PRNGKey(3))
    b, s, seg = 2, 128, 32
    toks = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0, cfg.vocab_size)
    x, pos, pre = model._embed_inputs(params, {"tokens": toks})
    h, _, _ = model._decoder_stack(params, x, pos)
    full = np.asarray(model._logits(params, h[:, -1:, :]), np.float32)
    lg, cache = model.prefill_chunked(params, {"tokens": toks}, seg_len=seg)
    np.testing.assert_allclose(np.asarray(lg, np.float32), full,
                               rtol=5e-2, atol=5e-2)
    assert int(cache.length) == s
    # a decode step continues correctly from the chunked cache
    lg2, _ = model.decode_step(params, cache, toks[:, :1])
    assert np.isfinite(np.asarray(lg2, np.float32)).all()


def test_full_configs_match_public_specs():
    spec = {
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        c = get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, h, kv, ff, v), arch


def test_param_counts_sane():
    expect = {
        "mistral-nemo-12b": 12.2e9, "stablelm-1.6b": 1.6e9,
        "granite-34b": 34e9, "deepseek-67b": 67e9,
        "mixtral-8x22b": 141e9, "falcon-mamba-7b": 7.3e9,
        "paligemma-3b": 3.0e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.12, (arch, got, n)
