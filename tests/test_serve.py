"""Serving engine + retrieval layer behaviour."""
import numpy as np
import pytest

from repro.core import search
from repro.serve.engine import ServingEngine
from repro.serve.retrieval import EmbeddingRetriever


def test_engine_matches_direct_search(tiny_index):
    idx = tiny_index
    eng = ServingEngine(idx, batch_size=8, flush_us=0.0)
    q = idx.dataset.queries[:13]          # non-multiple of batch: forces pad
    rids = [eng.submit(qq) for qq in q]
    eng.drain()
    got = np.stack([eng.done[r].ids for r in rids])
    direct = np.asarray(
        search(idx.corpus(), q, idx.config.search, idx.dataset.metric).ids
    )
    # same result sets per query (padding lanes must not leak)
    match = (np.sort(got, 1) == np.sort(direct, 1)).mean()
    assert match == 1.0
    assert eng.stats["queries"] == 13
    lats = [eng.done[r].latency_ms for r in rids]
    assert all(l >= 0 for l in lats)


def test_engine_batching_counters(tiny_index):
    eng = ServingEngine(tiny_index, batch_size=4, flush_us=1e9)
    for qq in tiny_index.dataset.queries[:8]:
        eng.submit(qq)
        eng.step()          # flushes only when 4 queued (huge timeout)
    eng.drain()
    assert eng.stats["batches"] == 2
    assert eng.stats["pad_fraction"] == 0.0


def test_engine_fifo_ordering(tiny_index):
    """Requests complete in submission order, batch by batch, and the
    latency accounting is monotone (t_submit <= t_done, nondecreasing
    t_done across batches)."""
    eng = ServingEngine(tiny_index, batch_size=4, flush_us=0.0)
    q = tiny_index.dataset.queries[:10]
    rids = [eng.submit(qq) for qq in q]
    completed = []
    while eng.queue:
        completed.extend(r.rid for r in eng.step(force=True))
    assert completed == rids                      # strict FIFO
    dones = [eng.done[r].t_done for r in rids]
    assert all(b >= a for a, b in zip(dones, dones[1:]))
    for r in rids:
        req = eng.done[r]
        assert req.t_done >= req.t_submit
        assert req.latency_ms >= 0.0


def test_engine_flush_timeout(tiny_index):
    """A sub-batch queue flushes only after flush_us elapses."""
    import time as _time

    eng = ServingEngine(tiny_index, batch_size=8, flush_us=5e4)  # 50 ms
    eng.submit(tiny_index.dataset.queries[0])
    assert eng.step() == []                       # timeout not reached
    assert len(eng.queue) == 1
    _time.sleep(0.06)
    out = eng.step()                              # now due
    assert [r.rid for r in out] == [0]
    assert not eng.queue
    assert eng.done[0].latency_ms >= 50.0         # waited for the timeout


def test_engine_flush_timeout_after_idle_gap(tiny_index):
    """Regression: the flush timeout is anchored to the head request's
    submit time, not the last flush. After an idle gap longer than
    flush_us, the first submitted request must still wait its full window
    for batch-mates instead of flushing immediately in a batch of 1."""
    import time as _time

    eng = ServingEngine(tiny_index, batch_size=8, flush_us=5e4)  # 50 ms
    _time.sleep(0.08)                 # idle gap > flush_us since construction
    eng.submit(tiny_index.dataset.queries[0])
    assert eng.step() == [], "flushed immediately after an idle gap"
    eng.submit(tiny_index.dataset.queries[1])   # joins the pending batch
    assert len(eng.queue) == 2
    _time.sleep(0.06)
    out = eng.step()                  # head has now waited >= flush_us
    assert sorted(r.rid for r in out) == [0, 1]


def test_engine_pad_fraction_is_bounded_mean(tiny_index):
    """Regression: stats['pad_fraction'] reports the running mean over
    batches (the old accumulating sum grew without bound)."""
    eng = ServingEngine(tiny_index, batch_size=8, flush_us=0.0)
    for qq in tiny_index.dataset.queries[:6]:   # 6 batches of 1 -> pad 0/1
        eng.submit(qq)
        eng.drain()
    assert eng.stats["batches"] == 6
    assert 0.0 <= eng.stats["pad_fraction"] <= 1.0
    assert eng.stats["pad_fraction"] == 0.0     # batch of 1 -> bucket of 1
    rids = [eng.submit(qq) for qq in tiny_index.dataset.queries[:3]]
    eng.drain()                                 # 3 queries -> bucket of 4
    assert eng.stats["batches"] == 7
    assert eng.stats["pad_fraction"] == pytest.approx((6 * 0.0 + 0.25) / 7)


def test_engine_beam_width_exposed(tiny_index):
    """ServingEngine(beam_width=E) overrides the config end to end and
    serves the same result sets as the direct beam search."""
    import dataclasses

    eng = ServingEngine(tiny_index, batch_size=8, flush_us=0.0, beam_width=4)
    assert eng.cfg.beam_width == 4
    q = tiny_index.dataset.queries[:8]
    rids = [eng.submit(qq) for qq in q]
    eng.drain()
    got = np.stack([eng.done[r].ids for r in rids])
    cfg4 = dataclasses.replace(tiny_index.config.search, beam_width=4)
    direct = np.asarray(
        search(tiny_index.corpus(), q, cfg4, tiny_index.dataset.metric).ids
    )
    assert (np.sort(got, 1) == np.sort(direct, 1)).all()


def test_engine_step_noop_without_requests(tiny_index):
    eng = ServingEngine(tiny_index, batch_size=4, flush_us=0.0)
    assert eng.step() == []
    assert eng.step(force=True) == []
    assert eng.drain() == []
    assert eng.stats["batches"] == 0


def test_engine_bucketed_batches_reuse_compiles(tiny_index):
    """Varying queue depths hit a fixed set of power-of-two bucket shapes:
    flushing many different sub-batch sizes may only add one compiled
    executable per bucket, never one per batch size.  The invariant is now
    asserted through the observability layer: the engine's ``KernelWatch``
    tracks jit-cache growth per kernel and raises ``RecompileWarning`` when
    a batch defeats the bucket scheme."""
    import warnings

    from repro.obs import Observability, RecompileWarning

    if not hasattr(search, "_cache_size"):
        pytest.skip("jax.jit cache introspection unavailable")
    obs = Observability.on(tracing=False, nand_billing=False)
    q = tiny_index.dataset.queries
    got = {}
    with warnings.catch_warnings():
        warnings.simplefilter("error", RecompileWarning)
        eng = ServingEngine(tiny_index, batch_size=8, flush_us=0.0, obs=obs)
        for n in (1, 2, 3, 5, 6, 7, 3, 1, 5):   # buckets: 1, 2, 4, 8 only
            rids = [eng.submit(qq) for qq in q[:n]]
            eng.drain()
            for i, r in enumerate(rids):
                got[r] = eng.done[r].ids
    growth = obs.metrics.gauge_value("jit_cache_growth",
                                     kernel="graph_search")
    assert growth is not None, "KernelWatch never sampled"
    # warm-up compiled the full-batch bucket before the watch baseline;
    # serving may add at most the remaining pow2 buckets (1, 2, 4)
    assert growth <= 4, f"{growth} compiles for 9 batch sizes"
    assert obs.metrics.counter_total("unexpected_recompiles") == 0
    # padding lanes never leak into results
    direct = np.asarray(
        search(tiny_index.corpus(), q[:7], tiny_index.config.search,
               tiny_index.dataset.metric).ids
    )
    rids = [eng.submit(qq) for qq in q[:7]]
    eng.drain()
    out = np.stack([eng.done[r].ids for r in rids])
    assert (np.sort(out, 1) == np.sort(direct, 1)).all()


def test_engine_sharded_path(tiny_index):
    """num_tiles > 1 routes batches through the channel-parallel search and
    serves results equivalent to the single-tile engine."""
    eng = ServingEngine(tiny_index, batch_size=8, flush_us=0.0, num_tiles=2,
                        shard_policy="hash")
    assert eng.tiled is not None and eng.tiled.num_tiles == 2
    q = tiny_index.dataset.queries[:8]
    rids = [eng.submit(qq) for qq in q]
    eng.drain()
    got = np.stack([eng.done[r].ids for r in rids])
    direct = np.asarray(
        search(tiny_index.corpus(), q, tiny_index.config.search,
               tiny_index.dataset.metric).ids
    )
    overlap = np.mean([
        len(set(got[i].tolist()) & set(direct[i].tolist())) / direct.shape[1]
        for i in range(len(q))
    ])
    assert overlap >= 0.7, f"sharded engine diverged: overlap {overlap}"


def test_embedding_retriever_self_query():
    rng = np.random.default_rng(0)
    embs = rng.standard_normal((400, 64)).astype(np.float32)
    retr = EmbeddingRetriever(embs, metric="angular", max_degree=16)
    hits = 0
    for qi in (3, 77, 200, 399):
        ids, _ = retr.query(embs[qi], k=5)
        hits += int(qi in ids[0].tolist())
    assert hits >= 3  # a corpus vector should find itself (ANN: allow 1 miss)


def test_embedding_retriever_batched_metadata():
    """query() metadata reflects the actual batch: num_queries derives from
    the queries searched, not the build-time placeholder of 1."""
    rng = np.random.default_rng(1)
    embs = rng.standard_normal((300, 32)).astype(np.float32)
    retr = EmbeddingRetriever(embs, metric="angular", max_degree=16)
    ids, dists = retr.query(embs[:5], k=3)
    assert ids.shape == (5, 3) and dists.shape == (5, 3)
    assert retr.index.config.dataset.num_queries == 5
    assert retr.index.dataset.config.num_queries == 5
    retr.query(embs[0], k=3)
    assert retr.index.config.dataset.num_queries == 1
