"""Serving engine + retrieval layer behaviour."""
import numpy as np
import pytest

from repro.core import search
from repro.serve.engine import ServingEngine
from repro.serve.retrieval import EmbeddingRetriever


def test_engine_matches_direct_search(tiny_index):
    idx = tiny_index
    eng = ServingEngine(idx, batch_size=8, flush_us=0.0)
    q = idx.dataset.queries[:13]          # non-multiple of batch: forces pad
    rids = [eng.submit(qq) for qq in q]
    eng.drain()
    got = np.stack([eng.done[r].ids for r in rids])
    direct = np.asarray(
        search(idx.corpus(), q, idx.config.search, idx.dataset.metric).ids
    )
    # same result sets per query (padding lanes must not leak)
    match = (np.sort(got, 1) == np.sort(direct, 1)).mean()
    assert match == 1.0
    assert eng.stats["queries"] == 13
    lats = [eng.done[r].latency_ms for r in rids]
    assert all(l >= 0 for l in lats)


def test_engine_batching_counters(tiny_index):
    eng = ServingEngine(tiny_index, batch_size=4, flush_us=1e9)
    for qq in tiny_index.dataset.queries[:8]:
        eng.submit(qq)
        eng.step()          # flushes only when 4 queued (huge timeout)
    eng.drain()
    assert eng.stats["batches"] == 2
    assert eng.stats["pad_fraction"] == 0.0


def test_engine_fifo_ordering(tiny_index):
    """Requests complete in submission order, batch by batch, and the
    latency accounting is monotone (t_submit <= t_done, nondecreasing
    t_done across batches)."""
    eng = ServingEngine(tiny_index, batch_size=4, flush_us=0.0)
    q = tiny_index.dataset.queries[:10]
    rids = [eng.submit(qq) for qq in q]
    completed = []
    while eng.queue:
        completed.extend(r.rid for r in eng.step(force=True))
    assert completed == rids                      # strict FIFO
    dones = [eng.done[r].t_done for r in rids]
    assert all(b >= a for a, b in zip(dones, dones[1:]))
    for r in rids:
        req = eng.done[r]
        assert req.t_done >= req.t_submit
        assert req.latency_ms >= 0.0


def test_engine_flush_timeout(tiny_index):
    """A sub-batch queue flushes only after flush_us elapses."""
    import time as _time

    eng = ServingEngine(tiny_index, batch_size=8, flush_us=5e4)  # 50 ms
    eng._last_flush = _time.time()
    eng.submit(tiny_index.dataset.queries[0])
    assert eng.step() == []                       # timeout not reached
    assert len(eng.queue) == 1
    _time.sleep(0.06)
    out = eng.step()                              # now due
    assert [r.rid for r in out] == [0]
    assert not eng.queue
    assert eng.done[0].latency_ms >= 50.0         # waited for the timeout


def test_engine_step_noop_without_requests(tiny_index):
    eng = ServingEngine(tiny_index, batch_size=4, flush_us=0.0)
    assert eng.step() == []
    assert eng.step(force=True) == []
    assert eng.drain() == []
    assert eng.stats["batches"] == 0


def test_embedding_retriever_self_query():
    rng = np.random.default_rng(0)
    embs = rng.standard_normal((400, 64)).astype(np.float32)
    retr = EmbeddingRetriever(embs, metric="angular", max_degree=16)
    hits = 0
    for qi in (3, 77, 200, 399):
        ids, _ = retr.query(embs[qi], k=5)
        hits += int(qi in ids[0].tolist())
    assert hits >= 3  # a corpus vector should find itself (ANN: allow 1 miss)
