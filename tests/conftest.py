"""Shared test fixtures. NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see 1 device; only launch/dryrun.py forces 512 host devices
(and the distributed tests spawn subprocesses that set their own flags)."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.base import (  # noqa: E402
    DatasetConfig, GraphConfig, PQConfig, ProximaConfig, SearchConfig,
)


@pytest.fixture(scope="session")
def tiny_proxima_cfg():
    return ProximaConfig(
        dataset=DatasetConfig(name="sift-like", num_base=1500, num_queries=24,
                              dim=64, num_clusters=12, cluster_std=0.3, seed=0),
        pq=PQConfig(num_subvectors=32, num_centroids=128, kmeans_iters=8),
        graph=GraphConfig(max_degree=24, build_list_size=48, alpha=1.2),
        search=SearchConfig(k=10, list_size=64, t_init=16, t_step=8,
                            repetition_rate=3, beta=1.06),
        hot_node_fraction=0.03,
    )


@pytest.fixture(scope="session")
def tiny_index(tiny_proxima_cfg):
    from repro.core import build_index

    return build_index(tiny_proxima_cfg, reorder_samples=24)
