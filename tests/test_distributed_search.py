"""Distributed Proxima search (shard_map over 8 host devices) must be
bit-identical to single-device search in both dataflow modes. Runs in a
subprocess because XLA device count is locked at first jax init."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.configs.base import (ProximaConfig, DatasetConfig, PQConfig,
                                GraphConfig, SearchConfig)
from repro.core import build_index, search
from repro.core.distributed import shard_corpus, distributed_search
from repro.launch.mesh import make_mesh

cfg = ProximaConfig(
    dataset=DatasetConfig(name="sift-like", num_base=1200, num_queries=16,
                          dim=64, num_clusters=12, seed=0),
    pq=PQConfig(num_subvectors=16, num_centroids=64, kmeans_iters=5),
    graph=GraphConfig(max_degree=16, build_list_size=32),
    search=SearchConfig(k=10, list_size=48, t_init=16, t_step=8,
                        repetition_rate=2, beta=1.06),
    hot_node_fraction=0.03,
)
idx = build_index(cfg, reorder_samples=16)
res = search(idx.corpus(), idx.dataset.queries, cfg.search, idx.dataset.metric)
single = np.sort(np.asarray(res.ids), axis=1)

mesh = make_mesh((4, 2), ("data", "model"))
sc = shard_corpus(idx.graph.adjacency, idx.codes, idx._search_base(),
                  idx.codebook.centroids, idx.graph.entry_point,
                  idx.hot_count, num_shards=4)
for mode in ("nsp", "fetch"):
    ids, d = distributed_search(sc, idx.dataset.queries, cfg.search,
                                idx.dataset.metric, mode=mode, mesh=mesh)
    got = np.sort(np.asarray(ids), axis=1)
    match = (got == single).mean()
    assert match == 1.0, f"mode={mode}: match={match}"
    print(f"mode={mode}: exact match")

# beam-parallel traversal distributes identically: E=4 must stay
# bit-identical to the single-device beam search
import dataclasses
cfg4 = dataclasses.replace(cfg.search, beam_width=4)
res4 = search(idx.corpus(), idx.dataset.queries, cfg4, idx.dataset.metric)
single4 = np.sort(np.asarray(res4.ids), axis=1)
assert np.asarray(res4.rounds).mean() < np.asarray(res.rounds).mean()
for mode in ("nsp", "fetch"):
    ids, d = distributed_search(sc, idx.dataset.queries, cfg4,
                                idx.dataset.metric, mode=mode, mesh=mesh)
    got = np.sort(np.asarray(ids), axis=1)
    match = (got == single4).mean()
    assert match == 1.0, f"mode={mode} E=4: match={match}"
    print(f"mode={mode} E=4: exact match")
print("OK")
"""


@pytest.mark.slow
def test_distributed_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
