"""Proximity-graph builder invariants."""
from collections import deque

import numpy as np
import pytest

from repro.configs.base import DatasetConfig, GraphConfig
from repro.core.dataset import make_dataset
from repro.core.graph import _greedy_search_np, build_graph


@pytest.fixture(scope="module")
def ds():
    return make_dataset(DatasetConfig(name="sift-like", num_base=1200,
                                      num_queries=16, dim=48,
                                      num_clusters=10, seed=1))


def _reachable(g):
    seen = {g.entry_point}
    dq = deque([g.entry_point])
    while dq:
        v = dq.popleft()
        for u in g.adjacency[v, : g.degrees[v]]:
            if int(u) not in seen:
                seen.add(int(u))
                dq.append(int(u))
    return len(seen)


@pytest.mark.parametrize("method", ["knn_prune", "incremental"])
def test_graph_invariants(ds, method):
    if method == "incremental":
        base = ds.base[:300]
        ds_gt = None
    else:
        base = ds.base
    cfg = GraphConfig(max_degree=16, build_list_size=32, alpha=1.2)
    g = build_graph(base, cfg, ds.metric, method=method)
    n = base.shape[0]
    assert g.adjacency.shape == (n, 16)
    assert (g.degrees >= 1).all() and (g.degrees <= 16).all()
    assert (g.adjacency >= 0).all() and (g.adjacency < n).all()
    # no self loops within true degree
    for i in range(0, n, max(n // 50, 1)):
        assert i not in set(g.adjacency[i, : g.degrees[i]].tolist())
    # fully reachable from the entry point (paper's BFS traversal premise)
    assert _reachable(g) == n


def test_greedy_search_recall(ds):
    cfg = GraphConfig(max_degree=24, build_list_size=48, alpha=1.2)
    g = build_graph(ds.base, cfg, ds.metric)
    hits = 0
    for i in range(ds.queries.shape[0]):
        order, _ = _greedy_search_np(ds.base, g.adjacency, g.degrees,
                                     g.entry_point, ds.queries[i],
                                     ds.metric, 64)
        top = [v for v, _ in order[:10]]
        hits += len(set(top) & set(ds.gt[i, :10].tolist()))
    recall = hits / (ds.queries.shape[0] * 10)
    # the 48-dim 10-cluster synthetic set is deliberately hard at R=24;
    # absolute quality claims are tested on the paper-scale PQ fixture
    assert recall > 0.65, f"greedy graph search recall too low: {recall}"
