"""Quality observability: shadow-recall estimator (seeded sampling, exact
oracle fidelity, tombstone/filter awareness), per-tenant SLO window
semantics, and the per-round convergence log (ring, labels, round-trip)."""
import numpy as np
import pytest

from repro.core.dataset import exact_knn, recall_at_k, recall_hits_per_query
from repro.obs import (
    ConvergenceLog, MetricsRegistry, Observability, QualityMonitor,
    SLOTarget, SLOTracker, trace_session, wilson_interval,
)
from repro.plan import Searcher, SearchRequest


# ---------------------------------------------------------------------------
# Wilson interval + seeded sampling
# ---------------------------------------------------------------------------

def test_wilson_interval_basics():
    assert wilson_interval(0, 0) == (0.0, 1.0)          # vacuous
    lo, hi = wilson_interval(80, 100)
    assert lo < 0.8 < hi
    lo2, hi2 = wilson_interval(800, 1000)
    assert hi2 - lo2 < hi - lo                          # narrows with trials
    lo, hi = wilson_interval(0, 50)                     # extremes stay in
    assert lo == 0.0 and 0.0 < hi < 0.2                 # [0, 1]
    lo, hi = wilson_interval(50, 50)
    assert 0.8 < lo < 1.0 and hi == 1.0


def test_sampling_deterministic_across_batch_boundaries():
    """The stream position depends only on requests observed, so one draw of
    100 equals any split into smaller batches — replays sample identically
    however the scheduler packed them."""
    whole = QualityMonitor(MetricsRegistry(), sample_rate=0.3, seed=7)
    split = QualityMonitor(MetricsRegistry(), sample_rate=0.3, seed=7)
    a = whole.sample_mask(100)
    b = np.concatenate([split.sample_mask(n) for n in (13, 1, 40, 46)])
    assert np.array_equal(a, b)
    other = QualityMonitor(MetricsRegistry(), sample_rate=0.3, seed=8)
    assert not np.array_equal(a, other.sample_mask(100))


def test_sampling_rate_edges_and_paused():
    qm0 = QualityMonitor(MetricsRegistry(), sample_rate=0.0, seed=0)
    assert not qm0.sample_mask(64).any()
    qm1 = QualityMonitor(MetricsRegistry(), sample_rate=1.0, seed=0)
    assert qm1.sample_mask(64).all()
    assert qm1.sample_mask(0).shape == (0,)
    # paused() suspends observe() without advancing the stream
    qm = QualityMonitor(MetricsRegistry(), sample_rate=0.5, seed=3)
    with qm.paused():
        assert qm.observe(None, None, np.zeros((4, 2)), None) is None
    assert qm._seq == 0


# ---------------------------------------------------------------------------
# Shadow-recall estimation against the exact oracle
# ---------------------------------------------------------------------------

def test_shadow_estimate_exact_at_full_sampling(tiny_index):
    """At sample_rate=1.0 the shadow estimate IS recall against the exact
    oracle — no sampling noise, so it must equal the independently computed
    value bit-for-bit."""
    obs = Observability.on(tracing=False, quality=True,
                           quality_sample_rate=1.0)
    s = Searcher.open(tiny_index, obs=obs)
    q = tiny_index.dataset.queries
    res = s.search(SearchRequest(queries=q))
    qm = obs.quality
    assert qm.samples == q.shape[0]
    gt = exact_knn(q, np.asarray(tiny_index.dataset.base, np.float32),
                   s.cfg.k, s.metric)
    want = recall_at_k(res.ids, gt, s.cfg.k)
    assert qm.overall()["estimate"] == pytest.approx(want)
    lo, hi = qm.overall()["ci_low"], qm.overall()["ci_high"]
    assert lo <= want <= hi
    cell = qm.estimate("flat", "none")
    assert cell["estimate"] == pytest.approx(want)
    m = obs.metrics
    assert m.counter_total("shadow_samples") == q.shape[0]
    assert m.gauge_value("recall_estimate", kind="flat", strategy="none",
                         ) == pytest.approx(want)


def test_shadow_oracle_filter_aware(tiny_index):
    """Masked plans replay against the attribute-passing subset only — every
    oracle id passes the filter, and ids outside the subset never appear."""
    from repro.filter import FilterSpec, attach_attributes, random_attributes

    try:
        store = attach_attributes(
            tiny_index, random_attributes(tiny_index.dataset.num_base,
                                          {"category": 4}, seed=5))
        obs = Observability.on(tracing=False, quality=True,
                               quality_sample_rate=1.0)
        s = Searcher.open(tiny_index, obs=obs)
        spec = FilterSpec.eq("category", 1)
        req = SearchRequest(queries=tiny_index.dataset.queries, filter=spec)
        plan = s.plan(req)
        gt = s.shadow_ground_truth(plan, req.queries)
        mask = np.asarray(store.mask(spec), bool)
        assert mask[gt].all(), "oracle returned ids that fail the filter"
        # and the full pipeline scores against that oracle without error
        s.search(req)
        assert obs.quality.samples == req.queries.shape[0]
        assert obs.metrics.counter_total("shadow_errors") == 0
    finally:
        tiny_index.attributes = None   # keep the shared fixture pristine


def test_shadow_oracle_tombstone_aware(tiny_index):
    """Merged plans replay against the LIVE corpus: tombstoned ids never
    appear in the oracle, and the estimate equals the independent truth
    computed from live_vectors directly."""
    from repro.stream import MutableIndex

    mut = MutableIndex(tiny_index)
    rng = np.random.default_rng(0)
    dead = rng.choice(tiny_index.dataset.num_base, size=50, replace=False)
    for ext in dead:
        mut.delete(int(ext))
    obs = Observability.on(tracing=False, quality=True,
                           quality_sample_rate=1.0)
    s = Searcher.open(mut, obs=obs)
    q = tiny_index.dataset.queries
    res = s.search(SearchRequest(queries=q))
    plan = res.plan
    assert plan.kind == "merged"
    gt = s.shadow_ground_truth(plan, q)
    assert not np.isin(gt, dead).any(), "tombstoned id in the oracle"
    ext_ids, vecs = mut.live_vectors()
    want_gt = ext_ids[exact_knn(q, vecs, plan.cfg.k, mut.metric)]
    hits = recall_hits_per_query(res.ids[:, :plan.cfg.k],
                                 want_gt[:, :plan.cfg.k])
    want = float(hits.sum()) / (q.shape[0] * plan.cfg.k)
    assert obs.quality.overall()["estimate"] == pytest.approx(want)


def test_shadow_errors_counted_not_raised(tiny_index):
    """The nand_bridge contract: a broken oracle must not take down the
    serving path — failures are counted as shadow_errors."""
    obs = Observability.on(tracing=False, quality=True,
                           quality_sample_rate=1.0)
    s = Searcher.open(tiny_index, obs=obs)
    q = tiny_index.dataset.queries[:4]
    plan = s.plan(SearchRequest(queries=q))

    class Broken:
        def shadow_ground_truth(self, plan, queries):
            raise RuntimeError("oracle down")

    out = obs.quality.observe(Broken(), plan, q, np.zeros((4, 10), np.int64))
    assert out is None
    assert obs.metrics.counter_total("shadow_errors") == 4


# ---------------------------------------------------------------------------
# SLO window semantics
# ---------------------------------------------------------------------------

def test_slo_empty_and_shallow_windows_never_violate():
    m = MetricsRegistry()
    t = SLOTracker(m, {None: SLOTarget(recall_floor=0.9,
                                       p99_latency_ms=10.0)},
                   min_samples=8)
    assert t.total_violations == 0
    for _ in range(7):                  # below min_samples: no evaluation,
        t.record_latency(None, 1e6)     # even with outrageous values
        t.record_recall(None, 0.0)
    assert t.total_violations == 0
    st = t.status()[None]
    assert st["latency_samples"] == 7 and st["recall_samples"] == 7


def test_slo_boundary_values_pass():
    """A window statistic exactly AT the target is on budget, not over."""
    m = MetricsRegistry()
    t = SLOTracker(m, {None: SLOTarget(recall_floor=0.5,
                                       p99_latency_ms=10.0)},
                   min_samples=8)
    for _ in range(16):
        t.record_latency(None, 10.0)    # window p99 == ceiling exactly
        t.record_recall(None, 0.5)      # window mean == floor exactly
    assert t.total_violations == 0
    assert m.counter_total("slo_violations") == 0


def test_slo_breach_increments_and_labels():
    m = MetricsRegistry()
    t = SLOTracker(m, {"acme": SLOTarget(recall_floor=0.9),
                       None: SLOTarget(p99_latency_ms=5.0)},
                   min_samples=4)
    for _ in range(4):
        t.record_recall("acme", 0.2)    # evaluates at depth 4: breach
    assert t.total_violations == 1
    assert m.counter_total("slo_violations") == 1
    assert m.gauge_value("slo_burn_rate", tenant="acme",
                         slo="recall_floor") == pytest.approx(7.0)
    for _ in range(4):
        t.record_latency(None, 50.0)    # p99 50 > 5: breach on record 4
    assert t.total_violations == 2
    # untracked tenants are ignored entirely
    t.record_latency("ghost", 1e9)
    t.record_recall("ghost", 0.0)
    assert t.total_violations == 2


def test_slo_window_rolls_off_old_breaches():
    m = MetricsRegistry()
    t = SLOTracker(m, {None: SLOTarget(recall_floor=0.5)},
                   window=8, min_samples=4)
    for _ in range(8):
        t.record_recall(None, 0.0)      # deep breach
    burned = t.total_violations
    assert burned > 0
    for _ in range(8):                  # healthy traffic displaces the
        t.record_recall(None, 1.0)      # breach from the rolling window
    assert t.status()[None]["window_recall"] == pytest.approx(1.0)
    for _ in range(4):
        t.record_recall(None, 1.0)
    # recovery breached only while the mixed window still averaged under
    # the floor (means 1/8, 2/8, 3/8; 4/8 is the passing boundary)
    assert t.total_violations == burned + 3


# ---------------------------------------------------------------------------
# Convergence log
# ---------------------------------------------------------------------------

def test_convergence_trace_roundtrip(tiny_index, tmp_path):
    """trace_session per-lane rounds == whole-batch SearchStats.rounds (the
    round-step equivalence contract), and the npz round-trips into the
    identical training matrix."""
    s = Searcher.open(tiny_index)
    q = tiny_index.dataset.queries
    plan = s.plan(SearchRequest(queries=q))
    sess = s.round_session(plan)
    log = ConvergenceLog(capacity=1 << 14)
    _, rounds = trace_session(sess, q, log)
    ref = s.search(SearchRequest(queries=q))
    assert float(np.mean(rounds)) == pytest.approx(float(ref.stats.rounds))
    assert log.dropped == 0 and log.count > 0
    assert set(log.labels.values()) == set(int(r) for r in rounds)

    X, y, names = log.dataset()
    assert X.shape == (log.count, len(names)) and len(y) == log.count
    # the label is the lane's TOTAL rounds, so every record's round column
    # is bounded by its label
    rcol = X[:, list(names).index("round")]
    assert (rcol <= y).all() and (y > 0).all()

    path = str(tmp_path / "conv.npz")
    log.save_npz(path)
    rt = ConvergenceLog.load_npz(path)
    X2, y2, _ = rt.dataset()
    assert np.array_equal(X, X2) and np.array_equal(y, y2)

    jl = tmp_path / "conv.jsonl"
    log.export_jsonl(str(jl))
    import json

    lines = [json.loads(ln) for ln in jl.read_text().splitlines()]
    assert sum(ln["type"] == "round" for ln in lines) == log.count
    assert sum(ln["type"] == "label" for ln in lines) == len(log.labels)


def test_convergence_ring_overflow_drops_oldest():
    class Lanes:
        pass

    def state_for(qid):
        st = Lanes()
        st.dists = np.array([[1.0, 2.0]])
        st.ids = np.array([[qid, qid + 1]])
        st.stable = np.array([1])
        st.t = np.array([2])
        st.rounds = np.array([3])
        st.done = np.array([False])
        st.evaluated = np.array([[True, False]])
        return st

    log = ConvergenceLog(capacity=4)
    for i in range(10):
        log.record_lanes([i], state_for(i), k=2)
    assert log.count == 4 and log.dropped == 6
    recs = log.to_arrays()
    assert recs["qid"].tolist() == [6, 7, 8, 9]    # oldest dropped
    log.finalize_lanes(range(10), [5] * 10)
    X, y, _ = log.dataset()
    assert len(y) == 4                             # labels outlive records
    with pytest.raises(ValueError):
        ConvergenceLog(capacity=0)
