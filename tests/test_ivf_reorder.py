"""IVF-PQ baseline behaviour + graph reordering correctness."""
import numpy as np
import pytest

from repro.configs.base import DatasetConfig, GraphConfig, PQConfig
from repro.core import recall_at_k
from repro.core.dataset import make_dataset
from repro.core.graph import build_graph
from repro.core.ivf import build_ivf, search_ivf
from repro.core.reorder import reorder_graph, remap_ground_truth


@pytest.fixture(scope="module")
def ds():
    return make_dataset(DatasetConfig(name="sift-like", num_base=1500,
                                      num_queries=24, dim=64,
                                      num_clusters=12, seed=0))


def test_ivf_recall_monotone_in_nprobe(ds):
    idx = build_ivf(ds.base, PQConfig(num_subvectors=16, num_centroids=64,
                                      kmeans_iters=5), ds.metric, nlist=32)
    recalls = []
    for nprobe in (1, 4, 16):
        ids, _, _ = search_ivf(idx, ds.queries, 10, nprobe=nprobe)
        recalls.append(recall_at_k(ids, ds.gt, 10))
    assert recalls[0] <= recalls[1] <= recalls[2] + 1e-9
    assert recalls[-1] > 0.3


def test_reordering_preserves_graph_semantics(ds):
    g = build_graph(ds.base, GraphConfig(max_degree=16, build_list_size=32),
                    ds.metric)
    n = g.num_vertices
    freq = np.random.default_rng(0).integers(0, 50, n)
    g2, reord = reorder_graph(g, freq, hot_fraction=0.03)
    # permutation is a bijection
    assert sorted(reord.perm.tolist()) == list(range(n))
    # entry point is the hottest id
    assert g2.entry_point == 0
    # edges are preserved under the relabeling
    for old_v in range(0, n, max(n // 40, 1)):
        new_v = reord.perm[old_v]
        old_edges = set(g.adjacency[old_v, : g.degrees[old_v]].tolist())
        new_edges = set(g2.adjacency[new_v, : g2.degrees[new_v]].tolist())
        assert {int(reord.perm[e]) for e in old_edges} == new_edges
    # ground-truth remap keeps recall vs permuted base exact
    gt2 = remap_ground_truth(reord, ds.gt)
    from repro.core.dataset import exact_knn
    base2 = ds.base[reord.inv]
    gt_direct = exact_knn(ds.queries, base2, 10, ds.metric)
    assert (gt2[:, :10] == gt_direct).mean() > 0.99


def test_system_end_to_end(tiny_index):
    """Deliverable (c) integration: index -> search -> NAND projection."""
    import numpy as np
    from repro.core import search
    from repro.nand.simulator import simulate, trace_from_search_result

    idx = tiny_index
    res = search(idx.corpus(), idx.dataset.queries, idx.config.search,
                 idx.dataset.metric)
    rec = recall_at_k(np.asarray(res.ids), idx.dataset.gt, 10)
    assert rec > 0.8
    tr = trace_from_search_result(
        res, dim=idx.dataset.dim, r_degree=idx.graph.max_degree,
        index_bits=idx.gap.bit_width, pq_bits=idx.codebook.num_subvectors * 8,
        metric=idx.dataset.metric)
    r = simulate(tr)
    assert r.qps > 1e4 and r.qps_per_watt > 1e3
    assert 0 < r.core_utilization < 1
