"""Launch-layer integration: lower_cell compiles a full-size architecture on
a small host-device mesh and produces a complete roofline record. Runs in a
subprocess (device count is locked at first jax init)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs import SHAPES
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import lower_cell

mesh = make_mesh((2, 4), ("data", "model"))
for shape_name in ("train_4k", "decode_32k"):
    rec = lower_cell("stablelm-1.6b", SHAPES[shape_name], mesh,
                     microbatches=4 if shape_name == "train_4k" else None)
    assert rec["status"] == "ok", rec
    rl = rec["roofline"]
    assert rl["flops"] > 0 and rl["coll_bytes"] >= 0
    assert rl["bottleneck"] in ("compute", "memory", "collective")
    assert 0 < rl["useful_ratio"] < 1.5
    assert rec["memory"].get("temp_size_in_bytes", 0) > 0
    print(shape_name, "ok", rl["bottleneck"])
print("OK")
"""


@pytest.mark.slow
def test_lower_cell_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "OK" in out.stdout
