"""Query-plan layer: planner strategy choice, the Searcher facade, and the
legacy-equivalence regression suite — the contract that makes the API
redesign safe: every legacy entry point must produce bit-identical
(ids, dists) to the equivalent ``Searcher.search(SearchRequest)`` call
across the beam/filter/shard/stream matrix."""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.configs.base import FilterConfig, PlanConfig, SearchConfig
from repro.filter import FilterSpec, random_attributes
from repro.plan import (
    PlanConfig as PlanConfigReexport,
    QueryPlan,
    SearchRequest,
    SearchStats,
    Searcher,
    validate_attribute_store,
)


@pytest.fixture(scope="module")
def tiny_store(tiny_index):
    return random_attributes(tiny_index.dataset.num_base,
                             {"category": 8, "price": 1000}, seed=7)


# the spec selectivities hit both filtered regimes: ~0.5 -> masked
# traversal, ~0.005 -> bitmap PQ scan (brute_force_selectivity = 0.02)
SPEC_MODERATE = FilterSpec.range("price", 0, 499)
SPEC_SHARP = FilterSpec.range("price", 0, 4)


def _legacy(callable_, *args, **kwargs):
    """Run a deprecated entry point, asserting it warns as documented.
    Warnings are deduplicated per entry point per process, so re-arm them
    first — each equivalence cell must see its own warning."""
    from repro.plan.searcher import reset_legacy_warnings

    reset_legacy_warnings()
    with pytest.warns(DeprecationWarning):
        return callable_(*args, **kwargs)


def test_legacy_warning_dedup(tiny_index):
    """A hammered legacy entry point warns once per process, not per call."""
    from repro.core import search as legacy_search
    from repro.plan.searcher import reset_legacy_warnings

    corpus = tiny_index.corpus()
    cfg = tiny_index.config.search
    q = tiny_index.dataset.queries[:2]
    reset_legacy_warnings()
    with pytest.warns(DeprecationWarning):
        legacy_search(corpus, q, cfg)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        legacy_search(corpus, q, cfg)      # second call: silent
    reset_legacy_warnings()
    with pytest.warns(DeprecationWarning):
        legacy_search(corpus, q, cfg)      # re-armed


# ---------------------------------------------------------------------------
# Equivalence matrix: {beam E in {1,4}} x {filtered, unfiltered}
#                     x {tiled, flat} x {static, mutable}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("beam", [1, 4])
@pytest.mark.parametrize("filtered", [False, True])
@pytest.mark.parametrize("tiled", [False, True])
@pytest.mark.parametrize("mutable", [False, True])
def test_planner_matches_legacy_paths(tiny_index, tiny_store, beam,
                                      filtered, tiled, mutable):
    """Each cell: the facade's (ids, dists) are bit-identical to the legacy
    entry point serving that cell (core.search / filter.filtered_search /
    shard.sharded_search / stream.search_merged)."""
    from repro.core import graph_search
    from repro.filter import adapt_search_cfg, tile_node_masks
    from repro.shard.search import sharded_search_kernel
    from repro.stream import MutableIndex
    from repro.stream.searcher import merged_search_kernel

    idx = tiny_index
    q = idx.dataset.queries[:8]
    cfg = dataclasses.replace(idx.config.search, beam_width=beam)
    spec = SPEC_MODERATE if filtered else None
    mask = tiny_store.mask(SPEC_MODERATE)
    fcfg = FilterConfig()
    n_tiles = 2 if tiled else 1

    if mutable:
        # fresh store per cell: streaming inserts append rows, and the
        # module-scoped tiny_store must keep matching the frozen corpus
        mut_store = random_attributes(idx.dataset.num_base,
                                      {"category": 8, "price": 1000}, seed=7)
        mask = mut_store.mask(SPEC_MODERATE)
        mut = MutableIndex(idx, attributes=mut_store)
        if tiled:
            mut.set_num_tiles(2, "hash")
        v = np.asarray(q[0]) + 1e-4
        mut.insert(v, attrs={"category": 1, "price": 250})
        mut.delete(3)
        legacy = merged_search_kernel(mut, q, cfg, filter_spec=spec)
        legacy_ids, legacy_dists = legacy.ids, legacy.dists
        s = Searcher.open(mut, cfg=cfg)
    elif tiled:
        s = Searcher.open(idx, cfg=cfg, num_tiles=2, shard_policy="hash",
                          attributes=tiny_store if filtered else None)
        if filtered:
            # the legacy tiled-filtered path: caller-adapted config +
            # per-tile mask slices into sharded_search
            eff = adapt_search_cfg(cfg, float(mask.mean()), fcfg)
            node_masks = tile_node_masks(s.tiled.tile_ids, mask)
            legacy = sharded_search_kernel(s.tiled, q, eff,
                                           idx.dataset.metric,
                                           node_masks=node_masks)
        else:
            legacy = sharded_search_kernel(s.tiled, q, cfg,
                                           idx.dataset.metric)
        legacy_ids = np.asarray(legacy.ids)
        legacy_dists = np.asarray(legacy.dists)
    else:
        s = Searcher.open(idx, cfg=cfg,
                          attributes=tiny_store if filtered else None)
        if filtered:
            # legacy flat-filtered semantics == filtered_search: adapted
            # config + masked traversal (selectivity ~0.5 -> traversal)
            eff = adapt_search_cfg(cfg, float(mask.mean()), fcfg)
            import jax.numpy as jnp

            legacy = graph_search(idx.corpus(), q, eff, idx.dataset.metric,
                                  node_mask=jnp.asarray(mask))
        else:
            legacy = graph_search(idx.corpus(), q, cfg, idx.dataset.metric)
        legacy_ids = np.asarray(legacy.ids)
        legacy_dists = np.asarray(legacy.dists)

    res = s.search(SearchRequest(queries=q, filter=spec))
    np.testing.assert_array_equal(res.ids, legacy_ids)
    np.testing.assert_array_equal(res.dists, legacy_dists)
    # the plan records what actually ran
    assert res.plan.cfg.beam_width == beam
    expect_kind = "merged" if mutable else ("tiled" if tiled else "flat")
    assert res.plan.kind == expect_kind
    assert res.stats.num_tiles == n_tiles
    assert res.stats.kind == expect_kind
    if filtered:
        assert res.plan.spec == spec


# ---------------------------------------------------------------------------
# The five deprecated wrappers delegate (and warn)
# ---------------------------------------------------------------------------

def test_wrapper_core_search_delegates(tiny_index):
    from repro.core import graph_search, search

    idx = tiny_index
    q = idx.dataset.queries[:4]
    legacy = _legacy(search, idx.corpus(), q, idx.config.search,
                     idx.dataset.metric)
    direct = graph_search(idx.corpus(), q, idx.config.search,
                          idx.dataset.metric)
    np.testing.assert_array_equal(np.asarray(legacy.ids),
                                  np.asarray(direct.ids))
    # counters survive the wrapper (it returns the raw kernel result)
    assert (np.asarray(legacy.n_hops) == np.asarray(direct.n_hops)).all()


def test_wrapper_core_search_node_mask(tiny_index, tiny_store):
    """core.search(node_mask=...) applies the mask VERBATIM (no selectivity
    adaptation) — the wrapper must preserve that semantics."""
    import jax.numpy as jnp

    from repro.core import graph_search, search

    idx = tiny_index
    q = idx.dataset.queries[:4]
    mask = tiny_store.mask(SPEC_MODERATE)
    legacy = _legacy(search, idx.corpus(), q, idx.config.search,
                     idx.dataset.metric, node_mask=jnp.asarray(mask))
    direct = graph_search(idx.corpus(), q, idx.config.search,
                          idx.dataset.metric, node_mask=jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(legacy.ids),
                                  np.asarray(direct.ids))


def test_wrapper_filtered_search_delegates(tiny_index, tiny_store):
    from repro.filter import filtered_search

    idx = tiny_index
    q = idx.dataset.queries[:4]
    s = Searcher.open(idx, attributes=tiny_store)
    for spec, mode in ((SPEC_MODERATE, "traversal"), (SPEC_SHARP, "scan")):
        fres = _legacy(filtered_search, idx.corpus(), q,
                       tiny_store.mask(spec), idx.config.search,
                       idx.dataset.metric)
        assert fres.mode == mode
        res = s.search(SearchRequest(queries=q, filter=spec))
        np.testing.assert_array_equal(fres.ids, res.ids)
        np.testing.assert_array_equal(fres.dists, res.dists)


def test_wrapper_sharded_search_delegates(tiny_index):
    from repro.shard import partition_index, sharded_search
    from repro.shard.search import sharded_search_kernel

    idx = tiny_index
    q = idx.dataset.queries[:4]
    tiled, _ = partition_index(idx, 2, "hash")
    legacy = _legacy(sharded_search, tiled, q, idx.config.search,
                     idx.dataset.metric)
    direct = sharded_search_kernel(tiled, q, idx.config.search,
                                   idx.dataset.metric)
    np.testing.assert_array_equal(np.asarray(legacy.ids),
                                  np.asarray(direct.ids))
    assert legacy.per_tile.ids.shape[0] == 2


def test_wrapper_search_merged_delegates(tiny_index):
    from repro.stream import MutableIndex, search_merged
    from repro.stream.searcher import merged_search_kernel

    mut = MutableIndex(tiny_index)
    q = tiny_index.dataset.queries[:4]
    mut.insert(np.asarray(q[0]) + 1e-4)
    legacy = _legacy(search_merged, mut, q)
    direct = merged_search_kernel(mut, q)
    np.testing.assert_array_equal(legacy.ids, direct.ids)
    np.testing.assert_array_equal(legacy.dists, direct.dists)


# ---------------------------------------------------------------------------
# Planner strategy choice + plan caching
# ---------------------------------------------------------------------------

def test_planner_strategy_selection(tiny_index, tiny_store):
    s = Searcher.open(tiny_index, attributes=tiny_store)
    q = tiny_index.dataset.queries[:2]
    plan_m = s.plan(SearchRequest(queries=q, filter=SPEC_MODERATE))
    assert (plan_m.kind, plan_m.strategy) == ("flat", "masked")
    # masked traversal inflates the candidate list (selectivity-adapted cfg)
    assert plan_m.cfg.list_size > tiny_index.config.search.list_size
    plan_s = s.plan(SearchRequest(queries=q, filter=SPEC_SHARP))
    assert plan_s.strategy == "scan"
    assert plan_s.cfg.list_size == tiny_index.config.search.list_size
    plan_e = s.plan(SearchRequest(
        queries=q, filter=FilterSpec.eq("price", 10_000)))
    assert plan_e.strategy == "empty"
    assert s.search(SearchRequest(queries=q,
                                  filter=FilterSpec.eq("price", 10_000))
                    ).ids.max() == -1
    # all-pass spec normalizes to the unfiltered plan (same cache key)
    plan_all = s.plan(SearchRequest(queries=q, filter=FilterSpec()))
    plan_none = s.plan(SearchRequest(queries=q))
    assert plan_all.cache_key == plan_none.cache_key


def test_plan_cache_hits(tiny_index, tiny_store):
    s = Searcher.open(tiny_index, attributes=tiny_store)
    q = tiny_index.dataset.queries[0]
    for _ in range(5):
        s.plan(SearchRequest(queries=q, filter=SPEC_MODERATE))
        s.plan(SearchRequest(queries=q))
    st = s.plan_cache_stats()
    assert st["plan_cache_misses"] == 2
    assert st["plan_cache_hits"] == 8
    # distinct per-request overrides are distinct plans
    s.plan(SearchRequest(queries=q, overrides={"beam_width": 4}))
    assert s.plan_cache_stats()["plan_cache_misses"] == 3


def test_request_overrides_and_k(tiny_index):
    s = Searcher.open(tiny_index)
    q = tiny_index.dataset.queries[:4]
    res = s.search(SearchRequest(queries=q, k=3,
                                 overrides={"beam_width": 4}))
    assert res.ids.shape == (4, 3)
    assert res.plan.cfg.k == 3 and res.plan.cfg.beam_width == 4
    assert res.stats.k == 3 and res.stats.beam_width == 4


def test_search_stats_as_dict(tiny_index):
    s = Searcher.open(tiny_index)
    res = s.search(SearchRequest(queries=tiny_index.dataset.queries[:4]))
    d = res.stats.as_dict()
    assert isinstance(d, dict)
    assert d["kind"] == "flat" and d["strategy"] == "none"
    assert d["hops"] > 0 and d["rounds"] > 0
    assert set(d) >= {"queries", "k", "selectivity", "pq", "acc",
                      "hot_hops", "free_pq", "delta_candidates",
                      "beam_width", "num_tiles"}


def test_engine_stats_derived_from_dataclass(tiny_index):
    from repro.serve.engine import EngineStats, ServingEngine

    eng = ServingEngine(tiny_index, batch_size=4, flush_us=0.0)
    assert isinstance(eng._stats, EngineStats)
    for qq in tiny_index.dataset.queries[:4]:
        eng.submit(qq)
    eng.drain()
    d = eng.stats
    assert d["batches"] == 1 and d["queries"] == 4
    # plan-cache counters surface through the dict view (merged from the
    # planner at read time — they are not EngineStats fields)
    assert d["plan_cache_misses"] >= 1
    assert d["plan_cache_hits"] >= 3
    assert set(d) == set(EngineStats().as_dict()) | {
        "plan_cache_hits", "plan_cache_misses"}, "dict view drifted"


def test_validate_attribute_store_shared_helper(tiny_index, tiny_store):
    from repro.serve.engine import ServingEngine

    short = random_attributes(10, {"price": 10}, seed=0)
    with pytest.raises(ValueError, match="attribute store has 10 rows"):
        Searcher.open(tiny_index, attributes=short)
    with pytest.raises(ValueError, match="attribute store has 10 rows"):
        ServingEngine(tiny_index, batch_size=4, attributes=short)
    assert validate_attribute_store(None, 123, "x") is None
    assert validate_attribute_store(tiny_store,
                                    tiny_index.dataset.num_base,
                                    "index") is tiny_store


def test_plan_config_collapses_engine_kwargs(tiny_index):
    """PlanConfig is the one knob object: an engine built from it matches
    one built from the legacy per-feature kwargs."""
    from repro.serve.engine import ServingEngine

    assert PlanConfigReexport is PlanConfig
    pc = PlanConfig(num_tiles=2, shard_policy="hash", beam_width=4)
    e1 = ServingEngine(tiny_index, batch_size=4, flush_us=0.0, plan=pc)
    e2 = ServingEngine(tiny_index, batch_size=4, flush_us=0.0, num_tiles=2,
                       shard_policy="hash", beam_width=4)
    assert e1.num_tiles == e2.num_tiles == 2
    assert e1.cfg == e2.cfg and e1.cfg.beam_width == 4
    q = tiny_index.dataset.queries[:4]
    r1 = [e1.submit(qq) for qq in q]
    r2 = [e2.submit(qq) for qq in q]
    e1.drain(), e2.drain()
    np.testing.assert_array_equal(
        np.stack([e1.done[r].ids for r in r1]),
        np.stack([e2.done[r].ids for r in r2]),
    )


def test_trace_from_plan_execution_matches_legacy(tiny_index, tiny_store):
    from repro.nand.simulator import (
        trace_from_plan_execution, trace_from_search_result,
    )

    idx = tiny_index
    geo = dict(dim=idx.dataset.dim, r_degree=idx.graph.adjacency.shape[1],
               index_bits=idx.gap.bit_width if idx.gap else 32,
               pq_bits=8 * idx.codes.shape[1], metric=idx.dataset.metric)
    s = Searcher.open(idx, attributes=tiny_store)
    q = idx.dataset.queries[:4]
    res = s.search(SearchRequest(queries=q))
    assert trace_from_plan_execution(res, index=idx) == \
        trace_from_search_result(res.raw, **geo)
    # filtered: mode/selectivity/attr_bits come off the plan
    fres = s.search(SearchRequest(queries=q, filter=SPEC_MODERATE))
    t = trace_from_plan_execution(fres, index=idx)
    assert t.filter_mode == "pushdown"
    assert t.attr_bits == tiny_store.attr_bits
    assert 0.0 < t.filter_selectivity < 1.0
    assert t.filter_selectivity == pytest.approx(fres.plan.selectivity)


def test_queryplan_hashable_cache_key(tiny_index, tiny_store):
    s = Searcher.open(tiny_index, attributes=tiny_store)
    q = tiny_index.dataset.queries[0]
    p1 = s.plan(SearchRequest(queries=q, filter=SPEC_MODERATE))
    p2 = s.plan(SearchRequest(queries=q, filter=SPEC_MODERATE))
    assert isinstance(p1, QueryPlan)
    assert hash(p1.cache_key) == hash(p2.cache_key)
    assert p1.cache_key != s.plan(SearchRequest(queries=q)).cache_key


def test_distributed_plan_single_device(tiny_index):
    """The distributed spine through the facade on a 1x1 mesh is
    bit-identical to the legacy distributed_search wrapper and consistent
    with the flat path's result sets."""
    import jax
    from jax.sharding import Mesh

    from repro.core import graph_search
    from repro.core.distributed import distributed_search, shard_corpus

    idx = tiny_index
    cfg = idx.config.search
    q = idx.dataset.queries[:4]
    sc = shard_corpus(idx.graph.adjacency, idx.codes, idx.dataset.base,
                      idx.codebook.centroids, int(idx.graph.entry_point),
                      idx.hot_count, num_shards=1)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    legacy_ids, legacy_d = _legacy(distributed_search, sc, q, cfg,
                                   idx.dataset.metric, mesh=mesh)
    s = Searcher.open(sc, cfg=cfg, metric=idx.dataset.metric, mesh=mesh)
    res = s.search(SearchRequest(queries=q))
    assert res.plan.kind == "distributed"
    np.testing.assert_array_equal(res.ids, np.asarray(legacy_ids))
    np.testing.assert_array_equal(res.dists, np.asarray(legacy_d))
    flat = graph_search(idx.corpus(), q, cfg, idx.dataset.metric)
    assert (np.sort(res.ids, 1) == np.sort(np.asarray(flat.ids), 1)).mean() \
        >= 0.9


def test_tenant_isolated_in_plan_key(tiny_index):
    """The tenant slot is part of the batching identity: two tenants never
    share a plan cache key (the multi-tenancy roadmap contract)."""
    s = Searcher.open(tiny_index)
    q = tiny_index.dataset.queries[0]
    pa = s.plan(SearchRequest(queries=q, tenant="a"))
    pb = s.plan(SearchRequest(queries=q, tenant="b"))
    assert pa.tenant == "a" and pb.tenant == "b"
    assert pa.cache_key != pb.cache_key


def test_merged_scan_billing_not_discounted(tiny_index):
    """Regression: a sharp filter on a mutable index routes the base
    through the bitmap scan, whose candidate stream is the passing subset
    itself — the plan-derived pushdown billing must not discount it by the
    selectivity (the flat path already special-cases this)."""
    from repro.nand.simulator import trace_from_plan_execution
    from repro.stream import MutableIndex

    store = random_attributes(tiny_index.dataset.num_base,
                              {"category": 8, "price": 1000}, seed=7)
    mut = MutableIndex(tiny_index, attributes=store)
    s = Searcher.open(mut)
    q = tiny_index.dataset.queries[:4]
    res = s.search(SearchRequest(queries=q, filter=SPEC_SHARP))
    assert res.raw.base_mode == "scan"
    assert trace_from_plan_execution(res, index=mut).filter_selectivity \
        == 1.0
    # the traversal regime keeps the measured passing fraction
    res2 = s.search(SearchRequest(queries=q, filter=SPEC_MODERATE))
    assert res2.raw.base_mode == "traversal"
    t2 = trace_from_plan_execution(res2, index=mut)
    assert 0.0 < t2.filter_selectivity < 1.0


def test_typed_request_filter_field(tiny_index):
    """serve.Request.filter is typed Optional[FilterSpec] (satellite)."""
    import typing

    from repro.serve.engine import Request

    hints = typing.get_type_hints(Request)
    assert hints["filter"] == typing.Optional[FilterSpec]


# ---------------------------------------------------------------------------
# Round-step equivalence: the continuous-batching spine.  Iterating the
# exported step kernels to quiescence must be BIT-identical to the
# lax.while_loop executor across {beam 1,4} x {unfiltered, masked} x
# {flat, merged} — the contract that lets the iteration-level scheduler
# serve the same results as a batch flush.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("beam", [1, 4])
@pytest.mark.parametrize("filtered", [False, True])
@pytest.mark.parametrize("mutable", [False, True])
def test_round_session_matches_batch_execute(tiny_index, tiny_store, beam,
                                             filtered, mutable):
    """RoundSession init/step*/finalize/complete == Searcher.search for the
    same plan, field for field."""
    idx = tiny_index
    q = idx.dataset.queries[:8]
    cfg = dataclasses.replace(idx.config.search, beam_width=beam)
    spec = SPEC_MODERATE if filtered else None

    if mutable:
        from repro.stream import MutableIndex

        mut_store = random_attributes(idx.dataset.num_base,
                                      {"category": 8, "price": 1000}, seed=7)
        mut = MutableIndex(idx, attributes=mut_store)
        v = np.asarray(q[0]) + 1e-4
        mut.insert(v, attrs={"category": 1, "price": 250})
        mut.delete(3)
        s = Searcher.open(mut, cfg=cfg)
    else:
        s = Searcher.open(idx, cfg=cfg,
                          attributes=tiny_store if filtered else None)

    batch = s.search(SearchRequest(queries=q, filter=spec))
    plan = s.plan(SearchRequest(queries=q[:1], filter=spec))
    sess = s.planner.round_session(plan)
    assert sess is not None, f"plan {plan.kind}/{plan.strategy} not steppable"

    state = sess.init(q)
    guard = cfg.max_rounds + 2
    while sess.active(state).any():
        state = sess.step(state)
        guard -= 1
        assert guard > 0, "round stepping failed to quiesce"
    res = sess.complete(q, sess.finalize(state))

    np.testing.assert_array_equal(np.asarray(res.ids),
                                  np.asarray(batch.ids))
    np.testing.assert_array_equal(np.asarray(res.dists),
                                  np.asarray(batch.dists))


@pytest.mark.parametrize("beam", [1, 4])
@pytest.mark.parametrize("masked", [False, True])
def test_core_stepped_matches_while_loop(tiny_index, tiny_store, beam,
                                         masked):
    """core.search.graph_search_stepped (init/step/finalize kernels driven
    from the host) is bit-identical to graph_search's lax.while_loop on
    every SearchResult field."""
    from repro.core.search import graph_search, graph_search_stepped

    idx = tiny_index
    corpus = idx.corpus()
    q = idx.dataset.queries[:6]
    cfg = dataclasses.replace(idx.config.search, beam_width=beam)
    mask = np.asarray(tiny_store.mask(SPEC_MODERATE)) if masked else None

    a = graph_search(corpus, q, cfg, idx.dataset.metric, node_mask=mask)
    b = graph_search_stepped(corpus, q, cfg, idx.dataset.metric,
                             node_mask=mask)
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"field {f} diverged between while_loop and stepped",
        )


def test_round_session_none_for_scan_plans(tiny_index, tiny_store):
    """Bitmap-scan plans have no per-round structure: the planner declines a
    session and callers fall back to whole-batch execution."""
    s = Searcher.open(tiny_index, attributes=tiny_store)
    plan = s.plan(SearchRequest(queries=tiny_index.dataset.queries[:1],
                                filter=SPEC_SHARP))
    assert plan.strategy == "scan"
    assert s.planner.round_session(plan) is None


def test_step_is_noop_on_quiesced_lanes(tiny_index):
    """Stepping a fully-done state changes NO state leaf — free slots in a
    continuous pool never burn rounds or drift."""
    import jax

    s = Searcher.open(tiny_index)
    plan = s.plan(SearchRequest(queries=tiny_index.dataset.queries[:1]))
    sess = s.planner.round_session(plan)
    state = sess.init(tiny_index.dataset.queries[:4])
    guard = tiny_index.config.search.max_rounds + 2
    while sess.active(state).any():
        state = sess.step(state)
        guard -= 1
        assert guard > 0
    again = sess.step(state)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(again)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
