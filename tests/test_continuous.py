"""Continuous (iteration-level) serving engine behaviour.

The bit-identity of the round-step kernels themselves lives in
``test_plan.py`` (the equivalence matrix); this file covers the SCHEDULER:
slot pools, immediate retirement, refill, drain bounds, streaming
consolidation safety, the deferred-plan recache, and the observability
surface the continuous path adds."""
import numpy as np
import pytest

from repro.serve.engine import ServingEngine


def test_continuous_matches_batch_results(tiny_index):
    """Same queries, same results (bit-identical ids/dists), regardless of
    which scheduler served them."""
    q = tiny_index.dataset.queries[:13]
    cont = ServingEngine(tiny_index, batch_size=8, continuous=True, slots=4)
    rc = [cont.submit(qq) for qq in q]
    cont.drain()
    batch = ServingEngine(tiny_index, batch_size=8, flush_us=0.0)
    rb = [batch.submit(qq) for qq in q]
    batch.drain()
    for a, b in zip(rc, rb):
        np.testing.assert_array_equal(cont.done[a].ids, batch.done[b].ids)
        np.testing.assert_array_equal(cont.done[a].dists,
                                      batch.done[b].dists)
    assert cont.stats["retired"] == len(q)
    assert cont.stats["queries"] == len(q)
    assert cont.stats["batches"] == 0          # never fell back


def test_lanes_retire_across_ticks_not_at_barrier(tiny_index):
    """Iteration-level scheduling: lanes finish on THEIR round, so a pool's
    completions spread over multiple ticks instead of arriving as one
    whole-batch barrier."""
    q = tiny_index.dataset.queries[:12]
    eng = ServingEngine(tiny_index, batch_size=8, continuous=True, slots=12)
    for qq in q:
        eng.submit(qq)
    retire_ticks = []
    guard = 0
    while eng.queue or eng.inflight():
        done = eng.step(force=True)
        if done:
            retire_ticks.append(len(done))
        guard += 1
        assert guard < 500
    assert sum(retire_ticks) == len(q)
    assert len(retire_ticks) > 1, (
        "all lanes retired in one tick — scheduler degenerated to a barrier"
    )


def test_slot_refill_serves_backlog(tiny_index):
    """A pool smaller than the workload turns over: freed slots re-admit
    queued requests until the backlog drains, and in-flight lanes never
    exceed the pool size."""
    q = tiny_index.dataset.queries
    eng = ServingEngine(tiny_index, batch_size=8, continuous=True, slots=3)
    rids = [eng.submit(qq) for qq in np.tile(q, (2, 1))[:20]]
    guard = 0
    while eng.queue or eng.inflight():
        eng.step(force=True)
        assert eng.inflight() <= 3
        guard += 1
        assert guard < 2000
    assert all(r in eng.done for r in rids)
    assert eng.stats["retired"] == 20


def test_drain_guard_raises_instead_of_spinning(tiny_index):
    eng = ServingEngine(tiny_index, batch_size=8, continuous=True, slots=4)
    eng.submit(tiny_index.dataset.queries[0])
    with pytest.raises(RuntimeError, match="drain"):
        eng.drain(max_steps=0)
    eng.drain()                                # recovers with a real budget
    assert eng.stats["retired"] == 1


def test_deferred_plan_recached_on_flush(tiny_index):
    """Satellite: when flush-time planning succeeds for a request whose plan
    was deferred, the plan is cached back onto it AND every queued
    same-filter request — later flushes never re-plan them."""
    q = tiny_index.dataset.queries[:6]
    eng = ServingEngine(tiny_index, batch_size=4, flush_us=0.0)
    for qq in q:
        eng.submit(qq)
    for r in eng.queue:
        r.plan = None                          # simulate deferred planning
    done = eng.step(force=True)                # flush replans the head once
    assert len(done) == 4
    assert all(r.plan is not None for r in done)
    # the two still-queued requests were recached from the head's plan
    assert all(r.plan is not None for r in eng.queue)
    plans = {id(r.plan) for r in list(eng.queue) + done}
    assert len(plans) == 1                     # one shared plan object
    eng.drain()
    assert eng.stats["queries"] == 6


def test_continuous_streaming_consolidation_safety(tiny_index):
    """Consolidation mid-flight: in-flight merged lanes complete against the
    old base BEFORE the rebuild, sessions reset, and post-consolidation
    submits serve correctly against the new id space."""
    from repro.stream import MutableIndex

    mut = MutableIndex(tiny_index)
    eng = ServingEngine(mut, batch_size=8, continuous=True, slots=4,
                        auto_consolidate=False)
    q = tiny_index.dataset.queries
    ext = eng.insert(np.asarray(q[0]) + 1e-4)
    eng.delete(3)
    rids = [eng.submit(qq) for qq in q[:6]]
    eng.step(force=True)                       # lanes now mid-traversal
    assert eng.inflight() > 0
    inflight = eng.inflight()
    eng.consolidate()                          # must complete lanes first
    assert eng.inflight() == 0
    # every in-flight lane retired against the OLD base; queued requests
    # stay queued and admit to fresh post-rebuild sessions
    assert sum(r in eng.done for r in rids) >= inflight
    assert eng.stats["consolidations"] == 1
    eng.drain()
    assert all(r in eng.done for r in rids)
    # deleted id never surfaces; the insert is findable after the rebuild
    for r in rids:
        assert 3 not in set(int(i) for i in eng.done[r].ids)
    r2 = eng.submit(q[0])
    eng.drain()
    assert ext in set(int(i) for i in eng.done[r2].ids)


def test_continuous_obs_surface(tiny_index):
    """The tick scheduler reports slot occupancy, per-lane rounds and NAND
    billing into the shared registry — and stays inside the recompile
    budget."""
    from repro.obs import Observability

    obs = Observability.on(nand_billing=True)
    eng = ServingEngine(tiny_index, batch_size=8, continuous=True, slots=4,
                        obs=obs)
    for qq in tiny_index.dataset.queries[:10]:
        eng.submit(qq)
    eng.drain()
    m = obs.metrics
    assert eng.stats["ticks"] > 0
    assert m.gauge_value("slot_occupancy", kind="flat",
                         strategy="none") is not None
    rounds = m.merged_histogram("rounds_in_flight")
    assert rounds is not None and rounds.count == 10
    assert rounds.mean > 1.0                   # real traversals, not no-ops
    lat = m.merged_histogram("request_latency_ms")
    assert lat is not None and lat.count == 10
    assert m.merged_histogram("nand_latency_us") is not None
    assert m.counter_total("unexpected_recompiles") == 0


def test_continuous_double_buffer_billing(tiny_index):
    """ServingEngine(nand=NandConfig(double_buffer=True)) bills a shorter
    modeled round than the sequential default for the same served work."""
    from repro.nand.device import NandConfig
    from repro.obs import Observability

    q = tiny_index.dataset.queries[:8]
    rounds = {}
    for db in (False, True):
        obs = Observability.on(nand_billing=True)
        eng = ServingEngine(tiny_index, batch_size=8, continuous=True,
                            slots=4, obs=obs,
                            nand=NandConfig(double_buffer=db))
        for qq in q:
            eng.submit(qq)
        eng.drain()
        m = obs.metrics
        rounds[db] = m.merged_histogram("nand_round_latency_us").mean
        saved = m.merged_histogram("nand_overlap_saved_us").mean
        assert (saved > 0.0) == db
    assert rounds[True] < rounds[False]


def test_continuous_non_steppable_plan_falls_back(tiny_index):
    """Plans without a round-steppable spine (bitmap scans) serve through
    the batch-flush path transparently."""
    from repro.filter import FilterSpec, random_attributes

    store = random_attributes(tiny_index.dataset.num_base,
                              {"category": 8, "price": 1000}, seed=7)
    eng = ServingEngine(tiny_index, batch_size=8, continuous=True, slots=4,
                        attributes=store, flush_us=0.0)
    sharp = FilterSpec.range("price", 0, 4)
    rids = [eng.submit(qq, filter=sharp)
            for qq in tiny_index.dataset.queries[:5]]
    eng.drain()
    assert all(r in eng.done for r in rids)
    assert eng.stats["fallback_batches"] >= 1
    assert eng.stats["retired"] == 0           # nothing took the tick path
    mask = np.asarray(store.mask(sharp))
    passing = set(np.flatnonzero(mask).tolist())
    for r in rids:
        got = [int(i) for i in eng.done[r].ids if i >= 0]
        assert set(got) <= passing
