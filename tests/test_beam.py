"""Beam-parallel traversal (SearchConfig.beam_width = E).

The contract: E=1 IS the pre-beam single-expansion engine (bit-identical
results and counters), the reference oracle pops the same E-wide beam (so
counter parity holds at every E), and E>1 trades a little extra frontier
work for ~E× fewer serial traversal rounds at iso-recall — which the NAND
model bills as plane-parallel page reads."""
import dataclasses

import numpy as np
import pytest

from repro.core import recall_at_k, search, search_reference
from repro.nand.simulator import WorkloadTrace, simulate, trace_from_search_result


def _run(idx, cfg):
    return search(idx.corpus(), idx.dataset.queries, cfg, idx.dataset.metric)


def _oracle(idx, cfg, i):
    return search_reference(
        idx.graph.adjacency, idx.graph.degrees, idx.codes,
        idx._search_base(), idx.codebook.centroids,
        idx.graph.entry_point, idx.dataset.queries[i], cfg,
        idx.dataset.metric, hot_count=idx.hot_count,
    )


def test_beam1_matches_single_expansion_oracle(tiny_index):
    """beam_width=1 reproduces the pre-beam single-expansion path exactly:
    with E=1 the oracle's loop IS the original Algorithm-1 transliteration
    (one pop per round), and the JAX engine must agree bit-for-bit on the
    result ids and every traversal counter. (`acc` is excluded: the JAX
    batch beta-rerank has always counted a handful more accurate distances
    than the oracle's incremental cache — a pre-beam divergence.)"""
    idx = tiny_index
    cfg = dataclasses.replace(idx.config.search, beam_width=1)
    res = _run(idx, cfg)
    for i in range(len(idx.dataset.queries)):
        rid, _, cnt = _oracle(idx, cfg, i)
        assert set(np.asarray(res.ids[i]).tolist()) == set(rid.tolist())
        assert int(res.n_hops[i]) == cnt["hops"]
        assert int(res.n_pq[i]) == cnt["pq"]
        assert int(res.n_hot_hops[i]) == cnt["hot"]
        assert int(res.n_free_pq[i]) == cnt["free"]
        assert int(res.rounds[i]) == cnt["rounds"]
        assert int(res.n_hops[i]) == int(res.rounds[i])  # 1 expansion/round


def test_beam_oracle_counter_parity_wide(tiny_index):
    """The oracle grows the same E-wide pop: counters stay bit-comparable
    at E=4 (same wavefront, same beam-order dedup attribution)."""
    idx = tiny_index
    cfg = dataclasses.replace(idx.config.search, beam_width=4)
    res = _run(idx, cfg)
    for i in range(8):
        rid, _, cnt = _oracle(idx, cfg, i)
        assert int(res.n_hops[i]) == cnt["hops"]
        assert int(res.n_pq[i]) == cnt["pq"]
        assert int(res.n_hot_hops[i]) == cnt["hot"]
        assert int(res.n_free_pq[i]) == cnt["free"]
        assert int(res.rounds[i]) == cnt["rounds"]
        assert set(np.asarray(res.ids[i]).tolist()) == set(rid.tolist())


def test_beam_cuts_rounds_at_iso_recall(tiny_index):
    """The tentpole claim: E=4 reduces mean traversal rounds >= 1.5x with
    recall within 0.01 of the E=1 baseline."""
    idx = tiny_index
    r1 = _run(idx, dataclasses.replace(idx.config.search, beam_width=1))
    r4 = _run(idx, dataclasses.replace(idx.config.search, beam_width=4))
    rounds1 = float(np.asarray(r1.rounds).mean())
    rounds4 = float(np.asarray(r4.rounds).mean())
    assert rounds1 / rounds4 >= 1.5, f"round speedup {rounds1 / rounds4:.2f}x"
    rec1 = recall_at_k(np.asarray(r1.ids), idx.dataset.gt, 10)
    rec4 = recall_at_k(np.asarray(r4.ids), idx.dataset.gt, 10)
    assert rec4 >= rec1 - 0.01, f"recall {rec4:.4f} vs E=1 {rec1:.4f}"
    # rounds-vs-hops separation: E expansions per round, up to the beam cap
    hops4 = float(np.asarray(r4.n_hops).mean())
    assert 1.0 < hops4 / rounds4 <= 4.0


def test_beam_pallas_path_equivalence(tiny_index):
    """The (L + E*R) merge through the Pallas bitonic network agrees with
    the jnp path at E>1."""
    idx = tiny_index
    cfg = dataclasses.replace(idx.config.search, list_size=32, t_init=8,
                              beam_width=4)
    plain = _run(idx, cfg)
    pall = _run(idx, dataclasses.replace(cfg, use_pallas=True))
    a = np.sort(np.asarray(plain.ids), 1)
    b = np.sort(np.asarray(pall.ids), 1)
    assert (a == b).mean() > 0.95


def test_nand_bills_beam_as_plane_parallel_reads(tiny_index):
    """The simulator divides the serial pointer-chase by min(E, n_planes):
    the measured E=4 trace must be faster than the same counters billed at
    beam_width=1, and trace_from_search_result derives the realized beam
    from the hops/rounds separation."""
    idx = tiny_index
    res = _run(idx, dataclasses.replace(idx.config.search, beam_width=4))
    kw = dict(dim=idx.dataset.dim, r_degree=idx.graph.max_degree,
              index_bits=32, pq_bits=idx.codebook.num_subvectors * 8)
    t4 = trace_from_search_result(res, **kw)
    assert 1.0 < t4.beam_width <= 4.0          # realized hops/rounds
    t1 = dataclasses.replace(t4, beam_width=1.0)
    sim4, sim1 = simulate(t4), simulate(t1)
    assert sim4.latency_us < sim1.latency_us
    assert sim4.qps > sim1.qps
    # explicit override wins over the derived value
    t_exp = trace_from_search_result(res, **kw, beam_width=4)
    assert t_exp.beam_width == 4.0
    # the plane count caps the billed parallelism
    t_wide = dataclasses.replace(t4, beam_width=64.0)
    from repro.nand.device import NandConfig

    nand = NandConfig()
    sim_wide = simulate(t_wide, nand)
    t_cap = dataclasses.replace(t4, beam_width=float(nand.n_planes))
    assert sim_wide.latency_us == pytest.approx(simulate(t_cap, nand).latency_us)


def test_beam_inherited_by_sharded_and_merged_paths(tiny_index):
    """shard.sharded_search and stream.search_merged pick beam_width up from
    the config untouched — per-tile/base rounds shrink the same way."""
    from repro.shard import sharded_search
    from repro.stream.mutable import MutableIndex
    from repro.stream.searcher import search_merged

    idx = tiny_index
    q = idx.dataset.queries[:8]
    cfg1 = dataclasses.replace(idx.config.search, beam_width=1)
    cfg4 = dataclasses.replace(idx.config.search, beam_width=4)

    tiled, _ = idx.sharded_corpus(2, "hash")
    s1 = sharded_search(tiled, q, cfg1, idx.dataset.metric)
    s4 = sharded_search(tiled, q, cfg4, idx.dataset.metric)
    assert (np.asarray(s4.per_tile.rounds).mean()
            < np.asarray(s1.per_tile.rounds).mean())

    mut = MutableIndex(idx)
    mut.insert(idx.dataset.queries[0])
    m1 = search_merged(mut, q, cfg1)
    m4 = search_merged(mut, q, cfg4)
    assert m4.ids.dtype == np.int32
    assert (np.asarray(m4.base.rounds).mean()
            < np.asarray(m1.base.rounds).mean())
