"""Bloom filter: no false negatives (property), FPR near analytic bound."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import bloom


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=200,
                unique=True))
def test_no_false_negatives(ids):
    bits = bloom.bloom_init(1 << 14)
    arr = jnp.asarray(np.asarray(ids, np.int32))
    bits = bloom.insert(bits, arr, jnp.ones(len(ids), bool))
    assert bool(bloom.contains(bits, arr).all())


def test_masked_insert_not_present():
    bits = bloom.bloom_init(1 << 14)
    ids = jnp.arange(100, dtype=jnp.int32)
    mask = ids < 50
    bits = bloom.insert(bits, ids, mask)
    assert bool(bloom.contains(bits, ids[:50]).all())
    # unmasked half should mostly be absent (tiny FPR allowed)
    fp = float(bloom.contains(bits, ids[50:]).mean())
    assert fp < 0.05


def test_fpr_close_to_analytic():
    m_bits, k, n = 1 << 15, 8, 1000
    rng = np.random.default_rng(0)
    inserted = rng.choice(2**30, size=n, replace=False).astype(np.int32)
    probes = rng.choice(2**30, size=4000, replace=False).astype(np.int32)
    probes = np.setdiff1d(probes, inserted)
    bits = bloom.bloom_init(m_bits)
    bits = bloom.insert(bits, jnp.asarray(inserted), jnp.ones(n, bool), k)
    fpr = float(bloom.contains(bits, jnp.asarray(probes), k).mean())
    bound = bloom.false_positive_rate(m_bits, k, n)
    assert fpr <= max(5 * bound, 0.01), (fpr, bound)


def test_paper_design_point():
    """12kB SRAM + 8 hashes at 8000 insertions -> FPR < 0.02% (paper §IV-D).
    (The paper's arithmetic; our init uses a power-of-two 16 kB array.)"""
    assert bloom.false_positive_rate(12 * 1024 * 8, 8, 8000) < 0.02
