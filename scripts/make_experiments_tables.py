"""Regenerate the EXPERIMENTS.md roofline table from results/dryrun.json."""
import json
import sys


def main(path="results/dryrun.json"):
    with open(path) as f:
        r = json.load(f)
    rows = []
    for k, v in sorted(r.items()):
        arch, shape, mesh = k.split("|")
        if v["status"] == "skipped":
            rows.append((arch, shape, mesh, "—", "—", "—", "skip*", "—", "—"))
            continue
        if v["status"] != "ok":
            rows.append((arch, shape, mesh, "ERR", "", "", "", "", ""))
            continue
        rl = v["roofline"]
        peak = v.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9
        rows.append((
            arch, shape, mesh,
            f"{rl['compute_s']:.4f}", f"{rl['memory_s']:.4f}",
            f"{rl['collective_s']:.4f}", rl["bottleneck"],
            f"{rl['useful_ratio']:.2f}", f"{peak:.1f}",
        ))
    print("| arch | shape | mesh | compute_s | memory_s | collective_s | bottleneck | MODEL/HLO | peak GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for row in rows:
        print("| " + " | ".join(row) + " |")


if __name__ == "__main__":
    main(*sys.argv[1:])
