"""§III-E — gap-encoding compression across graph scales. Paper: 1M-100M
graphs need 20-26 bits -> >=19-37% index compression vs uniform 32-bit."""
from __future__ import annotations

import numpy as np

from repro.core.gap_encoding import gap_encode
from repro.configs.base import DatasetConfig, GraphConfig
from repro.core.dataset import make_dataset
from repro.core.graph import build_graph


def main(out=print) -> None:
    for n in (1000, 4000, 16000):
        ds = make_dataset(DatasetConfig(
            name="sift-like", num_base=n, num_queries=8, dim=64,
            num_clusters=32, cluster_std=0.35, seed=1))
        g = build_graph(ds.base, GraphConfig(max_degree=32,
                                             build_list_size=48), ds.metric)
        enc = gap_encode(g.adjacency)
        # round-trip check inline (sorted adjacency semantics)
        from repro.core.gap_encoding import gap_decode
        dec = gap_decode(enc)
        ok = bool((np.sort(g.adjacency.astype(np.int64), 1) == dec).all())
        out(f"gap/n{n},{0:.1f},bits={enc.bit_width};"
            f"compression={enc.compression_ratio:.2%};roundtrip={ok}")


if __name__ == "__main__":
    main()
