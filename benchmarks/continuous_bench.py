"""Continuous vs batch serving under open-loop load — the tail-latency
artifact for the iteration-level scheduler.

Protocol:

  1. measure the BATCH engine's saturation throughput closed-loop (deep
     backlog, full buckets — its best case);
  2. replay a Poisson (or bursty, ``--burst``) arrival schedule at 0.8x
     that saturation against both engines in real time
     (``benchmarks.arrivals.replay``): same queries, same arrival
     timestamps, latencies from the engines' own ``perf_counter``
     bookkeeping;
  3. write ``BENCH_continuous.json``: per-engine p50/p99 latency, recall@k,
     modeled NAND pJ/query, and the double-buffered channel's per-round
     latency vs the sequential billing the batch run uses.

The continuous engine admits a request the moment a slot frees and retires
every lane the round it quiesces, so under load its tail is bounded by its
own traversal length — while the batch engine's tail stacks flush-window
wait plus whole-batch occupancy of the kernel.  The headline number is the
p99 ratio; CI's smoke mode asserts the continuous engine never loses, the
full run asserts the >= 2x win the JSON records.

    PYTHONPATH=src python -m benchmarks.continuous_bench [--smoke]
        [--burst] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.arrivals import arrival_schedule, replay
from benchmarks.common import get_index, served_recall
from repro.nand.device import NandConfig
from repro.obs import Observability
from repro.serve import ServingEngine

DEFAULT_JSON = "BENCH_continuous.json"
BATCH = 16
SLOTS = 16
FLUSH_US = 20_000.0      # batch flush window under open-loop load


def _batch_saturation_qps(idx, q: np.ndarray, passes: int = 4) -> float:
    """Closed-loop ceiling of the batch engine: a deep backlog drained with
    full buckets and no flush-window idling."""
    eng = ServingEngine(idx, batch_size=BATCH, flush_us=0.0)
    for qq in q:
        eng.submit(qq)
    eng.drain()                                   # warm every bucket
    n = passes * len(q)
    t0 = time.perf_counter()
    for qq in np.tile(q, (passes, 1)):
        eng.submit(qq)
    eng.drain()
    return n / (time.perf_counter() - t0)


def _serve(idx, q, gt, k, arrivals, *, continuous: bool) -> dict:
    obs = Observability.on(nand_billing=True)
    if continuous:
        eng = ServingEngine(idx, batch_size=BATCH, continuous=True,
                            slots=SLOTS, obs=obs,
                            nand=NandConfig(double_buffer=True))
    else:
        eng = ServingEngine(idx, batch_size=BATCH, flush_us=FLUSH_US,
                            obs=obs)
    for qq in q[:2 * BATCH]:                      # warm serving-path shapes
        eng.submit(qq)
    eng.drain()
    t0 = time.perf_counter()
    rids = replay(eng, q, arrivals)
    wall = time.perf_counter() - t0
    lat = np.array([eng.done[r].latency_ms for r in rids])
    m = obs.metrics
    pj = m.merged_histogram("nand_pj_per_query")
    rnd = m.merged_histogram("nand_round_latency_us")
    sav = m.merged_histogram("nand_overlap_saved_us")
    return {
        "mode": "continuous" if continuous else "batch",
        "queries": len(rids),
        "p50_ms": float(np.percentile(lat, 50)),
        "p99_ms": float(np.percentile(lat, 99)),
        "mean_ms": float(lat.mean()),
        "achieved_qps": len(rids) / wall,
        "recall_at_k": served_recall(eng.done, rids, gt, k),
        "nand_pj_per_query": pj.mean if pj is not None else None,
        "nand_round_latency_us": rnd.mean if rnd is not None else None,
        "nand_overlap_saved_us": sav.mean if sav is not None else None,
        "ticks": int(eng.stats.get("ticks", 0)),
        "retired": int(eng.stats.get("retired", 0)),
        "batches": int(eng.stats["batches"]),
        "unexpected_recompiles": int(
            m.counter_total("unexpected_recompiles")),
    }


def main(out=print, smoke: bool = False, json_path: str | None = None,
         arrival: str = "poisson") -> None:
    idx = get_index("sift-like")
    q = np.asarray(idx.dataset.queries, np.float32)
    gt = np.asarray(idx.dataset.gt)
    k = min(10, gt.shape[1])

    sat = _batch_saturation_qps(idx, q, passes=2 if smoke else 4)
    rate = 0.8 * sat
    n = 160 if smoke else 480
    arrivals = arrival_schedule(arrival, n, rate, seed=42)

    res_b = _serve(idx, q, gt, k, arrivals, continuous=False)
    res_c = _serve(idx, q, gt, k, arrivals, continuous=True)
    ratio = res_b["p99_ms"] / max(res_c["p99_ms"], 1e-9)

    payload = {
        "dataset": "sift-like",
        "arrival_process": arrival,
        "rate_qps": rate,
        "batch_saturation_qps": sat,
        "load_factor": 0.8,
        "k": k,
        "batch": res_b,
        "continuous": res_c,
        "p99_improvement": ratio,
    }
    path = json_path or DEFAULT_JSON
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)

    out(f"continuous/batch_p99,{res_b['p99_ms'] * 1e3:.0f},"
        f"p50_ms={res_b['p50_ms']:.1f};p99_ms={res_b['p99_ms']:.1f};"
        f"recall@{k}={res_b['recall_at_k']:.3f}")
    out(f"continuous/cont_p99,{res_c['p99_ms'] * 1e3:.0f},"
        f"p50_ms={res_c['p50_ms']:.1f};p99_ms={res_c['p99_ms']:.1f};"
        f"recall@{k}={res_c['recall_at_k']:.3f}")
    out(f"continuous/p99_gain,{0.0:.2f},"
        f"ratio={ratio:.2f}x;rate_qps={rate:.0f};"
        f"saturation_qps={sat:.0f}")
    out(f"continuous/nand,{res_c['nand_round_latency_us'] or 0.0:.2f},"
        f"seq_round_us={res_b['nand_round_latency_us'] or 0.0:.3f};"
        f"db_round_us={res_c['nand_round_latency_us'] or 0.0:.3f};"
        f"overlap_saved_us={res_c['nand_overlap_saved_us'] or 0.0:.3f}")

    # quality bars — continuous batching must not cost recall, the
    # double-buffered channel must actually shorten the modeled round, and
    # the scheduler must win the tail it exists to win
    assert abs(res_c["recall_at_k"] - res_b["recall_at_k"]) < 0.05, (
        f"recall diverged: batch {res_b['recall_at_k']:.3f} vs "
        f"continuous {res_c['recall_at_k']:.3f}"
    )
    assert (res_c["nand_round_latency_us"] or 0.0) < \
        (res_b["nand_round_latency_us"] or 1.0), \
        "double-buffered round latency not below sequential"
    assert (res_c["nand_overlap_saved_us"] or 0.0) > 0.0, \
        "double-buffer billing saved no overlap"
    if smoke:
        assert res_c["p99_ms"] <= res_b["p99_ms"], (
            f"continuous p99 {res_c['p99_ms']:.1f} ms worse than batch "
            f"{res_b['p99_ms']:.1f} ms under smoke Poisson load"
        )
    else:
        assert ratio >= 2.0, (
            f"continuous p99 improvement {ratio:.2f}x < 2x at "
            f"{rate:.0f} qps (0.8x saturation {sat:.0f})"
        )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short run + relaxed assert (CI smoke)")
    ap.add_argument("--burst", action="store_true",
                    help="bursty arrivals instead of Poisson")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help=f"snapshot output path (default {DEFAULT_JSON})")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke, json_path=args.json,
         arrival="burst" if args.burst else "poisson")
