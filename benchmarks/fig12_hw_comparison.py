"""Fig. 12 — Proxima NSP accelerator vs CPU / GPU / ASIC.

The CPU row is MEASURED (this container, JAX search wall-clock). Proxima
rows come from the NAND model driven by measured traces. GPU (GGNN on A40)
and ASIC (ANNA) rows are the paper's own reported numbers, included as
labelled reference constants — we cannot measure those devices here.
Expected relations (paper): Proxima > GGNN > HNSW-CPU in QPS;
Proxima ~ 6.6-13x ANNA; Proxima ~ 3 orders of magnitude over CPU in QPS/W.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import get_index
from repro.configs.base import SearchConfig
from repro.core import graph_search as search
from repro.nand.simulator import simulate, trace_from_search_result

# paper-reported reference points (order-of-magnitude anchors, SIFT-class)
PAPER_REFS = {
    "ggnn-a40": dict(qps=3e5, qps_per_w=1e3),
    "anna-asic": dict(qps=6e5, qps_per_w=4e4),
}
CPU_TDP_W = 225.0  # AMD EPYC 7543 (paper baseline hardware)


def main(out=print) -> None:
    ds = "sift-like"
    idx = get_index(ds)
    cfg = SearchConfig(k=10, list_size=128, t_init=16, t_step=8,
                       repetition_rate=2, beta=1.06)
    q = idx.dataset.queries
    corpus = idx.corpus()
    res = search(corpus, q, cfg, idx.dataset.metric)
    jax.block_until_ready(res.ids)
    t0 = time.perf_counter()
    res = search(corpus, q, cfg, idx.dataset.metric)
    jax.block_until_ready(res.ids)
    cpu_qps = q.shape[0] / (time.perf_counter() - t0)
    out(f"fig12/{ds}/cpu-jax,{1e6/cpu_qps:.1f},qps={cpu_qps:.0f};"
        f"qps_per_w={cpu_qps/CPU_TDP_W:.1f};measured=true")
    tr = trace_from_search_result(
        res, dim=idx.dataset.dim, r_degree=idx.graph.max_degree,
        index_bits=idx.gap.bit_width if idx.gap else 32,
        pq_bits=idx.codebook.num_subvectors * 8, metric=idx.dataset.metric)
    r = simulate(tr)
    out(f"fig12/{ds}/proxima-nsp,{r.latency_us:.1f},qps={r.qps:.0f};"
        f"qps_per_w={r.qps_per_watt:.0f};speedup_vs_cpu={r.qps/cpu_qps:.0f}x;"
        f"eff_vs_cpu={r.qps_per_watt/(cpu_qps/CPU_TDP_W):.0f}x")
    for name, ref in PAPER_REFS.items():
        out(f"fig12/{ds}/{name},0.0,qps={ref['qps']:.0f};"
            f"qps_per_w={ref['qps_per_w']:.0f};source=paper_reported")


if __name__ == "__main__":
    main()
