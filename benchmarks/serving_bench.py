"""Serving-path observability snapshot — the perf-trajectory artifact.

Runs the batched ``ServingEngine`` over the benchmark corpus with the full
observability bundle on (metrics + tracing + NAND billing), then writes the
headline serving numbers as ``BENCH_serving.json``:

  * end-to-end request latency p50/p95/p99 and queue-wait p50 (ms),
  * recall@10 of the served results against exact ground truth,
  * plan-cache hit rate over the run,
  * modeled NAND cost per query (pJ/query, latency us) from the per-batch
    cost-accounting bridge,
  * batch occupancy and jit-cache growth (the pow2-bucket contract).

CI's bench-smoke job keeps the JSON as an artifact, so serving regressions
show up as a trajectory, not an anecdote.

``--poisson RATE`` replays the query passes as an open-loop Poisson arrival
schedule at RATE qps instead of back-to-back submission (``--burst`` makes
the schedule bursty) — the same ``benchmarks.arrivals`` generator the
continuous-batching benchmark uses, so the two latency snapshots compare.

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke] [--json PATH]
        [--poisson RATE] [--burst]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import get_index
from repro.obs import Observability
from repro.serve import ServingEngine

DEFAULT_JSON = "BENCH_serving.json"


def _recall_at_k(done, rids, gt, k: int) -> float:
    hits = 0
    for qi, rid in enumerate(rids):
        got = set(int(i) for i in done[rid].ids[:k] if i >= 0)
        hits += len(got & set(int(i) for i in gt[qi, :k]))
    return hits / (len(rids) * k)


def main(out=print, smoke: bool = False, json_path: str | None = None,
         poisson: float | None = None, burst: bool = False) -> None:
    idx = get_index("sift-like")
    obs = Observability.on(tracing=True, nand_billing=True)
    eng = ServingEngine(idx, batch_size=16, flush_us=0.0, obs=obs)
    q = idx.dataset.queries
    gt = np.asarray(idx.dataset.gt)
    k = min(10, gt.shape[1])

    passes = 1 if smoke else 4
    rids_first: list[int] = []
    if poisson is not None or burst:
        # open-loop replay: arrival i carries query i % len(q), so the
        # first len(q) request ids line up with the ground-truth rows
        from benchmarks.arrivals import arrival_schedule, replay

        rate = poisson if poisson is not None else 100.0
        arrivals = arrival_schedule("burst" if burst else "poisson",
                                    passes * len(q), rate, seed=7)
        rids_first = replay(eng, q, arrivals)[: len(q)]
    else:
        for p in range(passes):
            rids = [eng.submit(qq) for qq in q]
            eng.drain()
            if p == 0:
                rids_first = rids
    recall = _recall_at_k(eng.done, rids_first, gt, k)

    m = obs.metrics
    lat = m.merged_histogram("request_latency_ms")
    wait = m.merged_histogram("queue_wait_ms")
    hits = m.counter_total("plan_cache_hits")
    misses = m.counter_total("plan_cache_misses")
    hit_rate = hits / max(hits + misses, 1)
    pj = m.merged_histogram("nand_pj_per_query")
    nand_lat = m.merged_histogram("nand_latency_us")
    growth = m.gauge_value("jit_cache_growth", kernel="graph_search")

    payload = {
        "dataset": "sift-like",
        "arrival_process": ("burst" if burst else
                            "poisson" if poisson is not None else "closed"),
        "arrival_rate_qps": poisson,
        "queries_served": int(eng.stats["queries"]),
        "batches": int(eng.stats["batches"]),
        "recall_at_k": recall,
        "k": k,
        "latency_ms": {"p50": lat.quantile(50), "p95": lat.quantile(95),
                       "p99": lat.quantile(99), "mean": lat.mean},
        "queue_wait_ms_p50": wait.quantile(50),
        "plan_cache_hit_rate": hit_rate,
        "nand_pj_per_query": pj.mean if pj is not None else None,
        "nand_latency_us": nand_lat.mean if nand_lat is not None else None,
        "batch_occupancy": m.gauge_value("batch_occupancy"),
        "jit_cache_growth": growth,
        "unexpected_recompiles": m.counter_total("unexpected_recompiles"),
    }
    path = json_path or DEFAULT_JSON
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)

    out(f"serving/latency,{lat.mean * 1e3:.2f},"
        f"p50_ms={lat.quantile(50):.3f};p99_ms={lat.quantile(99):.3f};"
        f"recall@{k}={recall:.3f}")
    out(f"serving/plan_cache,{0.0:.2f},"
        f"hit_rate={hit_rate:.4f};queue_wait_p50_ms={wait.quantile(50):.3f}")
    out(f"serving/nand_model,{nand_lat.mean if nand_lat else 0.0:.2f},"
        f"pj_per_query={pj.mean if pj else 0.0:.1f};"
        f"jit_cache_growth={growth}")

    # serving sanity bars — a broken engine must fail the smoke job
    assert recall >= 0.6, f"served recall@{k} collapsed: {recall:.3f}"
    assert hit_rate >= 0.9, f"plan-cache hit rate {hit_rate:.3f} < 0.9"
    assert m.counter_total("unexpected_recompiles") == 0, \
        "serving defeated the pow2-bucket compile cache"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single pass over the query set (CI smoke)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help=f"snapshot output path (default {DEFAULT_JSON})")
    ap.add_argument("--poisson", type=float, default=None, metavar="RATE",
                    help="open-loop Poisson arrivals at RATE qps instead "
                         "of back-to-back passes")
    ap.add_argument("--burst", action="store_true",
                    help="bursty arrival schedule (rate from --poisson, "
                         "default 100 qps)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke, json_path=args.json, poisson=args.poisson,
         burst=args.burst)
