"""Serving-path observability snapshot — the perf-trajectory artifact.

Runs the batched ``ServingEngine`` over the benchmark corpus with the full
observability bundle on (metrics + tracing + NAND billing), then writes the
headline serving numbers as ``BENCH_serving.json``:

  * end-to-end request latency p50/p95/p99 and queue-wait p50 (ms),
  * recall@10 of the served results against exact ground truth,
  * plan-cache hit rate over the run,
  * modeled NAND cost per query (pJ/query, latency us) from the per-batch
    cost-accounting bridge,
  * batch occupancy and jit-cache growth (the pow2-bucket contract).

CI's bench-smoke job keeps the JSON as an artifact, so serving regressions
show up as a trajectory, not an anecdote.

``--poisson RATE`` replays the query passes as an open-loop Poisson arrival
schedule at RATE qps instead of back-to-back submission (``--burst`` makes
the schedule bursty) — the same ``benchmarks.arrivals`` generator the
continuous-batching benchmark uses, so the two latency snapshots compare.

``--quality`` additionally turns on the quality-observability bundle: the
seeded shadow-recall estimator samples the served traffic against the exact
oracle, a per-tenant SLO tracker watches the recall floor, and an off-line
``trace_session`` pass exports the per-round convergence dataset to
``results/convergence_log.npz``.  The headline comparison — shadow estimate
vs the TRUE served recall the bench already computes — lands in
``BENCH_quality.json`` and is asserted to agree within 0.05 (and within the
estimator's own reported Wilson CI), so a drifting estimator fails the
smoke job just like a drifting engine.

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke] [--json PATH]
        [--poisson RATE] [--burst] [--quality] [--sample-rate R]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import get_index, served_recall
from repro.obs import ConvergenceLog, Observability, SLOTarget, trace_session
from repro.plan import SearchRequest
from repro.serve import ServingEngine

DEFAULT_JSON = "BENCH_serving.json"
QUALITY_JSON = "BENCH_quality.json"
CONVERGENCE_NPZ = os.path.join("results", "convergence_log.npz")


def main(out=print, smoke: bool = False, json_path: str | None = None,
         poisson: float | None = None, burst: bool = False,
         quality: bool = False, sample_rate: float = 0.25) -> None:
    idx = get_index("sift-like")
    obs = Observability.on(tracing=True, nand_billing=True, quality=quality,
                           quality_sample_rate=sample_rate, quality_seed=17)
    slo = {None: SLOTarget(recall_floor=0.5, p99_latency_ms=1e9)} \
        if quality else None
    eng = ServingEngine(idx, batch_size=16, flush_us=0.0, obs=obs, slo=slo)
    q = idx.dataset.queries
    gt = np.asarray(idx.dataset.gt)
    k = min(10, gt.shape[1])

    passes = 1 if smoke else 4
    rids_first: list[int] = []
    if poisson is not None or burst:
        # open-loop replay: arrival i carries query i % len(q), so the
        # first len(q) request ids line up with the ground-truth rows
        from benchmarks.arrivals import arrival_schedule, replay

        rate = poisson if poisson is not None else 100.0
        arrivals = arrival_schedule("burst" if burst else "poisson",
                                    passes * len(q), rate, seed=7)
        rids_first = replay(eng, q, arrivals)[: len(q)]
    else:
        for p in range(passes):
            rids = [eng.submit(qq) for qq in q]
            eng.drain()
            if p == 0:
                rids_first = rids
    recall = served_recall(eng.done, rids_first, gt, k)

    m = obs.metrics
    lat = m.merged_histogram("request_latency_ms")
    wait = m.merged_histogram("queue_wait_ms")
    hits = m.counter_total("plan_cache_hits")
    misses = m.counter_total("plan_cache_misses")
    hit_rate = hits / max(hits + misses, 1)
    pj = m.merged_histogram("nand_pj_per_query")
    nand_lat = m.merged_histogram("nand_latency_us")
    growth = m.gauge_value("jit_cache_growth", kernel="graph_search")

    payload = {
        "dataset": "sift-like",
        "arrival_process": ("burst" if burst else
                            "poisson" if poisson is not None else "closed"),
        "arrival_rate_qps": poisson,
        "queries_served": int(eng.stats["queries"]),
        "batches": int(eng.stats["batches"]),
        "recall_at_k": recall,
        "k": k,
        "latency_ms": {"p50": lat.quantile(50), "p95": lat.quantile(95),
                       "p99": lat.quantile(99), "mean": lat.mean},
        "queue_wait_ms_p50": wait.quantile(50),
        "plan_cache_hit_rate": hit_rate,
        "nand_pj_per_query": pj.mean if pj is not None else None,
        "nand_latency_us": nand_lat.mean if nand_lat is not None else None,
        "batch_occupancy": m.gauge_value("batch_occupancy"),
        "jit_cache_growth": growth,
        "unexpected_recompiles": m.counter_total("unexpected_recompiles"),
    }
    path = json_path or DEFAULT_JSON
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)

    out(f"serving/latency,{lat.mean * 1e3:.2f},"
        f"p50_ms={lat.quantile(50):.3f};p99_ms={lat.quantile(99):.3f};"
        f"recall@{k}={recall:.3f}")
    out(f"serving/plan_cache,{0.0:.2f},"
        f"hit_rate={hit_rate:.4f};queue_wait_p50_ms={wait.quantile(50):.3f}")
    out(f"serving/nand_model,{nand_lat.mean if nand_lat else 0.0:.2f},"
        f"pj_per_query={pj.mean if pj else 0.0:.1f};"
        f"jit_cache_growth={growth}")

    # serving sanity bars — a broken engine must fail the smoke job
    assert recall >= 0.6, f"served recall@{k} collapsed: {recall:.3f}"
    assert hit_rate >= 0.9, f"plan-cache hit rate {hit_rate:.3f} < 0.9"
    assert m.counter_total("unexpected_recompiles") == 0, \
        "serving defeated the pow2-bucket compile cache"

    if quality:
        _quality_report(out, eng, obs, q, recall, k, sample_rate)


def _quality_report(out, eng, obs, q, true_recall: float, k: int,
                    sample_rate: float) -> None:
    """Shadow-estimator calibration + convergence-dataset export, asserted:
    the online estimate must agree with the bench's true served recall both
    in absolute terms (<= 0.05) and within its own Wilson CI, and the
    off-line convergence labels must reproduce the whole-batch path's
    ``SearchStats.rounds`` (the round-step equivalence contract)."""
    qm = obs.quality
    ov = qm.overall()
    err = abs(ov["estimate"] - true_recall)

    # per-round convergence telemetry: trace one query pass off-line (the
    # monitor paused so the export does not perturb the sampling stream)
    log = ConvergenceLog(capacity=1 << 15)
    plan = eng.searcher.plan(SearchRequest(queries=q))
    sess = eng.searcher.round_session(plan)
    with qm.paused():
        _, rounds = trace_session(sess, q, log)
        ref = eng.searcher.search(SearchRequest(queries=q))
    os.makedirs(os.path.dirname(CONVERGENCE_NPZ), exist_ok=True)
    log.save_npz(CONVERGENCE_NPZ)
    rt = ConvergenceLog.load_npz(CONVERGENCE_NPZ)
    X, y, _ = rt.dataset()

    payload = {
        "dataset": "sift-like",
        "k": k,
        "sample_rate": sample_rate,
        "shadow": dict(ov),
        "true_recall_at_k": true_recall,
        "abs_error": err,
        "slo": eng.slo_status(),
        "slo_violations": int(eng.stats["slo_violations"]),
        "convergence": {
            "records": int(log.count),
            "dropped": int(log.dropped),
            "labeled_rows": int(len(y)),
            "mean_rounds": float(np.mean(rounds)),
            "npz": CONVERGENCE_NPZ,
        },
    }
    with open(QUALITY_JSON, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)

    out(f"serving/quality,{0.0:.2f},"
        f"estimate={ov['estimate']:.3f};true={true_recall:.3f};"
        f"ci=[{ov['ci_low']:.3f},{ov['ci_high']:.3f}];"
        f"samples={ov['samples']}")
    out(f"serving/convergence,{0.0:.2f},"
        f"records={log.count};labeled_rows={len(y)};"
        f"mean_rounds={float(np.mean(rounds)):.2f}")

    # estimator calibration bars
    assert ov["samples"] > 0, "quality monitor sampled nothing"
    assert err <= 0.05, (
        f"shadow estimate {ov['estimate']:.3f} vs true "
        f"{true_recall:.3f}: |err|={err:.3f} > 0.05"
    )
    eps = 1e-9
    assert ov["ci_low"] - eps <= true_recall <= ov["ci_high"] + eps, (
        f"true recall {true_recall:.3f} outside the estimator's CI "
        f"[{ov['ci_low']:.3f}, {ov['ci_high']:.3f}]"
    )
    assert int(eng.stats["slo_violations"]) == 0, \
        "healthy serving run burned SLO budget"
    # convergence-dataset integrity: labels == whole-batch round counters,
    # and the npz round-trips into the exact training matrix
    assert np.isclose(float(np.mean(rounds)), float(ref.stats.rounds)), (
        f"trace_session rounds {float(np.mean(rounds)):.3f} != whole-batch "
        f"SearchStats.rounds {float(ref.stats.rounds):.3f}"
    )
    X0, y0, _ = log.dataset()
    assert len(y) == len(y0) and np.array_equal(y, y0) \
        and np.array_equal(X, X0), "convergence npz round-trip mismatch"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single pass over the query set (CI smoke)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help=f"snapshot output path (default {DEFAULT_JSON})")
    ap.add_argument("--poisson", type=float, default=None, metavar="RATE",
                    help="open-loop Poisson arrivals at RATE qps instead "
                         "of back-to-back passes")
    ap.add_argument("--burst", action="store_true",
                    help="bursty arrival schedule (rate from --poisson, "
                         "default 100 qps)")
    ap.add_argument("--quality", action="store_true",
                    help="shadow-recall estimation + SLO tracking + "
                         f"convergence-dataset export ({QUALITY_JSON})")
    ap.add_argument("--sample-rate", type=float, default=0.25,
                    metavar="R", help="shadow-sampling rate for --quality "
                                      "(default 0.25)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke, json_path=args.json, poisson=args.poisson,
         burst=args.burst, quality=args.quality,
         sample_rate=args.sample_rate)
