"""Beam-parallel traversal sweep — E in {1, 2, 4, 8}.

Proxima keeps every NAND channel/plane busy by issuing neighbour fetches
wide, not one vertex at a time (§IV-D dataflow). ``SearchConfig.beam_width``
generalizes the Algorithm-1 loop: each round pops the E best unevaluated
candidates, gathers their E adjacency rows in one fetch and scores all E*R
fresh neighbours in one batch, so the SERIAL pointer-chase shrinks ~E× at
iso-recall while total work (hops, PQ lookups) grows only at the frontier's
edge. The sweep reports, per E:

  * mean traversal rounds + the rounds speedup vs E=1 (the tentpole claim:
    >= 1.5x at E=4 with recall within 0.01),
  * realized expansion parallelism (hops/rounds <= E),
  * recall@10 delta vs the E=1 baseline,
  * simulated NAND QPS / latency with the round-level parallelism billed to
    ``NandConfig.n_planes`` parallel plane reads (``WorkloadTrace.beam_width``).

``--smoke`` runs E in {1, 4} only (CI).

    PYTHONPATH=src python -m benchmarks.beam_bench [--smoke]
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import get_index
from repro.configs.base import SearchConfig
from repro.core import recall_at_k
from repro.core.dataset import exact_knn
from repro.nand.simulator import simulate, trace_from_plan_execution
from repro.plan import Searcher, SearchRequest


def main(out=print, smoke: bool = False) -> None:
    idx = get_index("sift-like")
    base_cfg = SearchConfig(k=10, list_size=128, t_init=16, t_step=8,
                            repetition_rate=3, beta=1.06)
    q = idx.dataset.queries
    metric = idx.dataset.metric
    gt = idx.dataset.gt
    if gt.shape[1] < 10:
        gt = exact_knn(q, idx.dataset.base, 10, metric)
    searcher = Searcher.open(idx, cfg=base_cfg)

    widths = (1, 4) if smoke else (1, 2, 4, 8)
    rec1 = rounds1 = qps1 = None
    for e in widths:
        res = searcher.search(SearchRequest(
            queries=q, overrides={"beam_width": e}))
        # planner regressions fail loudly: the plan must carry the
        # requested beam on the flat spine
        assert res.plan.kind == "flat" and res.plan.cfg.beam_width == e, \
            f"planner compiled {res.plan.kind}/E={res.plan.cfg.beam_width}"
        rec = recall_at_k(res.ids, gt, 10)
        rounds = res.stats.rounds
        hops = res.stats.hops
        sim = simulate(trace_from_plan_execution(res, index=idx))
        if rec1 is None:
            rec1, rounds1, qps1 = rec, rounds, sim.qps
        out(f"beam/E{e},{sim.latency_us:.1f},"
            f"recall={rec:.4f};d_recall={rec - rec1:+.4f};"
            f"rounds={rounds:.1f};round_speedup={rounds1 / rounds:.2f}x;"
            f"hops={hops:.1f};realized_beam={hops / max(rounds, 1):.2f};"
            f"qps={sim.qps:.0f};qps_scaling={sim.qps / qps1:.2f}x")
        if e == 4:
            if rounds1 / rounds < 1.5:
                out(f"beam/E4/ROUND_SPEEDUP_FAIL,0.0,"
                    f"{rounds1 / rounds:.2f}x < 1.5x")
            if rec < rec1 - 0.01:
                out(f"beam/E4/RECALL_PARITY_FAIL,0.0,"
                    f"recall {rec:.4f} vs E=1 {rec1:.4f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="E in {1, 4} only (CI smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
