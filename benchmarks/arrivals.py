"""Shared open-loop arrival processes for the serving benchmarks.

``continuous_bench`` and ``serving_bench --poisson/--burst`` drive the
engines with REAL-TIME arrival schedules from here, so the two benchmarks
load the engines identically and their latency percentiles compare.

All generators are seeded and return absolute arrival offsets in SECONDS
from the run's start, sorted ascending.
"""
from __future__ import annotations

import numpy as np


def poisson_arrivals(n: int, rate_qps: float, seed: int = 0) -> np.ndarray:
    """``n`` arrivals of a homogeneous Poisson process at ``rate_qps``
    (exponential inter-arrival gaps)."""
    if rate_qps <= 0:
        raise ValueError(f"rate_qps must be positive, got {rate_qps}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_qps, size=n))


def bursty_arrivals(n: int, rate_qps: float, burst_size: int = 8,
                    spread: float = 0.1, seed: int = 0) -> np.ndarray:
    """Bursts of ``burst_size`` near-simultaneous arrivals with Poisson
    burst starts, mean rate still ``rate_qps``: each burst's members land
    within ``spread`` of the mean burst period after its start.  The
    open-loop equivalent of the paper's queue-filling traffic — it stresses
    admission (iteration-level schedulers absorb a burst into free slots;
    batch flushers serialize it into consecutive flush windows)."""
    if rate_qps <= 0:
        raise ValueError(f"rate_qps must be positive, got {rate_qps}")
    if burst_size < 1:
        raise ValueError(f"burst_size must be >= 1, got {burst_size}")
    rng = np.random.default_rng(seed)
    period = burst_size / rate_qps
    n_bursts = -(-n // burst_size)
    starts = np.cumsum(rng.exponential(period, size=n_bursts))
    t = np.repeat(starts, burst_size)[:n]
    t = t + rng.uniform(0.0, spread * period, size=n)
    return np.sort(t)


def arrival_schedule(kind: str, n: int, rate_qps: float, seed: int = 0,
                     **kwargs) -> np.ndarray:
    """Dispatch by name: ``poisson`` | ``burst``."""
    if kind == "poisson":
        return poisson_arrivals(n, rate_qps, seed=seed)
    if kind == "burst":
        return bursty_arrivals(n, rate_qps, seed=seed, **kwargs)
    raise ValueError(f"unknown arrival process {kind!r} "
                     "(expected 'poisson' or 'burst')")


def replay(engine, queries: np.ndarray, arrivals: np.ndarray,
           filters=None) -> list:
    """Drive ``engine`` open-loop in real time: submit query ``i % len``
    when the wall clock passes ``arrivals[i]``, stepping the engine between
    arrivals; drain at the end.  Returns the request ids in arrival order.

    Latencies come from the engine's own ``perf_counter`` timestamps
    (``Request.latency_ms``), so queueing delay under load is measured, not
    modeled.
    """
    import time

    n = len(arrivals)
    nq = len(queries)
    rids: list = []
    i = 0
    t0 = time.perf_counter()
    while i < n:
        now = time.perf_counter() - t0
        if arrivals[i] <= now:
            f = filters[i % len(filters)] if filters is not None else None
            rids.append(engine.submit(queries[i % nq], filter=f))
            i += 1
            continue
        engine.step()
        idle = not engine.queue and (
            not engine.continuous or engine.inflight() == 0)
        if idle:
            wait = arrivals[i] - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(min(wait, 1e-3))
    engine.drain()
    return rids
