"""Flat vs segmented build: peak builder RSS and served recall at equal
corpus size — the memory claim behind the out-of-core builder.

The monolithic pipeline's working set is dominated by the exact-kNN
temporaries of the graph build (an O(n^2) distance block plus argpartition
scratch); the segmented builder bounds those by the SEGMENT, so its peak
RSS must sit well below the flat build's while the stitched graph serves
recall@10 within 1% of the flat-built index.

Peak RSS is a PROCESS-lifetime high-water mark (``resource.getrusage``
never goes down), so each build mode runs in its own child subprocess; the
parent collects one JSON line per child.

``--smoke`` asserts (loudly) that segmented peak RSS < flat peak RSS and
segmented recall@10 >= flat recall@10 - 0.01.

    PYTHONPATH=src python -m benchmarks.build_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

NUM_BASE = 4000
NUM_SEGMENTS = 4
DIM = 64


def _bench_cfg():
    from repro.configs.base import (
        DatasetConfig, GraphConfig, PQConfig, ProximaConfig, SearchConfig,
    )

    return ProximaConfig(
        dataset=DatasetConfig(name="sift-like", num_base=NUM_BASE,
                              num_queries=64, dim=DIM, num_clusters=16,
                              cluster_std=0.3, seed=0),
        pq=PQConfig(num_subvectors=8, num_centroids=64, kmeans_iters=8),
        graph=GraphConfig(max_degree=24, build_list_size=48, alpha=1.2),
        search=SearchConfig(k=10, list_size=64, t_init=16, t_step=8,
                            repetition_rate=3, beta=1.06),
        hot_node_fraction=0.03,
    )


def _child(mode: str) -> None:
    """Build in ``mode`` (flat | segmented), serve the held-out queries
    through the flat engine, print ONE json line: peak RSS + recall +
    build seconds (+ stitch/NAND accounting for the segmented mode)."""
    import resource

    import jax.numpy as jnp
    import numpy as np

    from repro.core.dataset import make_dataset, recall_at_k
    from repro.core.search import graph_search

    cfg = _bench_cfg()
    ds = make_dataset(cfg.dataset)
    t0 = time.perf_counter()
    extra = {}
    if mode == "flat":
        from repro.core.index import build_index_monolithic

        index = build_index_monolithic(cfg, dataset=ds, reorder_samples=16)
    else:
        from repro.core.segmented import build_segmented
        from repro.nand.simulator import simulate_build

        seg = build_segmented(cfg, dataset=ds, reorder_samples=16,
                              segment_size=NUM_BASE // NUM_SEGMENTS)
        sim = simulate_build(seg.build_trace())
        extra = {
            "num_segments": seg.num_segments,
            "cross_edges": seg.stitch.cross_edges,
            "build_write_amplification": sim.write_amplification,
        }
        index = seg.to_flat()
    build_s = time.perf_counter() - t0

    res = graph_search(index.corpus(), jnp.asarray(ds.queries),
                       cfg.search, ds.metric)
    recall = recall_at_k(np.asarray(res.ids), index.dataset.gt, 10)
    # ru_maxrss: KB on Linux — the process high-water mark, which the build
    # temporaries dominate at this scale
    peak_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    print(json.dumps({
        "mode": mode, "peak_rss_mb": peak_kb / 1024.0,
        "recall_at_10": recall, "build_s": build_s, **extra,
    }))


def _run_child(mode: str) -> dict:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.build_bench", "--child", mode],
        capture_output=True, text=True, timeout=1800, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    if r.returncode != 0:
        raise RuntimeError(
            f"build_bench child {mode!r} failed:\n{r.stderr[-2000:]}"
        )
    return json.loads(r.stdout.strip().splitlines()[-1])


def main(out=print, smoke: bool = False) -> None:
    flat = _run_child("flat")
    seg = _run_child("segmented")
    for row in (flat, seg):
        out(
            f"build_{row['mode']},{row['build_s'] * 1e6:.0f},"
            f"peak_mb={row['peak_rss_mb']:.1f};recall={row['recall_at_10']:.4f}"
        )
    out(
        f"build_segmented_vs_flat,0.0,"
        f"rss_ratio={seg['peak_rss_mb'] / max(flat['peak_rss_mb'], 1e-9):.3f};"
        f"recall_delta={seg['recall_at_10'] - flat['recall_at_10']:+.4f};"
        f"segments={seg['num_segments']};"
        f"build_wa={seg['build_write_amplification']:.3f}"
    )
    if smoke:
        assert seg["peak_rss_mb"] < flat["peak_rss_mb"], (
            f"segmented peak RSS {seg['peak_rss_mb']:.1f} MB must be BELOW "
            f"flat {flat['peak_rss_mb']:.1f} MB — the out-of-core working "
            "set is not bounded by the segment"
        )
        assert seg["recall_at_10"] >= flat["recall_at_10"] - 0.01, (
            f"segmented recall {seg['recall_at_10']:.4f} fell more than 1% "
            f"below flat {flat['recall_at_10']:.4f} — stitching lost "
            "navigability"
        )
        out("build_bench_smoke,0.0,ok")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--child", default="",
                    help="internal: run one build mode in-process")
    args = ap.parse_args()
    if args.child:
        _child(args.child)
    else:
        main(smoke=args.smoke)
