"""Query-plan layer overhead — plan-cache hit rate + dispatch cost.

The redesign's serving-path tax is one ``QueryPlanner.plan`` lookup per
submit and one plan-keyed dispatch per flush; both must be noise against a
compiled batch search.  This bench serves a mixed workload (unfiltered +
two repeated ``FilterSpec``s, the shape the plan cache is built for) and
reports:

  * plan-cache hit rate (misses == distinct request shapes only),
  * mean ``plan()`` dispatch overhead per query, absolute and as a share of
    the measured batch search latency — acceptance bar: **< 5%** (asserted,
    so a planner regression fails the bench-smoke CI job loudly),
  * enabled-observability tax: the same dispatch stream against an
    obs-enabled searcher — the ADDED cost must also stay **< 5%** of batch
    latency (asserted; the zero-cost-when-off contract, measured when on).

``--smoke`` shrinks the request count for CI.

    PYTHONPATH=src python -m benchmarks.planner_bench [--smoke]
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import get_index
from repro.configs.base import SearchConfig
from repro.filter import FilterSpec, attach_attributes, random_attributes
from repro.obs import Observability
from repro.plan import Searcher, SearchRequest

PRICE_CARD = 1000


def main(out=print, smoke: bool = False) -> None:
    idx = get_index("sift-like")
    store = attach_attributes(
        idx, random_attributes(idx.dataset.num_base,
                               {"category": 16, "price": PRICE_CARD},
                               seed=11))
    cfg = SearchConfig(k=10, list_size=128, t_init=16, t_step=8,
                       repetition_rate=3, beta=1.06)
    searcher = Searcher.open(idx, cfg=cfg)
    q = idx.dataset.queries
    specs = [None,
             FilterSpec.range("price", 0, 499),          # masked regime
             FilterSpec.range("price", 0, 9)]            # scan regime
    requests = [SearchRequest(queries=q, filter=specs[i % len(specs)])
                for i in range(60 if smoke else 300)]

    # ---- batch search latency (the denominator), per strategy warm --------
    for r in requests[:3]:
        searcher.search(r)                               # warm compiles
    t0 = time.perf_counter()
    reps = 3 if smoke else 6
    for _ in range(reps):
        for r in requests[:3]:
            searcher.search(r)
    batch_s = (time.perf_counter() - t0) / (3 * reps)

    # ---- plan dispatch cost ------------------------------------------------
    h0 = searcher.plan_cache_stats()
    t0 = time.perf_counter()
    for r in requests:
        searcher.plan(r)
    plan_s = (time.perf_counter() - t0) / len(requests)
    h1 = searcher.plan_cache_stats()
    hits = h1["plan_cache_hits"] - h0["plan_cache_hits"]
    misses = h1["plan_cache_misses"] - h0["plan_cache_misses"]
    hit_rate = hits / max(hits + misses, 1)
    per_query_overhead = plan_s / q.shape[0]
    share = plan_s / max(batch_s, 1e-12)

    out(f"planner/dispatch,{plan_s * 1e6:.2f},"
        f"hit_rate={hit_rate:.4f};misses={misses};"
        f"overhead_us_per_query={per_query_overhead * 1e6:.3f};"
        f"batch_us={batch_s * 1e6:.0f};overhead_share={share:.5f}")

    # ---- observability tax: same dispatch stream, obs-enabled searcher ----
    obs = Observability.on(tracing=False, nand_billing=False)
    searcher_obs = Searcher.open(idx, cfg=cfg, obs=obs)
    for r in requests[:3]:
        searcher_obs.plan(r)                             # warm the plan cache
    t0 = time.perf_counter()
    for r in requests:
        searcher_obs.plan(r)
    plan_obs_s = (time.perf_counter() - t0) / len(requests)
    # normalize the delta by BATCH latency, not by the microsecond-scale
    # dispatch itself — two tiny timings compared directly are runner noise
    obs_share = (plan_obs_s - plan_s) / max(batch_s, 1e-12)

    out(f"planner/obs_tax,{plan_obs_s * 1e6:.2f},"
        f"disabled_us={plan_s * 1e6:.2f};"
        f"obs_share_of_batch={obs_share:.5f}")

    # ---- quality-monitoring tax: the shadow-recall hook per batch ---------
    # qm.observe is what the engine runs per flushed batch when quality obs
    # is on; amortized over the batch it must stay < 5% of batch latency.
    # The hook is sampled (rate 0.25 here, matching the serving bench), so
    # the measured mean folds the occasional exact-oracle replay in with the
    # cheap not-sampled ticks — exactly the production mix.
    obs_q = Observability.on(tracing=False, nand_billing=False, quality=True,
                             quality_sample_rate=0.25, quality_seed=3)
    searcher_q = Searcher.open(idx, cfg=cfg, obs=obs_q)
    r0 = requests[0]
    plan_q = searcher_q.plan(r0)
    ex = searcher_q.execute(plan_q, r0.queries)
    qm = obs_q.quality
    qm.observe(searcher_q, plan_q, r0.queries, ex.ids)   # warm the oracle
    q_reps = 20 if smoke else 50
    t0 = time.perf_counter()
    for _ in range(q_reps):
        qm.observe(searcher_q, plan_q, r0.queries, ex.ids)
    quality_s = (time.perf_counter() - t0) / q_reps
    quality_share = quality_s / max(batch_s, 1e-12)

    out(f"planner/quality_tax,{quality_s * 1e6:.2f},"
        f"quality_share_of_batch={quality_share:.5f};"
        f"samples={qm.samples}")

    # the redesign's acceptance bars — fail the smoke job loudly
    assert misses == 0, f"plan cache missed {misses}x on repeated requests"
    assert hit_rate >= 0.99, f"plan-cache hit rate {hit_rate:.3f} < 0.99"
    assert share < 0.05, (
        f"plan dispatch is {share:.1%} of batch latency (bar: < 5%)")
    assert obs_share < 0.05, (
        f"enabled observability adds {obs_share:.1%} of batch latency to "
        f"dispatch (bar: < 5%)")
    assert quality_share < 0.05, (
        f"shadow-recall monitoring adds {quality_share:.1%} of batch "
        f"latency per batch (bar: < 5%)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short request stream (CI smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
