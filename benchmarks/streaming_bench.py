"""Streaming mutable-index benchmark — the workload class the static paper
pipeline cannot serve.

Measures, against the sift-like corpus:
  * merged-search recall@10 (vs exact kNN of the *current* corpus) and QPS
    as the delta segment grows to 5/10/20% of the base;
  * mixed read/write throughput through the ServingEngine (interleaved
    submit/insert/delete with periodic consolidation);
  * the NAND update model: sustainable insert throughput, program/erase
    energy, write amplification and endurance at several offered rates.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import get_index
from repro.core.dataset import exact_knn, recall_at_k
from repro.nand.simulator import (
    UpdateTrace, simulate_mixed, simulate_updates, trace_from_plan_execution,
)
from repro.plan import Searcher, SearchRequest
from repro.serve.engine import ServingEngine
from repro.stream import MutableIndex


def _perturbed(base: np.ndarray, n: int, rng) -> np.ndarray:
    """New vectors from the corpus distribution (jittered resamples)."""
    picks = base[rng.choice(base.shape[0], n)]
    return (picks + 0.1 * rng.standard_normal(picks.shape)).astype(np.float32)


def main(out=print) -> None:
    idx = get_index("sift-like")
    metric = idx.dataset.metric
    queries = idx.dataset.queries
    n_base = idx.dataset.num_base
    rng = np.random.default_rng(11)

    # ---- recall + QPS vs delta fraction (deletes fixed at 5%) --------------
    mut = MutableIndex(idx)
    searcher = Searcher.open(mut)
    deleted = rng.choice(n_base, int(0.05 * n_base), replace=False)
    for e in deleted:
        mut.delete(int(e))
    grown = 0.0
    base_res = None
    for frac in (0.05, 0.10, 0.20):
        need = int(frac * n_base) - int(grown * n_base)
        for v in _perturbed(idx.dataset.base, need, rng):
            mut.insert(v)
        grown = frac
        ext_ids, vecs = mut.live_vectors()
        gt = ext_ids[exact_knn(queries, vecs, 10, metric)]
        req = SearchRequest(queries=queries)
        res = searcher.search(req)                 # warm/compile
        # planner regressions fail loudly: a mutable target must take the
        # base+delta merged spine
        assert res.plan.kind == "merged", res.plan.kind
        t0 = time.perf_counter()
        for _ in range(3):
            res = searcher.search(req)
        dt = (time.perf_counter() - t0) / 3
        rec = recall_at_k(res.ids, gt, 10)
        qps = queries.shape[0] / dt
        out(f"streaming/delta{int(frac*100)}pct,{dt/queries.shape[0]*1e6:.1f},"
            f"recall={rec:.4f};qps={qps:.0f};live={mut.live_count()}"
            f";delta_cand={res.stats.delta_candidates:.1f}")
        base_res = res

    # ---- consolidation restores the single-segment path --------------------
    t0 = time.perf_counter()
    mut.consolidate()
    dt_cons = time.perf_counter() - t0
    ext_ids, vecs = mut.live_vectors()
    gt = ext_ids[exact_knn(queries, vecs, 10, metric)]
    res = Searcher.open(mut).search(SearchRequest(queries=queries))
    rec = recall_at_k(res.ids, gt, 10)
    out(f"streaming/consolidated,{dt_cons*1e6:.0f},"
        f"recall={rec:.4f};wa={mut.write_amplification():.2f}")

    # ---- mixed read/write ops through the engine ---------------------------
    eng = ServingEngine(MutableIndex(get_index("sift-like")), batch_size=16,
                        flush_us=0.0)
    new_vecs = _perturbed(idx.dataset.base, 400, rng)
    t0 = time.perf_counter()
    ops = 0
    vi = 0
    inserted: list[int] = []
    for i in range(120):
        for q in queries[rng.choice(queries.shape[0], 4)]:
            eng.submit(q)
        for _ in range(3):
            inserted.append(eng.insert(new_vecs[vi % len(new_vecs)]))
            vi += 1
        if i % 8 == 7 and inserted:
            eng.delete(inserted.pop(0))
        ops += 7 + (1 if i % 8 == 7 else 0)
        eng.step()
    eng.drain()
    dt = time.perf_counter() - t0
    out(f"streaming/mixed-engine,{dt/ops*1e6:.1f},"
        f"ops_per_s={ops/dt:.0f};batches={eng.stats['batches']};"
        f"consolidations={eng.stats['consolidations']}")

    # ---- NAND update model -------------------------------------------------
    trace = trace_from_plan_execution(base_res, index=mut)
    cap = simulate_updates(UpdateTrace(insert_rate=1.0)).update_throughput_per_s
    out(f"streaming/nand-max-updates,0.0,inserts_per_s={cap:.0f}")
    for rate in (1e3, 1e4, 1e5):
        u = UpdateTrace(insert_rate=rate, delete_rate=0.2 * rate,
                        dim=idx.dataset.dim, r_degree=idx.graph.max_degree)
        m = simulate_mixed(trace, u)
        out(f"streaming/mixed-sim-{rate:.0e},0.0,"
            f"qps={m.qps:.0f};wa={m.update.write_amplification:.2f};"
            f"e_prog_pj={m.update.program_energy_pj_per_insert:.0f};"
            f"e_erase_pj={m.update.erase_energy_pj_per_insert:.0f};"
            f"endurance_yr={m.update.endurance_years:.2f}")


if __name__ == "__main__":
    main()
