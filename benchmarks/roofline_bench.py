"""§Roofline — the dry-run roofline table (reads results/dryrun.json;
run ``python -m repro.launch.dryrun`` first to (re)generate)."""
from __future__ import annotations

import json
import os


def main(out=print) -> None:
    path = os.environ.get("REPRO_DRYRUN_RESULTS", "results/dryrun.json")
    if not os.path.exists(path):
        out("roofline/missing,0.0,run `python -m repro.launch.dryrun` first")
        return
    with open(path) as f:
        results = json.load(f)
    n_ok = n_skip = n_err = 0
    for key, rec in sorted(results.items()):
        if rec["status"] == "skipped":
            n_skip += 1
            continue
        if rec["status"] != "ok":
            n_err += 1
            out(f"roofline/{key.replace('|','/')},0.0,ERROR")
            continue
        n_ok += 1
        rl = rec["roofline"]
        dominant_s = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        out(
            f"roofline/{key.replace('|','/')},{dominant_s*1e6:.1f},"
            f"compute_s={rl['compute_s']:.4f};memory_s={rl['memory_s']:.4f};"
            f"collective_s={rl['collective_s']:.4f};"
            f"bottleneck={rl['bottleneck']};useful={rl['useful_ratio']:.3f}"
        )
    out(f"roofline/summary,0.0,ok={n_ok};skipped_by_design={n_skip};errors={n_err}")


if __name__ == "__main__":
    main()
