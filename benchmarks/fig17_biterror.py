"""Fig. 17 — storage bit-error sensitivity: flip bits in the stored PQ codes
and raw vectors at SLC/MLC/TLC-class rates and measure recall. Paper: SLC
(<1e-5) loses <3% recall without ECC; MLC/TLC (>1e-4) degrade sharply.

On TPU this doubles as a silent-data-corruption tolerance study (DESIGN.md
§2) — the same injection, reinterpreted."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import get_index
from repro.configs.base import SearchConfig
from repro.core import recall_at_k, graph_search as search
from repro.core.search import Corpus
import jax.numpy as jnp


def flip_bits(arr: np.ndarray, rate: float, rng) -> np.ndarray:
    raw = arr.view(np.uint8).copy()
    n_bits = raw.size * 8
    n_flip = rng.binomial(n_bits, rate)
    if n_flip == 0:
        return arr.copy()
    pos = rng.integers(0, n_bits, size=n_flip)
    np.bitwise_xor.at(raw.reshape(-1), pos // 8,
                      (1 << (pos % 8)).astype(np.uint8))
    return raw.view(arr.dtype).reshape(arr.shape)


def main(out=print) -> None:
    idx = get_index("sift-like")
    cfg = SearchConfig(k=10, list_size=128, t_init=16, t_step=8,
                       repetition_rate=2, beta=1.06)
    rng = np.random.default_rng(3)
    base = None
    for rate in (0.0, 1e-6, 1e-5, 1e-4, 1e-3):
        codes = flip_bits(idx.codes, rate, rng)
        raw = flip_bits(idx._search_base().astype(np.float32), rate, rng)
        # guard rerank against inf/nan from exponent flips (engine clamps)
        raw = np.nan_to_num(raw, nan=0.0, posinf=1e6, neginf=-1e6)
        corpus = idx.corpus()._replace(
            codes=jnp.asarray(codes), base=jnp.asarray(raw)
        )
        res = search(corpus, idx.dataset.queries, cfg, idx.dataset.metric)
        rec = recall_at_k(np.asarray(res.ids), idx.dataset.gt, 10)
        if base is None:
            base = rec
        out(f"fig17/ber{rate:g},{0:.1f},recall={rec:.4f};delta={rec-base:+.4f}")


if __name__ == "__main__":
    main()
