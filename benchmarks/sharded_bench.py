"""Sharded serving sweep — num_tiles x allocation policy x routing.

Three serving regimes over the same partitioned corpus:

  * **fan-out** — every query probes every tile. Recall jumps well above
    the single-tile graph's ceiling (each tile is searched near-
    exhaustively) at the cost of total work: the acceptance bar is 4-tile
    recall within 1% of single-tile, which fan-out clears with margin.
  * **routed** (cluster policy) — the coarse router sends each query to its
    ``probe_tiles`` nearest tiles only; unprobed channels skip it. This is
    what makes throughput SCALE with the channel count.
  * **routed + scaled frontier** — per-tile ``list_size = L/P``: the
    aggregate candidate budget of the single-tile search, split across
    channels; the max-QPS corner of the trade-off.

Every row reports the channel-parallel NAND model (``simulate_sharded``):
aggregate QPS, scaling vs the single-tile baseline, per-channel core
utilization, straggler load imbalance, and the partition's hot-node
replication overhead.

``--smoke`` shrinks the sweep to cluster x P=4 for CI.

    PYTHONPATH=src python -m benchmarks.sharded_bench [--smoke]
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import get_index, recall_at_k
from repro.configs.base import SearchConfig
from repro.core.dataset import exact_knn
from repro.nand.simulator import (
    simulate,
    simulate_sharded,
    trace_from_plan_execution,
    traces_from_plan_execution,
)
from repro.plan import Searcher, SearchRequest
from repro.shard import partition_index


def main(out=print, smoke: bool = False) -> None:
    idx = get_index("sift-like")
    cfg = SearchConfig(k=10, list_size=128, t_init=16, t_step=8,
                       repetition_rate=3, beta=1.06)
    q = idx.dataset.queries
    metric = idx.dataset.metric
    gt = idx.dataset.gt
    if gt.shape[1] < 10:
        gt = exact_knn(q, idx.dataset.base, 10, metric)
    # --- single-tile baseline ------------------------------------------------
    res1 = Searcher.open(idx, cfg=cfg).search(SearchRequest(queries=q))
    assert res1.plan.kind == "flat", res1.plan.kind
    rec1 = recall_at_k(res1.ids, gt, 10)
    sim1 = simulate(trace_from_plan_execution(res1, index=idx))
    out(f"sharded/baseline/P1,{sim1.latency_us:.1f},"
        f"recall={rec1:.4f};qps={sim1.qps:.0f};util={sim1.core_utilization:.2f}")

    def row(label, part, res):
        rec = recall_at_k(res.ids, gt, 10)
        sim = simulate_sharded(traces_from_plan_execution(res, index=idx))
        utils = ";".join(f"{u:.2f}" for u in sim.channel_utilization)
        out(f"sharded/{label},{sim.latency_us:.1f},"
            f"recall={rec:.4f};d_recall={rec - rec1:+.4f};"
            f"qps={sim.qps:.0f};scaling={sim.qps / sim1.qps:.2f}x;"
            f"ch_util={utils};imbalance={sim.load_imbalance:.2f};"
            f"hot_replica_overhead="
            f"{part.replicated_fraction(idx.dataset.num_base):.3f}")
        return rec

    policies = ("cluster",) if smoke else ("contiguous", "hash", "cluster")
    tile_counts = (4,) if smoke else (2, 4, 8)
    for policy in policies:
        for p in tile_counts:
            tiled, part = partition_index(idx, p, policy)
            searcher = Searcher.open(tiled, cfg=cfg, metric=metric)
            res = searcher.search(SearchRequest(queries=q))
            # planner regressions fail loudly: the tiled spine must serve
            assert res.plan.kind == "tiled" and res.stats.num_tiles == p, \
                f"planner compiled {res.plan.kind}/P={res.stats.num_tiles}"
            rec = row(f"{policy}/P{p}/fanout", part, res)
            if p == 4 and rec < rec1 - 0.01:
                out(f"sharded/{policy}/P4/RECALL_PARITY_FAIL,0.0,"
                    f"recall {rec:.4f} vs single-tile {rec1:.4f}")
            if policy != "cluster":
                continue
            # the router only makes sense with geometry-aware allocation
            for nprobe in (1, 2):
                if nprobe >= p:
                    continue
                res = searcher.search(SearchRequest(queries=q,
                                                    probe_tiles=nprobe))
                row(f"{policy}/P{p}/probe{nprobe}", part, res)
            # max-throughput corner: single-tile candidate budget split
            # across channels + single-tile routing
            small_l = max(2 * cfg.k, cfg.list_size // p)
            res = searcher.search(SearchRequest(
                queries=q, probe_tiles=1, overrides={"list_size": small_l}))
            row(f"{policy}/P{p}/probe1_L{small_l}", part, res)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="cluster x 4 tiles only (CI smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
