import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Distributed Proxima search on the production mesh — the paper-technique
roofline cell (§Perf hillclimb D-series).

Lowers ``core.distributed.distributed_search`` (corpus round-robin over the
16-way ``data`` axis = NAND cores; query batch over the 16-way ``model``
axis = search queues) at 1M-vector scale with ShapeDtypeStructs, compiles,
and parses per-round collective bytes for the two dataflows:

  * mode="fetch": ship PQ CODES to the engine (DiskANN-on-a-host style)
  * mode="nsp":   ship DISTANCES (the paper's near-storage insight)

Run standalone:  PYTHONPATH=src python -m benchmarks.proxima_dryrun
"""
import json

import jax
import jax.numpy as jnp
import numpy as np


def main(out=print) -> None:
    from repro.configs.base import SearchConfig
    from repro.core.distributed import ShardedCorpus, distributed_search_kernel
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import hlo_parse
    from repro.roofline.analysis import ICI_BW

    mesh = make_production_mesh()          # (data=16, model=16)
    n, r, m, c, d = 1_000_000, 64, 32, 256, 128
    q_global = 256
    p = 16
    hot = int(0.03 * n)
    sds = jax.ShapeDtypeStruct
    cfg = SearchConfig(k=10, list_size=128, t_init=16, t_step=8,
                       repetition_rate=2, beta=1.06, max_rounds=192)

    def corpus_shapes(hot_count):
        h = max(hot_count, 1)
        return ShardedCorpus(
            adjacency=sds((p, n // p, r), jnp.int32),
            codes=sds((p, n // p, m), jnp.uint8),
            base=sds((p, n // p, d), jnp.float32),
            centroids=sds((m, c, d // m), jnp.float32),
            hot_adjacency=sds((h, r), jnp.int32),
            hot_codes=sds((h, m), jnp.uint8),
            hot_base=sds((h, d), jnp.float32),
            entry_point=sds((), jnp.int32),
            hot_count=sds((), jnp.int32),
            num_vertices=n,
            num_shards=p,
        )

    queries = sds((q_global, d), jnp.float32)
    results = {}
    for mode in ("fetch", "nsp"):
        lowered = distributed_search_kernel.lower(
            corpus_shapes(hot), queries, cfg, "l2", mode=mode, mesh=mesh,
        )
        compiled = lowered.compile()
        cost = hlo_parse.analyze_text(compiled.as_text())
        per_round = cost.coll_bytes / cfg.max_rounds
        per_query_round = per_round / (q_global / mesh.shape["model"])
        coll_s = cost.coll_bytes / ICI_BW
        results[mode] = dict(
            coll_bytes_per_device=cost.coll_bytes,
            per_round=per_round,
            per_query_round=per_query_round,
            collective_s=coll_s,
            kinds={k: int(v) for k, v in cost.coll_by_kind.items()},
        )
        out(f"proxima-dist/{mode},{per_query_round:.0f},"
            f"coll_bytes/dev={cost.coll_bytes:.3e};"
            f"per_round={per_round:.0f};collective_s={coll_s:.4f}")
    ratio = results["fetch"]["coll_bytes_per_device"] / max(
        results["nsp"]["coll_bytes_per_device"], 1)
    out(f"proxima-dist/nsp_gain,{0:.1f},fetch_over_nsp={ratio:.2f}x "
        f"(paper's NSP thesis: move compute to the data)")
    os.makedirs("results", exist_ok=True)
    with open("results/proxima_dist.json", "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
