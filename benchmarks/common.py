"""Shared benchmark fixtures: synthetic datasets at paper-like geometry and
cached Proxima indexes (graph build is the slow offline phase)."""
from __future__ import annotations

import os
import pickle
import time

import numpy as np

from repro.configs.base import (
    DatasetConfig, GraphConfig, PQConfig, ProximaConfig, SearchConfig,
)
from repro.core import build_index
from repro.core.dataset import make_dataset

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "results/bench_cache")
SCALE = os.environ.get("REPRO_BENCH_SCALE", "small")

# paper datasets stood in at container-feasible scale (Table I geometry)
_SPECS = {
    "small": dict(num_base=4000, num_queries=64),
    "full": dict(num_base=20000, num_queries=256),
}

DATASETS = {
    "sift-like": dict(name="sift-like", dim=128, num_clusters=64,
                      cluster_std=0.35, metric="l2"),
    "glove-like": dict(name="glove-like", dim=100, num_clusters=64,
                       cluster_std=0.35, metric="angular"),
    "deep-like": dict(name="deep-like", dim=96, num_clusters=64,
                      cluster_std=0.35, metric="ip"),
}

_PQ_M = {"sift-like": 32, "glove-like": 25, "deep-like": 32}


def proxima_config(dataset: str, hot: float = 0.03,
                   search: SearchConfig | None = None) -> ProximaConfig:
    spec = dict(DATASETS[dataset])
    spec.update(_SPECS[SCALE])
    return ProximaConfig(
        dataset=DatasetConfig(seed=7, **spec),
        pq=PQConfig(num_subvectors=_PQ_M[dataset], num_centroids=256,
                    kmeans_iters=8),
        graph=GraphConfig(max_degree=32, build_list_size=64, alpha=1.2),
        search=search or SearchConfig(k=10, list_size=128, t_init=16,
                                      t_step=8, repetition_rate=2, beta=1.06),
        hot_node_fraction=hot,
    )


def get_index(dataset: str, hot: float = 0.03):
    """Build (or load cached) Proxima index for a benchmark dataset."""
    os.makedirs(CACHE_DIR, exist_ok=True)
    key = f"{dataset}_{SCALE}_hot{hot}"
    path = os.path.join(CACHE_DIR, key + ".pkl")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    cfg = proxima_config(dataset, hot)
    t0 = time.perf_counter()
    idx = build_index(cfg, reorder_samples=64)
    print(f"# built {key} in {time.perf_counter()-t0:.1f}s")
    with open(path, "wb") as f:
        pickle.dump(idx, f)
    return idx


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """(result, us_per_call)."""
    for _ in range(warmup):
        out = fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    return out, (time.perf_counter() - t0) / iters * 1e6


# --------------------------------------------------------------------- recall
# THE recall@k implementation lives in repro.core.dataset (Paper Eq. (2),
# -1-padding aware) and is shared with the serving-path shadow-recall
# estimator (repro.obs.quality); benches import it from here so the bench
# suite has one entry point and no private reimplementations.
from repro.core.dataset import recall_at_k  # noqa: E402,F401  (re-export)


def served_recall(done, rids, gt, k: int) -> float:
    """recall@k over a ``ServingEngine``'s completed requests: ``done`` maps
    rid -> completed Request, ``rids`` aligns requests with ground-truth
    rows (wrapping modulo len(gt) for multi-pass replays)."""
    nq = gt.shape[0]
    pred = np.stack([np.asarray(done[rid].ids) for rid in rids])
    gtm = np.stack([gt[i % nq] for i in range(len(rids))])
    return recall_at_k(pred, gtm, k)
