"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines (see each module's docstring
for the paper claim it validates).

    PYTHONPATH=src python -m benchmarks.run [--only fig11,fig13]
    REPRO_BENCH_SCALE=full for the larger corpora.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "fig9_nand_tradeoff",
    "gap_compression",
    "fig11_recall_qps",
    "fig12_hw_comparison",
    "fig13_ablation",
    "fig14_traffic",
    "fig15_hotnodes",
    "fig16_queues",
    "fig17_biterror",
    "streaming_bench",
    "sharded_bench",
    "beam_bench",
    "kernels_bench",
    "roofline_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = [s.strip() for s in args.only.split(",") if s.strip()]

    print("name,us_per_call,derived")
    for modname in MODULES:
        if only and not any(modname.startswith(o) for o in only):
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{modname}", fromlist=["main"])
            mod.main(out=print)
            print(f"# {modname} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            print(f"{modname}/FAILED,0.0,{traceback.format_exc().splitlines()[-1]}")
            traceback.print_exc(file=sys.stderr)

    # distributed-search dry-run needs 512 host devices -> own process
    if not only or any("proxima" in o for o in only):
        import os
        import subprocess

        t0 = time.time()
        env = dict(os.environ)
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.proxima_dryrun"],
            capture_output=True, text=True, timeout=900, env=env,
        )
        for line in r.stdout.splitlines():
            if line.startswith("proxima-dist"):
                print(line)
        if r.returncode != 0:
            print(f"proxima_dryrun/FAILED,0.0,rc={r.returncode}")
            print(r.stderr[-1500:], file=sys.stderr)
        else:
            print(f"# proxima_dryrun done in {time.time()-t0:.1f}s",
                  file=sys.stderr)


if __name__ == "__main__":
    main()
