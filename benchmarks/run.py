"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines (see each module's docstring
for the paper claim it validates).

    PYTHONPATH=src python -m benchmarks.run [--only fig11,fig13] [--list]
    REPRO_BENCH_SCALE=full for the larger corpora.

``--only`` takes EXACT module names; append ``*`` for explicit prefix
matching (``--only 'fig1*'`` runs fig11..fig17 — a bare ``fig1`` used to,
silently). ``--list`` prints the registered modules and exits.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "fig9_nand_tradeoff",
    "gap_compression",
    "fig11_recall_qps",
    "fig12_hw_comparison",
    "fig13_ablation",
    "fig14_traffic",
    "fig15_hotnodes",
    "fig16_queues",
    "fig17_biterror",
    "streaming_bench",
    "sharded_bench",
    "beam_bench",
    "filtered_bench",
    "planner_bench",
    "serving_bench",
    "continuous_bench",
    "kernels_bench",
    "roofline_bench",
    "build_bench",
]

# runs in its own subprocess (needs 512 host devices), not importable here
SUBPROCESS_MODULES = ["proxima_dryrun"]


def selected(modname: str, only: list[str]) -> bool:
    """Exact-name match, with ``pattern*`` as the explicit prefix opt-in."""
    for o in only:
        if o.endswith("*"):
            if modname.startswith(o[:-1]):
                return True
        elif modname == o:
            return True
    return False


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated module names (exact; 'prefix*' "
                         "for prefix matching)")
    ap.add_argument("--list", action="store_true",
                    help="print registered benchmark modules and exit")
    args = ap.parse_args()
    only = [s.strip() for s in args.only.split(",") if s.strip()]

    if args.list:
        for modname in MODULES + SUBPROCESS_MODULES:
            print(modname)
        return

    unknown = [o for o in only
               if not any(selected(m, [o]) for m in MODULES + SUBPROCESS_MODULES)]
    if unknown:
        print(f"# --only matched nothing for: {', '.join(unknown)} "
              f"(see --list; use 'prefix*' for prefix matching)",
              file=sys.stderr)

    print("name,us_per_call,derived")
    for modname in MODULES:
        if only and not selected(modname, only):
            continue
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{modname}", fromlist=["main"])
            mod.main(out=print)
            print(f"# {modname} done in {time.perf_counter()-t0:.1f}s", file=sys.stderr)
        except Exception:
            print(f"{modname}/FAILED,0.0,{traceback.format_exc().splitlines()[-1]}")
            traceback.print_exc(file=sys.stderr)

    # distributed-search dry-run needs 512 host devices -> own process
    if not only or selected("proxima_dryrun", only):
        import os
        import subprocess

        t0 = time.perf_counter()
        env = dict(os.environ)
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.proxima_dryrun"],
            capture_output=True, text=True, timeout=900, env=env,
        )
        for line in r.stdout.splitlines():
            if line.startswith("proxima-dist"):
                print(line)
        if r.returncode != 0:
            print(f"proxima_dryrun/FAILED,0.0,rc={r.returncode}")
            print(r.stderr[-1500:], file=sys.stderr)
        else:
            print(f"# proxima_dryrun done in {time.perf_counter()-t0:.1f}s",
                  file=sys.stderr)


if __name__ == "__main__":
    main()
