"""Fig. 11 — recall vs QPS: Proxima search vs DiskANN-PQ-style, HNSW-style
(accurate traversal) and IVF-PQ, on three paper-geometry datasets.

Validates the paper's algorithm claims:
  * Proxima (PQ + beta-rerank + ET) tracks or beats DiskANN-PQ recall at
    equal list size, with fewer accurate distance computations;
  * IVF-PQ saturates below the graph methods (lossy PQ, no rerank).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import DATASETS, get_index, proxima_config, recall_at_k
from repro.configs.base import PQConfig, SearchConfig
from repro.core import graph_search as search
from repro.core.ivf import build_ivf, search_ivf


def _qps(fn, queries, iters=3):
    out = fn(queries)
    jax.block_until_ready(out.ids)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(queries)
        jax.block_until_ready(out.ids)
    dt = (time.perf_counter() - t0) / iters
    return out, queries.shape[0] / dt


def main(out=print) -> None:
    for ds in DATASETS:
        idx = get_index(ds)
        corpus = idx.corpus()
        q = idx.dataset.queries
        gt = idx.dataset.gt
        metric = idx.dataset.metric
        # repetition rate r is per-dataset tuned in the paper (1..15);
        # r=3 suits the easy corpora, harder distributions need more rounds
        r_et = {"sift-like": 3, "glove-like": 4, "deep-like": 6}[ds]
        variants = {
            "proxima": lambda L: SearchConfig(
                k=10, list_size=L, t_init=16, t_step=8, repetition_rate=r_et,
                beta=1.06),
            "diskann-pq": lambda L: SearchConfig(
                k=10, list_size=L, beta=1.0, early_termination=False),
            "hnsw-exact": lambda L: SearchConfig(
                k=10, list_size=L, use_pq=False, early_termination=False),
        }
        for name, mk in variants.items():
            for L in (32, 64, 128):
                cfg = mk(L)
                res, qps = _qps(lambda qq: search(corpus, qq, cfg, metric), q)
                rec = recall_at_k(np.asarray(res.ids), gt, 10)
                acc = float(np.asarray(res.n_acc).mean())
                out(f"fig11/{ds}/{name}/L{L},{1e6/qps:.1f},"
                    f"recall={rec:.4f};acc_dists={acc:.0f};qps={qps:.0f}")
        # IVF-PQ baseline
        ivf = build_ivf(idx.dataset.base, PQConfig(
            num_subvectors=idx.codebook.num_subvectors, num_centroids=256,
            kmeans_iters=8), metric, nlist=64)
        for nprobe in (2, 8, 16):
            t0 = time.perf_counter()
            ids, _, scanned = search_ivf(ivf, q, 10, nprobe=nprobe)
            dt = time.perf_counter() - t0
            rec = recall_at_k(ids, gt, 10)
            out(f"fig11/{ds}/ivf-pq/np{nprobe},{dt/q.shape[0]*1e6:.1f},"
                f"recall={rec:.4f};scanned={scanned.mean():.0f}")


if __name__ == "__main__":
    main()
