"""Fig. 16 — queue-size (N_q) sweep on the NAND model: throughput, energy
efficiency and 3D-NAND core utilization for N_q in 32..512. Paper: 3.8x
throughput gain at 256 queues, utilization 17.9% -> 68%, ~20% efficiency
cost; saturation beyond 256."""
from __future__ import annotations

from benchmarks.common import get_index
from repro.configs.base import SearchConfig
from repro.core import graph_search as search
from repro.nand.simulator import simulate, trace_from_search_result


def main(out=print) -> None:
    idx = get_index("sift-like", hot=0.0)   # paper sweeps without hot nodes
    cfg = SearchConfig(k=10, list_size=128, t_init=16, t_step=8,
                       repetition_rate=2, beta=1.06)
    res = search(idx.corpus(), idx.dataset.queries, cfg, idx.dataset.metric)
    tr = trace_from_search_result(
        res, dim=idx.dataset.dim, r_degree=idx.graph.max_degree,
        index_bits=idx.gap.bit_width if idx.gap else 32,
        pq_bits=idx.codebook.num_subvectors * 8, metric=idx.dataset.metric,
        use_hot=False,
    )
    base = None
    for nq in (32, 64, 128, 256, 512):
        r = simulate(tr, n_queues=nq)
        if base is None:
            base = r
        out(f"fig16/Nq{nq},{r.latency_us:.1f},"
            f"qps={r.qps:.0f};gain={r.qps/base.qps:.2f}x;"
            f"util={r.core_utilization:.2f};"
            f"qps_per_w_rel={r.qps_per_watt/base.qps_per_watt:.2f}")


if __name__ == "__main__":
    main()
