"""Fig. 16 — queue-size (N_q) sweep on the NAND model: throughput, energy
efficiency and 3D-NAND core utilization for N_q in 32..512. Paper: 3.8x
throughput gain at 256 queues, utilization 17.9% -> 68%, ~20% efficiency
cost; saturation beyond 256.

Revived through the SERVING path: each N_q point runs the continuous
(iteration-level) engine over the query set with NAND billing on and the
engine's ``nand_queues`` knob set, so the modeled figures come from the
same per-retire cost accounting production serving reports — not from a
detached trace.  Host-side behavior is identical across the sweep (N_q is
a billing-model parameter); the derived columns are the modeled QPS gain,
utilization and relative efficiency, exactly Fig. 16's axes.
"""
from __future__ import annotations

import time

from benchmarks.common import get_index
from repro.configs.base import SearchConfig
from repro.obs import Observability
from repro.serve import ServingEngine


def main(out=print) -> None:
    idx = get_index("sift-like", hot=0.0)   # paper sweeps without hot nodes
    cfg = SearchConfig(k=10, list_size=128, t_init=16, t_step=8,
                       repetition_rate=2, beta=1.06)
    q = idx.dataset.queries
    base = None
    for nq in (32, 64, 128, 256, 512):
        obs = Observability.on(nand_billing=True)
        eng = ServingEngine(idx, batch_size=16, cfg=cfg, continuous=True,
                            slots=16, obs=obs, nand_queues=nq)
        t0 = time.perf_counter()
        for qq in q:
            eng.submit(qq)
        eng.drain()
        host_qps = len(q) / (time.perf_counter() - t0)
        m = obs.metrics
        qps = m.merged_histogram("nand_model_qps").mean
        util = m.merged_histogram("nand_core_utilization").mean
        power = m.merged_histogram("nand_power_w").mean
        lat = m.merged_histogram("nand_latency_us").mean
        point = dict(qps=qps, ppw=qps / max(power, 1e-9))
        if base is None:
            base = point
        out(f"fig16/Nq{nq},{lat:.1f},"
            f"qps={qps:.0f};gain={qps / base['qps']:.2f}x;"
            f"util={util:.2f};"
            f"qps_per_w_rel={point['ppw'] / base['ppw']:.2f};"
            f"host_qps={host_qps:.0f}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
