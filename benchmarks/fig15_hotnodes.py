"""Fig. 15 — runtime breakdown vs hot-node percentage (0..7%). Re-runs the
search with indexes reordered at each hot fraction and feeds the measured
hot-hit counters through the NAND model. Paper: +1% -> 2.2x, 3% -> ~3x,
plateau beyond 3%."""
from __future__ import annotations

import numpy as np

from benchmarks.common import get_index
from repro.configs.base import SearchConfig
from repro.core import graph_search as search
from repro.nand.simulator import simulate, trace_from_search_result


def main(out=print) -> None:
    ds = "sift-like"
    base_lat = None
    for hot in (0.0, 0.01, 0.03, 0.05, 0.07):
        idx = get_index(ds, hot=hot)
        cfg = SearchConfig(k=10, list_size=128, t_init=16, t_step=8,
                           repetition_rate=2, beta=1.06)
        res = search(idx.corpus(), idx.dataset.queries, cfg,
                     idx.dataset.metric)
        tr = trace_from_search_result(
            res, dim=idx.dataset.dim, r_degree=idx.graph.max_degree,
            index_bits=idx.gap.bit_width if idx.gap else 32,
            pq_bits=idx.codebook.num_subvectors * 8,
            metric=idx.dataset.metric, use_hot=hot > 0,
        )
        r = simulate(tr)
        if base_lat is None:
            base_lat = r.latency_us
        hot_rate = float(np.asarray(res.n_hot_hops).mean()
                         / max(np.asarray(res.n_hops).mean(), 1))
        bd = ";".join(f"{k}={v:.2f}" for k, v in r.breakdown.items())
        out(f"fig15/hot{hot:.2f},{r.latency_us:.1f},"
            f"speedup={base_lat/r.latency_us:.2f}x;hot_hit_rate={hot_rate:.2f};{bd}")

    # paper-scale extrapolation: at 100M scale the reordered graph serves
    # >80-90% of expansions from the hot set (our small corpora reach ~25%);
    # replay the same per-query work with a 90% hot-hit trace to check the
    # model reproduces the paper's ~3x claim under the paper's conditions
    from repro.nand.simulator import WorkloadTrace
    base = WorkloadTrace(hops=40, pq=210, acc=60, hot_hops=0, free_pq=0,
                         rounds=40, dim=128, r_degree=64, index_bits=24,
                         pq_bits=256)
    hot90 = WorkloadTrace(**{**base.__dict__, "hot_hops": 36.0,
                             "free_pq": 189.0})
    r0, r9 = simulate(base), simulate(hot90)
    out(f"fig15/synthetic-hit0.9,{r9.latency_us:.1f},"
        f"speedup={r0.latency_us/r9.latency_us:.2f}x_vs_no_hot;"
        f"paper_claim=~3x_at_their_hit_rates")


if __name__ == "__main__":
    main()
