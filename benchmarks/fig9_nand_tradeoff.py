"""Fig. 9 — 3D NAND density/latency/area tradeoff sweep from the device
model; the Proxima core design point (128B granularity, 64 blocks) must land
< 300 ns while SSD-class pages land in the 10^4-10^5 ns range."""
from __future__ import annotations

from repro.nand.device import NandConfig


def main(out=print) -> None:
    nand = NandConfig()
    out(f"fig9/proxima_core,{nand.read_latency_ns()/1e3:.3f},"
        f"read_ns={nand.read_latency_ns():.0f};page_b={nand.page_bytes};"
        f"capacity_gb={nand.capacity_bits/8/1e9:.0f}")
    for row in nand.latency_density_tradeoff():
        out(f"fig9/page{row['page_bytes']},{row['read_latency_ns']/1e3:.3f},"
            f"latency_ns={row['read_latency_ns']:.0f};"
            f"area_eff={row['area_efficiency']:.2f};blocks={row['n_block']}")


if __name__ == "__main__":
    main()
