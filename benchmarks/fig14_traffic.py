"""Fig. 14 / Fig. 6-b — per-query memory-traffic breakdown (NN-index bytes,
PQ-code bytes, raw-vector bytes) for HNSW, DiskANN-PQ, and Proxima with gap
encoding + early termination. Validates the paper's 1.9-2.4x total traffic
reduction vs HNSW and the 80-90% index-fetch share."""
from __future__ import annotations

import numpy as np

from benchmarks.common import get_index
from benchmarks.fig13_ablation import variant_traces
from repro.nand.simulator import _accesses_per_query
from repro.nand.device import NandConfig


def main(out=print) -> None:
    nand = NandConfig()
    for ds in ("sift-like", "glove-like"):
        idx = get_index(ds)
        traces = variant_traces(idx, idx.dataset.metric)
        totals = {}
        for name, tr in traces.items():
            _, _, _, traffic = _accesses_per_query(tr, nand)
            total = sum(traffic.values())
            totals[name] = total
            shares = ";".join(f"{k}={v/total:.2f}" for k, v in traffic.items())
            out(f"fig14/{ds}/{name},{total:.0f},bytes_per_query;{shares}")
        out(f"fig14/{ds}/reduction,{totals['hnsw']/totals['proxima-GE']:.2f},"
            f"hnsw_over_proximaGE (paper: 1.9-2.4x)")


if __name__ == "__main__":
    main()
