"""Pallas kernel microbenchmarks (interpret mode on CPU — correctness-path
timing; on a real TPU re-run with REPRO_PALLAS_INTERPRET=0) plus the jnp
reference path, which is what the compiled search uses on CPU."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.kernels import ops


def main(out=print) -> None:
    rng = np.random.default_rng(0)
    M, C, dsub, N, Q = 32, 256, 4, 4096, 8
    q = jnp.asarray(rng.standard_normal((Q, M * dsub)), jnp.float32)
    cents = jnp.asarray(rng.standard_normal((M, C, dsub)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, C, (N, M)), jnp.uint8)
    adt = jnp.asarray(rng.standard_normal((M, C)), jnp.float32)
    keys = jnp.asarray(rng.standard_normal((64, 256)), jnp.float32)
    vals = jnp.asarray(rng.integers(0, 1 << 20, (64, 256)), jnp.int32)
    qr = jnp.asarray(rng.standard_normal((Q, 128)), jnp.float32)
    cands = jnp.asarray(rng.standard_normal((Q, 128, 128)), jnp.float32)

    pairs = [
        ("pq_adt", lambda: ops.pq_adt(q, cents), lambda: ops.pq_adt_ref(q, cents)),
        ("pq_lookup", lambda: ops.pq_lookup(codes, adt), lambda: ops.pq_lookup_ref(codes, adt)),
        ("bitonic_sort", lambda: ops.bitonic_sort_pairs(keys, vals),
         lambda: ops.bitonic_sort_pairs_ref(keys, vals)),
        ("l2_rerank", lambda: ops.l2_rerank(qr, cands), lambda: ops.l2_rerank_ref(qr, cands)),
    ]
    import jax

    def blocked(f):
        def g():
            r = f()
            jax.block_until_ready(r)
            return r
        return g

    for name, kern, ref in pairs:
        _, us_k = timed(blocked(kern))
        _, us_r = timed(blocked(ref))
        out(f"kernels/{name}_interp,{us_k:.1f},ref_jnp_us={us_r:.1f}")


if __name__ == "__main__":
    main()
