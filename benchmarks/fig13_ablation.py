"""Fig. 13 — graph algorithms on the Proxima NSP accelerator: HNSW,
DiskANN-PQ, Proxima+G+E (gap encoding + early termination) and
Proxima+G+E+H (+ hot node repetition), all simulated from REAL search
traces through the 3D NAND model. Reports QPS, QPS/W, latency."""
from __future__ import annotations

import numpy as np

from benchmarks.common import get_index
from repro.configs.base import SearchConfig
from repro.core import graph_search as search
from repro.nand.simulator import simulate, trace_from_search_result


def variant_traces(idx, metric):
    corpus = idx.corpus()
    q = idx.dataset.queries
    d = idx.dataset.dim
    r = idx.graph.max_degree
    m = idx.codebook.num_subvectors
    gap_bits = idx.gap.bit_width if idx.gap else 32
    runs = {
        "hnsw": (SearchConfig(k=10, list_size=128, use_pq=False,
                              early_termination=False),
                 dict(index_bits=32, use_pq=False, use_hot=False)),
        "diskann-pq": (SearchConfig(k=10, list_size=128, beta=1.0,
                                    early_termination=False),
                       dict(index_bits=32, use_pq=True, use_hot=False)),
        "proxima-GE": (SearchConfig(k=10, list_size=128, t_init=16, t_step=8,
                                    repetition_rate=2, beta=1.06),
                       dict(index_bits=gap_bits, use_pq=True, use_hot=False)),
        "proxima-GEH": (SearchConfig(k=10, list_size=128, t_init=16, t_step=8,
                                     repetition_rate=2, beta=1.06),
                        dict(index_bits=gap_bits, use_pq=True, use_hot=True)),
    }
    out = {}
    for name, (cfg, kw) in runs.items():
        res = search(corpus, q, cfg, metric)
        out[name] = trace_from_search_result(
            res, dim=d, r_degree=r, pq_bits=m * 8, metric=metric, **kw
        )
    return out


def main(out=print) -> None:
    for ds in ("sift-like", "deep-like"):
        idx = get_index(ds)
        traces = variant_traces(idx, idx.dataset.metric)
        base_qps = None
        for name, tr in traces.items():
            r = simulate(tr)
            if base_qps is None:
                base_qps = r.qps
            out(f"fig13/{ds}/{name},{r.latency_us:.1f},"
                f"qps={r.qps:.0f};qps_per_w={r.qps_per_watt:.0f};"
                f"speedup_vs_hnsw={r.qps/base_qps:.2f}x;"
                f"util={r.core_utilization:.2f}")


if __name__ == "__main__":
    main()
