"""Filtered-search sweep — selectivity in {0.5, 0.1, 0.01, 0.001}.

Production vector search is mostly *filtered* ("nearest WHERE category=shoes
AND price<50"); the ``repro.filter`` subsystem serves those queries with a
selectivity-adaptive regime switch (masked traversal with an inflated
frontier at moderate selectivity, bitmap-driven brute-force PQ scan over the
passing subset when the filter is sharp) and the NAND model bills the
predicate where Proxima's thesis says it belongs: evaluated INSIDE the tile
against attribute words co-located in the page spare area, so only passing
candidates ever cross the channel. Per selectivity the sweep reports:

  * regime chosen + effective list size,
  * recall@10 against the filtered brute-force oracle (exact kNN over the
    passing subset) — acceptance bar: >= 0.9 at selectivity 0.01,
  * simulated QPS/latency of the filtered trace, and
  * pushdown-vs-host-filter channel-transfer energy + latency savings
    (acceptance bar: pushdown strictly cheaper in transfer energy).

``--smoke`` runs selectivities {0.5, 0.01} only (CI).

    PYTHONPATH=src python -m benchmarks.filtered_bench [--smoke]
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import get_index, recall_at_k
from repro.configs.base import SearchConfig
from repro.core.dataset import exact_knn
from repro.filter import FilterSpec, attach_attributes, random_attributes
from repro.nand.simulator import filter_comparison, trace_from_plan_execution
from repro.plan import Searcher, SearchRequest

SELECTIVITIES = (0.5, 0.1, 0.01, 0.001)
PRICE_CARD = 1000   # "price" uniform in [0, 1000): Range(0, s*1000-1) ~ s


def main(out=print, smoke: bool = False) -> None:
    idx = get_index("sift-like")
    n = idx.dataset.num_base
    store = attach_attributes(
        idx, random_attributes(n, {"category": 16, "price": PRICE_CARD},
                               seed=11)
    )
    cfg = SearchConfig(k=10, list_size=128, t_init=16, t_step=8,
                       repetition_rate=3, beta=1.06)
    q = idx.dataset.queries
    metric = idx.dataset.metric
    searcher = Searcher.open(idx, cfg=cfg)

    sweep = (0.5, 0.01) if smoke else SELECTIVITIES
    for s in sweep:
        hi = max(int(round(s * PRICE_CARD)) - 1, 0)
        spec = FilterSpec.range("price", 0, hi)
        mask = store.mask(spec)
        n_pass = int(mask.sum())
        if n_pass == 0:
            out(f"filtered/s{s},0.0,EMPTY;n_pass=0")
            continue
        pres = searcher.search(SearchRequest(queries=q, filter=spec))
        fres = pres.raw
        # planner regressions fail loudly: sharp filters MUST take the
        # bitmap-scan strategy, moderate ones the masked traversal
        expect = "scan" if s <= 0.02 else "masked"
        assert pres.plan.strategy == expect, (
            f"planner chose {pres.plan.strategy!r} at selectivity {s} "
            f"(expected {expect!r})")

        # filtered brute-force oracle: exact kNN over the passing subset
        pids = np.nonzero(mask)[0]
        k_eff = min(cfg.k, n_pass)
        gt = pids[exact_knn(q, idx.dataset.base[pids], k_eff, metric)]
        rec = recall_at_k(pres.ids, gt, k_eff)

        trace = trace_from_plan_execution(pres, index=idx)
        cmpres = filter_comparison(trace)
        push, host = cmpres["pushdown"], cmpres["host"]
        out(f"filtered/s{s},{push.latency_us:.1f},"
            f"mode={fres.mode};sel={fres.selectivity:.4f};n_pass={n_pass};"
            f"eff_L={fres.effective.list_size};recall={rec:.4f};"
            f"qps={push.qps:.0f};"
            f"xfer_pj_push={push.transfer_pj_per_query:.0f};"
            f"xfer_pj_host={host.transfer_pj_per_query:.0f};"
            f"xfer_ratio={cmpres['transfer_energy_ratio']:.3f};"
            f"host_lat_speedup={cmpres['latency_speedup']:.2f}x")
        if abs(s - 0.01) < 1e-9 and rec < 0.9:
            out(f"filtered/s{s}/RECALL_FAIL,0.0,"
                f"recall {rec:.4f} < 0.9 vs filtered oracle")
        if push.transfer_pj_per_query >= host.transfer_pj_per_query:
            out(f"filtered/s{s}/PUSHDOWN_FAIL,0.0,"
                f"pushdown transfer {push.transfer_pj_per_query:.0f}pJ "
                f">= host {host.transfer_pj_per_query:.0f}pJ")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="selectivities {0.5, 0.01} only (CI smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
