"""Model-zoo x Proxima integration: semantic image retrieval.

The PaliGemma (smoke) backbone embeds synthetic patch-embedding "images";
the embeddings feed a Proxima index; nearest-neighbour retrieval then runs
through the paper's search algorithm. This is the DESIGN.md §4 integration
point: the ANNS layer is orthogonal to the architecture — any encoder
output can be indexed.

    PYTHONPATH=src python examples/image_retrieval.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import build_model
from repro.serve.retrieval import EmbeddingRetriever

print("embedding 512 synthetic images with the paligemma-3b smoke backbone ...")
cfg = get_smoke_config("paligemma-3b")
model = build_model(cfg, q_chunk=64)
params, _ = model.init(jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
# 16 "classes" of images: patch embeddings cluster per class
centers = rng.standard_normal((16, cfg.frontend_tokens, cfg.frontend_dim))
labels = rng.integers(0, 16, 512)
frontends = (centers[labels]
             + 0.3 * rng.standard_normal((512, cfg.frontend_tokens,
                                          cfg.frontend_dim))).astype(np.float32)


@jax.jit
def embed(frontend):
    batch = {"tokens": jnp.zeros((frontend.shape[0], 4), jnp.int32),
             "frontend": frontend}
    x, pos, pre = model._embed_inputs(params, batch)
    h, _, _ = model._decoder_stack(params, x, pos, prefix_len=pre)
    return h[:, :pre, :].mean(axis=1)          # pooled image embedding


embs = []
for s in range(0, 512, 64):
    embs.append(np.asarray(embed(jnp.asarray(frontends[s:s + 64]))))
embs = np.concatenate(embs).astype(np.float32)

print("indexing with Proxima (graph + PQ + hot nodes) ...")
retr = EmbeddingRetriever(embs, metric="angular")

hits = total = 0
for qi in rng.choice(512, 32, replace=False):
    ids, _ = retr.query(embs[qi], k=6)
    neigh = [i for i in ids[0].tolist() if i != qi][:5]
    hits += sum(labels[n] == labels[qi] for n in neigh)
    total += len(neigh)
print(f"label purity of retrieved neighbours: {hits/total:.2%} "
      f"(random would be ~6%)")
