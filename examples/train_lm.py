"""Train a small LM end-to-end with the production training stack
(AdamW + microbatching + checkpoints + fault tolerance) on the synthetic
pipeline. Defaults to a ~20M model for CPU speed; pass --params-millions 100
for the ~100M run.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import subprocess
import sys
import argparse

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--params-millions", type=float, default=20)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

cmd = [
    sys.executable, "-m", "repro.launch.train",
    "--params-millions", str(args.params_millions),
    "--steps", str(args.steps),
    "--batch", "8", "--seq", "129", "--microbatches", "2",
    "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50", "--log-every", "10",
]
print("+", " ".join(cmd))
sys.exit(subprocess.call(cmd))
