"""End-to-end serving driver (the paper's workload kind): batched ANN query
serving through the query-plan layer — one ``Searcher`` facade for direct
calls, the ``ServingEngine`` (built on the same facade) for queued serving
with fixed-batch scheduling, latency percentiles and recall — plus a
filtered-query flow ("nearest WHERE category=c AND price<=p"):
per-request ``FilterSpec``s compile to ``QueryPlan``s, requests batch by
plan cache key, and results come back against only attribute-passing nodes.

    PYTHONPATH=src python examples/ann_serving.py
"""
import os
import time

import numpy as np

from repro.configs.base import (
    DatasetConfig, GraphConfig, PQConfig, ProximaConfig, SearchConfig,
)
from repro.core import build_index, recall_at_k
from repro.core.dataset import exact_knn
from repro.filter import FilterSpec, attach_attributes, random_attributes
from repro.obs import Observability
from repro.plan import Searcher, SearchRequest
from repro.serve.engine import ServingEngine

cfg = ProximaConfig(
    dataset=DatasetConfig(name="sift-like", num_base=3000, num_queries=192,
                          dim=64, num_clusters=24, cluster_std=0.35, seed=1),
    pq=PQConfig(num_subvectors=32, num_centroids=128),
    graph=GraphConfig(max_degree=24, build_list_size=48),
    search=SearchConfig(k=10, list_size=64, t_init=16, t_step=8,
                        repetition_rate=2, beta=1.06),
    hot_node_fraction=0.03,
)
print("building index ...")
idx = build_index(cfg)
# workload attributes (category/price per vector) for the filtered flow
store = attach_attributes(
    idx, random_attributes(idx.dataset.num_base,
                           {"category": 8, "price": 1000}, seed=2)
)

# --- the Searcher facade: the one supported query API -----------------------
searcher = Searcher.open(idx)
res = searcher.search(SearchRequest(queries=idx.dataset.queries[:8]))
print(f"direct search: plan={res.plan.kind}/{res.plan.strategy} "
      f"rounds/query {res.stats.rounds:.1f} hops/query {res.stats.hops:.1f}")

# full observability: metrics registry + Chrome trace + per-batch NAND billing
obs = Observability.on(tracing=True, nand_billing=True)
eng = ServingEngine(idx, batch_size=32, obs=obs)

print("serving 192 requests (open loop, bursty arrivals) ...")
t0 = time.time()
rng = np.random.default_rng(0)
for i, q in enumerate(idx.dataset.queries):
    eng.submit(q)
    if rng.random() < 0.2:
        time.sleep(0.002)          # bursty arrival gaps
    eng.step()
eng.drain()
dt = time.time() - t0

done = sorted(eng.done.values(), key=lambda r: r.rid)
lats = np.asarray([r.latency_ms for r in done])
ids = np.stack([r.ids for r in done])
rec = recall_at_k(ids, idx.dataset.gt, 10)
print(f"QPS {len(done)/dt:.0f} | latency p50 {np.percentile(lats, 50):.1f}ms "
      f"p95 {np.percentile(lats, 95):.1f}ms p99 {np.percentile(lats, 99):.1f}ms")
print(f"recall@10 {rec:.3f} | batches {eng.stats['batches']} "
      f"(avg pad {eng.stats['pad_fraction']:.0%})")

# --- filtered queries: same engine, per-request FilterSpec ------------------
print("serving 64 filtered requests (category=3, price<=250) ...")
spec = FilterSpec.eq("category", 3) & FilterSpec.range("price", None, 250)
mask = store.mask(spec)
# the planner compiles the spec once; every matching request plan-cache-hits
fplan = eng.searcher.plan(SearchRequest(queries=idx.dataset.queries[0],
                                        filter=spec))
print(f"filtered plan: {fplan.kind}/{fplan.strategy} "
      f"selectivity={fplan.selectivity:.3f} eff_L={fplan.cfg.list_size}")
frids = [eng.submit(q, filter=spec) for q in idx.dataset.queries[:64]]
eng.drain()
fids = np.stack([eng.done[r].ids for r in frids])
# filtered oracle: exact kNN over the passing subset only
pids = np.nonzero(mask)[0]
k_eff = min(10, len(pids))
fgt = pids[exact_knn(idx.dataset.queries[:64], idx.dataset.base[pids],
                     k_eff, idx.dataset.metric)]
frec = recall_at_k(fids, fgt, k_eff)
print(f"filter selectivity {mask.mean():.3f} ({int(mask.sum())} passing) | "
      f"filtered recall@{k_eff} {frec:.3f} | "
      f"filtered queries {eng.stats['filtered_queries']} | "
      f"plan cache {eng.stats['plan_cache_hits']} hits / "
      f"{eng.stats['plan_cache_misses']} misses")

# --- observability: the same run, as measured by the engine itself ----------
m = obs.metrics
lat = m.merged_histogram("request_latency_ms")
wait = m.merged_histogram("queue_wait_ms")
pj = m.merged_histogram("nand_pj_per_query")
print("\nobservability snapshot (engine-measured):")
print(f"  latency p50 {lat.quantile(50):.1f}ms p95 {lat.quantile(95):.1f}ms "
      f"p99 {lat.quantile(99):.1f}ms | queue-wait p50 {wait.quantile(50):.1f}ms")
print(f"  NAND model: {pj.mean/1e6:.2f} uJ/query | "
      f"plan cache hits {m.counter_total('plan_cache_hits'):.0f} | "
      f"batch occupancy {m.gauge_value('batch_occupancy'):.0%}")
os.makedirs("results", exist_ok=True)
m.to_json("results/serving_metrics.json")
obs.tracer.export("results/serving_trace.json")
print("  wrote results/serving_metrics.json + results/serving_trace.json "
      "(open the trace in chrome://tracing or ui.perfetto.dev)")
