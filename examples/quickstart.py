"""Quickstart: build a Proxima index, search it, project onto the 3D NAND
accelerator model.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.base import (
    DatasetConfig, GraphConfig, PQConfig, ProximaConfig, SearchConfig,
)
from repro.core import build_index, recall_at_k, search
from repro.nand.simulator import simulate, trace_from_search_result

# 1. a synthetic corpus (offline stand-in for SIFT; see DESIGN.md §7)
cfg = ProximaConfig(
    dataset=DatasetConfig(name="sift-like", num_base=3000, num_queries=64,
                          dim=64, num_clusters=24, cluster_std=0.35, seed=0),
    pq=PQConfig(num_subvectors=32, num_centroids=128),     # §III-B
    graph=GraphConfig(max_degree=24, build_list_size=48),  # Vamana-style
    search=SearchConfig(k=10, list_size=64, t_init=16, t_step=8,
                        repetition_rate=2, beta=1.06),     # Algorithm 1
    hot_node_fraction=0.03,                                # §IV-E
)

print("building index (PQ + graph + reorder + gap encoding) ...")
idx = build_index(cfg)
print(f"  gap encoding: {idx.gap.bit_width} bits/edge "
      f"({idx.gap.compression_ratio:.0%} saved vs 32-bit)")
print(f"  hot nodes: {idx.hot_count} ({cfg.hot_node_fraction:.0%})")
print(f"  storage: {idx.index_bytes()}")

# 2. batched search (Algorithm 1, JAX)
res = search(idx.corpus(), idx.dataset.queries, cfg.search, idx.dataset.metric)
rec = recall_at_k(np.asarray(res.ids), idx.dataset.gt, 10)
print(f"\nrecall@10 = {rec:.3f}")
print(f"per query: {np.asarray(res.n_hops).mean():.0f} expansions, "
      f"{np.asarray(res.n_pq).mean():.0f} PQ distances, "
      f"{np.asarray(res.n_acc).mean():.0f} accurate distances "
      f"({np.asarray(res.n_hot_hops).mean():.0f} hot hits)")

# 3. project the measured trace onto the 3D NAND accelerator (§IV)
tr = trace_from_search_result(
    res, dim=idx.dataset.dim, r_degree=idx.graph.max_degree,
    index_bits=idx.gap.bit_width, pq_bits=idx.codebook.num_subvectors * 8,
    metric=idx.dataset.metric)
sim = simulate(tr)
print(f"\nProxima accelerator projection: {sim.qps:,.0f} QPS, "
      f"{sim.latency_us:.0f} us/query, {sim.qps_per_watt:,.0f} QPS/W, "
      f"core util {sim.core_utilization:.0%}")
