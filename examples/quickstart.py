"""Quickstart: build a Proxima index, search it, project onto the 3D NAND
accelerator model.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.base import (
    DatasetConfig, GraphConfig, PQConfig, ProximaConfig, SearchConfig,
)
from repro.core import build_index, recall_at_k
from repro.nand.simulator import simulate, trace_from_plan_execution
from repro.plan import Searcher, SearchRequest

# 1. a synthetic corpus (offline stand-in for SIFT; see DESIGN.md §7)
cfg = ProximaConfig(
    dataset=DatasetConfig(name="sift-like", num_base=3000, num_queries=64,
                          dim=64, num_clusters=24, cluster_std=0.35, seed=0),
    pq=PQConfig(num_subvectors=32, num_centroids=128),     # §III-B
    graph=GraphConfig(max_degree=24, build_list_size=48),  # Vamana-style
    search=SearchConfig(k=10, list_size=64, t_init=16, t_step=8,
                        repetition_rate=2, beta=1.06),     # Algorithm 1
    hot_node_fraction=0.03,                                # §IV-E
)

print("building index (PQ + graph + reorder + gap encoding) ...")
idx = build_index(cfg)
print(f"  gap encoding: {idx.gap.bit_width} bits/edge "
      f"({idx.gap.compression_ratio:.0%} saved vs 32-bit)")
print(f"  hot nodes: {idx.hot_count} ({cfg.hot_node_fraction:.0%})")
print(f"  storage: {idx.index_bytes()}")

# 2. batched search (Algorithm 1 through the query-plan layer)
searcher = Searcher.open(idx)
res = searcher.search(SearchRequest(queries=idx.dataset.queries))
rec = recall_at_k(res.ids, idx.dataset.gt, 10)
print(f"\nrecall@10 = {rec:.3f} (plan: {res.plan.kind}/{res.plan.strategy})")
print(f"per query: {res.stats.hops:.0f} expansions, "
      f"{res.stats.pq:.0f} PQ distances, "
      f"{res.stats.acc:.0f} accurate distances "
      f"({res.stats.hot_hops:.0f} hot hits)")

# 3. project the executed plan onto the 3D NAND accelerator (§IV)
tr = trace_from_plan_execution(res, index=idx)
sim = simulate(tr)
print(f"\nProxima accelerator projection: {sim.qps:,.0f} QPS, "
      f"{sim.latency_us:.0f} us/query, {sim.qps_per_watt:,.0f} QPS/W, "
      f"core util {sim.core_utilization:.0%}")
