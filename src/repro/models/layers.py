"""Transformer building blocks: RMSNorm, RoPE, GQA/SWA attention (chunked,
flash-style memory footprint), SwiGLU MLP, and capacity-based MoE.

Everything is a pure function over an explicit parameter dict. Parameters are
created by the matching ``init_*`` functions which also return a *logical
sharding spec* pytree (axis names resolved to mesh axes by
``repro.distributed.sharding``).

Logical axis vocabulary:
    "embed"   — d_model            -> sharded on "model"
    "heads"   — attention heads    -> "model"
    "kv"      — kv heads           -> "model" (if divisible) else replicated
    "mlp"     — FFN hidden         -> "model"
    "vocab"   — vocabulary         -> "model"
    "experts" — MoE experts        -> "model" (expert parallelism)
    None      — replicated
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shard_lib

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norm / RoPE
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = (x32 * x32).mean(-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA / SWA), chunked over query blocks
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d ** -0.5
    dt = _dtype(cfg)
    p = {
        "wq": (jax.random.normal(k1, (d, nq * hd)) * scale).astype(dt),
        "wk": (jax.random.normal(k2, (d, nkv * hd)) * scale).astype(dt),
        "wv": (jax.random.normal(k3, (d, nkv * hd)) * scale).astype(dt),
        "wo": (jax.random.normal(k4, (nq * hd, d)) * (nq * hd) ** -0.5).astype(dt),
    }
    s = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv"),
        "wv": ("embed", "kv"),
        "wo": ("heads", "embed"),
    }
    return p, s


def _attn_mask(q_pos, k_pos, sliding_window: int, prefix_len: int = 0):
    """(Sq, Sk) boolean mask. Causal, optional sliding window, optional
    bidirectional prefix (PaliGemma-style prefix-LM)."""
    causal = q_pos[:, None] >= k_pos[None, :]
    if prefix_len > 0:
        bidir = (q_pos[:, None] < prefix_len) & (k_pos[None, :] < prefix_len)
        causal = causal | bidir
    if sliding_window > 0:
        causal &= q_pos[:, None] - k_pos[None, :] < sliding_window
    return causal


def attention(
    p: Params,
    x: jnp.ndarray,                  # (B, S, d)
    cfg: ModelConfig,
    positions: jnp.ndarray,          # (B, S)
    kv_cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    cache_len: Optional[jnp.ndarray] = None,
    q_chunk: int = 1024,
    prefix_len: int = 0,
    attend_cache: bool = False,
) -> Tuple[jnp.ndarray, Optional[Tuple[jnp.ndarray, jnp.ndarray]]]:
    """GQA attention. With ``kv_cache=(k,v)`` of shape (B, C, Hkv, hd) this is
    a decode/prefill-extend step: new k/v are written at ``cache_len`` and
    attention runs over the cache. Returns (out, new_cache)."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    g = nq // nkv

    p = shard_lib.param_hints(p, {
        "wq": ("embed", "heads"), "wk": ("embed", "kv"),
        "wv": ("embed", "kv"), "wo": ("heads", "embed"),
    })
    q = (x @ p["wq"]).reshape(b, s, nq, hd)
    k = (x @ p["wk"]).reshape(b, s, nkv, hd)
    v = (x @ p["wv"]).reshape(b, s, nkv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache
        cap = ck.shape[1]
        ring = cfg.sliding_window > 0 and cap <= 2 * cfg.sliding_window
        if s > cap and not ring:
            raise ValueError(
                f"prefill length {s} exceeds non-ring cache capacity {cap}"
            )
        # write the (last cap) new k/v into the cache. Slots are pos % cap in
        # ring mode; the slice below guarantees no duplicate slots.
        if s >= cap:
            offs = jnp.arange(s - cap, s)
            kw, vw = k[:, -cap:], v[:, -cap:]
        else:
            offs = jnp.arange(s)
            kw, vw = k, v
        idx = (cache_len + offs) % cap if ring else cache_len + offs
        ck = ck.at[:, idx].set(kw.astype(ck.dtype))
        cv = cv.at[:, idx].set(vw.astype(cv.dtype))
        new_cache = (ck, cv)
        if s > 1 and not attend_cache:
            # single-shot prefill: attend over the in-flight k/v (window mask
            # applies); the cache is only written for subsequent decode steps
            k_all, v_all = k, v
            k_pos_all = positions
        else:
            # decode, or segmented (chunked) prefill: attend over the cache
            # (already containing this segment's keys); absolute-position
            # masking handles both full and ring buffers
            k_all, v_all = ck, cv
            k_pos_all = _cache_positions(cache_len, s, cap, ring)
    else:
        k_all, v_all = k, v
        k_pos_all = positions

    # grouped heads: (B, S, Hkv, G, hd) — constrain head sharding so the
    # attention einsums stay model-parallel (GSPMD loses it through the
    # chunking reshapes otherwise; see EXPERIMENTS.md §Perf iteration 1)
    qg = shard_lib.hint(q.reshape(b, s, nkv, g, hd), shard_lib.qkv_spec)
    k_all = shard_lib.hint(k_all, shard_lib.qkv_spec)
    v_all = shard_lib.hint(v_all, shard_lib.qkv_spec)
    scale = hd ** -0.5

    def attend_chunk(q_blk, qpos_blk):
        # q_blk (B, sq, Hkv, G, hd)
        logits = jnp.einsum(
            "bqhgd,bkhd->bhgqk", q_blk.astype(jnp.float32),
            k_all.astype(jnp.float32),
        ) * scale
        if cfg.logit_softcap > 0:
            c = cfg.logit_softcap
            logits = c * jnp.tanh(logits / c)
        mask = jax.vmap(
            lambda qp, kp: _attn_mask(qp, kp, cfg.sliding_window, prefix_len)
        )(qpos_blk, jnp.broadcast_to(k_pos_all, (b, k_pos_all.shape[-1])))
        logits = jnp.where(mask[:, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum(
            "bhgqk,bkhd->bqhgd", w.astype(v_all.dtype), v_all
        )

    if s > q_chunk and s % q_chunk == 0:
        nchunks = s // q_chunk
        qs = qg.reshape(b, nchunks, q_chunk, nkv, g, hd).swapaxes(0, 1)
        ps = positions.reshape(b, nchunks, q_chunk).swapaxes(0, 1)
        out = jax.lax.map(
            lambda args: shard_lib.hint(
                attend_chunk(shard_lib.hint(args[0], shard_lib.qkv_spec),
                             args[1]),
                shard_lib.qkv_spec,
            ),
            (qs, ps),
        )
        out = out.swapaxes(0, 1).reshape(b, s, nq * hd)
    else:
        out = attend_chunk(qg, positions).reshape(b, s, nq * hd)
    out = shard_lib.hint(out, shard_lib.heads_concat_spec)
    return out @ p["wo"], new_cache


def _cache_positions(cache_len, s_new, cap, ring: bool):
    """Absolute positions represented in the cache (for masking)."""
    if ring:
        # ring buffer: slot i holds the largest position p < total with
        # p % cap == i; slots not yet written get a huge position (masked).
        total = cache_len + s_new
        slot = jnp.arange(cap)
        last_full = total - 1
        pos = slot + ((last_full - slot) // cap) * cap
        pos = jnp.where((pos < total) & (pos >= 0), pos, jnp.int32(2**30))
        return pos[None, :]
    pos = jnp.arange(cap)
    return jnp.where(pos < cache_len + s_new, pos, 2**30)[None, :]


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Tuple[Params, Params]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    dt = _dtype(cfg)
    if cfg.mlp_variant == "gelu":
        p = {
            "wi_up": (jax.random.normal(k2, (d, f)) * d**-0.5).astype(dt),
            "wo": (jax.random.normal(k3, (f, d)) * f**-0.5).astype(dt),
        }
        s = {"wi_up": ("embed", "mlp"), "wo": ("mlp", "embed")}
        return p, s
    p = {
        "wi_gate": (jax.random.normal(k1, (d, f)) * d**-0.5).astype(dt),
        "wi_up": (jax.random.normal(k2, (d, f)) * d**-0.5).astype(dt),
        "wo": (jax.random.normal(k3, (f, d)) * f**-0.5).astype(dt),
    }
    s = {"wi_gate": ("embed", "mlp"), "wi_up": ("embed", "mlp"), "wo": ("mlp", "embed")}
    return p, s


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    p = shard_lib.param_hints(p, {
        "wi_gate": ("embed", "mlp"), "wi_up": ("embed", "mlp"),
        "wo": ("mlp", "embed"),
    })
    if "wi_gate" not in p:
        return jax.nn.gelu(x @ p["wi_up"]) @ p["wo"]
    return (jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])) @ p["wo"]


def init_moe(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = _dtype(cfg)
    p = {
        "router": (jax.random.normal(k1, (d, e)) * d**-0.5).astype(jnp.float32),
        "wi_gate": (jax.random.normal(k2, (e, d, f)) * d**-0.5).astype(dt),
        "wi_up": (jax.random.normal(k3, (e, d, f)) * d**-0.5).astype(dt),
        "wo": (jax.random.normal(k4, (e, f, d)) * f**-0.5).astype(dt),
    }
    s = {
        "router": ("embed", None),
        "wi_gate": ("experts", "embed", "mlp"),
        "wi_up": ("experts", "embed", "mlp"),
        "wo": ("experts", "mlp", "embed"),
    }
    return p, s


def moe(
    p: Params, x: jnp.ndarray, cfg: ModelConfig, capacity_factor: float = 1.25,
    dispatch_hint: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-based top-k routing with SCATTER/GATHER dispatch.

    The classic Shazeer dense-dispatch einsum materializes a (T, E*cap)
    one-hot — O(T^2) at pod scale (1M tokens -> petabytes). Here each
    (token, slot) computes its destination ``expert*cap + position`` and is
    scattered into the (E*cap, d) expert buffer (mode="drop" implements
    capacity dropping for free); results are gathered back with the same
    index map. Memory is O(T*k*d), and under GSPMD the scatter/gather lower
    to the expert-parallel all-to-alls. Returns (out, aux_loss).
    """
    b, s, d = x.shape
    e, kk = cfg.num_experts, cfg.experts_per_token
    t = b * s
    p = shard_lib.param_hints(p, {
        "router": ("embed", None),
        "wi_gate": ("experts", "embed", "mlp"),
        "wi_up": ("experts", "embed", "mlp"),
        "wo": ("experts", "mlp", "embed"),
    })
    xt = x.reshape(t, d)
    logits = (xt.astype(jnp.float32) @ p["router"])             # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, kk)              # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(int(np.ceil(t * kk / e * capacity_factor)), 4)
    flat_idx = gate_idx.reshape(-1)                             # (T*k,)
    oh = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)           # (T*k, E)
    pos_all = jnp.cumsum(oh, axis=0) - oh                       # (T*k, E)
    pos = jnp.take_along_axis(pos_all, flat_idx[:, None], 1)[:, 0]
    keep = pos < cap
    dest = jnp.where(keep, flat_idx * cap + pos, e * cap)       # OOB -> drop

    x_rep = jnp.repeat(xt, kk, axis=0)                          # (T*k, d)
    buf = jnp.zeros((e * cap, d), x.dtype).at[dest].set(x_rep, mode="drop")
    # NOTE: the expert einsum chain is deliberately UNconstrained — GSPMD's
    # preferred strategy is a partial expert-dim sharding that NamedSharding
    # cannot express; forcing it inserts involuntary-rematerialization
    # copies (EXPERIMENTS.md §Perf, hypotheses M2/M4). The dispatch-buffer
    # hint alone is a per-arch tuning knob: it halves collective time for
    # few-expert models (mixtral E=8) and doubles it for many-expert ones
    # (granite E=40) — see the M4/M5 log.
    if dispatch_hint:
        buf = shard_lib.hint(buf, shard_lib.moe_buffer_spec)
    xe = buf.reshape(e, cap, d)
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi_gate"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xe, p["wi_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(e * cap, d)
    y = ye.at[jnp.minimum(dest, e * cap - 1)].get(mode="clip")  # (T*k, d)
    y = jnp.where(keep[:, None], y, 0.0)
    out = (
        (y.reshape(t, kk, d) * gate_vals[..., None].astype(y.dtype)).sum(1)
    ).reshape(b, s, d)
    # load-balancing aux loss (Switch-style)
    density = jax.nn.one_hot(gate_idx, e).any(1).astype(jnp.float32).mean(0)
    p_mean = probs.mean(0)
    aux = (density * p_mean).sum() * (e ** 2) / kk
    return out, aux
