"""repro.models subpackage."""
