"""Selective state-space blocks (Mamba-1 for falcon-mamba, Mamba-2/SSD for
zamba2) — TPU-native formulation.

The CUDA reference implementations use a hardware-aware fused scan kernel;
the TPU-idiomatic equivalent (DESIGN.md §2) is a CHUNKED associative scan:
the sequence is split into chunks of ``chunk`` steps; within a chunk the
recurrence h_t = a_t * h_{t-1} + b_t runs as ``jax.lax.associative_scan``
(log-depth, VPU-friendly), and a sequential ``lax.scan`` carries the
(d_inner, d_state) boundary state across chunks. Peak memory is
O(chunk * d_inner * d_state) instead of O(S * d_inner * d_state), which is
what lets the 500k-token decode/prefill cells fit HBM.

Decode (S=1) reuses the same cell with the carried state — the SSM's "KV
cache" is the O(1) (d_inner, d_state) state, the reason the long_500k cell
runs on SSM/hybrid archs only.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shard_lib

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def init_mamba(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    """Mamba-1 block parameters (falcon-mamba geometry)."""
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ds = cfg.ssm_state
    dt_rank = max(di // 16, 1)
    conv = cfg.ssm_conv
    ks = jax.random.split(key, 6)
    dt = _dtype(cfg)
    p = {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * d**-0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (conv, di)) * conv**-0.5).astype(dt),
        "x_proj": (jax.random.normal(ks[2], (di, dt_rank + 2 * ds)) * di**-0.5).astype(dt),
        "dt_proj": (jax.random.normal(ks[3], (dt_rank, di)) * dt_rank**-0.5).astype(dt),
        "dt_bias": jnp.asarray(
            np.log(np.expm1(np.random.default_rng(0).uniform(1e-3, 0.1, di))),
            jnp.float32,
        ),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (di, d)) * di**-0.5).astype(dt),
    }
    s = {
        "in_proj": ("embed", "mlp"),
        "conv_w": (None, "mlp"),
        "x_proj": ("mlp", None),
        "dt_proj": (None, "mlp"),
        "dt_bias": ("mlp",),
        "a_log": ("mlp", None),
        "d_skip": ("mlp",),
        "out_proj": ("mlp", "embed"),
    }
    return p, s


def selective_scan(
    dt_: jnp.ndarray,      # (B, S, di) input-dependent step sizes
    a_mat: jnp.ndarray,    # (di, ds) continuous-time decay (negative)
    xi: jnp.ndarray,       # (B, S, di) inputs
    b_in: jnp.ndarray,     # (B, S, ds) input gates
    c_in: jnp.ndarray,     # (B, S, ds) output gates
    h0: jnp.ndarray,       # (B, di, ds) initial state
    chunk: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked selective scan: h_t = exp(dt_t a) h_{t-1} + (dt_t xi_t) b_t,
    y_t = <h_t, c_t>. The (chunk, di, ds) decay/input tensors are built
    INSIDE the chunk loop — peak memory is O(chunk*di*ds), never
    O(S*di*ds) (a 4.3 GB/layer difference at 4k tokens for zamba2; see
    EXPERIMENTS.md §Perf). Returns (y (B,S,di), h_last)."""
    bsz, s, di = xi.shape
    ds = a_mat.shape[1]
    if s % chunk != 0:
        chunk = s
    nchunks = s // chunk

    def to_chunks(x):
        return x.reshape(bsz, nchunks, chunk, *x.shape[2:]).swapaxes(0, 1)

    dt_c, xi_c, b_c, c_c = map(to_chunks, (dt_, xi, b_in, c_in))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    def chunk_step(h, inp):
        dtk, xik, bk, ck = inp             # (B, chunk, ...)
        a_bar = jnp.exp(dtk[..., None] * a_mat[None, None])   # (B,c,di,ds)
        a_bar = shard_lib.hint(a_bar, shard_lib.ssm_state_spec)
        b_bar = (dtk * xik)[..., None] * bk[:, :, None, :]
        b_bar = shard_lib.hint(b_bar, shard_lib.ssm_state_spec)
        b_bar = b_bar.at[:, 0].add(a_bar[:, 0] * h)  # fold carried state
        _, hh = jax.lax.associative_scan(combine, (a_bar, b_bar), axis=1)
        yk = (hh * ck[:, :, None, :]).sum(-1)        # (B, chunk, di)
        return hh[:, -1], yk

    h_last, ys = jax.lax.scan(chunk_step, h0, (dt_c, xi_c, b_c, c_c))
    y = ys.swapaxes(0, 1).reshape(bsz, s, di)
    return y, h_last


def mamba(
    p: Params,
    x: jnp.ndarray,                      # (B, S, d)
    cfg: ModelConfig,
    state: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    chunk: int = 256,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Mamba-1 selective SSM. ``state = (conv_state (B, conv-1, di),
    ssm_state (B, di, ds))`` enables stateful decode. Returns (y, new_state).
    """
    bsz, s, d = x.shape
    di = cfg.ssm_expand * d
    ds = cfg.ssm_state
    conv = cfg.ssm_conv
    dt_rank = max(di // 16, 1)

    p = shard_lib.param_hints(p, {
        "in_proj": ("embed", "mlp"), "x_proj": ("mlp", None),
        "dt_proj": (None, "mlp"), "out_proj": ("mlp", "embed"),
        "conv_w": (None, "mlp"),
    })
    xz = x @ p["in_proj"]                               # (B, S, 2di)
    xi, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv1d
    if state is not None:
        conv_state = state[0]                           # (B, conv-1, di)
        xi_pad = jnp.concatenate([conv_state.astype(xi.dtype), xi], axis=1)
    else:
        xi_pad = jnp.pad(xi, ((0, 0), (conv - 1, 0), (0, 0)))
    new_conv_state = xi_pad[:, -(conv - 1):, :] if conv > 1 else jnp.zeros(
        (bsz, 0, di), xi.dtype
    )
    idx = jnp.arange(s)[:, None] + jnp.arange(conv)[None, :]
    xw = xi_pad[:, idx, :]                              # (B, S, conv, di)
    xi = jax.nn.silu((xw * p["conv_w"][None, None]).sum(2))

    # input-dependent SSM parameters
    proj = xi @ p["x_proj"]                             # (B, S, dt_rank+2ds)
    dt_in = proj[..., :dt_rank]
    b_in = proj[..., dt_rank : dt_rank + ds].astype(jnp.float32)
    c_in = proj[..., dt_rank + ds :].astype(jnp.float32)
    dt_ = jax.nn.softplus(
        (dt_in @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"]
    )                                                   # (B, S, di)
    a = -jnp.exp(p["a_log"])                            # (di, ds)
    xf = xi.astype(jnp.float32)

    h0 = (
        state[1].astype(jnp.float32)
        if state is not None
        else jnp.zeros((bsz, di, ds), jnp.float32)
    )
    y, h_last = selective_scan(dt_, a, xf, b_in, c_in, h0, chunk)
    y = y + xf * p["d_skip"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, (new_conv_state, h_last.astype(jnp.float32))


def init_mamba2(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    """Mamba-2 (SSD) block: scalar decay per head; B/C shared across head dims
    (geometry follows zamba2: d_inner = expand*d, head_dim 64)."""
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ds = cfg.ssm_state
    hd = 64
    nh = di // hd
    conv = cfg.ssm_conv
    ks = jax.random.split(key, 5)
    dt = _dtype(cfg)
    p = {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di + 2 * ds + nh)) * d**-0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (conv, di + 2 * ds)) * conv**-0.5).astype(dt),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "a_log": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.zeros((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[2], (di, d)) * di**-0.5).astype(dt),
    }
    s = {
        "in_proj": ("embed", "mlp"),
        "conv_w": (None, "mlp"),
        "dt_bias": (None,),
        "a_log": (None,),
        "d_skip": (None,),
        "norm_w": ("mlp",),
        "out_proj": ("mlp", "embed"),
    }
    return p, s


def mamba2(
    p: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    state: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    chunk: int = 256,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Mamba-2 / SSD with scalar per-head decay. State:
    (conv_state (B, conv-1, di+2ds), ssm_state (B, nh, hd, ds))."""
    from repro.models.layers import rms_norm

    bsz, s, d = x.shape
    di = cfg.ssm_expand * d
    ds = cfg.ssm_state
    hd = 64
    nh = di // hd
    conv = cfg.ssm_conv

    p = shard_lib.param_hints(p, {
        "in_proj": ("embed", "mlp"), "out_proj": ("mlp", "embed"),
        "conv_w": (None, "mlp"),
    })
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * ds]
    dt_in = zxbcdt[..., -nh:]

    if state is not None:
        conv_state = state[0]
        xbc_pad = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    else:
        xbc_pad = jnp.pad(xbc, ((0, 0), (conv - 1, 0), (0, 0)))
    new_conv_state = xbc_pad[:, -(conv - 1):, :] if conv > 1 else jnp.zeros(
        (bsz, 0, xbc.shape[-1]), xbc.dtype
    )
    idx = jnp.arange(s)[:, None] + jnp.arange(conv)[None, :]
    xw = xbc_pad[:, idx, :]
    xbc = jax.nn.silu((xw * p["conv_w"][None, None]).sum(2))

    xif = xbc[..., :di].astype(jnp.float32)                 # (B, S, di)
    b_in = xbc[..., di : di + ds].astype(jnp.float32)       # (B, S, ds)
    c_in = xbc[..., di + ds :].astype(jnp.float32)          # (B, S, ds)
    dt_h = jax.nn.softplus(dt_in.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    # scalar per-head decay broadcast to per-channel form for the shared scan
    dt_ = jnp.repeat(dt_h, hd, axis=-1)                     # (B, S, di)
    a_mat = jnp.repeat(-jnp.exp(p["a_log"]), hd)[:, None] * jnp.ones(
        (1, ds), jnp.float32
    )                                                       # (di, ds)
    h0 = (
        state[1].astype(jnp.float32)
        if state is not None
        else jnp.zeros((bsz, nh, hd, ds), jnp.float32)
    )
    y, h_last = selective_scan(
        dt_, a_mat, xif, b_in, c_in, h0.reshape(bsz, di, ds), chunk
    )                                                       # (B, S, di)
    y = y + xif * jnp.repeat(p["d_skip"], hd)
    y = y.astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, (new_conv_state, h_last.reshape(bsz, nh, hd, ds).astype(jnp.float32))
