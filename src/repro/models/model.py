"""Model zoo composer: builds any of the ten assigned architectures from its
``ModelConfig`` with a uniform interface:

    model = build_model(cfg)
    params, specs = model.init(rng)          # specs: logical-axis pytree
    loss, metrics = model.loss(params, batch)
    logits, cache = model.prefill(params, batch)
    logits, cache = model.decode_step(params, cache, tokens)

Layers are SCANNED with stacked parameters (compile time and HLO size are
O(1) in depth — essential for the 88/95-layer archs in the dry-run), with
``jax.checkpoint`` applied per block (remat policy configurable).

Families: dense | moe | vlm (prefix-LM over stub patch embeddings) | ssm
(Mamba-1) | hybrid (Mamba-2 + shared attention, zamba2-style) | encdec
(audio frames -> encoder, tokens -> decoder with cross-attention).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    BLOCK_ATTN,
    BLOCK_MAMBA1,
    BLOCK_MAMBA2,
    BLOCK_SHARED_ATTN,
    ModelConfig,
    ShapeConfig,
)
from repro.models import layers as L
from repro.models import ssm as S

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Per-block init / apply
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str):
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.dtype)
    norm = lambda: jnp.zeros((cfg.d_model,), jnp.float32)
    if kind == BLOCK_ATTN:
        attn_p, attn_s = L.init_attention(k1, cfg)
        if cfg.family == "moe":
            ff_p, ff_s = L.init_moe(k2, cfg)
        else:
            ff_p, ff_s = L.init_mlp(k2, cfg)
        p = {"ln1": norm(), "attn": attn_p, "ln2": norm(), "ff": ff_p}
        s = {"ln1": ("embed",), "attn": attn_s, "ln2": ("embed",), "ff": ff_s}
    elif kind == BLOCK_MAMBA1:
        m_p, m_s = S.init_mamba(k1, cfg)
        p = {"ln1": norm(), "ssm": m_p}
        s = {"ln1": ("embed",), "ssm": m_s}
    elif kind == BLOCK_MAMBA2:
        # zamba2 geometry: the mamba2 blocks carry no MLP — the MLP lives in
        # the (single, shared) attention block
        m_p, m_s = S.init_mamba2(k1, cfg)
        p = {"ln1": norm(), "ssm": m_p}
        s = {"ln1": ("embed",), "ssm": m_s}
    else:
        raise ValueError(kind)
    return p, s


def _stack_init(key, cfg: ModelConfig, kind: str, n: int):
    keys = jax.random.split(key, n)
    p0, s0 = _init_block(keys[0], cfg, kind)
    stacked = jax.vmap(lambda k: _init_block(k, cfg, kind)[0])(keys)
    specs = jax.tree_util.tree_map(lambda sp: (None, *sp), s0,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return stacked, specs


# ---------------------------------------------------------------------------
# Cache containers
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    """Stacked per-layer caches + scalar fill pointer."""
    kv_k: Optional[jnp.ndarray]       # (n_attn, B, cap, Hkv, hd)
    kv_v: Optional[jnp.ndarray]
    conv: Optional[jnp.ndarray]       # (n_ssm, B, conv-1, width)
    ssm: Optional[jnp.ndarray]        # (n_ssm, B, di(, ...), ds)
    enc_out: Optional[jnp.ndarray]    # (B, S_enc, d) — encdec only
    length: jnp.ndarray               # () int32


def _cache_capacity(cfg: ModelConfig, max_len: int, ring_mult: int = 1) -> int:
    if cfg.sliding_window > 0:
        return min(max_len, ring_mult * cfg.sliding_window)
    return max_len


# ---------------------------------------------------------------------------
# The Model object
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    config: ModelConfig
    remat: str = "block"     # "none" | "block"
    q_chunk: int = 1024
    ssm_chunk: int = 256
    moe_capacity: float = 1.25
    moe_dispatch_hint: bool = True   # per-arch MoE layout knob (§Perf M4/M5)
    seq_parallel: bool = False  # shard saved residuals' seq dim over "model"

    def _residual_hint(self, x):
        if not self.seq_parallel:
            return x
        from repro.distributed import sharding as shard_lib

        return shard_lib.hint(x, shard_lib.seq_parallel_spec)

    # ------------------------------------------------------------------ init
    def init(self, rng) -> Tuple[Params, Params]:
        cfg = self.config
        dt = jnp.dtype(cfg.dtype)
        keys = jax.random.split(rng, 8)
        d = cfg.d_model
        params: Params = {
            "embed": (jax.random.normal(keys[0], (cfg.vocab_size, d)) * 0.02).astype(dt),
            "ln_f": jnp.zeros((d,), jnp.float32),
        }
        specs: Params = {"embed": ("vocab", "embed"), "ln_f": ("embed",)}
        if not cfg.tie_embeddings:
            params["unembed"] = (
                jax.random.normal(keys[1], (d, cfg.vocab_size)) * d**-0.5
            ).astype(dt)
            specs["unembed"] = ("embed", "vocab")

        pattern = cfg.block_pattern()
        if cfg.family in ("dense", "moe", "vlm"):
            params["blocks"], specs["blocks"] = _stack_init(
                keys[2], cfg, BLOCK_ATTN, cfg.num_layers
            )
        elif cfg.family == "ssm":
            params["blocks"], specs["blocks"] = _stack_init(
                keys[2], cfg, BLOCK_MAMBA1, cfg.num_layers
            )
        elif cfg.family == "hybrid":
            n_m = sum(1 for b in pattern if b == BLOCK_MAMBA2)
            params["blocks"], specs["blocks"] = _stack_init(
                keys[2], cfg, BLOCK_MAMBA2, n_m
            )
            # the single SHARED attention block (weights tied across uses)
            sp, ss = _init_block(keys[3], cfg, BLOCK_ATTN)
            params["shared_attn"], specs["shared_attn"] = sp, ss
        elif cfg.family == "encdec":
            params["blocks"], specs["blocks"] = _stack_init(
                keys[2], cfg, BLOCK_ATTN, cfg.num_layers
            )
            params["enc_blocks"], specs["enc_blocks"] = _stack_init(
                keys[3], cfg, BLOCK_ATTN, cfg.encoder_layers
            )
            xp, xs = _stack_init(keys[4], cfg, BLOCK_ATTN, cfg.num_layers)
            # cross-attention re-uses attention geometry (q from decoder,
            # kv from encoder output)
            params["cross_blocks"] = {"ln": jax.vmap(
                lambda _: jnp.zeros((d,), jnp.float32)
            )(jnp.arange(cfg.num_layers)), "attn": xp["attn"]}
            specs["cross_blocks"] = {"ln": (None, "embed"),
                                     "attn": xs["attn"]}
        else:
            raise ValueError(cfg.family)

        if cfg.frontend_dim:
            params["frontend_proj"] = (
                jax.random.normal(keys[5], (cfg.frontend_dim, d))
                * cfg.frontend_dim**-0.5
            ).astype(dt)
            specs["frontend_proj"] = (None, "embed")
        return params, specs

    # ------------------------------------------------------------- forwards
    def _attn_block(self, bp, x, positions, kv=None, cache_len=None,
                    prefix_len=0, attend_cache=False):
        cfg = self.config
        h, new_kv = L.attention(
            bp["attn"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), cfg,
            positions, kv_cache=kv, cache_len=cache_len,
            q_chunk=self.q_chunk, prefix_len=prefix_len,
            attend_cache=attend_cache,
        )
        x = x + h
        y = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            ff, aux = L.moe(bp["ff"], y, cfg, self.moe_capacity,
                            dispatch_hint=self.moe_dispatch_hint)
        else:
            ff, aux = L.mlp(bp["ff"], y), 0.0
        return self._residual_hint(x + ff), new_kv, aux

    def _mamba_block(self, bp, x, state=None, kind=BLOCK_MAMBA1):
        cfg = self.config
        fn = S.mamba if kind == BLOCK_MAMBA1 else S.mamba2
        h, new_state = fn(
            bp["ssm"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), cfg,
            state=state, chunk=self.ssm_chunk,
        )
        x = x + h
        if "ff" in bp:
            x = x + L.mlp(bp["ff"], L.rms_norm(x, bp["ln2"], cfg.norm_eps))
        return self._residual_hint(x), new_state

    def _cross_block(self, cp, x, enc_out, enc_positions):
        """Decoder cross-attention: q from x, kv from encoder output."""
        cfg = self.config
        b, s, d = x.shape
        hd = cfg.resolved_head_dim
        nq, nkv = cfg.num_heads, cfg.num_kv_heads
        y = L.rms_norm(x, cp["ln"], cfg.norm_eps)
        q = (y @ cp["attn"]["wq"]).reshape(b, s, nq, hd)
        k = (enc_out @ cp["attn"]["wk"]).reshape(b, -1, nkv, hd)
        v = (enc_out @ cp["attn"]["wv"]).reshape(b, -1, nkv, hd)
        g = nq // nkv
        qg = q.reshape(b, s, nkv, g, hd)
        logits = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
        ) * hd**-0.5
        w = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
        return x + o.reshape(b, s, nq * hd) @ cp["attn"]["wo"]

    def _maybe_remat(self, f):
        if self.remat == "block":
            return jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
        return f

    def _decoder_stack(self, params, x, positions, caches=None, cache_len=None,
                       prefix_len=0, enc_out=None, enc_positions=None,
                       attend_cache=False):
        """Runs the (scanned) decoder stack. Returns (x, new_caches, aux)."""
        cfg = self.config
        fam = cfg.family

        if fam in ("dense", "moe", "vlm"):
            has_cache = caches is not None

            def block(carry, xs):
                (x,) = carry
                bp, kk, vv = xs
                kv = (kk, vv) if has_cache else None
                x, new_kv, aux = self._attn_block(
                    bp, x, positions, kv, cache_len, prefix_len,
                    attend_cache=attend_cache,
                )
                if new_kv is None:
                    new_kv = (jnp.zeros((0,), x.dtype),) * 2
                return (x,), (new_kv[0], new_kv[1], jnp.float32(aux))

            xs = (params["blocks"],
                  caches.kv_k if has_cache else jnp.zeros((cfg.num_layers, 0)),
                  caches.kv_v if has_cache else jnp.zeros((cfg.num_layers, 0)))
            (x,), (nk, nv, auxs) = jax.lax.scan(
                self._maybe_remat(block), (x,), xs
            )
            new_caches = None
            if has_cache:
                new_caches = caches._replace(kv_k=nk, kv_v=nv)
            return x, new_caches, jnp.sum(auxs)

        if fam == "ssm":
            def block(carry, xs):
                x, = carry
                bp, conv_st, ssm_st = xs
                st = (conv_st, ssm_st) if caches is not None else None
                x, new_st = self._mamba_block(bp, x, st, BLOCK_MAMBA1)
                return (x,), new_st

            blk = self._maybe_remat(block)
            xs = (params["blocks"],
                  caches.conv if caches is not None else jnp.zeros((cfg.num_layers, 0)),
                  caches.ssm if caches is not None else jnp.zeros((cfg.num_layers, 0)))
            (x,), (ncv, nss) = jax.lax.scan(blk, (x,), xs)
            new_caches = None
            if caches is not None:
                new_caches = caches._replace(conv=ncv, ssm=nss)
            return x, new_caches, 0.0

        if fam == "hybrid":
            return self._hybrid_stack(params, x, positions, caches, cache_len,
                                      attend_cache=attend_cache)

        if fam == "encdec":
            def block(carry, xs):
                x, = carry
                bp, cp, kv_k, kv_v = xs
                kv = (kv_k, kv_v) if caches is not None else None
                x, new_kv, _ = self._attn_block(bp, x, positions, kv,
                                                cache_len,
                                                attend_cache=attend_cache)
                x = self._cross_block(cp, x, enc_out, enc_positions)
                nk, nv = (new_kv if new_kv is not None else (jnp.zeros((0,)),) * 2)
                return (x,), (nk, nv)

            blk = self._maybe_remat(block)
            xs = (params["blocks"], params["cross_blocks"],
                  caches.kv_k if caches is not None else jnp.zeros((cfg.num_layers, 0)),
                  caches.kv_v if caches is not None else jnp.zeros((cfg.num_layers, 0)))
            (x,), (nk, nv) = jax.lax.scan(blk, (x,), xs)
            new_caches = None
            if caches is not None:
                new_caches = caches._replace(kv_k=nk, kv_v=nv)
            return x, new_caches, 0.0

        raise ValueError(fam)

    def _hybrid_stack(self, params, x, positions, caches, cache_len,
                      attend_cache=False):
        """zamba2: mamba2 blocks with a SHARED attention block every
        ``attn_every`` layers. The shared block's weights are reused at every
        occurrence; its KV caches are per-occurrence."""
        cfg = self.config
        pattern = cfg.block_pattern()
        n_att = sum(1 for b in pattern if b == BLOCK_SHARED_ATTN)
        every = cfg.attn_every or 6

        def mamba_seq(x, bps, states):
            def blk(carry, xs):
                x, = carry
                bp, cv, ss = xs
                st = (cv, ss) if caches is not None else None
                x, new_st = self._mamba_block(bp, x, st, BLOCK_MAMBA2)
                return (x,), new_st
            (x,), (ncv, nss) = jax.lax.scan(self._maybe_remat(blk), (x,), (bps, *states))
            return x, (ncv, nss)

        m_per_group = every - 1
        n_groups = n_att
        n_m = sum(1 for b in pattern if b == BLOCK_MAMBA2)
        tail = n_m - n_groups * m_per_group

        def slice_blocks(tree, start, count):
            return jax.tree_util.tree_map(lambda a: a[start : start + count], tree)

        new_conv, new_ssm, new_k, new_v = [], [], [], []
        mi = 0
        for gi in range(n_groups):
            bps = slice_blocks(params["blocks"], mi, m_per_group)
            if caches is not None:
                sts = (caches.conv[mi : mi + m_per_group],
                       caches.ssm[mi : mi + m_per_group])
            else:
                sts = (jnp.zeros((m_per_group, 0)), jnp.zeros((m_per_group, 0)))
            x, (ncv, nss) = mamba_seq(x, bps, sts)
            new_conv.append(ncv)
            new_ssm.append(nss)
            mi += m_per_group
            kv = None
            if caches is not None:
                kv = (caches.kv_k[gi], caches.kv_v[gi])
            x, new_kv, _ = self._attn_block(
                params["shared_attn"], x, positions, kv, cache_len,
                attend_cache=attend_cache,
            )
            if new_kv is not None:
                new_k.append(new_kv[0])
                new_v.append(new_kv[1])
        if tail > 0:
            bps = slice_blocks(params["blocks"], mi, tail)
            if caches is not None:
                sts = (caches.conv[mi : mi + tail], caches.ssm[mi : mi + tail])
            else:
                sts = (jnp.zeros((tail, 0)), jnp.zeros((tail, 0)))
            x, (ncv, nss) = mamba_seq(x, bps, sts)
            new_conv.append(ncv)
            new_ssm.append(nss)
        new_caches = None
        if caches is not None:
            new_caches = caches._replace(
                conv=jnp.concatenate(new_conv), ssm=jnp.concatenate(new_ssm),
                kv_k=jnp.stack(new_k), kv_v=jnp.stack(new_v),
            )
        return x, new_caches, 0.0

    def _encode(self, params, frames):
        """Encoder stack over frontend frame embeddings (bidirectional)."""
        cfg = self.config
        x = frames.astype(jnp.dtype(cfg.dtype)) @ params["frontend_proj"]
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        def block(carry, bp):
            x, = carry
            x, _, _ = self._attn_block(bp, x, positions, prefix_len=s)
            return (x,), None

        (x,), _ = jax.lax.scan(self._maybe_remat(block), (x,), params["enc_blocks"])
        return x, positions

    def _embed_inputs(self, params, batch):
        """tokens (+ frontend embeddings) -> (x, positions, prefix_len)."""
        cfg = self.config
        tokens = batch["tokens"]
        x = params["embed"][tokens]
        if cfg.family == "vlm":
            pre = batch["frontend"].astype(x.dtype) @ params["frontend_proj"]
            x = jnp.concatenate([pre, x], axis=1)
            prefix = cfg.frontend_tokens
        else:
            prefix = 0
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        return x, positions, prefix

    def _logits(self, params, x):
        from repro.distributed import sharding as shard_lib

        cfg = self.config
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        if cfg.tie_embeddings:
            w = shard_lib.param_hint(params["embed"], ("vocab", "embed")).T
        else:
            w = shard_lib.param_hint(params["unembed"], ("embed", "vocab"))
        logits = x @ w
        if cfg.logit_softcap > 0:
            c = cfg.logit_softcap
            logits = c * jnp.tanh(logits / c)
        return logits

    # -------------------------------------------------------------- training
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.config
        if cfg.family == "encdec":
            enc_out, enc_pos = self._encode(params, batch["frontend"])
            x, positions, prefix = self._embed_inputs(params, batch)
            x, _, aux = self._decoder_stack(
                params, x, positions, enc_out=enc_out, enc_positions=enc_pos
            )
        else:
            x, positions, prefix = self._embed_inputs(params, batch)
            x, _, aux = self._decoder_stack(params, x, positions,
                                            prefix_len=prefix)
        logits = self._logits(params, x)
        labels = batch["labels"]
        if prefix:
            logits = logits[:, prefix:, :]
        lg = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        nll = ((lse - ll) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        total = nll + 0.01 * aux
        return total, {"nll": nll, "aux": aux}

    # -------------------------------------------------------------- serving
    def init_cache(self, batch_size: int, max_len: int,
                   ring_mult: int = 1) -> DecodeCache:
        cfg = self.config
        dt = jnp.dtype(cfg.dtype)
        hd = cfg.resolved_head_dim
        cap = _cache_capacity(cfg, max_len, ring_mult)
        kv_k = kv_v = conv = ssm_st = enc = None
        pattern = cfg.block_pattern()
        n_attn = sum(1 for b in pattern if b in (BLOCK_ATTN, BLOCK_SHARED_ATTN))
        n_ssm = len(pattern) - n_attn
        if n_attn:
            kv_k = jnp.zeros((n_attn, batch_size, cap, cfg.num_kv_heads, hd), dt)
            kv_v = jnp.zeros_like(kv_k)
        if cfg.family == "ssm":
            di = cfg.ssm_expand * cfg.d_model
            conv = jnp.zeros((n_ssm, batch_size, cfg.ssm_conv - 1, di), dt)
            ssm_st = jnp.zeros((n_ssm, batch_size, di, cfg.ssm_state), jnp.float32)
        elif cfg.family == "hybrid":
            di = cfg.ssm_expand * cfg.d_model
            width = di + 2 * cfg.ssm_state
            nh = di // 64
            conv = jnp.zeros((n_ssm, batch_size, cfg.ssm_conv - 1, width), dt)
            ssm_st = jnp.zeros((n_ssm, batch_size, nh, 64, cfg.ssm_state), jnp.float32)
        return DecodeCache(kv_k=kv_k, kv_v=kv_v, conv=conv, ssm=ssm_st,
                           enc_out=enc, length=jnp.int32(0))

    def prefill(self, params, batch, max_len: Optional[int] = None):
        cfg = self.config
        tokens = batch["tokens"]
        b, s = tokens.shape
        internal = s + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
        cache = self.init_cache(b, max(max_len or 0, internal + 1))
        if cfg.family == "encdec":
            enc_out, enc_pos = self._encode(params, batch["frontend"])
            cache = cache._replace(enc_out=enc_out)
            x, positions, prefix = self._embed_inputs(params, batch)
            x, cache, _ = self._decoder_stack(
                params, x, positions, caches=cache, cache_len=jnp.int32(0),
                enc_out=enc_out, enc_positions=enc_pos,
            )
        else:
            x, positions, prefix = self._embed_inputs(params, batch)
            x, cache, _ = self._decoder_stack(
                params, x, positions, caches=cache, cache_len=jnp.int32(0),
                prefix_len=prefix,
            )
        cache = cache._replace(length=jnp.int32(x.shape[1]))
        logits = self._logits(params, x[:, -1:, :])
        return logits, cache

    def prefill_chunked(self, params, batch, seg_len: int = 4096,
                        max_len: Optional[int] = None):
        """Segmented prefill (EXPERIMENTS.md §Perf P1): the prompt is
        processed ``seg_len`` tokens at a time against the growing KV cache,
        bounding attention logits and MoE dispatch buffers to one segment.
        SWA archs use a 2x-window ring so every query's window is resident.
        Not supported for vlm (prefix handling) or encdec (cross-attn) —
        those use the single-shot path."""
        cfg = self.config
        assert cfg.family in ("dense", "moe", "ssm", "hybrid")
        tokens = batch["tokens"]
        b, s = tokens.shape
        assert s % seg_len == 0, (s, seg_len)
        if cfg.sliding_window > 0:
            assert seg_len <= cfg.sliding_window, "segment must fit the window"
        # SWA: a 2x-window ring keeps every in-segment query's window
        # resident; others: full cache
        cache = self.init_cache(b, max(max_len or 0, s + 1), ring_mult=2)
        nseg = s // seg_len
        segs = tokens.reshape(b, nseg, seg_len).swapaxes(0, 1)

        def seg_step(cache, seg_tokens):
            x = params["embed"][seg_tokens]
            bsz, sl, _ = x.shape
            positions = jnp.broadcast_to(
                (cache.length + jnp.arange(sl))[None], (bsz, sl)
            )
            x, cache2, _ = self._decoder_stack(
                params, x, positions, caches=cache, cache_len=cache.length,
                attend_cache=True,
            )
            cache2 = cache2._replace(length=cache.length + sl,
                                     enc_out=cache.enc_out)
            return cache2, x[:, -1:, :]

        cache, last_x = jax.lax.scan(seg_step, cache, segs)
        logits = self._logits(params, last_x[-1])
        return logits, cache

    def decode_step(self, params, cache: DecodeCache, tokens: jnp.ndarray):
        """tokens: (B, 1) — one decode step against the cache."""
        cfg = self.config
        x = params["embed"][tokens]
        b, s, _ = x.shape
        positions = jnp.broadcast_to(
            (cache.length + jnp.arange(s))[None], (b, s)
        )
        if cfg.family == "encdec":
            enc_pos = jnp.broadcast_to(
                jnp.arange(cache.enc_out.shape[1])[None], (b, cache.enc_out.shape[1])
            )
            x, cache2, _ = self._decoder_stack(
                params, x, positions, caches=cache, cache_len=cache.length,
                enc_out=cache.enc_out, enc_positions=enc_pos,
            )
        else:
            x, cache2, _ = self._decoder_stack(
                params, x, positions, caches=cache, cache_len=cache.length,
            )
        cache2 = cache2._replace(length=cache.length + s,
                                 enc_out=cache.enc_out)
        return self._logits(params, x), cache2


def build_model(cfg: ModelConfig, **kw) -> Model:
    return Model(config=cfg, **kw)


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins; DESIGN.md §5)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct pytree for every model input of a given shape cell.
    ``train``/``prefill`` feed full sequences; ``decode`` feeds one token
    against a cache of seq_len (built by the caller via ``init_cache``)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    batch: Dict[str, Any] = {}
    if shape.kind == "train":
        batch["tokens"] = sd((b, s), i32)
        batch["labels"] = sd((b, s), i32)
        if cfg.family == "vlm":
            batch["frontend"] = sd((b, cfg.frontend_tokens, cfg.frontend_dim), f32)
        if cfg.family == "encdec":
            batch["frontend"] = sd((b, s, cfg.frontend_dim), f32)
    elif shape.kind == "prefill":
        batch["tokens"] = sd((b, s), i32)
        if cfg.family == "vlm":
            batch["frontend"] = sd((b, cfg.frontend_tokens, cfg.frontend_dim), f32)
        if cfg.family == "encdec":
            batch["frontend"] = sd((b, s, cfg.frontend_dim), f32)
    else:  # decode: one new token, cache of seq_len supplied separately
        batch["tokens"] = sd((b, 1), i32)
    return batch
