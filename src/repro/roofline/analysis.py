"""Roofline-term derivation from a compiled dry-run artifact (DESIGN.md §8).

    compute term    = HLO_FLOPs / (chips x peak_FLOPs)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x ICI_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``. Collective bytes
are NOT in cost_analysis: we parse the compiled HLO text and sum the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Ops inside while-loop bodies appear once in the text;
``while_trip_hint`` lets callers scale them (the Proxima search loop).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per-direction, one link)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(?P<otype>\([^)]*\)|[\w\[\],\s{}]+?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute|all-gather-start|all-reduce-start|"
    r"collective-permute-start)\s*\(",
    re.MULTILINE,
)

_SHAPE_RE = re.compile(r"(?P<dt>\w+?)\[(?P<dims>[\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of all array shapes in an HLO type string
    (handles tuple types '(f32[8,128], u32[])')."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind byte totals (output shapes of collective ops — the data
    that crosses ICI)."""
    out: Dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        op = m.group("op").replace("-start", "")
        out[op] = out.get(op, 0) + _shape_bytes(m.group("otype"))
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                  # total HLO flops (whole program, all chips)
    hbm_bytes: float              # total bytes accessed
    coll_bytes: float             # total collective bytes
    coll_breakdown: Dict[str, int]
    chips: int
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0
    useful_ratio: float = 0.0

    def finalize(self, model_flops: float = 0.0) -> "Roofline":
        self.compute_s = self.flops / (self.chips * PEAK_FLOPS)
        self.memory_s = self.hbm_bytes / (self.chips * HBM_BW)
        self.collective_s = self.coll_bytes / (self.chips * ICI_BW)
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.bottleneck = max(terms, key=terms.get)
        self.model_flops = model_flops
        self.useful_ratio = model_flops / self.flops if self.flops else 0.0
        return self

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def analyze(compiled, chips: int, model_flops: float = 0.0,
            hlo_text: Optional[str] = None,
            hbm_bytes_per_device: Optional[float] = None) -> Roofline:
    """Derive roofline terms from a compiled SPMD artifact.

    FLOPs and collective bytes come from the structural HLO parser
    (``hlo_parse``): per-device numbers with while-loop trip counts applied
    (XLA's cost_analysis counts loop bodies once — useless for
    scan-over-layers). The memory term uses the analytic per-device HBM
    traffic if provided (``analytic_hbm_bytes``), falling back to XLA's
    (body-once) estimate."""
    from repro.roofline import hlo_parse

    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older API returns [dict]
        ca = ca[0]
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    parsed = hlo_parse.analyze_text(text)
    hbm = hbm_bytes_per_device if hbm_bytes_per_device is not None else xla_bytes
    rl = Roofline(
        flops=parsed.flops, hbm_bytes=hbm, coll_bytes=parsed.coll_bytes,
        coll_breakdown={k: int(v) for k, v in parsed.coll_by_kind.items()},
        chips=chips,
    )
    # per-device program: terms are per-chip seconds directly
    rl.compute_s = parsed.flops / PEAK_FLOPS
    rl.memory_s = hbm / HBM_BW
    rl.collective_s = parsed.coll_bytes / ICI_BW
    terms = {"compute": rl.compute_s, "memory": rl.memory_s,
             "collective": rl.collective_s}
    rl.bottleneck = max(terms, key=terms.get)
    rl.model_flops = model_flops
    rl.useful_ratio = (
        model_flops / (parsed.flops * chips) if parsed.flops else 0.0
    )
    return rl


def train_model_flops(param_count: int, tokens: int) -> float:
    """6*N*D rule (fwd 2ND + bwd 4ND)."""
    return 6.0 * param_count * tokens


def decode_model_flops(active_params: int, tokens: int) -> float:
    """2*N per generated token (fwd only)."""
    return 2.0 * active_params * tokens


def analytic_hbm_bytes(
    cfg, shape, mesh, microbatches: int = 1, kv_cache_bytes: float = 0.0
) -> float:
    """Per-device HBM traffic estimate (documented roofline memory model).

    train (per step):
      params: fwd read + bwd read (2 x 4B fp32), grad accumulate r/w per
      microbatch (8B x mb), AdamW update (read p,m,v + write p,m,v = 24B)
      activations: saved block boundaries written+read once each:
      mb x layers x (tokens_local/mb) x d_model x 2B x 2
    prefill: params read (4B) + activations written once + KV written
    decode: params read (4B) + full KV cache read + O(1) writes
    """
    import numpy as np

    n_devices = mesh.devices.size
    msize = mesh.shape.get("model", 1)
    dsize = int(np.prod([s for a, s in mesh.shape.items() if a != "model"]))
    params_local = cfg.param_count() / n_devices
    active_local = cfg.active_param_count() / n_devices
    tokens_local = shape.global_batch * shape.seq_len / max(dsize, 1)
    d = cfg.d_model
    if shape.kind == "train":
        param_traffic = params_local * (2 * 4 + 8 * microbatches + 24)
        act_traffic = (
            microbatches * cfg.num_layers
            * (tokens_local / max(microbatches, 1)) * d * 2 * 2
        )
        return param_traffic + act_traffic
    if shape.kind == "prefill":
        act = cfg.num_layers * tokens_local * d * 2
        return active_local * 4 + act + kv_cache_bytes
    # decode: read all active params + the whole KV cache once per token
    return active_local * 2 + kv_cache_bytes
