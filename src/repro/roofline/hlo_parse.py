"""Structural HLO cost parser.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE and has no
notion of trip counts, which makes it useless for scan-over-layers programs
(a 95-layer model reports 1 layer's FLOPs). This module parses the compiled
HLO text into its computations and aggregates:

  * dot/convolution FLOPs       (2 * prod(output dims) * contraction size)
  * collective operand bytes    (all-gather / all-reduce / reduce-scatter /
                                 all-to-all / collective-permute)

through the call graph: fusions attribute to their caller; while bodies are
multiplied by their trip count, recovered from the loop-condition comparison
constant (lax.scan lowers to a canonical counted while). Nested loops
multiply. Numbers are PER DEVICE (the SPMD module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*(?P<type>\([^)]*\)|[\w\[\],{}\s]+?)\s+"
    r"(?P<op>[\w\-]+)\((?P<args>.*)$"
)
_SHAPE = re.compile(r"(\w+?)\[([\d,]*)\]")
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_WHILE_PARTS = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONSTANT = re.compile(r"constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_ARGS_NAMES = re.compile(r"%([\w\.\-]+)")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "reduce-scatter-start", "collective-permute-start", "all-to-all-start",
)


def _first_shape(type_str: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    m = _SHAPE.search(type_str)
    if not m:
        return None
    dt = m.group(1)
    dims = tuple(int(d) for d in m.group(2).split(",") if d) if m.group(2) else ()
    return dt, dims


def _all_shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    fusion_calls: List[str] = dataclasses.field(default_factory=list)
    while_calls: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    max_constant: int = 0
    is_entry: bool = False


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    shapes: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line)
        if hdr and ("->" in line):
            cur = Computation(name=hdr.group(1),
                              is_entry=line.startswith("ENTRY"))
            comps[cur.name] = cur
            shapes = {}
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            c = _CONSTANT.search(line)
            if c:
                cur.max_constant = max(cur.max_constant, int(c.group(1)))
            continue
        name, type_str, op = m.group("name"), m.group("type"), m.group("op")
        sh = _first_shape(type_str)
        if sh:
            shapes[name] = sh
        cm = _CONSTANT.search(line)
        if cm:
            cur.max_constant = max(cur.max_constant, int(cm.group(1)))
        if op == "dot":
            out = _first_shape(type_str)
            contract = _CONTRACT.search(line)
            lhs_name_m = _ARGS_NAMES.search(m.group("args"))
            flops = 0.0
            if out is not None:
                n_out = 1
                for d in out[1]:
                    n_out *= d
                csize = 1
                if contract and lhs_name_m:
                    lhs = shapes.get(lhs_name_m.group(1))
                    if lhs:
                        for d in contract.group(1).split(","):
                            if d and int(d) < len(lhs[1]):
                                csize *= lhs[1][int(d)]
                flops = 2.0 * n_out * csize
            cur.flops += flops
        elif op in ("convolution",):
            out = _first_shape(type_str)
            if out is not None:
                n_out = 1
                for d in out[1]:
                    n_out *= d
                cur.flops += 2.0 * n_out  # lower bound (no kernel dims)
        elif op in _COLLECTIVES:
            kind = op.replace("-start", "")
            b = _all_shape_bytes(type_str)
            cur.coll_bytes += b
            cur.coll_by_kind[kind] = cur.coll_by_kind.get(kind, 0.0) + b
        elif op == "while":
            w = _WHILE_PARTS.search(line)
            if w:
                cur.while_calls.append((w.group(1), w.group(2)))
        if "calls=" in line and op != "while":
            for cm2 in _CALLS.finditer(line):
                cur.fusion_calls.append(cm2.group(1))
    return comps


@dataclasses.dataclass
class HloCost:
    flops: float                  # per device, trip-scaled
    coll_bytes: float             # per device, trip-scaled
    coll_by_kind: Dict[str, float]


def aggregate(comps: Dict[str, Computation]) -> HloCost:
    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def total(name: str, stack=()) -> Tuple[float, float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return 0.0, 0.0, {}
        c = comps[name]
        fl, cb = c.flops, c.coll_bytes
        kinds = dict(c.coll_by_kind)
        for f in c.fusion_calls:
            f2, c2, k2 = total(f, stack + (name,))
            fl += f2
            cb += c2
            for k, v in k2.items():
                kinds[k] = kinds.get(k, 0.0) + v
        for cond, body in c.while_calls:
            trips = max(comps.get(cond, Computation(cond)).max_constant, 1)
            f2, c2, k2 = total(body, stack + (name,))
            fl += trips * f2
            cb += trips * c2
            for k, v in k2.items():
                kinds[k] = kinds.get(k, 0.0) + trips * v
        memo[name] = (fl, cb, kinds)
        return memo[name]

    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:
        return HloCost(0.0, 0.0, {})
    fl, cb, kinds = total(entry)
    return HloCost(flops=fl, coll_bytes=cb, coll_by_kind=kinds)


def analyze_text(text: str) -> HloCost:
    return aggregate(parse_hlo(text))
