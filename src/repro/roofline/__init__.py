"""repro.roofline subpackage."""
