"""Recompile detector — jit executable-cache accounting per kernel.

JAX recompiles silently: a stray non-bucketed batch shape, a config object
that stopped hashing stably, or a weak-typed scalar can multiply compiles
and turn a serving loop into a trace loop.  ``KernelWatch`` samples each
registered kernel's executable-cache size into the metrics registry
(``jit_cache_entries{kernel=...}``) and warns — :class:`RecompileWarning` —
when a kernel exceeds its expected entry budget (for the serving engine:
``log2(batch_size)+1`` power-of-two buckets per distinct executed plan
config, the invariant the pow2-bucket compile-count test asserts).

Pallas kernel wrappers (``repro.kernels.ops``) report retraces through the
``kernel_traces`` counter instead — each wrapper body run under a JAX trace
is one (re)trace of that kernel — so both compile-count sources land in the
same registry.
"""
from __future__ import annotations

import warnings
from typing import Callable, Dict, Optional

from repro.obs.registry import MetricsRegistry


class RecompileWarning(UserWarning):
    """A watched kernel's jit cache grew past its expected entry budget."""


def default_kernel_sources() -> Dict[str, Callable[[], int]]:
    """Cache-size probes for the stack's jitted kernels (feature-detected:
    older jax builds without ``_cache_size`` just yield no sources)."""
    import importlib

    # repro.core re-exports a `search` FUNCTION that shadows the submodule
    # on attribute access — resolve the module itself
    core_search = importlib.import_module("repro.core.search")
    sizes = core_search.jit_cache_sizes()
    return {name: (lambda n=name: core_search.jit_cache_sizes().get(n, 0))
            for name in sizes}


class KernelWatch:
    def __init__(self, registry: MetricsRegistry,
                 sources: Optional[Dict[str, Callable[[], int]]] = None,
                 warn: bool = True):
        self.registry = registry
        self.sources = dict(sources) if sources is not None \
            else default_kernel_sources()
        self.warn = warn
        self._warned: set = set()
        # entries present at construction are pre-existing (warm-up compiles
        # by other owners) — budgets apply to growth observed by THIS watch
        self.baseline = {n: int(fn()) for n, fn in self.sources.items()}

    def register(self, name: str, cache_size: Callable[[], int]) -> None:
        self.sources[name] = cache_size
        self.baseline[name] = int(cache_size())

    def sample(self) -> Dict[str, int]:
        """Record every kernel's current cache size as a gauge; returns
        ``{kernel: entries}``."""
        out = {}
        for name, fn in self.sources.items():
            n = int(fn())
            out[name] = n
            self.registry.gauge("jit_cache_entries", n, kernel=name)
            self.registry.gauge("jit_cache_growth", n - self.baseline[name],
                                kernel=name)
        return out

    def check(self, expected_growth: int) -> Dict[str, int]:
        """Sample, then warn (once per kernel) if any kernel accumulated
        more than ``expected_growth`` NEW cache entries since this watch was
        constructed.  Returns the sampled sizes."""
        sizes = self.sample()
        for name, n in sizes.items():
            grew = n - self.baseline[name]
            if grew > expected_growth and name not in self._warned:
                self._warned.add(name)
                self.registry.counter("unexpected_recompiles",
                                      grew - expected_growth, kernel=name)
                if self.warn:
                    warnings.warn(
                        f"kernel '{name}' compiled {grew} new executables "
                        f"(expected <= {expected_growth}) — a non-bucketed "
                        f"batch shape or unstable static argument is "
                        f"defeating the compile cache",
                        RecompileWarning, stacklevel=2,
                    )
        return sizes
