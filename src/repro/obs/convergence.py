"""Per-round convergence telemetry — the learned-early-termination dataset.

The round-step kernels (``core.graph_search_step``) already carry everything
a per-query termination predictor needs, per lane per round: the sorted
candidate-list distances (top-k gap trajectory), the top-k repetition
counter ``stable`` the fixed rule thresholds on, the adaptive list length
``t`` and the unevaluated frontier.  This module captures that trajectory
into a bounded ring buffer of per-(lane, round) records plus a
rounds-to-quiesce label per lane — exported as ``.npz``/JSONL, it IS the
training set the ROADMAP's "per-query adaptive compute" item trains on::

    log = ConvergenceLog(capacity=1 << 16)
    sess = searcher.planner.round_session(searcher.plan(req))
    res, rounds = trace_session(sess, queries, log)     # off-line collection
    log.save_npz("results/convergence_log.npz")
    X, y, names = ConvergenceLog.load_npz(
        "results/convergence_log.npz").dataset()

or live, from the continuous engine (``Observability.on(convergence=True)``):
every scheduler tick appends one record per occupied lane and every retire
stamps the lane's label, so production traffic grows the same dataset.

Record fields (one row per lane per round):

  ``qid``       lane identity (engine: the request id; driver: sequential)
  ``round``     rounds executed so far (1-based after the first step)
  ``d_top1``    best candidate distance
  ``gap_topk``  d_k - d_1 over the candidate list (inf while the list is
                shorter than k)
  ``gap_rel``   gap_topk / max(|d_top1|, eps)
  ``stable``    consecutive rounds with an unchanged top-k (the fixed
                rule terminates at ``repetition_rate``)
  ``t_size``    adaptive candidate-list length T this round
  ``frontier``  valid-but-unevaluated candidates (expansion fuel left)
  ``churn``     top-k ids replaced since the lane's previous record
  ``done``      lane quiesced on this round

The ring drops the OLDEST records on overflow (``dropped`` counts them);
labels are kept for every finalized lane regardless, so late records always
find their label."""
from __future__ import annotations

import json
from typing import Dict, Optional, Sequence

import numpy as np

#: feature columns, in `dataset()` order; qid/done ride along as metadata
FEATURES = ("round", "d_top1", "gap_topk", "gap_rel", "stable", "t_size",
            "frontier", "churn")
FIELDS = ("qid",) + FEATURES + ("done",)

_DTYPES = {"qid": np.int64, "round": np.int32, "stable": np.int32,
           "t_size": np.int32, "frontier": np.int32, "churn": np.int32,
           "done": np.bool_, "d_top1": np.float32, "gap_topk": np.float32,
           "gap_rel": np.float32}


class ConvergenceLog:
    """Bounded ring of per-round traversal records + rounds-to-quiesce
    labels.  Append via :meth:`record_lanes` (or a ``RoundSession``'s
    ``record_round``), stamp labels via :meth:`finalize_lane`."""

    def __init__(self, capacity: int = 1 << 16):
        if capacity <= 0:
            raise ValueError("ConvergenceLog capacity must be positive")
        self.capacity = int(capacity)
        self._buf = {f: np.zeros(self.capacity, _DTYPES[f]) for f in FIELDS}
        self._n = 0                               # records ever appended
        self.labels: Dict[int, int] = {}          # qid -> rounds to quiesce
        self._prev_topk: Dict[int, np.ndarray] = {}
        self._next_qid = 0

    # ------------------------------------------------------------ recording
    def alloc_qids(self, n: int) -> np.ndarray:
        """Fresh lane ids for an off-line collection batch (the engine uses
        request ids instead — both are unique within one log)."""
        out = np.arange(self._next_qid, self._next_qid + n, dtype=np.int64)
        self._next_qid += n
        return out

    def record_lanes(self, qids: Sequence[int], state, k: int,
                     select: Optional[Sequence[int]] = None) -> None:
        """Append one record per lane from a post-step ``SearchState``.
        ``select`` picks lane rows (the engine passes its occupied slots);
        ``qids`` aligns with the selected rows."""
        lanes = getattr(state, "lanes", state)
        dists = np.asarray(lanes.dists, np.float64)
        ids = np.asarray(lanes.ids)
        stable = np.asarray(lanes.stable)
        t = np.asarray(lanes.t)
        rounds = np.asarray(lanes.rounds)
        done = np.asarray(lanes.done)
        evaluated = np.asarray(lanes.evaluated)
        if select is not None:
            sel = np.asarray(select, np.int64)
            dists, ids, evaluated = dists[sel], ids[sel], evaluated[sel]
            stable, t, rounds, done = stable[sel], t[sel], rounds[sel], \
                done[sel]
        for row in range(len(qids)):
            qid = int(qids[row])
            d = dists[row]
            valid = np.isfinite(d)
            d1 = float(d[0]) if valid[0] else np.inf
            dk = float(d[k - 1]) if k <= d.shape[0] and valid[
                min(k - 1, d.shape[0] - 1)] else np.inf
            gap = dk - d1
            gap_rel = gap / max(abs(d1), 1e-12) if np.isfinite(gap) \
                else np.inf
            topk = ids[row, :k][valid[:k]]
            prev = self._prev_topk.get(qid)
            if prev is None:
                churn = int(topk.size)
            else:
                churn = int(topk.size
                            - len(set(topk.tolist()) & set(prev.tolist())))
            self._prev_topk[qid] = np.array(topk)
            i = self._n % self.capacity
            b = self._buf
            b["qid"][i] = qid
            b["round"][i] = int(rounds[row])
            b["d_top1"][i] = d1
            b["gap_topk"][i] = gap
            b["gap_rel"][i] = gap_rel
            b["stable"][i] = int(stable[row])
            b["t_size"][i] = int(t[row])
            b["frontier"][i] = int((valid & ~evaluated[row]).sum())
            b["churn"][i] = churn
            b["done"][i] = bool(done[row])
            self._n += 1

    def finalize_lane(self, qid: int, rounds: int) -> None:
        """Stamp a lane's rounds-to-quiesce label (engine retire path)."""
        self.labels[int(qid)] = int(rounds)
        self._prev_topk.pop(int(qid), None)

    def finalize_lanes(self, qids: Sequence[int],
                       rounds: Sequence[int]) -> None:
        for q, r in zip(qids, rounds):
            self.finalize_lane(int(q), int(r))

    # ----------------------------------------------------------- inspection
    @property
    def count(self) -> int:
        return min(self._n, self.capacity)

    @property
    def dropped(self) -> int:
        return max(0, self._n - self.capacity)

    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Retained records in chronological order, one array per field."""
        n = self.count
        if self._n <= self.capacity:
            return {f: self._buf[f][:n].copy() for f in FIELDS}
        i0 = self._n % self.capacity
        return {f: np.concatenate([self._buf[f][i0:], self._buf[f][:i0]])
                for f in FIELDS}

    def dataset(self) -> tuple[np.ndarray, np.ndarray, tuple]:
        """(X, y, feature_names): one row per retained record whose lane has
        a label; ``y`` is the lane's TOTAL rounds-to-quiesce (subtract the
        ``round`` column for remaining-rounds targets)."""
        recs = self.to_arrays()
        qid = recs["qid"]
        have = np.array([int(q) in self.labels for q in qid], bool)
        X = np.stack([recs[f].astype(np.float64) for f in FEATURES],
                     axis=1)[have]
        y = np.array([self.labels[int(q)] for q in qid[have]], np.int64)
        return X, y, FEATURES

    # -------------------------------------------------------------- export
    def save_npz(self, path: str) -> None:
        recs = self.to_arrays()
        lq = np.fromiter(self.labels.keys(), np.int64, len(self.labels))
        lr = np.fromiter(self.labels.values(), np.int64, len(self.labels))
        np.savez(path, label_qid=lq, label_rounds=lr,
                 capacity=np.int64(self.capacity),
                 dropped=np.int64(self.dropped), **recs)

    @classmethod
    def load_npz(cls, path: str) -> "ConvergenceLog":
        with np.load(path) as z:
            log = cls(capacity=int(z["capacity"]))
            n = len(z["qid"])
            for f in FIELDS:
                log._buf[f][:n] = z[f]
            log._n = n
            log.labels = {int(q): int(r) for q, r in
                          zip(z["label_qid"], z["label_rounds"])}
        if log.labels:
            log._next_qid = max(log.labels) + 1
        return log

    def export_jsonl(self, path: str) -> None:
        """One JSON object per record, then one ``label`` object per lane.
        Non-finite floats are emitted as nulls so any strict parser reads
        the file back."""
        recs = self.to_arrays()

        def _j(v):
            f = float(v)
            return f if np.isfinite(f) else None

        with open(path, "w") as fh:
            for i in range(self.count):
                row = {"type": "round"}
                for f in FIELDS:
                    v = recs[f][i]
                    row[f] = _j(v) if np.issubdtype(type(v), np.floating) \
                        else (bool(v) if f == "done" else int(v))
                fh.write(json.dumps(row) + "\n")
            for q, r in sorted(self.labels.items()):
                fh.write(json.dumps(
                    {"type": "label", "qid": q, "rounds": r}) + "\n")


def trace_session(session, queries, log: ConvergenceLog,
                  qids: Optional[np.ndarray] = None):
    """Step a ``plan.RoundSession`` to quiescence, recording every round of
    every lane into ``log`` and stamping rounds-to-quiesce labels — the
    off-line dataset collector (``serving_bench --quality`` ships its output
    as the CI artifact).  Returns ``(core_result, rounds)`` where ``rounds``
    is the (Q,) per-lane round count — by the round-step equivalence
    contract it matches what the whole-batch path reports in
    ``SearchStats.rounds``."""
    q = np.atleast_2d(np.asarray(queries, np.float32))
    if qids is None:
        qids = log.alloc_qids(q.shape[0])
    state = session.init(q)
    active = session.active(state)
    while active.any():
        state = session.step(state)
        sel = np.nonzero(active)[0]
        session.record_round(log, np.asarray(qids)[sel], state, select=sel)
        active = session.active(state)
    rounds = session.rounds(state)
    log.finalize_lanes(qids, rounds)
    return session.finalize(state), np.asarray(rounds)
