"""Unified observability layer: metrics registry, per-request span tracing,
recompile detection and NAND cost-accounting export.

    obs = Observability.on()                    # metrics + tracing + billing
    eng = ServingEngine(idx, obs=obs)
    ... serve ...
    obs.metrics.snapshot()                      # percentiles, counters, pJ/q
    obs.tracer.export("trace.json")             # open in ui.perfetto.dev

Everything is **off by default** (``NULL_OBS``): a disabled registry/tracer
is a shared no-op object and the instrumented call sites pay one branch —
``benchmarks/planner_bench`` asserts the enabled-path overhead stays under
5% of dispatch cost and ``benchmarks/serving_bench`` writes the enabled
snapshot as the perf trajectory's ``BENCH_serving.json``.
"""
from __future__ import annotations

import dataclasses

from typing import Optional

from repro.obs.convergence import ConvergenceLog, trace_session
from repro.obs.kernelwatch import (
    KernelWatch, RecompileWarning, default_kernel_sources,
)
from repro.obs.nand_bridge import record_plan_execution
from repro.obs.quality import (
    QualityMonitor, SLOTarget, SLOTracker, wilson_interval,
)
from repro.obs.registry import Histogram, MetricsRegistry, NULL_REGISTRY
from repro.obs.tracing import NULL_SPAN, NULL_TRACER, Span, Tracer


@dataclasses.dataclass
class Observability:
    """The bundle every instrumented layer takes: one registry + one tracer
    (+ the per-batch NAND billing switch, + the optional quality layer: a
    shadow-recall :class:`QualityMonitor` and a per-round
    :class:`ConvergenceLog`).  Use :meth:`on` / :meth:`off`, or
    :meth:`resolve` to accept user input (None, a bundle, or a
    ``configs.base.ObsConfig``)."""
    metrics: MetricsRegistry
    tracer: Tracer
    nand_billing: bool = False
    quality: Optional[QualityMonitor] = None
    convergence: Optional[ConvergenceLog] = None

    @property
    def enabled(self) -> bool:
        return self.metrics.enabled or self.tracer.enabled

    @classmethod
    def on(cls, tracing: bool = True, nand_billing: bool = True,
           quality: bool = False, quality_sample_rate: float = 0.05,
           quality_seed: int = 0, convergence: bool = False,
           convergence_capacity: int = 1 << 16) -> "Observability":
        m = MetricsRegistry(enabled=True)
        return cls(metrics=m,
                   tracer=Tracer(enabled=tracing),
                   nand_billing=nand_billing,
                   quality=QualityMonitor(
                       m, sample_rate=quality_sample_rate,
                       seed=quality_seed) if quality else None,
                   convergence=ConvergenceLog(convergence_capacity)
                   if convergence else None)

    @classmethod
    def off(cls) -> "Observability":
        return NULL_OBS

    @classmethod
    def resolve(cls, obj) -> "Observability":
        """None -> the shared disabled bundle; an ``ObsConfig`` -> a fresh
        bundle per its flags; a bundle passes through."""
        if obj is None:
            return NULL_OBS
        if isinstance(obj, cls):
            return obj
        # configs.base.ObsConfig (duck-typed match; the import stays local
        # so the obs package keeps no top-level config dependency)
        if hasattr(obj, "metrics") and isinstance(obj.metrics, bool):
            from repro.configs.base import upgrade_config

            # pre-quality pickled configs gain the newer fields here, with
            # schema-owned defaults instead of per-site getattr fallbacks
            obj = upgrade_config(obj)
            if not (obj.metrics or obj.tracing or obj.quality
                    or obj.convergence):
                return NULL_OBS
            # the quality monitor publishes into the registry, so enabling
            # it implies a live registry even when metrics was left False
            m = MetricsRegistry(enabled=obj.metrics or obj.quality)
            return cls(metrics=m,
                       tracer=Tracer(enabled=obj.tracing),
                       nand_billing=obj.nand_billing,
                       quality=QualityMonitor(
                           m,
                           sample_rate=obj.quality_sample_rate,
                           seed=obj.quality_seed)
                       if obj.quality else None,
                       convergence=ConvergenceLog(obj.convergence_capacity)
                       if obj.convergence else None)
        raise TypeError(
            f"obs= takes an Observability, an ObsConfig or None, "
            f"got {type(obj).__name__}"
        )

    def install_kernel_hooks(self) -> None:
        """Point the module-level kernel instrumentation hooks (Pallas op
        wrappers, sharded fan-out) at this bundle's registry.  Process-wide
        by necessity — the kernels are module functions, not objects."""
        from repro.kernels import ops
        from repro.shard import search as shard_search

        ops.set_observability(self if self.enabled else None)
        shard_search.set_observability(self if self.enabled else None)


#: the default: everything off, all record calls are no-ops
NULL_OBS = Observability(metrics=NULL_REGISTRY, tracer=NULL_TRACER,
                         nand_billing=False)

__all__ = [
    "ConvergenceLog",
    "Histogram",
    "KernelWatch",
    "MetricsRegistry",
    "NULL_OBS",
    "NULL_REGISTRY",
    "NULL_SPAN",
    "NULL_TRACER",
    "Observability",
    "QualityMonitor",
    "RecompileWarning",
    "SLOTarget",
    "SLOTracker",
    "Span",
    "Tracer",
    "default_kernel_sources",
    "record_plan_execution",
    "trace_session",
    "wilson_interval",
]
