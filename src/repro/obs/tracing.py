"""Per-request span tracing with Chrome trace-event / Perfetto export.

A :class:`Tracer` records two event shapes:

* **sync spans** (``tracer.span("kernel-execute", ...)``) — complete
  ``"ph": "X"`` events with microsecond ``ts``/``dur``, nested by time
  containment on their track (``tid``).  The serving engine emits
  ``batch`` > ``batch-assembly`` / ``kernel-execute`` / ``post-process`` /
  ``nand-billing`` on the engine track.
* **async spans** (``async_begin``/``async_end``) — ``"ph": "b"/"e"``
  event pairs keyed by ``id``, for intervals that overlap freely across
  requests (``queue-wait`` from ``submit`` to its batch's flush).

``export()`` returns the standard ``{"traceEvents": [...]}`` JSON object
(load it in ``chrome://tracing`` or https://ui.perfetto.dev), with process/
thread metadata events naming the tracks.

Zero-cost-when-off: a disabled tracer hands back one shared no-op span
object — no allocation, no clock read.
"""
from __future__ import annotations

import json
import time
from typing import List, Optional

ENGINE_TID = 0          # the serving engine's synchronous track


class _NullSpan:
    """Shared no-op span for disabled tracers (and a safe ``set`` sink)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args) -> None:
        pass

    def end(self) -> None:
        pass


NULL_SPAN = _NullSpan()


class Span:
    """One in-flight sync span; closes into a complete ("X") trace event."""

    __slots__ = ("_tracer", "name", "cat", "tid", "args", "ts")

    def __init__(self, tracer: "Tracer", name: str, cat: str, tid: int,
                 args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.tid = tid
        self.args = args
        self.ts = tracer._now_us()

    def set(self, **args) -> None:
        """Attach (or update) event args after the span opened."""
        self.args.update(args)

    def end(self) -> None:
        t = self._tracer
        t._events.append({
            "name": self.name, "cat": self.cat, "ph": "X",
            "ts": self.ts, "dur": max(t._now_us() - self.ts, 0.0),
            "pid": t.pid, "tid": self.tid, "args": self.args,
        })

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> bool:
        self.end()
        return False


class Tracer:
    def __init__(self, enabled: bool = True, pid: int = 0):
        self.enabled = enabled
        self.pid = pid
        self._epoch = time.perf_counter()
        self._events: List[dict] = []
        if enabled:
            self._meta("process_name", ENGINE_TID, name="repro-serving")
            self._meta("thread_name", ENGINE_TID, name="engine")

    def _now_us(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e6

    def _meta(self, kind: str, tid: int, **args) -> None:
        self._events.append({"name": kind, "ph": "M", "pid": self.pid,
                             "tid": tid, "args": args})

    # ------------------------------------------------------------ sync spans
    def span(self, name: str, cat: str = "serve", tid: int = ENGINE_TID,
             **args):
        """Context manager recording a complete event on track ``tid``."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, cat, tid, args)

    # ----------------------------------------------------------- async spans
    def async_begin(self, name: str, id: int, cat: str = "request",
                    **args) -> None:
        if not self.enabled:
            return
        self._events.append({
            "name": name, "cat": cat, "ph": "b", "id": id,
            "ts": self._now_us(), "pid": self.pid, "tid": ENGINE_TID,
            "args": args,
        })

    def async_end(self, name: str, id: int, cat: str = "request",
                  **args) -> None:
        if not self.enabled:
            return
        self._events.append({
            "name": name, "cat": cat, "ph": "e", "id": id,
            "ts": self._now_us(), "pid": self.pid, "tid": ENGINE_TID,
            "args": args,
        })

    def instant(self, name: str, cat: str = "serve", **args) -> None:
        """Zero-duration marker (consolidation points, warnings...)."""
        if not self.enabled:
            return
        self._events.append({
            "name": name, "cat": cat, "ph": "i", "s": "g",
            "ts": self._now_us(), "pid": self.pid, "tid": ENGINE_TID,
            "args": args,
        })

    # --------------------------------------------------------------- reading
    def events(self) -> List[dict]:
        return list(self._events)

    def export(self, path: Optional[str] = None) -> dict:
        """Chrome trace-event JSON object; written to ``path`` if given."""
        doc = {"traceEvents": self.events(), "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc

    def clear(self) -> None:
        keep = [e for e in self._events if e.get("ph") == "M"]
        self._events = keep


#: the shared disabled tracer — every call is a no-op
NULL_TRACER = Tracer(enabled=False)
