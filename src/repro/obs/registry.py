"""Metrics registry — labeled counters, gauges, and fixed-bucket histograms.

The serving stack's one measurement sink: every layer (``ServingEngine``,
``QueryPlanner``, the Pallas kernel wrappers, the NAND cost bridge) records
into a shared :class:`MetricsRegistry`, and ``snapshot()`` renders the whole
system state — queue-wait and latency percentiles, batch occupancy,
plan-cache hit rates, per-batch NAND energy — as one plain dict (JSON-ready,
the ``BENCH_serving.json`` perf-trajectory format).

Histograms use fixed log-spaced buckets (Prometheus-style, never a sample
reservoir): ``observe`` is O(log buckets) with bounded memory, and
``p50/p95/p99`` are estimated by linear interpolation inside the covering
bucket — relative error is bounded by the bucket ratio (~8% at the default
16 buckets/decade; see tests/test_obs.py for the numpy.percentile check).

Zero-cost-when-off: a registry constructed with ``enabled=False`` (what
``NULL_REGISTRY`` is) returns from every record call on the first branch and
allocates nothing — the serving hot path pays one attribute load + one
predictable branch per call site, asserted under 5% of dispatch cost by
``benchmarks/planner_bench``.
"""
from __future__ import annotations

import json
import math
from bisect import bisect_right
from typing import Dict, Optional, Tuple

# default bucket geometry: 16 log-spaced buckets per decade covering
# microseconds-to-picojoule magnitudes (1e-6 .. 1e12) — one shared edge
# tuple, computed once, reused by every histogram instance
_BUCKETS_PER_DECADE = 16
_DECADE_LO, _DECADE_HI = -6, 12


def _default_edges() -> Tuple[float, ...]:
    n = (_DECADE_HI - _DECADE_LO) * _BUCKETS_PER_DECADE
    return tuple(
        10.0 ** (_DECADE_LO + i / _BUCKETS_PER_DECADE) for i in range(n + 1)
    )


_DEFAULT_EDGES = _default_edges()

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: dict) -> LabelKey:
    """Canonical hashable label identity (sorted, values stringified)."""
    if not labels:
        return ()
    return tuple(sorted((k, str(v)) for k, v in labels.items() if v is not None))


def _label_str(key: LabelKey) -> str:
    return ",".join(f"{k}={v}" for k, v in key)


class Histogram:
    """Fixed-bucket histogram with interpolated quantile estimation."""

    __slots__ = ("edges", "counts", "count", "total", "vmin", "vmax")

    def __init__(self, edges: Tuple[float, ...] = _DEFAULT_EDGES):
        self.edges = edges
        self.counts = [0] * (len(edges) + 1)   # +underflow/overflow slots
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        if math.isnan(v):
            return
        self.counts[bisect_right(self.edges, v)] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def quantile(self, q: float) -> float:
        """Estimate the q-th percentile (q in [0, 100]) by linear
        interpolation inside the covering bucket, clamped to the observed
        [min, max] so the tails are exact."""
        if self.count == 0:
            return math.nan
        target = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.edges[i - 1] if 0 < i <= len(self.edges) \
                    else self.vmin
                hi = self.edges[i] if i < len(self.edges) else self.vmax
                lo = max(lo, self.vmin)
                hi = min(hi, self.vmax)
                if hi <= lo:
                    return min(max(lo, self.vmin), self.vmax)
                frac = (target - cum) / c
                return min(max(lo + frac * (hi - lo), self.vmin), self.vmax)
            cum += c
        return self.vmax

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.mean,
            "min": self.vmin if self.count else math.nan,
            "max": self.vmax if self.count else math.nan,
            "p50": self.quantile(50.0),
            "p95": self.quantile(95.0),
            "p99": self.quantile(99.0),
        }


class MetricsRegistry:
    """Counter / gauge / histogram store, keyed by (name, label set).

    ``counter`` accumulates, ``gauge`` overwrites, ``observe`` feeds the
    named histogram.  Label sets are fully isolated: two label combinations
    of the same name never share a cell (the multi-tenant accounting
    contract — tenant A's counters cannot bleed into tenant B's).
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._counters: Dict[str, Dict[LabelKey, float]] = {}
        self._gauges: Dict[str, Dict[LabelKey, float]] = {}
        self._hists: Dict[str, Dict[LabelKey, Histogram]] = {}

    # ------------------------------------------------------------- recording
    def counter(self, name: str, value: float = 1.0, **labels) -> None:
        if not self.enabled:
            return
        cells = self._counters.setdefault(name, {})
        key = _label_key(labels)
        cells[key] = cells.get(key, 0.0) + value

    def gauge(self, name: str, value: float, **labels) -> None:
        if not self.enabled:
            return
        self._gauges.setdefault(name, {})[_label_key(labels)] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        if not self.enabled:
            return
        cells = self._hists.setdefault(name, {})
        key = _label_key(labels)
        hist = cells.get(key)
        if hist is None:
            hist = cells[key] = Histogram()
        hist.observe(value)

    # --------------------------------------------------------------- reading
    def counter_value(self, name: str, **labels) -> float:
        return self._counters.get(name, {}).get(_label_key(labels), 0.0)

    def counter_total(self, name: str) -> float:
        """Sum of a counter across every label set."""
        return sum(self._counters.get(name, {}).values())

    def gauge_value(self, name: str, **labels) -> Optional[float]:
        return self._gauges.get(name, {}).get(_label_key(labels))

    def histogram(self, name: str, **labels) -> Optional[Histogram]:
        return self._hists.get(name, {}).get(_label_key(labels))

    def merged_histogram(self, name: str) -> Optional[Histogram]:
        """One histogram aggregating every label set of ``name`` (bucket
        counts add exactly — same fixed edges everywhere)."""
        cells = self._hists.get(name)
        if not cells:
            return None
        out = Histogram()
        for h in cells.values():
            for i, c in enumerate(h.counts):
                out.counts[i] += c
            out.count += h.count
            out.total += h.total
            out.vmin = min(out.vmin, h.vmin)
            out.vmax = max(out.vmax, h.vmax)
        return out

    def snapshot(self) -> dict:
        """The whole registry as one JSON-ready dict:
        ``{"counters": {name: {label_str: value}}, "gauges": {...},
        "histograms": {name: {label_str: {count,sum,mean,min,max,
        p50,p95,p99}}}}``."""
        return {
            "counters": {
                n: {_label_str(k): v for k, v in cells.items()}
                for n, cells in self._counters.items()
            },
            "gauges": {
                n: {_label_str(k): v for k, v in cells.items()}
                for n, cells in self._gauges.items()
            },
            "histograms": {
                n: {_label_str(k): h.snapshot() for k, h in cells.items()}
                for n, cells in self._hists.items()
            },
        }

    def to_json(self, path: Optional[str] = None, indent: int = 2) -> str:
        payload = json.dumps(self.snapshot(), indent=indent, sort_keys=True,
                             allow_nan=True)
        if path is not None:
            with open(path, "w") as f:
                f.write(payload)
        return payload

    def clear(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()


#: the shared disabled registry — every record call is a no-op
NULL_REGISTRY = MetricsRegistry(enabled=False)
