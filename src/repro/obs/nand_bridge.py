"""NAND cost-accounting bridge — simulated hardware cost into the registry.

Proxima's claims are about where energy and time go in the 3D NAND array;
the serving stack's claims are about host wall-time.  This bridge puts both
in ONE snapshot: after each executed batch, the plan execution's measured
counters are converted to a ``nand.simulator.WorkloadTrace`` (via
``trace_from_plan_execution`` — billing facts read off the plan) and run
through the analytic simulator, and the resulting per-query energy/latency/
transfer figures are recorded next to the host-side queue-wait and latency
histograms, labeled by plan kind / filter strategy / tenant.

Unbillable executions (distributed plans carry no NAND counters; targets
opened without geometry) record a ``nand_unbilled_batches`` counter instead
of raising — observability must never fail the serving path.
"""
from __future__ import annotations

from typing import Optional


def record_plan_execution(metrics, pres, *, index=None, nand=None, eng=None,
                          batch_queries: Optional[int] = None,
                          n_queues: Optional[int] = None):
    """Bill one plan-layer ``SearchResult`` into ``metrics``.

    ``index`` resolves trace geometry (the served ``ProximaIndex`` /
    ``MutableIndex``); ``nand``/``eng`` override the simulator configs and
    ``n_queues`` the modeled scheduler queue count (Fig. 16 sweeps it
    through the serving path).  Returns the ``SimResult`` (or None when the
    execution is unbillable).
    """
    if not getattr(metrics, "enabled", False):
        return None
    from repro.nand.simulator import simulate, trace_from_plan_execution

    plan = pres.plan
    labels = dict(kind=plan.kind, strategy=plan.strategy, tenant=plan.tenant)
    try:
        trace = trace_from_plan_execution(pres, index=index)
    except ValueError:
        metrics.counter("nand_unbilled_batches", **labels)
        return None
    kwargs = {}
    if nand is not None:
        kwargs["nand"] = nand
    if eng is not None:
        kwargs["eng"] = eng
    if n_queues is not None:
        kwargs["n_queues"] = n_queues
    sim = simulate(trace, **kwargs)
    for name, value in sim.metrics().items():
        metrics.observe(name, value, **labels)
    for category, nbytes in sim.traffic_bytes_per_query.items():
        metrics.counter("nand_traffic_bytes", nbytes, category=category,
                        **labels)
    n = batch_queries if batch_queries is not None else pres.stats.queries
    metrics.counter("nand_billed_queries", n, **labels)
    return sim
