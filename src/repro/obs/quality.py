"""Online result-quality observability: shadow-recall estimation + SLOs.

Production serving has no ground truth, so "what recall are we actually
delivering?" is unanswerable from the request path alone.  This module
answers it the way the large-scale ANN serving literature does — by
*shadowing*: a :class:`QualityMonitor` deterministically samples a small
fraction of live requests and replays them OFF-PATH against an exact
brute-force oracle (``core.dataset.exact_knn`` over the same population the
plan searched — tombstone-aware for merged plans, filter-aware for
masked/scan plans), then publishes the running recall estimate with a
Wilson-score confidence interval into the shared ``MetricsRegistry``::

    obs = Observability.on(quality=True, quality_sample_rate=0.05)
    eng = ServingEngine(idx, obs=obs, slo={None: SLOTarget(recall_floor=0.8,
                                                           p99_latency_ms=50)})
    ... serve ...
    obs.quality.overall()        # {'estimate': .91, 'ci_low': .88, ...}
    obs.metrics.gauge_value("recall_estimate", kind="flat", strategy="none")
    eng.stats["slo_violations"]

Sampling is a seeded PCG64 stream indexed by the monitor's request sequence
number, so a replayed workload samples the *same* requests regardless of how
the engine batched them — estimates are reproducible, and
``benchmarks/serving_bench --quality`` asserts the estimate lands within its
own CI of the true (full ground-truth) recall.

:class:`SLOTracker` evaluates per-tenant targets (recall floor, p99 latency
ceiling) over rolling windows: every recorded observation re-evaluates its
tenant's window and, while the window statistic is in breach, bumps a
burn-rate-style ``slo_violations{tenant,slo}`` counter (plus a
``slo_burn_rate`` gauge — error-budget consumption rate, 1.0 = exactly on
budget).  Boundary values are NOT violations: a window p99 exactly at the
ceiling, or a window recall exactly at the floor, passes.

Both classes follow the ``nand_bridge`` contract: they never raise into the
serving path (oracle failures are counted, not thrown) and they exist only
when explicitly enabled — the default ``NULL_OBS`` bundle carries neither.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from collections import deque
from typing import Deque, Dict, Optional

import numpy as np


def wilson_interval(hits: float, trials: float, z: float = 1.96
                    ) -> tuple[float, float]:
    """Wilson score interval for a binomial proportion — well-behaved at the
    extremes (p near 0/1, few trials) where the normal approximation's
    interval escapes [0, 1].  Returns the vacuous (0, 1) for zero trials."""
    if trials <= 0:
        return 0.0, 1.0
    p = hits / trials
    z2 = z * z
    denom = 1.0 + z2 / trials
    center = (p + z2 / (2.0 * trials)) / denom
    half = z * math.sqrt(p * (1.0 - p) / trials
                         + z2 / (4.0 * trials * trials)) / denom
    return max(0.0, center - half), min(1.0, center + half)




# --------------------------------------------------------------------- SLOs
@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """Per-tenant service-level objectives.  ``None`` fields are untracked."""
    recall_floor: Optional[float] = None      # rolling recall must stay >=
    p99_latency_ms: Optional[float] = None    # rolling window p99 must stay <=


class SLOTracker:
    """Rolling-window SLO evaluation, one window pair per tenant.

    ``record_latency`` feeds every completed request; ``record_recall`` feeds
    the shadow-recall samples the :class:`QualityMonitor` produces (recall is
    only observable where ground truth was computed).  Evaluation happens on
    record — an empty window never evaluates, so it never violates."""

    def __init__(self, metrics, targets: Dict[Optional[str], SLOTarget],
                 window: int = 256, min_samples: int = 8):
        self.metrics = metrics
        self.targets = dict(targets or {})
        self.window = int(window)
        # windows below this depth have meaningless statistics — a p99 of
        # three points, or a recall mean of one all-or-nothing query (per-
        # query recall is bimodal, so a single sampled miss would burn the
        # whole budget); both window kinds evaluate only once this deep
        self.min_samples = int(min_samples)
        self._lat: Dict[Optional[str], Deque[float]] = {}
        self._rec: Dict[Optional[str], Deque[float]] = {}
        self.total_violations = 0

    def target_for(self, tenant: Optional[str]) -> Optional[SLOTarget]:
        return self.targets.get(tenant)

    # ------------------------------------------------------------ recording
    def record_latency(self, tenant: Optional[str], ms: float) -> None:
        tgt = self.target_for(tenant)
        if tgt is None or tgt.p99_latency_ms is None:
            return
        w = self._lat.setdefault(tenant, deque(maxlen=self.window))
        w.append(float(ms))
        if len(w) < self.min_samples:
            return
        arr = np.fromiter(w, float, len(w))
        p99 = float(np.percentile(arr, 99))
        # burn rate: fraction of the window over the ceiling, normalized by
        # the 1% budget a p99 target implies (1.0 = exactly on budget)
        burn = float((arr > tgt.p99_latency_ms).mean()) / 0.01
        self.metrics.gauge("slo_window_p99_ms", p99, tenant=tenant)
        self.metrics.gauge("slo_burn_rate", burn, tenant=tenant,
                           slo="latency_p99")
        if p99 > tgt.p99_latency_ms:          # boundary value passes
            self.total_violations += 1
            self.metrics.counter("slo_violations", tenant=tenant,
                                 slo="latency_p99")

    def record_recall(self, tenant: Optional[str], value: float) -> None:
        tgt = self.target_for(tenant)
        if tgt is None or tgt.recall_floor is None:
            return
        w = self._rec.setdefault(tenant, deque(maxlen=self.window))
        w.append(float(value))
        if len(w) < self.min_samples:
            return
        est = float(np.mean(np.fromiter(w, float, len(w))))
        # budget here is the tolerated recall shortfall (1 - floor); a
        # window estimate at floor - (1 - floor) burns at 1.0
        gap = max(0.0, tgt.recall_floor - est)
        burn = gap / max(1.0 - tgt.recall_floor, 1e-9)
        self.metrics.gauge("slo_window_recall", est, tenant=tenant)
        self.metrics.gauge("slo_burn_rate", burn, tenant=tenant,
                           slo="recall_floor")
        if est < tgt.recall_floor:            # boundary value passes
            self.total_violations += 1
            self.metrics.counter("slo_violations", tenant=tenant,
                                 slo="recall_floor")

    # ------------------------------------------------------------ inspection
    def status(self) -> dict:
        """Current window statistics per tracked tenant (for snapshots and
        admin endpoints); tenants with empty windows report ``samples: 0``
        and no breach."""
        out = {}
        for tenant, tgt in self.targets.items():
            lat = self._lat.get(tenant)
            rec = self._rec.get(tenant)
            entry: dict = {"target": dataclasses.asdict(tgt),
                           "latency_samples": len(lat) if lat else 0,
                           "recall_samples": len(rec) if rec else 0}
            if lat and len(lat) >= self.min_samples:
                entry["window_p99_ms"] = float(
                    np.percentile(np.fromiter(lat, float, len(lat)), 99))
            if rec:
                entry["window_recall"] = float(
                    np.mean(np.fromiter(rec, float, len(rec))))
            out[tenant] = entry
        return out


# ------------------------------------------------------------ shadow recall
class QualityMonitor:
    """Seeded shadow-recall estimator over live serving traffic.

    ``observe`` is called once per completed batch (engine flush/retire, or
    ``Searcher.search``) with the batch's plan, queries and result ids.  It
    advances the sampling stream one draw per request, replays the sampled
    subset against ``Searcher.shadow_ground_truth`` (the exact oracle in the
    plan's own result-id space) and accumulates hits/trials per
    (kind, strategy, tenant) cell, publishing::

        recall_estimate{kind,strategy,tenant}           running estimate
        recall_estimate_ci_low / _ci_high{...}          95% Wilson bounds
        shadow_samples / shadow_trials / shadow_hits    counters
        shadow_unsupported / shadow_errors              skipped requests

    The stream position depends only on how many requests were observed
    before this one — not on batch boundaries — so a replayed workload
    samples identically however the scheduler packed it."""

    def __init__(self, metrics, *, sample_rate: float = 0.05, seed: int = 0):
        self.metrics = metrics
        self.sample_rate = float(sample_rate)
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)
        self._seq = 0                       # requests observed (stream pos)
        self._paused = 0
        self.slo: Optional[SLOTracker] = None
        # (kind, strategy, tenant) -> [hits, trials, samples, recall_sum]
        self._cells: Dict[tuple, list] = {}
        self.hits = 0
        self.trials = 0
        self.samples = 0
        self._recall_sum = 0.0

    # ------------------------------------------------------------- sampling
    def sample_mask(self, n: int) -> np.ndarray:
        """Deterministic coin flips for the next ``n`` requests; advances the
        stream."""
        self._seq += n
        if n == 0:
            return np.zeros((0,), bool)
        return self._rng.random(n) < self.sample_rate

    @contextlib.contextmanager
    def paused(self):
        """Suspend sampling (no draws, no stream advance) — the engine wraps
        its warm-up searches so synthetic queries never pollute the
        estimate."""
        self._paused += 1
        try:
            yield
        finally:
            self._paused -= 1

    # ------------------------------------------------------------ observing
    def observe(self, searcher, plan, queries, ids) -> Optional[dict]:
        """Score one completed batch; returns the batch's shadow stats (or
        ``None`` when nothing was sampled).  Never raises into the serving
        path — oracle failures are counted as ``shadow_errors``."""
        if self._paused:
            return None
        q = np.atleast_2d(np.asarray(queries, np.float32))
        mask = self.sample_mask(q.shape[0])
        if not mask.any():
            return None
        labels = dict(kind=plan.kind, strategy=plan.strategy,
                      tenant=plan.tenant)
        try:
            return self._replay(searcher, plan, q[mask],
                                np.atleast_2d(np.asarray(ids))[mask], labels)
        except Exception:
            self.metrics.counter("shadow_errors", float(mask.sum()), **labels)
            return None

    def _replay(self, searcher, plan, q, pred, labels) -> Optional[dict]:
        gt = searcher.shadow_ground_truth(plan, q)
        if gt is None:
            self.metrics.counter("shadow_unsupported", float(len(q)),
                                 **labels)
            return None
        from repro.core.dataset import recall_hits_per_query

        k = min(int(plan.cfg.k), gt.shape[1])
        if k == 0:            # empty oracle population (e.g. nothing passes
            return None       # the filter) — recall is undefined, skip
        row_hits = recall_hits_per_query(pred[:, :k], gt[:, :k])
        hits, trials, n = int(row_hits.sum()), len(q) * k, len(q)
        rsum = float((row_hits / k).sum())
        cell = self._cells.setdefault(
            (plan.kind, plan.strategy, plan.tenant), [0, 0, 0, 0.0])
        cell[0] += hits
        cell[1] += trials
        cell[2] += n
        cell[3] += rsum
        self.hits += hits
        self.trials += trials
        self.samples += n
        self._recall_sum += rsum
        m = self.metrics
        m.counter("shadow_samples", float(n), **labels)
        m.counter("shadow_trials", float(trials), **labels)
        m.counter("shadow_hits", float(hits), **labels)
        est = cell[0] / cell[1]
        # CI at QUERY granularity: a query's k result slots hit or miss
        # together when its traversal diverges, so trial-level Wilson would
        # be overconfident by up to sqrt(k).  Wilson over the per-query
        # recall mean treats each sampled query as one (fractional) trial —
        # conservative under within-query correlation.
        lo, hi = wilson_interval(cell[3], cell[2])
        m.gauge("recall_estimate", est, **labels)
        m.gauge("recall_estimate_ci_low", lo, **labels)
        m.gauge("recall_estimate_ci_high", hi, **labels)
        if self.slo is not None:
            for h in row_hits:
                self.slo.record_recall(plan.tenant, h / k)
        return {"sampled": n, "hits": hits, "trials": trials,
                "estimate": est, "ci_low": lo, "ci_high": hi}

    # ------------------------------------------------------------ inspection
    def overall(self) -> dict:
        """Running estimate pooled over every label cell."""
        lo, hi = wilson_interval(self._recall_sum, self.samples)
        return {"samples": self.samples, "hits": self.hits,
                "trials": self.trials,
                "estimate": self.hits / self.trials if self.trials else None,
                "ci_low": lo, "ci_high": hi}

    def estimate(self, kind: str, strategy: str,
                 tenant: Optional[str] = None) -> Optional[dict]:
        """Per-cell estimate, or ``None`` if the cell has no samples."""
        cell = self._cells.get((kind, strategy, tenant))
        if cell is None or not cell[1]:
            return None
        lo, hi = wilson_interval(cell[3], cell[2])
        return {"samples": cell[2], "hits": cell[0], "trials": cell[1],
                "estimate": cell[0] / cell[1], "ci_low": lo, "ci_high": hi}
