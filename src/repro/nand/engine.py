"""Search-engine (CMOS wafer) timing/energy model — paper §IV-D, Table II.

Clock 1 GHz (22 nm-scaled). Components and their Table II power numbers:
search queues x256, candidate list 2 kB, Bloom filter 12 kB SRAM + 8
SeaHashes, ADT memory 16 kB, PQ module (codebook 64 kB + 32 FP16 MACs),
one shared 256-point bitonic sorter.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    clock_ghz: float = 1.0
    n_queues: int = 256               # N_q
    # -- latency models (cycles), §IV-D
    adt_cycles_per_dim_l2: int = 24   # Euclidean ADT build: 24*D cycles
    adt_cycles_per_dim_ip: int = 8    # Angular/IP: 8*D
    pq_dist_cycles_per_code: int = 32 # M cycles per candidate (M=32)
    acc_dist_cycles_per_dim: int = 1  # D cycles per accurate distance
    sorter_points: int = 256
    # -- power (mW), Table II
    p_static_mw: float = 2141.752
    p_dynamic_mw: float = 2423.802
    # -- per-op dynamic energy split (derived from Table II power @1GHz,
    #    attributed per active unit)
    e_pq_dist_pj: float = 7.0         # M LUT+adds
    e_acc_dist_pj: float = 20.0       # D MACs
    e_sort_pj: float = 486.0          # one 256-pt bitonic pass
    e_bloom_pj: float = 4.6
    e_adt_pj: float = 120.0

    def cycles_to_ns(self, cycles: float) -> float:
        return cycles / self.clock_ghz

    def adt_latency_ns(self, dim: int, metric: str) -> float:
        c = (self.adt_cycles_per_dim_l2 if metric == "l2"
             else self.adt_cycles_per_dim_ip)
        return self.cycles_to_ns(c * dim)

    def sorter_latency_ns(self) -> float:
        n = self.sorter_points
        stages = (math.log2(n) * (math.log2(n) + 1)) / 2
        return self.cycles_to_ns(2 * math.log2(n))  # stage-pipelined (§IV-D)

    def pq_batch_latency_ns(self, n_candidates: int, m: int = 32) -> float:
        """PQ distances for one neighbour fetch (pipelined MACs)."""
        return self.cycles_to_ns(m + n_candidates)

    def acc_latency_ns(self, dim: int) -> float:
        return self.cycles_to_ns(dim)
