"""3D NAND device model (paper §IV-C, Fig. 9, Table II).

Analytical read-latency/energy/area model for a 96-layer 3D NAND core,
calibrated to the paper's reported design points:

  * commercial SSD-class chips (8-16 KB pages, hundreds of blocks):
    15-90 us page reads — precharge/discharge of the huge BL capacitance is
    ~90% of the latency [55]
  * the customized Proxima core (N_BL=36864, N_SSL=4, N_block=64, 32:1 BL
    MUX -> 128 B granularity): < 300 ns reads

Latency model: t_read = t_pre + t_wl + t_sense + t_xfer, with
t_pre ∝ C_BL ∝ (N_block stacked on the bitline) x (precharged BL count).
The 32:1 MUX divides the precharged BL count (partial precharging), which
both cuts t_pre and shrinks the page buffer 32x (§IV-C).

Energy/area constants come straight from Table II.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class NandConfig:
    # -- geometry (Proxima defaults, §IV-C / Table II)
    n_bl: int = 36864                 # bitlines per core
    n_ssl: int = 4
    n_block: int = 64                 # blocks per core (BL capacitive load)
    bl_mux: int = 32                  # 32:1 BL MUX -> partial precharge
    n_layers: int = 96
    cores_per_tile: int = 32
    n_tiles: int = 16
    n_planes: int = 4                 # independent planes per core: the cap
                                      # on same-round parallel page reads
                                      # (beam-parallel traversal issues up to
                                      # min(E, n_planes) reads concurrently)
    # -- timing calibration
    t_wl_setup_ns: float = 20.0       # word-line setup
    t_sense_ns: float = 25.0          # sense amp
    # precharge: t_pre = k_pre * (n_block/64) * (bl_precharged/1152)
    k_pre_ns: float = 230.0           # calibrated -> ~300 ns Proxima core
    bus_bytes_per_ns: float = 32.0    # Cu-Cu bonded H-tree bandwidth/core
    # -- energy (Table II)
    e_core_read_pj: float = 4442.0    # 3D NAND block read, dynamic
    e_core_htree_pj: float = 21.4
    e_tile_htree_pj: float = 198.6
    # -- program/erase (SLC update path; reads stay the paper's fast path)
    t_program_base_ns: float = 60_000.0   # ISPP pulse train, one WL (SLC)
    t_erase_ns: float = 2_000_000.0       # block erase (~2 ms SLC)
    e_program_pj: float = 45_000.0        # one page program (ISPP + verify)
    e_erase_pj: float = 1_500_000.0       # one block erase
    pe_cycle_limit: int = 100_000         # SLC endurance (P/E cycles)
    # -- capacity
    bits_per_cell: int = 1            # SLC (ECC-free, §V-E)
    # -- channel pipelining (NDSEARCH-style round overlap)
    double_buffer: bool = False       # page buffer is double-buffered: page
                                      # reads for round t+1 overlap the PQ
                                      # scoring of round t, so a round's
                                      # critical path is max(read, score)
                                      # instead of read + score

    @property
    def n_cores(self) -> int:
        return self.cores_per_tile * self.n_tiles

    @property
    def page_bytes(self) -> int:
        """Effective data granularity after the BL MUX (128 B for defaults)."""
        return self.n_bl // self.bl_mux // 8

    @property
    def capacity_bits(self) -> int:
        # per core: n_bl x n_ssl x n_block x n_layers SLC cells
        per_core = self.n_bl * self.n_ssl * self.n_block * self.n_layers
        return per_core * self.n_cores * self.bits_per_cell

    # ------------------------------------------------------------- latency
    def read_latency_ns(self, page_bytes: int | None = None,
                        n_block: int | None = None) -> float:
        """Page read latency for a given effective page size / block load."""
        pb = page_bytes if page_bytes is not None else self.page_bytes
        nb = n_block if n_block is not None else self.n_block
        precharged_bl = pb * 8
        t_pre = self.k_pre_ns * (nb / 64.0) * (precharged_bl / 1152.0)
        t_xfer = pb / self.bus_bytes_per_ns
        return t_pre + self.t_wl_setup_ns + self.t_sense_ns + t_xfer

    def access_latency_ns(self, bytes_read: int) -> float:
        """One WL activation + streaming ``bytes_read`` through the BL MUX.
        A word line holds n_bl bits (4.6 KB); reading more bytes than one
        MUX-window adds only transfer cycles, NOT another precharge — this
        is what makes hot-node repetition a single-shot access (§IV-E)."""
        base = self.read_latency_ns()
        extra = max(0, bytes_read - self.page_bytes)
        return base + extra / self.bus_bytes_per_ns

    def access_energy_pj(self, bytes_read: int) -> float:
        """One WL activation + H-tree transfer of ``bytes_read``."""
        windows = max(1, -(-bytes_read // self.page_bytes))
        return (
            self.e_core_read_pj
            + windows * (self.e_core_htree_pj + self.e_tile_htree_pj)
        )

    # ------------------------------------------------------- program / erase
    @property
    def block_bytes(self) -> int:
        """Erase granularity: one block's cells across all layers/SSLs."""
        return self.n_bl * self.n_ssl * self.n_layers * self.bits_per_cell // 8

    def program_latency_ns(self, bytes_written: int) -> float:
        """Sequential page programs: each MUX-window page pays the full ISPP
        pulse train (program latency is verify-dominated, not width-dominated)
        plus the H-tree data load."""
        pages = max(1, -(-bytes_written // self.page_bytes))
        return pages * (
            self.t_program_base_ns + self.page_bytes / self.bus_bytes_per_ns
        )

    def program_energy_pj(self, bytes_written: int) -> float:
        pages = max(1, -(-bytes_written // self.page_bytes))
        return pages * (
            self.e_program_pj + self.e_core_htree_pj + self.e_tile_htree_pj
        )

    def erase_latency_ns(self, bytes_invalidated: int) -> float:
        """Block erases needed to reclaim ``bytes_invalidated``."""
        blocks = max(1, -(-bytes_invalidated // self.block_bytes))
        return blocks * self.t_erase_ns

    def erase_energy_pj(self, bytes_invalidated: int) -> float:
        blocks = max(1, -(-bytes_invalidated // self.block_bytes))
        return blocks * self.e_erase_pj

    # ---------------------------------------------------------- Fig 9 sweep
    def latency_density_tradeoff(self, page_sizes=(128, 512, 2048, 8192, 16384)):
        """Reproduces the Fig. 9 trend: latency and area efficiency vs page
        size (SSD-class large pages -> 10^4 ns reads; Proxima point < 300ns).
        Area efficiency proxy: NAND array area / (array + page buffer),
        where the page buffer scales with the un-muxed page width."""
        rows = []
        for pb in page_sizes:
            nb = 64 if pb <= 512 else 1024  # SSD-class chips stack more blocks
            lat = self.read_latency_ns(page_bytes=pb, n_block=nb)
            buffer_cost = pb * 8 / self.n_bl      # page-buffer area share proxy
            area_eff = 1.0 / (1.0 + 0.35 * buffer_cost * 32)
            rows.append({
                "page_bytes": pb,
                "n_block": nb,
                "read_latency_ns": lat,
                "area_efficiency": area_eff,
            })
        return rows
