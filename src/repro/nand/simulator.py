"""Trace-driven Proxima accelerator simulator (front-end in the spirit of the
paper's modified NeuroSIM; back-end = device.py timing/energy).

Input: a ``WorkloadTrace`` built from REAL search-counter traces
(core/search.py SearchResult) — expansions, PQ distance counts, rerank
counts, hot-node hits — plus the data-layout bit widths (gap encoding).

Output: QPS, query latency, QPS/W, runtime breakdown (NAND access vs H-tree
vs engine compute), and core utilization, under an M/M/1-style contention
model across the 512 NAND cores. Reproduces the shapes of paper Figs 12-16.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.nand.device import NandConfig
from repro.nand.engine import EngineConfig


@dataclasses.dataclass
class WorkloadTrace:
    """Per-query averages from a measured search run."""
    hops: float                 # vertex expansions (index fetches)
    pq: float                   # PQ distance computations (code fetches)
    acc: float                  # accurate distance computations (raw fetches)
    hot_hops: float = 0.0       # expansions served by hot-node repetition
    free_pq: float = 0.0        # PQ fetches covered by hot pages
    rounds: float = 0.0
    dim: int = 128
    r_degree: int = 64
    index_bits: int = 32        # 32 uncompressed; 20-26 gap-encoded
    pq_bits: int = 256          # M=32 x 8b codes
    raw_bytes: int = 512        # D x fp32
    metric: str = "l2"
    use_pq: bool = True


@dataclasses.dataclass
class SimResult:
    qps: float
    latency_us: float
    qps_per_watt: float
    power_w: float
    core_utilization: float
    breakdown: Dict[str, float]          # fractional runtime shares
    traffic_bytes_per_query: Dict[str, float]

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _accesses_per_query(t: WorkloadTrace, nand: NandConfig):
    """Returns (WL activations, core-busy ns, traffic bytes by category).

    Each access = one WL activation; extra bytes beyond the MUX window add
    transfer time only (device.access_latency_ns). Hot hops read the
    co-located index+codes record in a single activation (§IV-E)."""
    cold_hops = max(t.hops - t.hot_hops, 0.0)
    idx_bytes_each = t.r_degree * t.index_bits / 8.0
    hot_bytes_each = (t.r_degree * (t.index_bits + t.pq_bits) + t.pq_bits) / 8.0
    cold_pq = max(t.pq - t.free_pq, 0.0)
    pq_bytes_each = t.pq_bits / 8.0

    n_access = cold_hops * (1 + cold_pq / max(cold_hops, 1.0)) \
        + t.hot_hops + t.acc
    busy_ns = (
        cold_hops * nand.access_latency_ns(int(idx_bytes_each))
        + t.hot_hops * nand.access_latency_ns(int(hot_bytes_each))
        + cold_pq * nand.access_latency_ns(int(pq_bytes_each))
        + t.acc * nand.access_latency_ns(t.raw_bytes)
    )
    energy_pj = (
        cold_hops * nand.access_energy_pj(int(idx_bytes_each))
        + t.hot_hops * nand.access_energy_pj(int(hot_bytes_each))
        + cold_pq * nand.access_energy_pj(int(pq_bytes_each))
        + t.acc * nand.access_energy_pj(t.raw_bytes)
    )
    traffic = {
        "index": cold_hops * idx_bytes_each + t.hot_hops * hot_bytes_each,
        "pq_codes": cold_pq * pq_bytes_each,
        "raw": t.acc * t.raw_bytes,
    }
    return n_access, busy_ns, energy_pj, traffic


def _engine_ns_per_query(t: WorkloadTrace, eng: EngineConfig) -> float:
    ns = eng.adt_latency_ns(t.dim, t.metric) if t.use_pq else 0.0
    per_round_pq = t.pq / max(t.rounds, 1.0)
    ns += t.rounds * (
        eng.pq_batch_latency_ns(per_round_pq)
        + eng.sorter_latency_ns()
        + 1.0  # bloom
    )
    ns += t.acc * eng.acc_latency_ns(t.dim)
    return ns


def simulate(
    trace: WorkloadTrace,
    nand: NandConfig = NandConfig(),
    eng: EngineConfig = EngineConfig(),
    n_queues: int | None = None,
    iters: int = 40,
) -> SimResult:
    nq = n_queues if n_queues is not None else eng.n_queues
    t_core = nand.read_latency_ns()
    accesses, busy_ns_q, energy_pj_q, traffic = _accesses_per_query(trace, nand)
    engine_ns = _engine_ns_per_query(trace, eng)

    cold_hops = max(trace.hops - trace.hot_hops, 0.0)
    hot_bytes_each = (
        trace.r_degree * (trace.index_bits + trace.pq_bits) + trace.pq_bits
    ) / 8.0
    # critical path: per cold hop an index fetch followed by one (parallel)
    # neighbour-code wave; per hot hop one single-shot activation
    s_t0 = (
        cold_hops * 2.0 * t_core
        + trace.hot_hops * nand.access_latency_ns(int(hot_bytes_each))
        + 2.0 * t_core  # rerank waves (pipelined raw fetches)
    )

    # contention equilibrium (M/M/1 per core):
    #   latency = S/(1-rho) + E,  rho = QPS*busy/C,  QPS = Nq/latency
    # -> quadratic  -E rho^2 + (S + E + K) rho - K = 0,  K = Nq*busy/C
    e_ns = engine_ns
    k = nq * busy_ns_q / nand.n_cores
    if e_ns > 1e-12:
        b = s_t0 + e_ns + k
        disc = max(b * b - 4.0 * e_ns * k, 0.0)
        rho = (b - math.sqrt(disc)) / (2.0 * e_ns)
    else:
        rho = k / (s_t0 + k)
    rho = min(max(rho, 0.0), 0.95)
    lat_ns = s_t0 / max(1.0 - rho, 0.05) + e_ns
    qps = nq / (lat_ns * 1e-9)

    # --- power
    p_nand_w = qps * energy_pj_q * 1e-12
    busy_frac = min(qps * engine_ns * 1e-9 / nq, 1.0)
    queue_scale = nq / 256.0
    p_engine_w = (
        eng.p_static_mw * queue_scale
        + eng.p_dynamic_mw * busy_frac * queue_scale
    ) * 1e-3
    power = p_nand_w + p_engine_w

    nand_ns = s_t0 / max(1.0 - rho, 0.05)
    bus_ns = sum(traffic.values()) / nand.bus_bytes_per_ns / max(nand.n_cores / 8, 1)
    total = nand_ns + bus_ns + engine_ns
    return SimResult(
        qps=qps,
        latency_us=lat_ns * 1e-3,
        qps_per_watt=qps / max(power, 1e-9),
        power_w=power,
        core_utilization=rho,
        breakdown={
            "nand_access": nand_ns / total,
            "htree_bus": bus_ns / total,
            "engine": engine_ns / total,
        },
        traffic_bytes_per_query=traffic,
    )


def trace_from_search_result(res, *, dim, r_degree, index_bits, pq_bits,
                             metric="l2", use_pq=True, use_hot=True) -> WorkloadTrace:
    """Average the per-query counters of a core.search SearchResult."""
    import numpy as np

    f = lambda x: float(np.asarray(x).mean())
    return WorkloadTrace(
        hops=f(res.n_hops), pq=f(res.n_pq), acc=f(res.n_acc),
        hot_hops=f(res.n_hot_hops) if use_hot else 0.0,
        free_pq=f(res.n_free_pq) if use_hot else 0.0,
        rounds=f(res.rounds), dim=dim, r_degree=r_degree,
        index_bits=index_bits, pq_bits=pq_bits, raw_bytes=dim * 4,
        metric=metric, use_pq=use_pq,
    )
