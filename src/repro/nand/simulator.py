"""Trace-driven Proxima accelerator simulator (front-end in the spirit of the
paper's modified NeuroSIM; back-end = device.py timing/energy).

Input: a ``WorkloadTrace`` built from REAL search-counter traces
(core/search.py SearchResult) — expansions, PQ distance counts, rerank
counts, hot-node hits — plus the data-layout bit widths (gap encoding).

Output: QPS, query latency, QPS/W, runtime breakdown (NAND access vs H-tree
vs engine compute), and core utilization, under an M/M/1-style contention
model across the 512 NAND cores. Reproduces the shapes of paper Figs 12-16.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.nand.device import NandConfig
from repro.nand.engine import EngineConfig


@dataclasses.dataclass
class WorkloadTrace:
    """Per-query averages from a measured search run."""
    hops: float                 # vertex expansions (index fetches)
    pq: float                   # PQ distance computations (code fetches)
    acc: float                  # accurate distance computations (raw fetches)
    hot_hops: float = 0.0       # expansions served by hot-node repetition
    free_pq: float = 0.0        # PQ fetches covered by hot pages
    rounds: float = 0.0
    beam_width: float = 1.0     # E — expansions issued per traversal round;
                                # up to min(E, NandConfig.n_planes) of a
                                # round's page reads overlap on parallel
                                # planes, shortening the serial pointer-chase
    dim: int = 128
    r_degree: int = 64
    index_bits: int = 32        # 32 uncompressed; 20-26 gap-encoded
    pq_bits: int = 256          # M=32 x 8b codes
    raw_bytes: int = 512        # D x fp32
    metric: str = "l2"
    use_pq: bool = True
    # --- filtered-query billing (repro.filter) -----------------------------
    attr_bits: int = 0          # per-node attribute word (page spare area)
    filter_mode: str = "off"    # off | pushdown | host — where the
                                # predicate is evaluated (see
                                # _accesses_per_query for the billing split)
    filter_selectivity: float = 1.0  # passing fraction of scored candidates


def logical_insert_bytes(dim: int, pq_bits: int, r_degree: int,
                         index_bits: int) -> float:
    """Bytes one insert adds to the NAND-resident index: raw vector + PQ
    code + one adjacency row. Shared by the analytic update model below and
    the live delta segment's write accounting (stream.delta) so the two
    cannot drift."""
    return dim * 4 + pq_bits / 8.0 + r_degree * index_bits / 8.0


@dataclasses.dataclass
class UpdateTrace:
    """Streaming-update workload: online inserts/deletes buffered in a DRAM
    delta segment, folded into NAND by periodic consolidation (the
    ``stream.MutableIndex`` serving model). NAND sees no per-insert program;
    it sees the consolidation rewrite — that rewrite/logical ratio IS the
    subsystem's write amplification."""
    insert_rate: float = 0.0          # inserts per second offered
    delete_rate: float = 0.0          # deletes per second offered
    corpus_size: int = 1_000_000      # live vectors at steady state
    consolidate_fraction: float = 0.25  # delta/base fraction triggering rebuild
    dim: int = 128
    r_degree: int = 64
    index_bits: int = 32
    pq_bits: int = 256

    @property
    def bytes_per_insert(self) -> float:
        return logical_insert_bytes(self.dim, self.pq_bits, self.r_degree,
                                    self.index_bits)


@dataclasses.dataclass
class UpdateSimResult:
    update_throughput_per_s: float    # max sustainable inserts/sec
    program_mb_per_s: float           # NAND program bandwidth at offered rate
    write_amplification: float        # programmed / logical bytes
    program_energy_pj_per_insert: float
    erase_energy_pj_per_insert: float
    update_power_w: float             # program+erase power at offered rate
    program_busy_fraction: float      # share of core-time spent programming
    endurance_years: float            # to SLC P/E limit at offered rate

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SimResult:
    qps: float
    latency_us: float
    qps_per_watt: float
    power_w: float
    core_utilization: float
    breakdown: Dict[str, float]          # fractional runtime shares
    traffic_bytes_per_query: Dict[str, float]
    transfer_pj_per_query: float = 0.0   # H-tree channel-transfer energy —
                                         # the quantity predicate pushdown
                                         # shrinks vs host-side filtering
    round_latency_us: float = 0.0        # ONE traversal round's critical
                                         # path: read + score sequential, or
                                         # max(read, score) double-buffered
    overlap_saved_us: float = 0.0        # per-query latency hidden by the
                                         # double-buffered channel (0 when
                                         # NandConfig.double_buffer is off)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def metrics(self) -> dict:
        """Flat name -> value mapping for the observability registry
        (``repro.obs.record_plan_execution``): the per-query cost figures
        serving snapshots report next to host wall-time percentiles.
        ``nand_pj_per_query`` is TOTAL power (NAND array + CMOS engine)
        amortized per query at the modeled QPS."""
        return {
            "nand_latency_us": self.latency_us,
            "nand_model_qps": self.qps,
            "nand_power_w": self.power_w,
            "nand_pj_per_query": self.power_w / max(self.qps, 1e-12) * 1e12,
            "nand_transfer_pj_per_query": self.transfer_pj_per_query,
            "nand_core_utilization": self.core_utilization,
            "nand_round_latency_us": self.round_latency_us,
            "nand_overlap_saved_us": self.overlap_saved_us,
        }


def _transfer_pj(traffic: Dict[str, float], nand: NandConfig) -> float:
    """Channel-transfer energy of the per-query H-tree traffic (continuous
    window billing — strictly monotone in bytes, so a strict byte saving is
    a strict energy saving)."""
    per_window = nand.e_core_htree_pj + nand.e_tile_htree_pj
    return sum(traffic.values()) / nand.page_bytes * per_window


def _accesses_per_query(t: WorkloadTrace, nand: NandConfig):
    """Returns (WL activations, core-busy ns, traffic bytes by category).

    Each access = one WL activation; extra bytes beyond the MUX window add
    transfer time only (device.access_latency_ns). Hot hops read the
    co-located index+codes record in a single activation (§IV-E).

    Filtered queries split by where the predicate runs:

      * ``pushdown`` — each neighbour's attribute word sits in the spare
        area of the adjacency page, so the expansion's WL activation
        already returns it (extra transfer bytes, no extra activation);
        the tile drops non-passing candidates BEFORE channel transfer, so
        only ``filter_selectivity`` of the candidate stream crosses the
        H-tree.
      * ``host`` — attribute words ride with the candidate's PQ record and
        every candidate (plus its attribute word) crosses the channel for
        host-side evaluation: the ``attrs`` traffic category is the
        pushdown path's saving.
    """
    cold_hops = max(t.hops - t.hot_hops, 0.0)
    attr_each = t.attr_bits / 8.0 if t.filter_mode != "off" else 0.0
    idx_xfer_each = t.r_degree * t.index_bits / 8.0
    hot_xfer_each = (t.r_degree * (t.index_bits + t.pq_bits) + t.pq_bits) / 8.0
    cold_pq = max(t.pq - t.free_pq, 0.0)
    pq_xfer_each = t.pq_bits / 8.0
    # bytes READ from the array per activation may exceed bytes that cross
    # the channel: pushdown consumes the spare-area attr words in-tile
    idx_read_each, hot_read_each, pq_read_each = \
        idx_xfer_each, hot_xfer_each, pq_xfer_each
    if t.filter_mode == "pushdown":
        # spare-area co-location: R neighbour attr words per adjacency read
        idx_read_each += t.r_degree * attr_each
        hot_read_each += t.r_degree * attr_each
    elif t.filter_mode == "host":
        # attr word rides with the candidate record AND crosses the channel
        pq_read_each += attr_each

    n_access = cold_hops * (1 + cold_pq / max(cold_hops, 1.0)) \
        + t.hot_hops + t.acc
    busy_ns = (
        cold_hops * nand.access_latency_ns(int(idx_read_each))
        + t.hot_hops * nand.access_latency_ns(int(hot_read_each))
        + cold_pq * nand.access_latency_ns(int(pq_read_each))
        + t.acc * nand.access_latency_ns(t.raw_bytes)
    )
    energy_pj = (
        cold_hops * nand.access_energy_pj(int(idx_read_each))
        + t.hot_hops * nand.access_energy_pj(int(hot_read_each))
        + cold_pq * nand.access_energy_pj(int(pq_read_each))
        + t.acc * nand.access_energy_pj(t.raw_bytes)
    )
    pass_frac = (
        min(max(t.filter_selectivity, 0.0), 1.0)
        if t.filter_mode == "pushdown" else 1.0
    )
    traffic = {
        "index": cold_hops * idx_xfer_each + t.hot_hops * hot_xfer_each,
        "pq_codes": cold_pq * pq_xfer_each * pass_frac,
        "raw": t.acc * t.raw_bytes,
        "attrs": cold_pq * attr_each if t.filter_mode == "host" else 0.0,
    }
    return n_access, busy_ns, energy_pj, traffic


def _engine_ns_per_query(t: WorkloadTrace, eng: EngineConfig) -> float:
    ns = eng.adt_latency_ns(t.dim, t.metric) if t.use_pq else 0.0
    per_round_pq = t.pq / max(t.rounds, 1.0)
    ns += t.rounds * (
        eng.pq_batch_latency_ns(per_round_pq)
        + eng.sorter_latency_ns()
        + 1.0  # bloom
    )
    ns += t.acc * eng.acc_latency_ns(t.dim)
    return ns


def simulate_updates(
    u: UpdateTrace,
    nand: NandConfig = NandConfig(),
) -> UpdateSimResult:
    """Program/erase cost of the streaming-update path.

    One consolidation cycle: ``consolidate_fraction * corpus_size`` inserts
    accumulate in DRAM, then the rebuilt index — every live vector's raw
    data + PQ code + adjacency row — is reprogrammed and the superseded
    blocks erased. Deletes add no program traffic of their own but shrink
    the live set the rewrite carries."""
    frac = max(u.consolidate_fraction, 1e-6)
    inserts_per_cycle = max(frac * u.corpus_size, 1.0)
    pvb = u.bytes_per_insert
    live_after = u.corpus_size * (1.0 + frac)
    if u.insert_rate > 0:
        live_after -= u.delete_rate / u.insert_rate * inserts_per_cycle
    live_after = max(live_after, inserts_per_cycle)
    rewrite_bytes = live_after * pvb
    logical_bytes = inserts_per_cycle * pvb
    wa = rewrite_bytes / logical_bytes

    prog_ns_cycle = nand.program_latency_ns(int(rewrite_bytes))
    erase_ns_cycle = nand.erase_latency_ns(int(rewrite_bytes))
    core_ns_cycle = (prog_ns_cycle + erase_ns_cycle) / nand.n_cores
    max_rate = inserts_per_cycle / (core_ns_cycle * 1e-9)

    e_prog_cycle = nand.program_energy_pj(int(rewrite_bytes))
    e_erase_cycle = nand.erase_energy_pj(int(rewrite_bytes))
    e_prog_ins = e_prog_cycle / inserts_per_cycle
    e_erase_ins = e_erase_cycle / inserts_per_cycle

    rate = u.insert_rate
    busy_frac = min(rate / max_rate, 1.0) if max_rate > 0 else 0.0
    power_w = rate * (e_prog_ins + e_erase_ins) * 1e-12
    prog_mb_s = rate * pvb * wa / 1e6

    # endurance: bytes erased per second wear the whole array uniformly
    # (consolidation is a sequential full rewrite -> perfect wear leveling)
    cap_bytes = nand.capacity_bits / 8.0
    bytes_per_s = rate * pvb * wa
    if bytes_per_s > 0:
        pe_per_s = bytes_per_s / cap_bytes
        endurance_years = nand.pe_cycle_limit / pe_per_s / (365.25 * 86400)
    else:
        endurance_years = float("inf")
    return UpdateSimResult(
        update_throughput_per_s=max_rate,
        program_mb_per_s=prog_mb_s,
        write_amplification=wa,
        program_energy_pj_per_insert=e_prog_ins,
        erase_energy_pj_per_insert=e_erase_ins,
        update_power_w=power_w,
        program_busy_fraction=busy_frac,
        endurance_years=endurance_years,
    )


@dataclasses.dataclass
class BuildTrace:
    """Offline segmented-build workload (``core.segmented``): each emitted
    segment programs its artifacts — raw vectors + PQ codes + adjacency
    rows, the same per-vertex record ``logical_insert_bytes`` prices for
    the streaming path — ONCE; cross-segment stitching then re-programs the
    adjacency rows it patched (erase + program of superseded rows).  The
    (logical + stitch) / logical ratio is the BUILD-time write
    amplification, reported next to serve-time reads."""
    segment_sizes: tuple              # vertices emitted per segment
    stitched_rows: int = 0            # adjacency rows rewritten by stitching
    dim: int = 128
    r_degree: int = 64
    index_bits: int = 32
    pq_bits: int = 256

    @property
    def bytes_per_vertex(self) -> float:
        return logical_insert_bytes(self.dim, self.pq_bits, self.r_degree,
                                    self.index_bits)

    @property
    def row_bytes(self) -> float:
        """One adjacency row — the unit stitching rewrites."""
        return self.r_degree * self.index_bits / 8.0


@dataclasses.dataclass
class BuildSimResult:
    build_seconds: float              # NAND program/erase time, all segments
    program_mb: float                 # total bytes programmed
    write_amplification: float        # programmed / logical bytes
    program_energy_uj: float
    erase_energy_uj: float
    per_segment_seconds: tuple        # program time per emitted segment

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def simulate_build(
    b: BuildTrace,
    nand: NandConfig = NandConfig(),
) -> BuildSimResult:
    """Program/erase cost of the segmented offline build.

    Segments are billed independently (each emission is one sequential
    program burst across the cores); stitch patches additionally erase the
    superseded adjacency rows and program the rewritten ones — reusing the
    same ``NandConfig`` program/erase model as :func:`simulate_updates`, so
    build-time and serve-time write amplification share one price list."""
    pvb = b.bytes_per_vertex
    seg_seconds = []
    e_prog = 0.0
    for n_seg in b.segment_sizes:
        seg_bytes = n_seg * pvb
        ns = nand.program_latency_ns(int(seg_bytes)) / nand.n_cores
        seg_seconds.append(ns * 1e-9)
        e_prog += nand.program_energy_pj(int(seg_bytes))

    logical = sum(b.segment_sizes) * pvb
    stitch_bytes = b.stitched_rows * b.row_bytes
    programmed = logical + stitch_bytes
    e_erase = 0.0
    stitch_ns = 0.0
    if b.stitched_rows:  # the device model floors at one page/block
        e_prog += nand.program_energy_pj(int(stitch_bytes))
        e_erase = nand.erase_energy_pj(int(stitch_bytes))
        stitch_ns = (
            nand.program_latency_ns(int(stitch_bytes))
            + nand.erase_latency_ns(int(stitch_bytes))
        ) / nand.n_cores

    return BuildSimResult(
        build_seconds=sum(seg_seconds) + stitch_ns * 1e-9,
        program_mb=programmed / 1e6,
        write_amplification=programmed / max(logical, 1e-12),
        program_energy_uj=e_prog / 1e6,
        erase_energy_uj=e_erase / 1e6,
        per_segment_seconds=tuple(seg_seconds),
    )


def simulate(
    trace: WorkloadTrace,
    nand: NandConfig = NandConfig(),
    eng: EngineConfig = EngineConfig(),
    n_queues: int | None = None,
    iters: int = 40,
    available_core_fraction: float = 1.0,
) -> SimResult:
    nq = n_queues if n_queues is not None else eng.n_queues
    t_core = nand.read_latency_ns()
    accesses, busy_ns_q, energy_pj_q, traffic = _accesses_per_query(trace, nand)
    # update programs steal core-time from reads (mixed read/write serving)
    busy_ns_q = busy_ns_q / max(available_core_fraction, 0.05)
    engine_ns = _engine_ns_per_query(trace, eng)

    cold_hops = max(trace.hops - trace.hot_hops, 0.0)
    hot_bytes_each = (
        trace.r_degree * (trace.index_bits + trace.pq_bits) + trace.pq_bits
    ) / 8.0
    # critical path: per cold hop an index fetch followed by one (parallel)
    # neighbour-code wave; per hot hop one single-shot activation. With
    # beam-parallel traversal the E expansions of one round are concurrent
    # page reads on independent planes, so the serial chain is divided by
    # the realized plane parallelism min(E, n_planes) — rounds, not hops,
    # set the pointer-chase length.
    par = max(1.0, min(trace.beam_width, float(nand.n_planes)))
    s_t0 = (
        cold_hops * 2.0 * t_core / par
        + trace.hot_hops * nand.access_latency_ns(int(hot_bytes_each)) / par
        + 2.0 * t_core  # rerank waves (pipelined raw fetches)
    )

    # contention equilibrium (M/M/1 per core):
    #   latency = S/(1-rho) + E,  rho = QPS*busy/C,  QPS = Nq/latency
    # -> quadratic  -E rho^2 + (S + E + K) rho - K = 0,  K = Nq*busy/C
    e_ns = engine_ns
    k = nq * busy_ns_q / nand.n_cores
    if e_ns > 1e-12:
        b = s_t0 + e_ns + k
        disc = max(b * b - 4.0 * e_ns * k, 0.0)
        rho = (b - math.sqrt(disc)) / (2.0 * e_ns)
    else:
        rho = k / (s_t0 + k)
    rho = min(max(rho, 0.0), 0.95)
    lat_ns = s_t0 / max(1.0 - rho, 0.05) + e_ns

    # --- double-buffered channel (NDSEARCH-style round pipelining) ---------
    # With a double-buffered page buffer the page reads for round t+1 issue
    # while the CMOS engine scores round t, so a steady-state round's
    # critical path is max(read, score) instead of read + score.  Core BUSY
    # time is unchanged (the work still happens — overlap hides latency,
    # not occupancy), so rho and power are untouched; the pipeline saves
    # min(read, score) per round after the one fill round.
    rounds = max(trace.rounds, 1.0)
    read_chain_ns = max(s_t0 - 2.0 * t_core, 0.0)   # minus the rerank waves
    per_round_read = read_chain_ns / rounds / max(1.0 - rho, 0.05)
    per_round_pq = trace.pq / rounds
    per_round_score = (
        eng.pq_batch_latency_ns(per_round_pq) + eng.sorter_latency_ns() + 1.0
    )
    if nand.double_buffer:
        round_ns = max(per_round_read, per_round_score)
        overlap_ns = (rounds - 1.0) * min(per_round_read, per_round_score)
        lat_ns = max(lat_ns - overlap_ns, round_ns)
    else:
        round_ns = per_round_read + per_round_score
        overlap_ns = 0.0
    qps = nq / (lat_ns * 1e-9)

    # --- power
    p_nand_w = qps * energy_pj_q * 1e-12
    busy_frac = min(qps * engine_ns * 1e-9 / nq, 1.0)
    queue_scale = nq / 256.0
    p_engine_w = (
        eng.p_static_mw * queue_scale
        + eng.p_dynamic_mw * busy_frac * queue_scale
    ) * 1e-3
    power = p_nand_w + p_engine_w

    nand_ns = s_t0 / max(1.0 - rho, 0.05)
    bus_ns = sum(traffic.values()) / nand.bus_bytes_per_ns / max(nand.n_cores / 8, 1)
    total = nand_ns + bus_ns + engine_ns
    return SimResult(
        qps=qps,
        latency_us=lat_ns * 1e-3,
        qps_per_watt=qps / max(power, 1e-9),
        power_w=power,
        core_utilization=rho,
        breakdown={
            "nand_access": nand_ns / total,
            "htree_bus": bus_ns / total,
            "engine": engine_ns / total,
        },
        traffic_bytes_per_query=traffic,
        transfer_pj_per_query=_transfer_pj(traffic, nand),
        round_latency_us=round_ns * 1e-3,
        overlap_saved_us=overlap_ns * 1e-3,
    )


@dataclasses.dataclass
class MixedSimResult:
    """Read + update serving on the same cores."""
    read: SimResult
    update: UpdateSimResult
    qps: float                        # read QPS under update contention
    update_rate: float                # offered inserts/sec
    total_power_w: float
    qps_per_watt: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def simulate_mixed(
    trace: WorkloadTrace,
    updates: UpdateTrace,
    nand: NandConfig = NandConfig(),
    eng: EngineConfig = EngineConfig(),
    n_queues: int | None = None,
) -> MixedSimResult:
    """Mixed read/write serving: the update stream's program/erase busy
    fraction derates the cores available to reads."""
    upd = simulate_updates(updates, nand)
    read = simulate(
        trace, nand, eng, n_queues=n_queues,
        available_core_fraction=1.0 - min(upd.program_busy_fraction, 0.95),
    )
    power = read.power_w + upd.update_power_w
    return MixedSimResult(
        read=read,
        update=upd,
        qps=read.qps,
        update_rate=updates.insert_rate,
        total_power_w=power,
        qps_per_watt=read.qps / max(power, 1e-9),
    )


def filter_comparison(
    trace: WorkloadTrace,
    nand: NandConfig = NandConfig(),
    eng: EngineConfig = EngineConfig(),
    n_queues: int | None = None,
) -> dict:
    """Near-storage predicate pushdown vs host-side filtering for the SAME
    measured trace: the pushdown path bills attribute words as spare-area
    reads co-located with adjacency pages and lets only passing candidates
    cross the channel; the host path ships every candidate plus its
    attribute word. Returns both SimResults and the savings ratios.
    ``trace.attr_bits`` must be set (> 0) for the comparison to bite."""
    push = simulate(dataclasses.replace(trace, filter_mode="pushdown"),
                    nand, eng, n_queues=n_queues)
    host = simulate(dataclasses.replace(trace, filter_mode="host"),
                    nand, eng, n_queues=n_queues)
    return {
        "pushdown": push,
        "host": host,
        "transfer_bytes_saved": (
            sum(host.traffic_bytes_per_query.values())
            - sum(push.traffic_bytes_per_query.values())
        ),
        "transfer_energy_ratio": (
            push.transfer_pj_per_query / max(host.transfer_pj_per_query, 1e-12)
        ),
        "latency_speedup": host.latency_us / max(push.latency_us, 1e-12),
        "qps_per_watt_gain": (
            push.qps_per_watt / max(host.qps_per_watt, 1e-12)
        ),
    }


def trace_from_search_result(res, *, dim, r_degree, index_bits, pq_bits,
                             metric="l2", use_pq=True, use_hot=True,
                             beam_width=None, attr_bits=0,
                             filter_mode="off",
                             filter_selectivity=1.0) -> WorkloadTrace:
    """Average the per-query counters of a core.search SearchResult.

    A ``shard.ShardedSearchResult`` is accepted too: its (P, Q) counters are
    summed across the tile axis first, so the trace carries the TOTAL work a
    query costs across all channels (use ``traces_from_sharded_result`` +
    ``simulate_sharded`` for the per-channel view).

    ``beam_width`` defaults to the REALIZED per-round expansion parallelism
    measured from the counters themselves (mean hops / mean rounds — the
    n_hops-vs-rounds separation core.search maintains); pass the configured
    ``SearchConfig.beam_width`` explicitly to bill the nominal E instead.

    A ``filter.FilteredSearchResult`` is accepted too (its ``.result``
    counters are used, and ``filter_selectivity`` defaults to the result's
    measured selectivity); set ``attr_bits``/``filter_mode`` to bill the
    predicate evaluation (see ``filter_comparison``)."""
    import numpy as np

    if hasattr(res, "mode") and hasattr(res, "result"):   # FilteredSearchResult
        if filter_selectivity == 1.0:
            # traversal mode scores the full frontier, of which only
            # `selectivity` passes; scan mode's candidate stream is the
            # passing subset itself — every scored candidate crosses, so
            # pushdown must not discount it
            filter_selectivity = (
                res.selectivity if res.mode == "traversal" else 1.0
            )
        res = res.result
    if hasattr(res, "per_tile"):
        res = res.per_tile
        f = lambda x: float(np.asarray(x).sum(0).mean())
    else:
        f = lambda x: float(np.asarray(x).mean())
    hops, rounds = f(res.n_hops), f(res.rounds)
    if beam_width is None:
        beam_width = hops / max(rounds, 1.0)
    return WorkloadTrace(
        hops=hops, pq=f(res.n_pq), acc=f(res.n_acc),
        hot_hops=f(res.n_hot_hops) if use_hot else 0.0,
        free_pq=f(res.n_free_pq) if use_hot else 0.0,
        rounds=rounds, beam_width=max(float(beam_width), 1.0),
        dim=dim, r_degree=r_degree,
        index_bits=index_bits, pq_bits=pq_bits, raw_bytes=dim * 4,
        metric=metric, use_pq=use_pq,
        attr_bits=attr_bits, filter_mode=filter_mode,
        filter_selectivity=filter_selectivity,
    )


def traces_from_sharded_result(res, *, dim, r_degree, index_bits, pq_bits,
                               metric="l2", use_pq=True, use_hot=True,
                               beam_width=None, attr_bits=0,
                               filter_mode="off",
                               filter_selectivity=1.0) -> list[WorkloadTrace]:
    """Per-tile workload traces from a ``shard.ShardedSearchResult`` — the
    per-tile counter axis maps 1:1 onto NAND channel groups. ``beam_width``
    propagates to every channel trace (None -> realized hops/rounds,
    measured per tile); so do the filter billing knobs."""
    per = res.per_tile if hasattr(res, "per_tile") else res
    num_tiles = per.ids.shape[0]
    return [
        trace_from_search_result(
            type(per)(*(f[p] for f in per)),
            dim=dim, r_degree=r_degree, index_bits=index_bits,
            pq_bits=pq_bits, metric=metric, use_pq=use_pq, use_hot=use_hot,
            beam_width=beam_width, attr_bits=attr_bits,
            filter_mode=filter_mode, filter_selectivity=filter_selectivity,
        )
        for p in range(num_tiles)
    ]


# ---------------------------------------------------------------------------
# Channel-parallel (sharded) serving model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedSimResult:
    """Multi-channel serving: P tiles, each on its own slice of the NAND
    cores, every query fanned out to all channels and merged by the shared
    bitonic sorter."""
    per_channel: list                     # SimResult per channel group
    qps: float                            # aggregate (straggler-bound)
    latency_us: float                     # max channel latency + merge pass
    qps_per_watt: float
    power_w: float
    channel_utilization: list             # per-channel rho
    load_imbalance: float                 # max/mean channel busy-time
    merge_overhead_us: float              # cross-tile bitonic merge per query

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["per_channel"] = [r.to_dict() for r in self.per_channel]
        return d


def _with_cores(nand: NandConfig, cores: int) -> NandConfig:
    """A NandConfig whose core count is one channel group's share."""
    cores = max(int(cores), 1)
    if cores % nand.cores_per_tile == 0:
        return dataclasses.replace(nand, n_tiles=cores // nand.cores_per_tile)
    return dataclasses.replace(nand, n_tiles=1, cores_per_tile=cores)


def simulate_sharded(
    traces: list,
    nand: NandConfig = NandConfig(),
    eng: EngineConfig = EngineConfig(),
    n_queues: int | None = None,
    available_core_fraction: float = 1.0,
) -> ShardedSimResult:
    """Serve one query stream over P corpus tiles on channel-partitioned
    cores.

    Each of the P tiles gets ``n_cores / P`` cores; a query runs on every
    channel concurrently (per-tile traversal of a 1/P-size graph), so query
    latency is the slowest channel's latency plus one cross-tile bitonic
    merge pass, and the engine's N_q queues bound concurrency exactly as in
    the single-tile model. Per-tile traces carry less work per query than
    the single-tile trace (shorter traversals on smaller graphs), which is
    where the channel-level bandwidth win comes from; imbalance across
    channels (allocation-policy dependent) shows up as straggler latency.

    With routed probing (``shard.sharded_search(probe_tiles=...)``) the
    skipped lanes arrive zeroed, so each per-tile trace is the channel's
    work amortized over ALL arriving queries — correct for throughput and
    utilization; per-query latency of the probed subset is then slightly
    underestimated (amortized chain length < probed chain length).
    """
    if not traces:
        raise ValueError("need at least one per-tile trace")
    p = len(traces)
    nq = n_queues if n_queues is not None else eng.n_queues
    ch_nand = _with_cores(nand, nand.n_cores // p)
    per = [
        simulate(t, ch_nand, eng, n_queues=nq,
                 available_core_fraction=available_core_fraction)
        for t in traces
    ]
    merge_us = eng.sorter_latency_ns() * 1e-3
    lat_us = max(r.latency_us for r in per) + merge_us
    qps = nq / (lat_us * 1e-6)

    # power: every channel pays its NAND access energy at the aggregate
    # query rate; the CMOS engine is shared and counted once
    e_nand_pj = sum(_accesses_per_query(t, ch_nand)[2] for t in traces)
    p_nand_w = qps * e_nand_pj * 1e-12
    engine_ns = max(_engine_ns_per_query(t, eng) for t in traces)
    busy_frac = min(qps * engine_ns * 1e-9 / nq, 1.0)
    queue_scale = nq / 256.0
    p_engine_w = (
        eng.p_static_mw * queue_scale
        + eng.p_dynamic_mw * busy_frac * queue_scale
    ) * 1e-3
    power = p_nand_w + p_engine_w

    busy = [_accesses_per_query(t, ch_nand)[1] for t in traces]
    imbalance = max(busy) / max(sum(busy) / p, 1e-9)
    return ShardedSimResult(
        per_channel=per,
        qps=qps,
        latency_us=lat_us,
        qps_per_watt=qps / max(power, 1e-9),
        power_w=power,
        channel_utilization=[r.core_utilization for r in per],
        load_imbalance=imbalance,
        merge_overhead_us=merge_us,
    )


# ---------------------------------------------------------------------------
# Plan-derived billing (repro.plan)
# ---------------------------------------------------------------------------

def _plan_geometry(index, dim, r_degree, index_bits, pq_bits) -> dict:
    """Resolve trace geometry from an index handle (a ``ProximaIndex`` or a
    ``stream.MutableIndex``) unless given explicitly."""
    if index is not None:
        base = index.base if hasattr(index, "delta") and \
            hasattr(index, "base") else index
        dim = base.dataset.dim if dim is None else dim
        r_degree = base.graph.adjacency.shape[1] if r_degree is None \
            else r_degree
        index_bits = (base.gap.bit_width if base.gap else 32) \
            if index_bits is None else index_bits
        pq_bits = 8 * base.codes.shape[1] if pq_bits is None else pq_bits
    missing = [n for n, v in (("dim", dim), ("r_degree", r_degree),
                              ("index_bits", index_bits),
                              ("pq_bits", pq_bits)) if v is None]
    if missing:
        raise ValueError(
            f"trace geometry underspecified: pass index= or {missing}"
        )
    return dict(dim=dim, r_degree=r_degree, index_bits=index_bits,
                pq_bits=pq_bits)


def _plan_filter_billing(pres) -> dict:
    """Filter billing facts read off the executed plan: where the predicate
    ran (pushdown vs host), the attribute-word width, and the passing
    fraction of the scored candidate stream (scan mode's candidates are the
    passing subset itself — every scored candidate crosses the channel, so
    pushdown must not discount it)."""
    plan = pres.plan
    filtered = plan.strategy not in ("none",)
    if not filtered:
        return dict(attr_bits=0, filter_mode="off", filter_selectivity=1.0)
    sel = pres.stats.selectivity if plan.strategy in ("masked", "adaptive") \
        else 1.0
    # a merged plan defers the regime choice to execute time; when its base
    # actually ran the bitmap scan, the scored candidates ARE the passing
    # subset and every one crosses the channel — no pushdown discount
    if plan.strategy == "adaptive" and \
            getattr(pres.raw, "base_mode", None) in ("scan", "empty"):
        sel = 1.0
    return dict(
        attr_bits=plan.attr_bits,
        filter_mode="pushdown" if plan.pushdown else "host",
        filter_selectivity=float(sel),
    )


def _plan_counters(pres):
    """The counter-carrying kernel result inside a plan execution."""
    raw = pres.raw
    if hasattr(raw, "delta_candidates"):      # MergedResult: bill the base
        return raw.base
    if hasattr(raw, "n_hops") or hasattr(raw, "per_tile") \
            or hasattr(raw, "result"):        # core / sharded / filtered
        return raw
    raise ValueError(                         # distributed (ids, dists) pair
        "distributed plan executions carry no NAND counters — bill a "
        "flat/tiled/merged execution of the same workload instead"
    )


def trace_from_plan_execution(pres, *, index=None, dim=None, r_degree=None,
                              index_bits=None, pq_bits=None, use_hot=True,
                              beam_width=None) -> WorkloadTrace:
    """One aggregate ``WorkloadTrace`` from a ``repro.plan.SearchResult`` —
    billing derived from the PLAN (filter strategy, selectivity, attribute
    word width, metric, PQ use) instead of hand-threaded per-path trace
    constructor arguments. Geometry comes from ``index=`` (the served
    ``ProximaIndex``/``MutableIndex``) or the explicit kwargs.

    ``beam_width`` follows ``trace_from_search_result``: None bills the
    REALIZED per-round parallelism measured from the counters; pass
    ``pres.plan.cfg.beam_width`` to bill the nominal E instead."""
    plan = pres.plan
    geo = _plan_geometry(index, dim, r_degree, index_bits, pq_bits)
    fb = _plan_filter_billing(pres)
    return trace_from_search_result(
        _plan_counters(pres), metric=plan.metric, use_pq=plan.cfg.use_pq,
        use_hot=use_hot, beam_width=beam_width, **geo, **fb,
    )


def traces_from_plan_execution(pres, *, index=None, dim=None, r_degree=None,
                               index_bits=None, pq_bits=None, use_hot=True,
                               beam_width=None) -> list:
    """Per-channel ``WorkloadTrace`` list from a tiled plan execution (the
    input ``simulate_sharded`` consumes); the execution's raw result must
    carry a per-tile counter axis (a tiled plan, or a merged plan over a
    tiled base)."""
    plan = pres.plan
    geo = _plan_geometry(index, dim, r_degree, index_bits, pq_bits)
    fb = _plan_filter_billing(pres)
    counters = _plan_counters(pres)
    if not hasattr(counters, "per_tile"):
        raise ValueError(
            "plan execution has no per-tile counter axis — use "
            "trace_from_plan_execution for flat/merged-over-flat plans"
        )
    return traces_from_sharded_result(
        counters, metric=plan.metric, use_pq=plan.cfg.use_pq,
        use_hot=use_hot, beam_width=beam_width, **geo, **fb,
    )
