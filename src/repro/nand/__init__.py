"""repro.nand subpackage."""
