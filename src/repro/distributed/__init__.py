"""repro.distributed subpackage."""
