"""Fault tolerance, straggler mitigation and elastic scaling.

At 1000+ nodes, three failure classes dominate; the policies here are the
single-controller-side mechanisms (the JAX runtime + this framework's
checkpoint layer handle the rest):

1. **Hard node loss** — checkpoint/restart. ``FaultTolerantLoop`` wraps the
   train loop: periodic (optionally async) checkpoints, and on ANY step
   exception (device loss surfaces as XlaRuntimeError) it restores the
   latest checkpoint and replays. Because the data pipeline is step-seeded
   (train/data.py), replay is bit-deterministic — no data state to recover.

2. **Silent data corruption / numerics** — per-step loss/grad-norm guards:
   a non-finite loss or a grad-norm spike beyond ``gnorm_sigma`` standard
   deviations triggers a rollback-and-skip (restore latest, skip the
   offending step's data by advancing one step). This mirrors the paper's
   §V-E bit-error study: Proxima tolerates storage bit errors at the
   algorithm level; a trainer must tolerate them at the loop level.

3. **Stragglers / elasticity** — checkpoints are topology-independent
   (logical-axis manifest, ckpt/checkpoint.py): restoring onto a smaller or
   larger mesh re-shards automatically (``elastic_restore``). The batch
   schedule is resolution-independent (global batch fixed; per-device batch
   changes), so throughput degrades gracefully instead of halting when a pod
   is drained. Synchronous collectives bound straggler damage to one step;
   the dry-run's ``pod`` axis is the drain/failover granularity.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt


@dataclasses.dataclass
class FaultConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    async_ckpt: bool = True
    gnorm_sigma: float = 6.0     # spike threshold (running stats)
    max_restarts: int = 8


class FaultTolerantLoop:
    """Wraps (state, step) -> (state, metrics) with checkpoint/restart."""

    def __init__(
        self,
        step_fn: Callable[[Any, int], tuple],
        state: Any,
        cfg: FaultConfig,
        shardings: Any = None,
        start_step: int = 0,
    ):
        self.step_fn = step_fn
        self.state = state
        self.cfg = cfg
        self.shardings = shardings
        self.step = start_step
        self.restarts = 0
        self._gn_mean = 0.0
        self._gn_var = 1.0
        self._gn_count = 0
        self._pending: Optional[Any] = None

    # ------------------------------------------------------------- recovery
    def try_resume(self) -> bool:
        latest = ckpt.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return False
        self.state, self.step, _ = ckpt.restore_checkpoint(
            self.cfg.ckpt_dir, self.state, shardings=self.shardings
        )
        return True

    def _rollback(self, skip_bad_step: bool) -> None:
        self.restarts += 1
        if self.restarts > self.cfg.max_restarts:
            raise RuntimeError("exceeded max_restarts; giving up")
        bad = self.step
        self.state, self.step, _ = ckpt.restore_checkpoint(
            self.cfg.ckpt_dir, self.state, shardings=self.shardings
        )
        if skip_bad_step:
            # deterministic pipeline: skipping = advancing past the bad batch
            self.step = max(self.step, bad) + 1

    def _checkpoint(self) -> None:
        if self._pending is not None:
            self._pending.join()
        self._pending = ckpt.save_checkpoint(
            self.cfg.ckpt_dir, self.step, self.state,
            async_mode=self.cfg.async_ckpt, keep=self.cfg.keep,
        )

    def _gnorm_spike(self, gnorm: float) -> bool:
        if not math.isfinite(gnorm):
            return True
        if self._gn_count >= 20:
            sd = math.sqrt(max(self._gn_var, 1e-12))
            if gnorm > self._gn_mean + self.cfg.gnorm_sigma * sd:
                return True
        self._gn_count += 1
        d = gnorm - self._gn_mean
        self._gn_mean += d / self._gn_count
        self._gn_var += (d * (gnorm - self._gn_mean) - self._gn_var) / self._gn_count
        return False

    # ----------------------------------------------------------------- run
    def run(self, num_steps: int, on_metrics=None) -> Any:
        if self.step == 0:
            self._checkpoint()  # step-0 anchor so rollback always has a base
        end = self.step + num_steps
        while self.step < end:
            try:
                state2, metrics = self.step_fn(self.state, self.step)
                loss = float(metrics.get("loss", np.nan))
                gnorm = float(metrics.get("grad_norm", 0.0))
                if not math.isfinite(loss) or self._gnorm_spike(gnorm):
                    raise FloatingPointError(
                        f"numerics fault at step {self.step}: loss={loss} gnorm={gnorm}"
                    )
                self.state = state2
                self.step += 1
                if on_metrics:
                    on_metrics(self.step, metrics)
                if self.step % self.cfg.ckpt_every == 0:
                    self._checkpoint()
            except FloatingPointError:
                self._rollback(skip_bad_step=True)
            except jax.errors.JaxRuntimeError:
                self._rollback(skip_bad_step=False)
        if self._pending is not None:
            self._pending.join()
        return self.state


def elastic_restore(ckpt_dir: str, target: Any, new_mesh, specs) -> Any:
    """Restore a checkpoint onto a DIFFERENT mesh (elastic scale up/down):
    shardings are re-derived from the logical specs against ``new_mesh``."""
    from repro.distributed import sharding as shard_lib

    sh = shard_lib.param_shardings(specs, target, new_mesh)
    state, step, extra = ckpt.restore_checkpoint(ckpt_dir, target, shardings=sh)
    return state, step, extra
