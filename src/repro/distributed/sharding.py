"""Logical-axis -> mesh-axis sharding rules (GSPMD / pjit).

Parameters carry *logical* axis names (see models/layers.py); this module
resolves them against a mesh. The default rules implement:

  * tensor parallelism on "model": heads / kv / mlp / vocab / experts dims
  * FSDP (ZeRO-3-style) on "data": the "embed" dim of weight matrices is
    sharded over the data axis — parameters and optimizer state are fully
    sharded; XLA inserts the all-gathers before use and reduce-scatters of
    gradients (the classic MaxText fsdp mapping)
  * "pod" (multi-pod) extends the batch axis only: FSDP stays *within* a pod
    so param all-gathers ride the fast intra-pod ICI; each pod holds a full
    (sharded) replica, gradients all-reduce across pods.

Activations are constrained on the batch dim; everything else propagates.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_HINT_MESH: Optional[Mesh] = None


def abstract_mesh(axis_sizes, axis_names):
    """Version-portable ``jax.sharding.AbstractMesh`` constructor.

    jax >= 0.5 takes ``(axis_sizes, axis_names)``; jax 0.4.x takes a single
    ``shape_tuple`` of (name, size) pairs. Only the axis-name -> size mapping
    matters to the sharding rules, so either form works downstream.
    """
    from jax.sharding import AbstractMesh

    # try the 0.4.x single-argument form first: on newer jax it fails the
    # signature bind (axis_names required), while the reverse order could
    # silently misroute axis_names into 0.4.x's positional axis_types
    try:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    except TypeError:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))


class activation_hints:
    """Context manager enabling activation sharding constraints during
    tracing/lowering. Model code calls ``hint(x, spec_fn)``; outside this
    context those calls are no-ops (single-device tests stay clean)."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh

    def __enter__(self):
        global _HINT_MESH
        self._old = _HINT_MESH
        _HINT_MESH = self.mesh
        return self.mesh

    def __exit__(self, *exc):
        global _HINT_MESH
        _HINT_MESH = self._old
        return False


def hint(x, spec_fn):
    """Apply with_sharding_constraint(spec_fn(mesh, x.shape)) if hints are
    enabled. spec_fn returns a PartitionSpec."""
    if _HINT_MESH is None:
        return x
    spec = spec_fn(_HINT_MESH, tuple(x.shape))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_HINT_MESH, spec)
    )


import functools


@functools.lru_cache(maxsize=None)
def _grad_sharded_fn(sh: NamedSharding):
    """identity with a sharding constraint on the COTANGENT (one cached
    custom_vjp per sharding — NamedSharding is hashable)."""

    @jax.custom_vjp
    def f(x):
        return x

    def fwd(x):
        return x, None

    def bwd(_, g):
        return (jax.lax.with_sharding_constraint(g, sh),)

    f.defvjp(fwd, bwd)
    return f


def param_hint(x, logical: Tuple[Optional[str], ...]):
    """Constrain a weight (inside a scanned block body) to its logical
    sharding — on the FORWARD value and, via custom_vjp, on its COTANGENT.
    Critical for training memory: without the cotangent constraint, the
    layer-scan backward accumulates per-layer weight gradients into a fully
    REPLICATED stacked buffer (268 GB/device for a 67B model); constraining
    the cotangent forces a reduce-scatter back to the FSDP/TP sharding every
    layer (see EXPERIMENTS.md §Perf)."""
    if _HINT_MESH is None:
        return x
    spec = logical_to_spec(logical, shape=tuple(x.shape), mesh=_HINT_MESH)
    sh = NamedSharding(_HINT_MESH, spec)
    x = jax.lax.with_sharding_constraint(x, sh)
    return _grad_sharded_fn(sh)(x)


def param_hints(p: dict, logical: dict) -> dict:
    """param_hint over a dict of weights (missing keys pass through)."""
    return {
        k: param_hint(v, logical[k]) if k in logical else v
        for k, v in p.items()
    }


def _bspec_axes(mesh: Mesh, dim: int):
    baxes = batch_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in baxes]))
    return baxes if dim % bsize == 0 else None


def qkv_spec(mesh: Mesh, shape) -> P:
    """Grouped-query activations (b, s, nkv, g, hd) / (b, s, h, hd):
    shard batch over (pod, data); shard kv heads over model when divisible,
    else shard the query-group dim (MQA: many groups per kv head)."""
    m = mesh.shape.get("model", 1)
    spec = [_bspec_axes(mesh, shape[0])] + [None] * (len(shape) - 1)
    if len(shape) >= 5:
        if shape[2] % m == 0:
            spec[2] = "model"
        elif shape[3] % m == 0:
            spec[3] = "model"
    elif len(shape) == 4:
        if shape[2] % m == 0:
            spec[2] = "model"
    return P(*spec)


def heads_concat_spec(mesh: Mesh, shape) -> P:
    """(b, s, h*hd) attention output before wo: shard the flattened head dim
    over model (row-parallel input)."""
    m = mesh.shape.get("model", 1)
    last = "model" if shape[-1] % m == 0 else None
    return P(_bspec_axes(mesh, shape[0]), *([None] * (len(shape) - 2)), last)


def residual_spec(mesh: Mesh, shape) -> P:
    """Residual stream (b, s, d): batch-sharded, d replicated."""
    return P(_bspec_axes(mesh, shape[0]), *([None] * (len(shape) - 1)))


def seq_parallel_spec(mesh: Mesh, shape) -> P:
    """Residual stream (b, s, d) with the SEQUENCE dim sharded over the
    model axis (Megatron-style sequence parallelism). Shrinks the per-layer
    saved activation stack (the layer-scan's backward residuals) by the
    model-axis size — the lever that fits 67B+ train cells in HBM."""
    m = mesh.shape.get("model", 1)
    seq = "model" if len(shape) >= 3 and shape[1] % m == 0 else None
    return P(_bspec_axes(mesh, shape[0]), seq, None)


def moe_buffer_spec(mesh: Mesh, shape) -> P:
    """(E*cap, d) expert dispatch buffer: shard slots over data (tokens come
    from data-sharded batch; scatter becomes the expert all-to-all)."""
    d = mesh.shape.get("data", 1)
    return P("data" if shape[0] % d == 0 else None, None)


def moe_hidden_spec(mesh: Mesh, shape) -> P:
    """(E, cap, f) expert hidden activations: capacity slots over data, the
    FFN hidden dim over model — keeps the expert einsum chain consistently
    sharded (without it GSPMD picks expert-dim shardings that force
    involuntary full rematerializations in the backward)."""
    d = mesh.shape.get("data", 1)
    m = mesh.shape.get("model", 1)
    cap = "data" if shape[1] % d == 0 else None
    hid = "model" if shape[2] % m == 0 else None
    return P(None, cap, hid)


def moe_out_spec(mesh: Mesh, shape) -> P:
    """(E, cap, d) expert outputs: capacity over data, d replicated."""
    d = mesh.shape.get("data", 1)
    return P(None, "data" if shape[1] % d == 0 else None, None)


def ssm_state_spec(mesh: Mesh, shape) -> P:
    """(b, s, di, ds) / (b, di, ds) scan tensors: batch + d_inner over model."""
    m = mesh.shape.get("model", 1)
    spec = [_bspec_axes(mesh, shape[0])] + [None] * (len(shape) - 1)
    di_axis = len(shape) - 2
    if shape[di_axis] % m == 0:
        spec[di_axis] = "model"
    return P(*spec)


DEFAULT_RULES: Dict[Optional[str], Optional[Tuple[str, ...]]] = {
    "embed": ("data",),        # FSDP
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    None: None,
}


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def logical_to_spec(
    logical: Tuple[Optional[str], ...],
    rules: Dict[Optional[str], Optional[Tuple[str, ...]]] = None,
    shape: Optional[Tuple[int, ...]] = None,
    mesh: Optional[Mesh] = None,
) -> P:
    """Resolve one logical spec tuple to a PartitionSpec. If ``shape``+``mesh``
    are given, axes that don't divide evenly fall back to replication (e.g.
    kv=1 MQA heads can't be sharded 16-ways)."""
    rules = rules or DEFAULT_RULES
    out = []
    used = set()
    for i, name in enumerate(logical):
        mapped = rules.get(name)
        if mapped is None:
            out.append(None)
            continue
        mapped = tuple(m for m in mapped if m not in used)
        if not mapped:
            out.append(None)
            continue
        if shape is not None and mesh is not None:
            size = int(np.prod([mesh.shape[m] for m in mapped]))
            if shape[i] % size != 0:
                out.append(None)
                continue
        used.update(mapped)
        out.append(mapped if len(mapped) > 1 else mapped[0])
    return P(*out)


def param_shardings(
    specs: Any, params_shape: Any, mesh: Mesh, rules=None
) -> Any:
    """specs: pytree of logical tuples; params_shape: matching pytree of
    ShapeDtypeStructs (or arrays). Returns NamedSharding pytree."""

    def resolve(spec, arr):
        return NamedSharding(
            mesh, logical_to_spec(spec, rules, tuple(arr.shape), mesh)
        )

    return jax.tree_util.tree_map(
        resolve, specs, params_shape,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


def batch_sharding(mesh: Mesh) -> NamedSharding:
    """Batch pytrees: leading dim over (pod, data)."""
    return NamedSharding(mesh, P(batch_axes(mesh)))


def batch_spec(mesh: Mesh) -> P:
    return P(batch_axes(mesh))


def cache_shardings(mesh: Mesh, cache, cfg, seq_shard: bool = False):
    """Decode-cache shardings. KV caches (n_layers, B, cap, Hkv, hd):
    batch over (pod,data) when divisible; kv heads over model when divisible;
    with ``seq_shard`` (long-context, tiny batch) the cap/sequence dim is
    sharded over data instead — sequence-parallel KV."""
    baxes = batch_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in baxes]))
    msize = mesh.shape["model"]

    def spec_for(path, arr):
        if arr.ndim == 0:
            return NamedSharding(mesh, P())
        name = path[-1] if path else ""
        shape = arr.shape
        if name in ("kv_k", "kv_v") and arr.ndim == 5:
            # (n_layers, B, cap, Hkv, hd). Preference order:
            #   batch  -> (pod, data)    when divisible
            #   heads  -> model          when divisible (GQA with enough kv)
            #   cap    -> model          otherwise (MQA / small-kv: shard the
            #            sequence dim — softmax collectives inserted by GSPMD)
            #   cap    -> data           when batch is unshardable (B=1 long
            #            context: sequence-parallel KV)
            b, cap, hkv = shape[1], shape[2], shape[3]
            pb = baxes if b % bsize == 0 else None
            ph = "model" if hkv % msize == 0 else None
            pseq = None
            if ph is None and cap % msize == 0:
                pseq = "model"
            if pb is None and cap % (mesh.shape["data"] * (msize if pseq == "model" else 1)) == 0:
                pseq = ("data", "model") if pseq == "model" else "data"
            return NamedSharding(mesh, P(None, pb, pseq, ph, None))
        if name == "enc_out" and arr.ndim == 3:
            b = shape[0]
            pb = baxes if b % bsize == 0 else None
            return NamedSharding(mesh, P(pb, None, None))
        if arr.ndim >= 2:  # ssm/conv states: (n, B, ...)
            b = shape[1]
            pb = baxes if b % bsize == 0 else None
            rest = [None] * (arr.ndim - 2)
            # shard the widest state dim over model if divisible
            widths = list(shape[2:])
            if widths:
                j = int(np.argmax(widths))
                if widths[j] % msize == 0:
                    rest[j] = "model"
            return NamedSharding(mesh, P(None, pb, *rest))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(
        lambda path, a: spec_for(tuple(getattr(p, "name", getattr(p, "idx", "")) for p in path), a),
        cache,
    )
