"""repro.ckpt subpackage."""
