"""Sharded, topology-independent checkpointing.

Design (DESIGN.md §5 fault tolerance):
  * each leaf is written as one ``.npy`` (gathered to host); the manifest
    records the tree structure, dtypes, shapes, the *logical* sharding specs
    and a sha256 digest per leaf — restore onto ANY mesh re-shards from the
    logical specs, which is what makes elastic re-meshing work.
  * writes are atomic: tmp directory + rename; a ``latest`` symlink flips
    last, so a crash mid-write never corrupts the previous checkpoint.
  * optional async mode hands the arrays to a writer thread (training keeps
    stepping while the previous state persists).
  * data-pipeline state is NOT stored: the pipeline is step-seeded
    (train/data.py), so ``step`` alone resumes the exact stream.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)
import numpy as np

_SEP = "/"


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype name including ml_dtypes extensions (bfloat16...)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def name(path):
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return _SEP.join(parts)

    return [(name(path), leaf) for path, leaf in flat]


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


def save_checkpoint(
    directory: str,
    step: int,
    state: Any,
    extra: Optional[Dict[str, Any]] = None,
    async_mode: bool = False,
    keep: int = 3,
) -> threading.Thread | None:
    """Persist ``state`` under ``directory/step_{step:08d}``."""
    os.makedirs(directory, exist_ok=True)
    leaves = _flatten_with_paths(state)
    # gather to host BEFORE handing off (donated buffers may be reused)
    host_leaves = [(n, np.asarray(jax.device_get(a))) for n, a in leaves]

    def write():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}, "extra": extra or {}}
        for name, arr in host_leaves:
            fn = name.replace(_SEP, "__") + ".npy"
            # raw byte storage: round-trips ml_dtypes (bfloat16, fp8) that
            # np.save cannot represent; shape/dtype live in the manifest
            raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
            np.save(os.path.join(tmp, fn), raw)
            manifest["leaves"][name] = {
                "file": fn,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _update_latest(directory, final)
        _gc(directory, keep)

    if async_mode:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _update_latest(directory: str, final: str) -> None:
    link = os.path.join(directory, "latest")
    tmp_link = link + ".tmp"
    if os.path.lexists(tmp_link):
        os.remove(tmp_link)
    os.symlink(os.path.basename(final), tmp_link)
    os.replace(tmp_link, link)


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    link = os.path.join(directory, "latest")
    if not os.path.exists(link):
        return None
    name = os.path.basename(os.path.realpath(link))
    return int(name.split("_")[1])


def restore_checkpoint(
    directory: str,
    target: Any,
    step: Optional[int] = None,
    shardings: Any = None,
    validate_digests: bool = False,
) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings — leaves are device_put with them (elastic re-meshing:
    pass shardings built against the NEW mesh)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    names = [n for n, _ in _flatten_with_paths(target)]
    tdef = _treedef_of(target)
    sh_leaves = (
        [s for _, s in _flatten_with_paths(shardings)] if shardings is not None
        else [None] * len(names)
    )
    leaves = []
    for name, sh in zip(names, sh_leaves):
        meta = manifest["leaves"][name]
        raw = np.load(os.path.join(path, meta["file"]))
        arr = raw.view(_np_dtype(meta["dtype"])).reshape(meta["shape"])
        if validate_digests:
            digest = hashlib.sha256(arr.tobytes()).hexdigest()
            if digest != meta["sha256"]:
                raise IOError(f"digest mismatch for {name} in {path}")
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(tdef, leaves), step, manifest["extra"]
