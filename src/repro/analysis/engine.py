"""proxlint rule engine — findings, suppressions, file walking.

A *rule* is a class with an ``id``, a default severity, and a ``check``
method producing :class:`Finding`s from a parsed file
(:class:`FileContext`).  Repo-wide rules (import-graph analyses) set
``project_rule = True`` and implement ``check_project`` over every scanned
file at once.

Suppressions are inline comments, narrowest-wins:

* ``# proxlint: disable=rule-a,rule-b`` on the finding's line suppresses
  those rules for that line only;
* ``# proxlint: disable-file=rule-a`` anywhere in a file suppresses the
  rule for the whole file.

Anything intentional but repo-visible goes in the checked-in baseline
instead (:mod:`repro.analysis.baseline`) so it carries a justification and
goes stale loudly when the code it covered changes.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SEVERITIES = ("error", "warning")
Severity = str  # "error" | "warning"

_SUPPRESS_RE = re.compile(r"#\s*proxlint:\s*disable=([\w,\-\s]+)")
_SUPPRESS_FILE_RE = re.compile(r"#\s*proxlint:\s*disable-file=([\w,\-\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to ``path:line``.

    ``line_text`` is the stripped source line (or a symbolic key for
    module-granularity findings) — it is the baseline-matching identity, so
    baselines survive unrelated edits that only shift line numbers, and go
    stale when the flagged line itself changes.
    """
    rule: str
    path: str                  # repo-relative posix path
    line: int
    col: int
    message: str
    fix_hint: str = ""
    severity: Severity = "error"
    line_text: str = ""

    @property
    def baseline_key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.line_text)

    def render(self) -> str:
        loc = f"{self.path}:{self.line}"
        out = f"{loc}: [{self.rule}] {self.severity}: {self.message}"
        if self.fix_hint:
            out += f"\n    fix: {self.fix_hint}"
        return out


class FileContext:
    """One parsed source file plus the per-line suppression table."""

    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.line_disables: Dict[int, set] = {}
        self.file_disables: set = set()
        for i, line in enumerate(self.lines, start=1):
            if "proxlint" not in line:
                continue
            m = _SUPPRESS_FILE_RE.search(line)
            if m:
                self.file_disables.update(
                    r.strip() for r in m.group(1).split(",") if r.strip())
                continue
            m = _SUPPRESS_RE.search(line)
            if m:
                self.line_disables[i] = {
                    r.strip() for r in m.group(1).split(",") if r.strip()}

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def suppressed(self, rule: str, line: int) -> bool:
        if rule in self.file_disables:
            return True
        return rule in self.line_disables.get(line, set())

    def finding(self, rule: "Rule", node, message: str,
                fix_hint: Optional[str] = None,
                severity: Optional[Severity] = None) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Finding(
            rule=rule.id, path=self.rel, line=line, col=col, message=message,
            fix_hint=rule.fix_hint if fix_hint is None else fix_hint,
            severity=rule.severity if severity is None else severity,
            line_text=self.line_text(line),
        )


class Rule:
    """Base rule: subclass, set ``id``/``severity``/``fix_hint``, implement
    ``check`` (or ``check_project`` with ``project_rule = True``)."""

    id: str = ""
    severity: Severity = "error"
    fix_hint: str = ""
    #: one line for ``--list-rules`` and the README rule table
    doc: str = ""
    #: True -> ``check_project(ctxs)`` runs once over the whole file set
    project_rule: bool = False
    #: repo root for project rules that consult files outside the scanned
    #: set (set by the runner from ``check_paths(root=...)``)
    root: str = "."

    def applies(self, rel: str) -> bool:
        """Scope gate — override to restrict a rule to subtrees."""
        return True

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        return ()

    def check_project(self, ctxs: Sequence[FileContext]) -> Iterable[Finding]:
        return ()


@dataclasses.dataclass
class Report:
    """One ``check`` run: every finding after suppressions, split against
    the baseline, plus baseline entries that no longer match anything."""
    findings: List[Finding]            # all non-suppressed findings
    new: List[Finding]                 # not covered by the baseline
    baselined: List[Finding]           # covered by the baseline
    stale: List                        # BaselineEntry no longer matching
    parse_errors: List[str]

    @property
    def ok(self) -> bool:
        return not self.new and not self.stale and not self.parse_errors


def _walk_py(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            out.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(
                d for d in dirnames
                if not d.startswith(".") and d != "__pycache__")
            for f in sorted(filenames):
                if f.endswith(".py"):
                    out.append(os.path.join(dirpath, f))
    return out


def _relpath(path: str, root: str) -> str:
    rel = os.path.relpath(os.path.abspath(path), os.path.abspath(root))
    return rel.replace(os.sep, "/")


def load_contexts(paths: Sequence[str], root: str = ".",
                  ) -> Tuple[List[FileContext], List[str]]:
    ctxs: List[FileContext] = []
    errors: List[str] = []
    for path in _walk_py(paths):
        rel = _relpath(path, root)
        try:
            with open(path, "r", encoding="utf-8") as f:
                source = f.read()
            ctxs.append(FileContext(path, rel, source))
        except (OSError, SyntaxError) as e:
            errors.append(f"{rel}: unparseable: {e}")
    return ctxs, errors


def run_rules(ctxs: Sequence[FileContext],
              rules: Optional[Sequence[Rule]] = None,
              root: str = ".") -> List[Finding]:
    """Every non-suppressed finding over the given files, stably ordered."""
    if rules is None:
        from repro.analysis.rules import ALL_RULES
        rules = [cls() for cls in ALL_RULES]
    by_rel = {c.rel: c for c in ctxs}
    findings: List[Finding] = []
    for rule in rules:
        rule.root = root
        if rule.project_rule:
            scoped = [c for c in ctxs if rule.applies(c.rel)]
            produced = rule.check_project(scoped)
        else:
            produced = []
            for ctx in ctxs:
                if rule.applies(ctx.rel):
                    produced.extend(rule.check(ctx))
        for f in produced:
            ctx = by_rel.get(f.path)
            if ctx is not None and ctx.suppressed(f.rule, f.line):
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def check_paths(paths: Sequence[str], root: str = ".",
                baseline=None,
                rules: Optional[Sequence[Rule]] = None) -> Report:
    """Scan ``paths`` and split findings against ``baseline`` (a
    :class:`repro.analysis.baseline.Baseline` or None)."""
    ctxs, errors = load_contexts(paths, root=root)
    findings = run_rules(ctxs, rules=rules, root=root)
    if baseline is None:
        from repro.analysis.baseline import Baseline
        baseline = Baseline(())
    new, covered, stale = baseline.split(findings)
    return Report(findings=findings, new=new, baselined=covered,
                  stale=stale, parse_errors=errors)


def check_source(source: str, rel: str = "<string>.py",
                 rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Rule-fixture entry point: findings for one in-memory source blob
    (what ``tests/test_analysis.py`` drives its per-rule fixtures through)."""
    ctx = FileContext(rel, rel, source)
    if rules is None:
        from repro.analysis.rules import ALL_RULES
        rules = [cls() for cls in ALL_RULES]
    out: List[Finding] = []
    for rule in rules:
        if rule.project_rule:
            produced = rule.check_project([ctx])
        elif rule.applies(rel):
            produced = rule.check(ctx)
        else:
            produced = ()
        out.extend(f for f in produced if not ctx.suppressed(f.rule, f.line))
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out
