"""monotonic-clock — wall clocks are forbidden in latency paths.

``time.time()`` is wall-clock: it jumps under NTP step corrections, which
turned the serving engine's flush timeout into an instant flush (the PR6
bug — submit/_flush_due/step measured queue wait with ``time.time()``).
Every duration measured in the serving stack (``serve/``, ``obs/``,
``plan/``) and every benchmark timing loop must use the monotonic
``time.perf_counter()``.  Wall-clock *timestamps* (log lines, trace epoch
anchors) are still fine outside those trees.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import FileContext, Rule
from repro.analysis.rules._ast_util import dotted_name

#: path components whose files are latency paths
_SCOPED = ("serve", "obs", "plan", "benchmarks")


class MonotonicClockRule(Rule):
    id = "monotonic-clock"
    severity = "error"
    fix_hint = ("use time.perf_counter() (monotonic) for anything that is "
                "subtracted; time.time() jumps under NTP corrections")
    doc = ("time.time() in serve/, obs/, plan/ or benchmarks/ — the PR6 "
           "flush-timeout bug class")

    def applies(self, rel: str) -> bool:
        parts = rel.split("/")
        return any(p in _SCOPED for p in parts[:-1])

    def check(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if dotted_name(node.func) == "time.time":
                yield ctx.finding(
                    self, node,
                    "time.time() in a latency path is wall-clock and "
                    "non-monotonic",
                )
