"""dtype-hygiene — int32 node ids, no float64 leaking into the kernels.

Two numeric contracts in ``core/`` and ``kernels/``:

* **node-id arrays are int32** — ids index adjacency/code pages on device;
  a 64-bit id array doubles gather bandwidth and silently promotes every
  downstream index computation.  Constructing an id-named array
  (``ids`` / ``*_ids``) without an explicit int32 dtype is a finding.
* **no float64 into jnp ops** — jax defaults to float32 (x64 disabled);
  an explicit ``np.float64`` literal/cast flowing into a jitted op either
  silently downcasts or, with x64 enabled, doubles NAND transfer sizes and
  splits the jit cache by dtype.  ``np.float64(...)``, ``astype(np.float64)``
  and ``dtype=np.float64`` are findings.

Deliberate wide integers (the uint64 gap-encoding bitstream, int64 scatter
indices) are untouched — the rule looks at float64 and id-*named* arrays
only.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import FileContext, Rule
from repro.analysis.rules._ast_util import dotted_name

_CONSTRUCTORS = {"arange", "zeros", "ones", "full", "empty"}
#: positional index of the dtype argument per constructor
_DTYPE_POS = {"arange": 3, "zeros": 1, "ones": 1, "full": 2, "empty": 1}
_F64_SPELLINGS = {"np.float64", "numpy.float64", "jnp.float64", "float64"}
_I32_SPELLINGS = {"np.int32", "numpy.int32", "jnp.int32", "int32"}


def _dtype_spelling(node: ast.AST):
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return dotted_name(node)


def _id_named(name: str) -> bool:
    return name == "ids" or name.endswith("_ids") or name.rstrip("0123456789") == "ids"


class DtypeHygieneRule(Rule):
    id = "dtype-hygiene"
    severity = "error"
    doc = ("node-id arrays not constructed int32, or float64 literals/casts "
           "in core//kernels/ — bandwidth and jit-cache-split guard")

    def applies(self, rel: str) -> bool:
        parts = rel.split("/")
        return "core" in parts or "kernels" in parts

    def check(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted_name(node.func)
            # --- float64 ---------------------------------------------------
            if d in _F64_SPELLINGS:
                yield ctx.finding(
                    self, node,
                    f"bare {d}(...) in the kernel tree — jax is float32 "
                    f"by default and float64 doubles transfer sizes",
                    fix_hint="use float32 (or jnp.asarray(..., dtype=...) "
                             "at the host boundary)",
                )
                continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "astype" and node.args \
                    and _dtype_spelling(node.args[0]) in _F64_SPELLINGS:
                yield ctx.finding(
                    self, node,
                    "astype(float64) in the kernel tree",
                    fix_hint="use float32 (or justify via the baseline if "
                             "the width is load-bearing)",
                )
                continue
            for kw in node.keywords:
                if kw.arg == "dtype" \
                        and _dtype_spelling(kw.value) in _F64_SPELLINGS:
                    yield ctx.finding(
                        self, node,
                        "dtype=float64 in the kernel tree",
                        fix_hint="use float32 (or justify via the baseline)",
                    )

        # --- id-named constructions ---------------------------------------
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            target = node.targets[0]
            if not (isinstance(target, ast.Name) and _id_named(target.id)):
                continue
            value = node.value
            if not isinstance(value, ast.Call):
                continue
            d = dotted_name(value.func)
            if d is None or "." not in d:
                continue
            root, leaf = d.split(".")[0], d.split(".")[-1]
            if root not in ("np", "numpy", "jnp") \
                    or leaf not in _CONSTRUCTORS:
                continue
            dtype = None
            for kw in value.keywords:
                if kw.arg == "dtype":
                    dtype = _dtype_spelling(kw.value)
            if dtype is None:
                pos = _DTYPE_POS[leaf]
                if len(value.args) > pos:
                    dtype = _dtype_spelling(value.args[pos])
            if dtype not in _I32_SPELLINGS:
                got = dtype or "the float/int64 default"
                yield ctx.finding(
                    self, node,
                    f"node-id array `{target.id}` constructed with {got} — "
                    f"ids must be int32",
                    fix_hint="pass dtype=np.int32 / jnp.int32 explicitly",
                )
