"""unreferenced-module — dead-code audit over the static import graph.

A module under ``src/`` that no live code can reach via *static* imports is
dead weight: it rots silently (no test imports it transitively), and its
contracts are never checked by the rest of this suite's runtime-reachable
guarantees.  The rule computes reachability over the scanned files plus the
repo's reference universe (``tests/``, ``examples/``, ``scripts/`` —
sources of truth for what is "live" even when they are not lint targets)
and flags unreachable src modules.

Exempt by construction:

* ``__main__.py`` and modules with an ``if __name__ == "__main__"`` guard
  (CLI entry points are roots, not dead code);
* modules reachable only through a *dynamic* registry
  (``importlib.import_module`` — e.g. the ``repro.configs`` arch zoo) are
  NOT exempt: they get flagged and belong in the baseline with a
  justification naming the registry, so the registry's existence stays
  documented and a module dropped from it goes stale loudly.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Optional, Sequence, Set

from repro.analysis.engine import FileContext, Rule

_UNIVERSE_DIRS = ("tests", "examples", "scripts")


def _module_name(rel: str) -> Optional[str]:
    """Dotted module for a src-layout path (``src/repro/core/pq.py`` ->
    ``repro.core.pq``); None for paths outside ``src/``."""
    parts = rel.split("/")
    if "src" not in parts:
        return None
    parts = parts[parts.index("src") + 1:]
    if not parts or not parts[-1].endswith(".py"):
        return None
    parts[-1] = parts[-1][:-3]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) if parts else None


def _has_main_guard(tree: ast.Module) -> bool:
    for node in tree.body:
        if isinstance(node, ast.If):
            t = node.test
            if isinstance(t, ast.Compare) and isinstance(t.left, ast.Name) \
                    and t.left.id == "__name__":
                return True
    return False


def _imports_of(tree: ast.Module, self_module: Optional[str]) -> Set[str]:
    """Every dotted module an AST references, including package prefixes."""
    out: Set[str] = set()

    def add(mod: str):
        parts = mod.split(".")
        for i in range(1, len(parts) + 1):
            out.add(".".join(parts[:i]))

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                add(alias.name)
        elif isinstance(node, ast.ImportFrom):
            base = node.module or ""
            if node.level:
                if self_module is None:
                    continue
                pkg = self_module.split(".")
                pkg = pkg[:len(pkg) - node.level] if len(pkg) >= node.level \
                    else []
                base = ".".join(pkg + ([base] if base else []))
            if not base:
                continue
            add(base)
            for alias in node.names:
                if alias.name != "*":
                    add(f"{base}.{alias.name}")
    return out


class UnreferencedModuleRule(Rule):
    id = "unreferenced-module"
    severity = "warning"
    project_rule = True
    fix_hint = ("delete the module (or note it in the README attic); if it "
                "is reached through a dynamic registry, baseline it with a "
                "justification naming the registry")
    doc = ("src/ module unreachable from tests/benchmarks/examples/scripts "
           "via static imports — dead-code audit")

    #: extra reference-source dirs, resolved against the cwd (repo root);
    #: overridable for fixtures
    universe_dirs: Sequence[str] = _UNIVERSE_DIRS

    def check_project(self, ctxs: Sequence[FileContext]):
        modules: Dict[str, FileContext] = {}
        for ctx in ctxs:
            m = _module_name(ctx.rel)
            if m is not None:
                modules[m] = ctx

        # roots: every scanned non-src file + the reference universe
        root_trees = []
        for ctx in ctxs:
            if _module_name(ctx.rel) is None:
                root_trees.append((ctx.tree, None))
        for d in self.universe_dirs:
            d = os.path.join(self.root, d)
            if not os.path.isdir(d):
                continue
            for dirpath, dirnames, filenames in os.walk(d):
                dirnames[:] = [x for x in dirnames if not x.startswith(".")
                               and x != "__pycache__"]
                for f in sorted(filenames):
                    if not f.endswith(".py"):
                        continue
                    try:
                        with open(os.path.join(dirpath, f), "r",
                                  encoding="utf-8") as fh:
                            root_trees.append((ast.parse(fh.read()), None))
                    except (OSError, SyntaxError):
                        continue

        # CLI entry points inside src are roots too
        for mod, ctx in modules.items():
            if ctx.rel.endswith("__main__.py") or _has_main_guard(ctx.tree):
                root_trees.append((ctx.tree, mod))

        reached: Set[str] = set()
        queue = set()
        for tree, self_mod in root_trees:
            if self_mod is not None:
                reached.add(self_mod)
            queue |= _imports_of(tree, self_mod)
        while queue:
            mod = queue.pop()
            if mod in reached or mod not in modules:
                reached.add(mod)
                continue
            reached.add(mod)
            ctx = modules[mod]
            queue |= _imports_of(ctx.tree, mod) - reached

        import dataclasses

        for mod in sorted(modules):
            if mod in reached:
                continue
            ctx = modules[mod]
            if ctx.rel.endswith("__main__.py") or _has_main_guard(ctx.tree):
                continue
            f = ctx.finding(
                self, ctx.tree.body[0] if ctx.tree.body else ctx.tree,
                f"module `{mod}` is unreachable from any static import "
                f"(tests, benchmarks, examples, scripts, CLI entries)",
            )
            # module-granularity identity: stable under content edits
            yield dataclasses.replace(f, line_text=f"module:{mod}")
