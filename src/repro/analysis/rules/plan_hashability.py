"""plan-hashability — frozen dataclasses must hash, at field-type level.

``QueryPlan.cache_key`` is the serving layer's batching identity and the
planner's artifact-cache key; ``PlanConfig``/``SearchConfig``/``FilterSpec``
ride inside it.  A frozen dataclass *generates* ``__hash__``, so an
unhashable field (list/dict/set/ndarray) type-checks, constructs, and then
explodes at the first cache lookup — at runtime, on the serving path.  The
rule rejects unhashable annotated types on any ``@dataclass(frozen=True)``
field, recursing through ``Optional``/``Union``/``Tuple`` wrappers.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import FileContext, Rule
from repro.analysis.rules._ast_util import dataclass_frozen, dotted_name

_UNHASHABLE = {
    "list": "tuple", "List": "Tuple",
    "dict": "a frozen mapping (tuple of items)", "Dict": "Tuple[...-items]",
    "set": "frozenset", "Set": "FrozenSet",
    "bytearray": "bytes",
    "np.ndarray": "a tuple (or keep arrays out of cache keys)",
    "numpy.ndarray": "a tuple (or keep arrays out of cache keys)",
    "jnp.ndarray": "a tuple (or keep arrays out of cache keys)",
    "jax.Array": "a tuple (or keep arrays out of cache keys)",
}
_WRAPPERS = {"Optional", "Union", "Tuple", "tuple", "typing.Optional",
             "typing.Union", "typing.Tuple", "FrozenSet", "frozenset",
             "ClassVar", "Final"}


def _unhashable_part(ann: ast.AST):
    """The offending type spelling inside an annotation, or None."""
    if ann is None:
        return None
    # string annotations ("SearchConfig") — parse and recurse
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        try:
            inner = ast.parse(ann.value, mode="eval").body
        except SyntaxError:
            return None
        return _unhashable_part(inner)
    d = dotted_name(ann)
    if d in _UNHASHABLE:
        return d
    if isinstance(ann, ast.Subscript):
        base = dotted_name(ann.value)
        if base in _UNHASHABLE:
            return base
        if base in _WRAPPERS or (base or "").split(".")[-1] in _WRAPPERS:
            inner = ann.slice
            elts = inner.elts if isinstance(inner, ast.Tuple) else [inner]
            for e in elts:
                bad = _unhashable_part(e)
                if bad:
                    return bad
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        return _unhashable_part(ann.left) or _unhashable_part(ann.right)
    return None


class PlanHashabilityRule(Rule):
    id = "plan-hashability"
    severity = "error"
    doc = ("unhashable field types on frozen dataclasses — cache-key "
           "integrity for QueryPlan/PlanConfig/FilterSpec")

    def check(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.ClassDef) and dataclass_frozen(node)):
                continue
            for stmt in node.body:
                if not isinstance(stmt, ast.AnnAssign):
                    continue
                bad = _unhashable_part(stmt.annotation)
                if bad:
                    field = stmt.target.id \
                        if isinstance(stmt.target, ast.Name) else "?"
                    yield ctx.finding(
                        self, stmt,
                        f"frozen dataclass {node.name}.{field} is annotated "
                        f"{bad} — hash() raises at the first cache lookup",
                        fix_hint=f"use {_UNHASHABLE[bad]} instead of {bad}, "
                                 f"or drop frozen=True if this is not a "
                                 f"cache-key type",
                    )
