"""config-forward-compat — no ``getattr(cfg, "field", default)`` shims.

Pickled index caches outlive config schema growth, and the repo's contract
for that (since PR8) is ``configs.upgrade_config``: rebuild the config with
current defaults ONCE at the deserialization boundary, then access fields
directly.  Per-site ``getattr(cfg, "field", default)`` shims silently
drift — each site hardcodes its own default, and a renamed field keeps
"working" with a stale value instead of failing.

The rule fires on 3-argument ``getattr`` with a string-literal field name
whose receiver is config-shaped: a name like ``cfg``/``config``/``*_cfg``/
``*cfg``, or an attribute chain ending in ``.cfg``/``.config``.  Capability
probes on heterogeneous non-config objects (``getattr(index, "attributes",
None)``) are out of scope.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import FileContext, Rule
from repro.analysis.rules._ast_util import is_str_constant

_CONFIG_NAMES = {"cfg", "config", "conf"}


def _is_config_receiver(node: ast.AST) -> bool:
    if isinstance(node, ast.Name):
        n = node.id.lower()
        return n in _CONFIG_NAMES or n.endswith("cfg") or n.endswith("config")
    if isinstance(node, ast.Attribute):
        a = node.attr.lower()
        return a in _CONFIG_NAMES or a.endswith("cfg") or a.endswith("config")
    return False


class ConfigForwardCompatRule(Rule):
    id = "config-forward-compat"
    severity = "error"
    fix_hint = ("upgrade once at the boundary with configs.upgrade_config("
                "cfg) and read the field directly")
    doc = ("getattr(cfg, \"field\", default) config shims — the PR8 "
           "upgrade_config contract")

    def check(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "getattr"
                    and len(node.args) == 3
                    and is_str_constant(node.args[1])):
                continue
            if _is_config_receiver(node.args[0]):
                field = node.args[1].value
                yield ctx.finding(
                    self, node,
                    f"getattr config shim for field {field!r} — per-site "
                    f"defaults drift from the schema",
                )
