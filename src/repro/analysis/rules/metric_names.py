"""metric-name-literals — metric/span names must be statically enumerable.

The ``obs`` registry keys cells by ``(name, label set)``.  Label *values*
are bounded by construction (plan kind, strategy, tenant); a dynamically
built metric *name* (an f-string, a formatted id, a request field) is an
unbounded-cardinality leak — every novel name allocates a fresh cell
forever, and dashboards cannot enumerate the series.  Names passed to
``metrics.counter/gauge/observe`` and ``tracer.span`` must be string
literals or module-level UPPER_CASE constants.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import FileContext, Rule
from repro.analysis.rules._ast_util import is_str_constant

_RECORD_METHODS = {"counter", "gauge", "observe", "span"}
#: receiver spellings that identify the obs registry / tracer at a call site
_RECEIVER_NAMES = {"metrics", "registry", "tracer", "m", "reg"}


def _is_obs_receiver(node: ast.AST) -> bool:
    if isinstance(node, ast.Name):
        return node.id in _RECEIVER_NAMES
    if isinstance(node, ast.Attribute):
        return node.attr in _RECEIVER_NAMES
    return False


def _is_constant_name(node: ast.AST) -> bool:
    """A module-constant reference: ``NAME`` or ``mod.NAME`` (UPPER_CASE)."""
    if isinstance(node, ast.Name):
        return node.id.isupper()
    if isinstance(node, ast.Attribute):
        return node.attr.isupper()
    return False


class MetricNameLiteralsRule(Rule):
    id = "metric-name-literals"
    severity = "error"
    fix_hint = ("pass a string literal or a module-level CONSTANT as the "
                "metric/span name; put variability in label values, which "
                "are bounded by construction")
    doc = ("dynamic metric/span names on the obs registry — label-"
           "cardinality explosion guard")

    def check(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _RECORD_METHODS
                    and _is_obs_receiver(node.func.value)
                    and node.args):
                continue
            name_arg = node.args[0]
            if is_str_constant(name_arg) or _is_constant_name(name_arg):
                continue
            kind = "f-string" if isinstance(name_arg, ast.JoinedStr) \
                else "dynamic expression"
            yield ctx.finding(
                self, node,
                f"metric/span name is a {kind} — every novel name "
                f"allocates an unbounded registry cell",
            )
