"""tracer-leak — no Python control flow on traced values.

Inside a jitted body, array values are tracers: a Python ``if`` / ``while``
/ ``assert`` on one (or a ``float()`` / ``int()`` / ``bool()`` coercion)
forces concretization — ``TracerBoolConversionError`` at best, a silent
trace-time constant at worst (the branch is baked in for every future
batch).  The rule runs a small flow-insensitive taint pass per jitted
function: results of ``jnp.*`` / ``jax.*`` / ``lax.*`` calls (and
assignments derived from them) are traced; consuming a traced name in a
Python test or a scalar coercion is a finding.

Static-shape reads (``x.shape`` / ``x.ndim`` / ``x.dtype`` / ``x.size``)
and ``is None`` checks are exempt — both are trace-time constants.
Bare *parameters* in control flow are jit-static-args' territory; this rule
tracks values produced inside the body.
"""
from __future__ import annotations

import ast
from typing import Set

from repro.analysis.engine import FileContext, Rule
from repro.analysis.rules._ast_util import dotted_name, jitted_functions

_TRACED_ROOTS = ("jnp", "jax", "lax", "pl", "plgpu")
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
_COERCIONS = {"int", "float", "bool", "complex"}


def _is_traced_call(node: ast.Call) -> bool:
    d = dotted_name(node.func)
    if not d:
        return False
    root = d.split(".")[0]
    return root in _TRACED_ROOTS


def _is_none_check(node: ast.AST) -> bool:
    return (isinstance(node, ast.Compare)
            and all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops)
            and all(isinstance(c, ast.Constant) and c.value is None
                    for c in node.comparators))


def _tainted_names_used(expr: ast.AST, tainted: Set[str]) -> Set[str]:
    """Tainted names consumed by ``expr``, skipping ``is None`` checks and
    static-shape attribute reads."""
    out: Set[str] = set()

    def visit(node: ast.AST):
        if _is_none_check(node):
            return
        if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
            return
        if isinstance(node, ast.Name) and node.id in tainted:
            out.add(node.id)
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(expr)
    return out


def _expr_tainted(expr: ast.AST, tainted: Set[str]) -> bool:
    if _tainted_names_used(expr, tainted):
        return True
    for node in ast.walk(expr):
        if isinstance(node, ast.Call) and _is_traced_call(node):
            return True
    return False


def _target_names(target: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(target):
        if isinstance(node, ast.Name):
            out.add(node.id)
    return out


class TracerLeakRule(Rule):
    id = "tracer-leak"
    severity = "error"
    fix_hint = ("replace the Python branch with jnp.where / lax.cond / "
                "lax.select, or hoist the decision out of the jitted body")
    doc = ("Python if/while/assert or scalar coercion on a traced value "
           "inside a jitted body — trace-time concretization")

    def check(self, ctx: FileContext):
        emitted = set()
        for fn, statics in jitted_functions(ctx.tree):
            body = fn.body if isinstance(fn.body, list) else [fn.body]
            # taint fixpoint over assignments
            tainted: Set[str] = set()
            for _ in range(8):
                before = len(tainted)
                for node in ast.walk(fn):
                    if isinstance(node, ast.Assign):
                        if _expr_tainted(node.value, tainted):
                            for t in node.targets:
                                tainted |= _target_names(t)
                    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)) \
                            and node.value is not None:
                        if _expr_tainted(node.value, tainted):
                            tainted |= _target_names(node.target)
                if len(tainted) == before:
                    break
            # Python for-loop / comprehension targets iterate host values
            # (dict keys, static ranges) even when the container name is
            # tainted — a traced array cannot be iterated lane-wise anyway,
            # so keeping them would only produce name-collision FPs.
            for node in ast.walk(fn):
                if isinstance(node, (ast.For, ast.comprehension)):
                    tainted -= _target_names(node.target)

            for node in ast.walk(fn):
                if isinstance(node, (ast.If, ast.While, ast.Assert, ast.IfExp)):
                    test = node.test
                    used = _tainted_names_used(test, tainted)
                    kind = type(node).__name__.lower()
                    for name in sorted(used):
                        key = (node.lineno, self.id, name, "branch")
                        if key in emitted:
                            continue
                        emitted.add(key)
                        yield ctx.finding(
                            self, node,
                            f"Python `{kind}` on `{name}`, a value produced "
                            f"by a traced op inside a jitted body",
                        )
                elif isinstance(node, ast.Call):
                    callee = dotted_name(node.func)
                    if callee in _COERCIONS and node.args \
                            and _expr_tainted(node.args[0], tainted):
                        key = (node.lineno, self.id, callee, "coerce")
                        if key in emitted:
                            continue
                        emitted.add(key)
                        yield ctx.finding(
                            self, node,
                            f"`{callee}()` coercion of a traced value "
                            f"inside a jitted body concretizes the tracer",
                        )
