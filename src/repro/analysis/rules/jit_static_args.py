"""jit-static-args — Python-visible jit arguments must be marked static.

The PR5 bug class: ``distributed_search_kernel`` took ``data_axis`` /
``queue_axis`` (Python strings threaded into collective axis names) without
listing them in ``static_argnames`` — jax either fails to trace or, worse,
retraces per value.  An argument is *Python-visible* when the traced body
consumes it outside the array domain:

* it (or an attribute of it, e.g. ``cfg.use_pq``) appears in an ``if`` /
  ``while`` test, an ``assert``, or a comprehension ``if`` guard —
  except pure ``is None`` checks, which jit resolves by pytree structure;
* it feeds ``range()`` or a subscript *slice* bound (loop trip counts and
  static shapes);
* it is coerced with ``int()`` / ``bool()`` / ``float()`` / ``str()`` at
  the Python level;
* it is compared against a string literal, or annotated / defaulted ``str``
  (strings are never valid tracer inputs).

Any such parameter missing from ``static_argnames``/``static_argnums`` is
a finding.  Scans both decorator jits and ``jax.jit(fn, ...)`` call forms
resolving ``fn`` in the same module.
"""
from __future__ import annotations

import ast
from typing import Set

from repro.analysis.engine import FileContext, Rule
from repro.analysis.rules._ast_util import (
    dotted_name,
    jitted_functions,
    static_params,
)

_COERCIONS = {"int", "bool", "float", "str"}


def _is_none_check(node: ast.AST) -> bool:
    return (isinstance(node, ast.Compare)
            and all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops)
            and all(isinstance(c, ast.Constant) and c.value is None
                    for c in node.comparators))


def test_names(expr: ast.AST) -> Set[str]:
    """Name ids consumed by a Python-level test, excluding names that only
    appear under ``is None`` / ``is not None`` checks (pytree-structural,
    trace-time safe)."""
    out: Set[str] = set()

    def visit(node: ast.AST):
        if _is_none_check(node):
            return
        if isinstance(node, ast.Name):
            out.add(node.id)
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(expr)
    return out


def _str_typed(arg: ast.arg, default) -> bool:
    if arg.annotation is not None and dotted_name(arg.annotation) == "str":
        return True
    return isinstance(default, ast.Constant) and isinstance(default.value, str)


def _params_with_defaults(fn):
    """[(arg, default-or-None)] over positional + kwonly args."""
    a = fn.args
    pos = list(a.posonlyargs) + list(a.args)
    defaults = [None] * (len(pos) - len(a.defaults)) + list(a.defaults)
    out = list(zip(pos, defaults))
    out += list(zip(a.kwonlyargs, a.kw_defaults))
    return out


def _python_visible_uses(fn: ast.AST, params: Set[str]):
    """{param: reason} for params the body consumes at the Python level."""
    uses = {}

    def mark(expr: ast.AST, reason: str):
        for name in test_names(expr) & params:
            uses.setdefault(name, reason)

    for node in ast.walk(fn):
        if isinstance(node, (ast.If, ast.While)):
            mark(node.test, "used in a Python `%s` test"
                 % ("if" if isinstance(node, ast.If) else "while"))
        elif isinstance(node, ast.IfExp):
            mark(node.test, "used in a conditional-expression test")
        elif isinstance(node, ast.Assert):
            mark(node.test, "used in an assert")
        elif isinstance(node, ast.comprehension):
            for guard in node.ifs:
                mark(guard, "used in a comprehension guard")
        elif isinstance(node, ast.Call):
            callee = dotted_name(node.func)
            if callee == "range":
                for a in node.args:
                    mark(a, "drives a range() trip count")
            elif callee in _COERCIONS and node.args:
                mark(node.args[0], f"coerced with {callee}()")
        elif isinstance(node, ast.Slice):
            for bound in (node.lower, node.upper, node.step):
                if bound is not None:
                    mark(bound, "used as a static slice bound")
        elif isinstance(node, ast.Compare):
            if any(isinstance(c, ast.Constant) and isinstance(c.value, str)
                   for c in node.comparators):
                mark(node, "compared against a string literal")
    return uses


class JitStaticArgsRule(Rule):
    id = "jit-static-args"
    severity = "error"
    fix_hint = ("list the argument in static_argnames (or static_argnums) "
                "on the jit decoration")
    doc = ("jitted function consumes an argument in Python control flow / "
           "shape arithmetic without marking it static — the PR5 "
           "distributed_search_kernel bug class")

    def check(self, ctx: FileContext):
        seen = set()
        for fn, statics in jitted_functions(ctx.tree):
            key = (getattr(fn, "lineno", 0), getattr(fn, "name", "<lambda>"))
            if key in seen:
                continue
            seen.add(key)
            static = static_params(fn, statics)
            fname = getattr(fn, "name", "<lambda>")
            params: Set[str] = set()
            if isinstance(fn, ast.Lambda):
                params = {p.arg for p in fn.args.args} - static
                uses = _python_visible_uses(fn.body, params)
            else:
                for arg, default in _params_with_defaults(fn):
                    if arg.arg in static or arg.arg == "self":
                        continue
                    if _str_typed(arg, default):
                        yield ctx.finding(
                            self, fn,
                            f"jitted `{fname}` takes str-typed argument "
                            f"`{arg.arg}` without marking it static — "
                            f"strings are never valid tracer inputs",
                        )
                        continue
                    params.add(arg.arg)
                uses = _python_visible_uses(fn, params)
            for name, reason in sorted(uses.items()):
                yield ctx.finding(
                    self, fn,
                    f"jitted `{fname}` argument `{name}` is {reason} "
                    f"but is not in static_argnames",
                )
