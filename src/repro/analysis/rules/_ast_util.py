"""Shared AST helpers for proxlint rules."""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Sequence, Set, Tuple


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def root_name(node: ast.AST) -> Optional[str]:
    """The base Name of an attribute chain: ``cfg`` for ``cfg.a.b``."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def is_str_constant(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and isinstance(node.value, str)


def _is_jit_callable(node: ast.AST) -> bool:
    """``jax.jit`` / bare ``jit`` imported from jax."""
    d = dotted_name(node)
    return d in ("jax.jit", "jit")


def jit_decoration(dec: ast.AST) -> Optional[Tuple[Set[str], Set[int]]]:
    """If ``dec`` is a jit decoration, return (static_argnames,
    static_argnums); else None.

    Recognized forms::

        @jax.jit
        @partial(jax.jit, static_argnames=(...), static_argnums=(...))
        @functools.partial(jax.jit, ...)
        jax.jit(fn, static_argnames=..., static_argnums=...)   (call form)
    """
    if _is_jit_callable(dec):
        return set(), set()
    if not isinstance(dec, ast.Call):
        return None
    fn = dotted_name(dec.func)
    if _is_jit_callable(dec.func):
        # jax.jit(fn, static_...=...) call form
        return _static_kwargs(dec.keywords)
    if fn in ("partial", "functools.partial") and dec.args \
            and _is_jit_callable(dec.args[0]):
        return _static_kwargs(dec.keywords)
    return None


def _static_kwargs(keywords: Sequence[ast.keyword]) -> Tuple[Set[str], Set[int]]:
    names: Set[str] = set()
    nums: Set[int] = set()
    for kw in keywords:
        if kw.arg == "static_argnames":
            names |= _const_strs(kw.value)
        elif kw.arg == "static_argnums":
            nums |= _const_ints(kw.value)
    return names, nums


def _const_strs(node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    if is_str_constant(node):
        out.add(node.value)
    elif isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for e in node.elts:
            if is_str_constant(e):
                out.add(e.value)
    return out


def _const_ints(node: ast.AST) -> Set[int]:
    out: Set[int] = set()
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        out.add(node.value)
    elif isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.add(e.value)
    return out


def param_names(fn: ast.AST) -> List[str]:
    a = fn.args
    params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        params.append(a.vararg.arg)
    if a.kwarg:
        params.append(a.kwarg.arg)
    return params


def static_params(fn: ast.AST, statics: Tuple[Set[str], Set[int]]) -> Set[str]:
    """Parameter names marked static by (argnames, argnums)."""
    names, nums = statics
    positional = [p.arg for p in fn.args.posonlyargs + fn.args.args]
    out = set(names)
    for i in nums:
        if 0 <= i < len(positional):
            out.add(positional[i])
    return out


def jitted_functions(tree: ast.Module) -> Iterable[
        Tuple[ast.AST, Tuple[Set[str], Set[int]]]]:
    """Every (function def, statics) jitted in the module — via decorator,
    or via a module/function-level ``jax.jit(name, ...)`` call referencing a
    def in the same file."""
    defs = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs[node.name] = node
            for dec in node.decorator_list:
                statics = jit_decoration(dec)
                if statics is not None:
                    yield node, statics
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jit_callable(node.func) \
                and node.args:
            target = node.args[0]
            if isinstance(target, ast.Name) and target.id in defs:
                yield defs[target.id], _static_kwargs(node.keywords)
            elif isinstance(target, ast.Lambda):
                yield target, _static_kwargs(node.keywords)


def dataclass_frozen(cls: ast.ClassDef) -> bool:
    """True when decorated ``@dataclass(frozen=True)`` (dataclasses.dataclass
    and bare dataclass forms)."""
    for dec in cls.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        if dotted_name(dec.func) not in ("dataclass", "dataclasses.dataclass"):
            continue
        for kw in dec.keywords:
            if kw.arg == "frozen" and isinstance(kw.value, ast.Constant) \
                    and kw.value.value is True:
                return True
    return False
