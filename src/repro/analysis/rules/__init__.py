"""proxlint rule registry — one module per contract, one class per rule.

Adding a rule: subclass :class:`repro.analysis.engine.Rule` in a new module
here, set ``id`` / ``severity`` / ``fix_hint`` / ``doc``, implement
``check`` (per-file AST) or ``check_project`` (repo-wide), and append the
class to :data:`ALL_RULES`.  Give it a positive + negative fixture in
``tests/test_analysis.py`` — the fixture must encode the bug pattern the
rule exists to prevent, so the rule cannot silently stop firing.
"""
from __future__ import annotations

from typing import List, Type

from repro.analysis.engine import Rule
from repro.analysis.rules.config_compat import ConfigForwardCompatRule
from repro.analysis.rules.dtype_hygiene import DtypeHygieneRule
from repro.analysis.rules.jit_static_args import JitStaticArgsRule
from repro.analysis.rules.metric_names import MetricNameLiteralsRule
from repro.analysis.rules.monotonic_clock import MonotonicClockRule
from repro.analysis.rules.plan_hashability import PlanHashabilityRule
from repro.analysis.rules.tracer_leak import TracerLeakRule
from repro.analysis.rules.unreferenced import UnreferencedModuleRule

ALL_RULES: List[Type[Rule]] = [
    JitStaticArgsRule,
    PlanHashabilityRule,
    MonotonicClockRule,
    MetricNameLiteralsRule,
    ConfigForwardCompatRule,
    TracerLeakRule,
    DtypeHygieneRule,
    UnreferencedModuleRule,
]


def get_rule(rule_id: str) -> Rule:
    for cls in ALL_RULES:
        if cls.id == rule_id:
            return cls()
    raise KeyError(f"unknown rule {rule_id!r}; "
                   f"known: {sorted(c.id for c in ALL_RULES)}")
