"""Checked-in proxlint baseline — grandfathered findings WITH justification.

The baseline is the pressure valve that lets the lint gate be strict from
day one: a finding that is intentional (a dynamic-registry import, a
bounded dynamic metric-name loop) is recorded here with a human
justification instead of being silently suppressed in code.  Two contracts
keep it honest:

* every entry must still match a live finding — an entry whose flagged
  line changed or disappeared is *stale* and fails the check (the
  grandfathered debt cannot outlive the code it excused);
* entries match on ``(rule, path, stripped-source-line)``, not line
  numbers, so unrelated edits never invalidate the baseline but any edit
  to the flagged line itself does.

Format (``proxlint.baseline.json`` at the repo root)::

    {"entries": [{"rule": ..., "path": ..., "line_text": ...,
                  "justification": ...}, ...]}
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Sequence, Tuple

DEFAULT_BASELINE_PATH = "proxlint.baseline.json"


@dataclasses.dataclass(frozen=True)
class BaselineEntry:
    rule: str
    path: str
    line_text: str
    justification: str = ""

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.line_text)

    def render(self) -> str:
        return (f"{self.path}: [{self.rule}] baseline entry no longer "
                f"matches any finding (line was {self.line_text!r}) — "
                f"remove or refresh it")


class Baseline:
    """An ordered set of :class:`BaselineEntry`, loadable/saveable as JSON."""

    def __init__(self, entries: Sequence[BaselineEntry] = ()):
        self.entries: List[BaselineEntry] = list(entries)

    # ------------------------------------------------------------------ io
    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls(())
        with open(path, "r", encoding="utf-8") as f:
            payload = json.load(f)
        return cls([BaselineEntry(**e) for e in payload.get("entries", [])])

    def save(self, path: str) -> None:
        payload = {"entries": [dataclasses.asdict(e) for e in self.entries]}
        with open(path, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")

    # ------------------------------------------------------------- matching
    def split(self, findings):
        """(new, covered, stale): findings not/FOUND in the baseline, plus
        entries matching no finding. A baseline entry may cover several
        findings with identical keys (one getattr shim pattern repeated on
        one line never happens in practice, but matching is set-based)."""
        keys = {e.key: e for e in self.entries}
        new, covered = [], []
        used = set()
        for f in findings:
            e = keys.get(f.baseline_key)
            if e is None:
                new.append(f)
            else:
                covered.append(f)
                used.add(e.key)
        stale = [e for e in self.entries if e.key not in used]
        return new, covered, stale

    @classmethod
    def from_findings(cls, findings, old: "Baseline" = None) -> "Baseline":
        """Baseline covering exactly ``findings`` — justifications carried
        over from ``old`` where the key survives, placeholder otherwise
        (``--update-baseline``; placeholders are meant to be edited)."""
        old_keys = {e.key: e for e in (old.entries if old else [])}
        entries, seen = [], set()
        for f in findings:
            if f.baseline_key in seen:
                continue
            seen.add(f.baseline_key)
            prev = old_keys.get(f.baseline_key)
            entries.append(BaselineEntry(
                rule=f.rule, path=f.path, line_text=f.line_text,
                justification=prev.justification if prev is not None
                else "TODO: justify or fix",
            ))
        entries.sort(key=lambda e: (e.path, e.rule, e.line_text))
        return cls(entries)
