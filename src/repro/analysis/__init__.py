"""``proxlint`` — repo-aware static analysis for the serving-stack contracts.

Every invariant this repo's layers depend on — hashable ``QueryPlan`` cache
keys, pow2-bucketed jit shapes with Python-visible arguments marked static,
monotonic clocks in latency paths, bounded metric-label cardinality, the
``upgrade_config`` forward-compat contract — used to be enforced only at
runtime (``KernelWatch``, the plan-equivalence CI step) or not at all, and
each has already been violated once in the PR history (the ``time.time()``
flush-timeout bug, the missing ``static_argnames`` on
``distributed_search_kernel``, the ``getattr`` config shims).  ``proxlint``
moves those contracts to compile time: an AST rule engine
(:mod:`repro.analysis.engine`), one visitor class per contract
(:mod:`repro.analysis.rules`), inline ``# proxlint: disable=RULE``
suppressions, and a checked-in justified baseline
(:mod:`repro.analysis.baseline`) for grandfathered findings.

Usage::

    PYTHONPATH=src python -m repro.analysis check src benchmarks
    PYTHONPATH=src python -m repro.analysis check --list-rules
    PYTHONPATH=src python -m repro.analysis check --update-baseline src benchmarks

The tier-1 pytest bridge (:mod:`repro.analysis.pytest_bridge`, consumed by
``tests/test_analysis.py``) reports each non-baselined finding as an
individual test failure, so a contract violation fails CI with a
``file:line`` pointer before it can reach the device.
"""
from repro.analysis.baseline import (  # noqa: F401
    Baseline,
    BaselineEntry,
    DEFAULT_BASELINE_PATH,
)
from repro.analysis.engine import (  # noqa: F401
    Finding,
    Report,
    Severity,
    check_paths,
    check_source,
)
from repro.analysis.rules import ALL_RULES, get_rule  # noqa: F401
