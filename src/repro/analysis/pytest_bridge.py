"""pytest bridge — proxlint as a tier-1 test, one failure per finding.

``tests/test_analysis.py`` calls :func:`finding_params` at collection time
and parametrizes one test per non-baselined finding (plus one per stale
baseline entry), so a contract violation fails CI as an individual test
named ``path:line [rule]`` instead of one opaque suite failure.  A clean
tree collects a single passing sentinel.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.analysis.baseline import Baseline
from repro.analysis.engine import Report, check_paths

CLEAN = "proxlint-clean"


def run(paths, root: str = ".", baseline_path: Optional[str] = None) -> Report:
    baseline = Baseline.load(baseline_path) if baseline_path else Baseline(())
    return check_paths(paths, root=root, baseline=baseline)


def finding_params(report: Report) -> List[Tuple[str, Optional[str]]]:
    """(test id, failure message) pairs for pytest.mark.parametrize.

    Each new finding becomes ``("src/x.py:12 [rule-id]", rendered)``; each
    stale baseline entry and parse error gets its own param too.  A clean
    report returns the single passing sentinel ``(CLEAN, None)``.
    """
    params: List[Tuple[str, Optional[str]]] = []
    for f in report.new:
        params.append((f"{f.path}:{f.line} [{f.rule}]", f.render()))
    for e in report.stale:
        params.append((f"{e.path} [stale-baseline:{e.rule}]", e.render()))
    for err in report.parse_errors:
        params.append((f"[parse-error] {err.split(':')[0]}", err))
    if not params:
        params.append((CLEAN, None))
    return params
