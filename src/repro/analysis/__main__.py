"""proxlint CLI.

    PYTHONPATH=src python -m repro.analysis check src benchmarks
    PYTHONPATH=src python -m repro.analysis check --list-rules
    PYTHONPATH=src python -m repro.analysis check --update-baseline src benchmarks

Exit status: 0 when every finding is baselined (with no stale baseline
entries and no parse errors), 1 otherwise — the CI ``lint`` job gates on
this.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.baseline import Baseline, DEFAULT_BASELINE_PATH
from repro.analysis.engine import check_paths
from repro.analysis.rules import ALL_RULES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = ap.add_subparsers(dest="cmd", required=True)
    chk = sub.add_parser("check", help="run every rule over the given paths")
    chk.add_argument("paths", nargs="*", default=None,
                     help="files/dirs to scan (default: src benchmarks)")
    chk.add_argument("--baseline", default=DEFAULT_BASELINE_PATH,
                     help="baseline file (default: %(default)s)")
    chk.add_argument("--no-baseline", action="store_true",
                     help="report every finding, ignoring the baseline")
    chk.add_argument("--update-baseline", action="store_true",
                     help="rewrite the baseline to cover current findings "
                          "(carries justifications over; new entries get a "
                          "TODO placeholder to edit)")
    chk.add_argument("--list-rules", action="store_true",
                     help="print rule ids and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for cls in ALL_RULES:
            print(f"{cls.id:24s} [{cls.severity}] {cls.doc}")
        return 0

    paths = args.paths or ["src", "benchmarks"]
    baseline = Baseline(()) if args.no_baseline \
        else Baseline.load(args.baseline)
    report = check_paths(paths, baseline=baseline)

    if args.update_baseline:
        new_baseline = Baseline.from_findings(report.findings, old=baseline)
        new_baseline.save(args.baseline)
        print(f"baseline written: {args.baseline} "
              f"({len(new_baseline.entries)} entries)")
        return 0

    for err in report.parse_errors:
        print(err, file=sys.stderr)
    for f in report.new:
        print(f.render())
    for e in report.stale:
        print(e.render())

    n_err = sum(1 for f in report.new if f.severity == "error")
    n_warn = len(report.new) - n_err
    print(f"proxlint: {n_err} error(s), {n_warn} warning(s), "
          f"{len(report.baselined)} baselined, {len(report.stale)} stale "
          f"baseline entr{'y' if len(report.stale) == 1 else 'ies'}, "
          f"{len(report.parse_errors)} parse error(s)")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
