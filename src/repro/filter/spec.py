"""FilterSpec — AND-composed attribute predicates over the corpus.

A spec is a tuple of predicates (equality / inclusive range / IN-set) over
named integer attribute columns; categorical fields are integer-coded by the
caller (``attributes.encode_categorical``). Specs are frozen and hashable so
the serving engine can batch requests by filter hash, and ``evaluate`` is
operator-only arithmetic that works identically on numpy (host-side mask
compilation) and jnp (device-side evaluation) column matrices.

Compose with ``&``::

    spec = FilterSpec.eq("category", 3) & FilterSpec.range("price", 0, 49)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union

import numpy as np


@dataclass(frozen=True)
class Eq:
    """``field == value``."""
    field: str
    value: int


@dataclass(frozen=True)
class Range:
    """``lo <= field <= hi`` (inclusive; ``None`` leaves a side open)."""
    field: str
    lo: Optional[int] = None
    hi: Optional[int] = None


@dataclass(frozen=True)
class In:
    """``field in values``."""
    field: str
    values: Tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "values",
                           tuple(int(v) for v in self.values))


Predicate = Union[Eq, Range, In]


def _eval_predicate(p: Predicate, col, xp):
    if isinstance(p, Eq):
        return col == p.value
    if isinstance(p, Range):
        m = xp.ones(col.shape, bool)
        if p.lo is not None:
            m = m & (col >= p.lo)
        if p.hi is not None:
            m = m & (col <= p.hi)
        return m
    if isinstance(p, In):
        if not p.values:
            return xp.zeros(col.shape, bool)
        vals = xp.asarray(p.values)
        return (col[:, None] == vals[None, :]).any(axis=1)
    raise TypeError(f"unknown predicate {type(p).__name__}")


@dataclass(frozen=True)
class FilterSpec:
    """AND-composition of predicates. The empty spec passes every node."""
    predicates: Tuple[Predicate, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "predicates", tuple(self.predicates))

    # --------------------------------------------------------- constructors
    @staticmethod
    def eq(field: str, value: int) -> "FilterSpec":
        return FilterSpec((Eq(field, int(value)),))

    @staticmethod
    def range(field: str, lo: Optional[int] = None,
              hi: Optional[int] = None) -> "FilterSpec":
        return FilterSpec((Range(field, lo, hi),))

    @staticmethod
    def isin(field: str, values) -> "FilterSpec":
        return FilterSpec((In(field, tuple(values)),))

    def __and__(self, other: "FilterSpec") -> "FilterSpec":
        return FilterSpec(self.predicates + other.predicates)

    # ----------------------------------------------------------- evaluation
    @property
    def is_all(self) -> bool:
        return not self.predicates

    def fields(self) -> Tuple[str, ...]:
        return tuple(p.field for p in self.predicates)

    def evaluate(self, values, fields: Tuple[str, ...], xp=np):
        """(N, F) column matrix -> (N,) boolean pass mask."""
        mask = xp.ones(values.shape[0], bool)
        for p in self.predicates:
            try:
                col = values[:, fields.index(p.field)]
            except ValueError:
                raise KeyError(
                    f"filter references unknown attribute {p.field!r}; "
                    f"store has {fields}"
                ) from None
            mask = mask & _eval_predicate(p, col, xp)
        return mask


ALL = FilterSpec()
