"""AttributeStore — per-node attribute columns + bitmap mask compilation.

Attributes live as a fixed-shape ``(N, F)`` int32 column matrix (categorical
fields are integer-coded), the host-side twin of the attribute words the NAND
layout keeps in each node's page spare area (``FilterConfig.attr_bits`` per
word, billed by ``nand.simulator``). A ``FilterSpec`` compiles to a per-node
boolean mask in one vectorized pass, and masks pack into uint32 bitmaps —
the wire/storage form the tile-level zero-pass skip and the pushdown
accounting use (32 nodes per word, fixed shapes, jit-friendly).

The store is row-indexed; what the rows key (a frozen index's reordered
internal ids, or a ``MutableIndex``'s stable external ids) is the owner's
contract. ``append`` supports the streaming insert path with amortized
doubling growth.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

from repro.filter.spec import FilterSpec


def encode_categorical(values: Sequence) -> Tuple[np.ndarray, Dict]:
    """String/object categories -> (int32 codes, {category: code} vocab).
    Codes are assigned in first-appearance order (deterministic)."""
    vocab: Dict = {}
    codes = np.empty(len(values), np.int32)
    for i, v in enumerate(values):
        if v not in vocab:
            vocab[v] = len(vocab)
        codes[i] = vocab[v]
    return codes, vocab


def pack_bitmap(mask: np.ndarray) -> np.ndarray:
    """(N,) bool -> (ceil(N/32),) uint32, little-endian bit order."""
    bits = np.packbits(np.asarray(mask, bool), bitorder="little")
    pad = (-len(bits)) % 4
    if pad:
        bits = np.concatenate([bits, np.zeros(pad, np.uint8)])
    return bits.view("<u4")


def unpack_bitmap(bitmap: np.ndarray, n: int) -> np.ndarray:
    """(W,) uint32 -> (n,) bool."""
    bits = np.unpackbits(np.ascontiguousarray(bitmap).view(np.uint8),
                         bitorder="little")
    return bits[:n].astype(bool)


def bitmap_popcount(bitmap: np.ndarray) -> int:
    return int(np.unpackbits(
        np.ascontiguousarray(bitmap).view(np.uint8)).sum())


class AttributeStore:
    """Column-oriented int32 attribute table over corpus rows."""

    def __init__(self, fields: Sequence[str], values: np.ndarray):
        values = np.asarray(values, np.int32)
        if values.ndim != 2 or values.shape[1] != len(tuple(fields)):
            raise ValueError(
                f"values must be (N, {len(tuple(fields))}), got {values.shape}"
            )
        self.fields: Tuple[str, ...] = tuple(fields)
        self._values = np.ascontiguousarray(values)
        self._len = values.shape[0]

    # -------------------------------------------------------- constructors
    @classmethod
    def from_columns(cls, columns: Dict[str, np.ndarray]) -> "AttributeStore":
        fields = tuple(columns)
        vals = np.stack(
            [np.asarray(columns[f], np.int32) for f in fields], axis=1
        ) if fields else np.zeros((0, 0), np.int32)
        return cls(fields, vals)

    def __len__(self) -> int:
        return self._len

    @property
    def values(self) -> np.ndarray:
        """(N, F) int32 view of the live rows."""
        return self._values[: self._len]

    @property
    def num_fields(self) -> int:
        return len(self.fields)

    @property
    def attr_bits(self) -> int:
        """Bits of one node's packed attribute word (spare-area footprint)."""
        return 32 * self.num_fields

    def column(self, field: str) -> np.ndarray:
        return self.values[:, self.fields.index(field)]

    # ------------------------------------------------------------ mutation
    def coerce_row(self, row) -> list:
        """Validate one node's attributes (dict by field name, or a value
        sequence in column order) into the int column order — raises
        without touching the store, so callers can validate BEFORE other
        state mutates (e.g. MutableIndex.insert)."""
        if isinstance(row, dict):
            unknown = set(row) - set(self.fields)
            if unknown:
                raise KeyError(f"unknown attribute fields {sorted(unknown)}")
            return [int(row.get(f, 0)) for f in self.fields]
        vals = [int(v) for v in row]
        if len(vals) != self.num_fields:
            raise ValueError(
                f"row has {len(vals)} values, store has "
                f"{self.num_fields} fields"
            )
        return vals

    def append(self, row) -> int:
        """Append one node's attributes; returns the new row id."""
        vals = self.coerce_row(row)
        if self._len == self._values.shape[0]:
            grown = np.zeros(
                (max(2 * self._len, 64), self.num_fields), np.int32
            )
            grown[: self._len] = self._values[: self._len]
            self._values = grown
        self._values[self._len] = vals
        self._len += 1
        return self._len - 1

    # ----------------------------------------------------- mask compilation
    def mask(self, spec: FilterSpec) -> np.ndarray:
        """Compile ``spec`` to a (N,) boolean pass mask."""
        return np.asarray(spec.evaluate(self.values, self.fields, np))

    def bitmap(self, spec: FilterSpec) -> np.ndarray:
        """Compile ``spec`` to the packed uint32 form (32 nodes per word)."""
        return pack_bitmap(self.mask(spec))

    def selectivity(self, spec: FilterSpec) -> float:
        """Exact passing fraction — the estimator is exact because the mask
        is one vectorized pass over a host-resident column matrix."""
        if self._len == 0:
            return 0.0
        return float(self.mask(spec).mean())

    # ------------------------------------------------------------- reindex
    def permuted(self, perm: np.ndarray) -> "AttributeStore":
        """Rows re-keyed through ``perm`` (e.g. the index's visit-frequency
        reordering: row i of the result is old row perm[i])."""
        return AttributeStore(self.fields, self.values[np.asarray(perm)])

    def take(self, ids: np.ndarray) -> np.ndarray:
        """Gather rows (e.g. one tile's slice); negative ids -> zero rows."""
        ids = np.asarray(ids)
        out = self.values[np.clip(ids, 0, None)].copy()
        out[ids < 0] = 0
        return out


def random_attributes(
    n: int,
    spec: Dict[str, int] | None = None,
    seed: int = 0,
) -> AttributeStore:
    """Synthetic workload attributes: ``spec`` maps field name -> cardinality
    (values uniform in [0, cardinality)). Default schema gives a coarse
    categorical plus a fine-grained int, enough to dial any selectivity."""
    spec = spec or {"category": 16, "price": 1000}
    rng = np.random.default_rng(seed)
    cols = {
        f: rng.integers(0, card, size=n, dtype=np.int32)
        for f, card in spec.items()
    }
    return AttributeStore.from_columns(cols)
