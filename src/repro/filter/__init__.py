"""Filtered ANN subsystem: attribute store, FilterSpec predicates,
selectivity-adaptive filtered traversal, and the per-tile bitmap plumbing
for near-storage predicate pushdown (billed by ``nand.simulator``)."""
from repro.filter.attributes import (
    AttributeStore,
    bitmap_popcount,
    encode_categorical,
    pack_bitmap,
    random_attributes,
    unpack_bitmap,
)
from repro.filter.spec import ALL, Eq, FilterSpec, In, Range
from repro.filter.traversal import (
    FilteredSearchResult,
    adapt_search_cfg,
    filtered_search,
    scan_search,
    tile_node_masks,
)


def attach_attributes(index, store: AttributeStore) -> AttributeStore:
    """Attach a per-node attribute store to a built ``ProximaIndex``. Rows
    must be keyed by the index's CURRENT (reordered) internal ids — permute
    a corpus-order store through ``index.reordering`` first::

        store = store.permuted(index.reordering.inv)    # if reordered

    Returns the store for chaining."""
    if len(store) != index.dataset.num_base:
        raise ValueError(
            f"attribute store has {len(store)} rows, index has "
            f"{index.dataset.num_base} vertices"
        )
    index.attributes = store
    return store


__all__ = [
    "ALL",
    "AttributeStore",
    "Eq",
    "FilterSpec",
    "FilteredSearchResult",
    "In",
    "Range",
    "adapt_search_cfg",
    "attach_attributes",
    "bitmap_popcount",
    "encode_categorical",
    "filtered_search",
    "pack_bitmap",
    "random_attributes",
    "scan_search",
    "tile_node_masks",
    "unpack_bitmap",
]
