"""Selectivity-adaptive filtered search kernels.

The regime DECISION now lives in ``repro.plan.QueryPlanner`` (the
masked-vs-scan filter strategy of a ``QueryPlan``); this module keeps the
kernels it composes (``scan_search``, ``adapt_search_cfg``,
``tile_node_masks``) plus the deprecated ``filtered_search`` wrapper.
The estimator (exact — the mask is one host-side vectorized pass) routes a
filtered query batch to one of three regimes:

  * **empty** — zero passing nodes: return -1/+inf immediately, no device
    dispatch (the zero-pass short circuit the shard layer also applies per
    tile).
  * **scan** (selectivity <= ``FilterConfig.brute_force_selectivity``, or
    fewer passing nodes than ``k``) — bitmap-driven brute-force PQ scan over
    the passing subset: gather the passing rows' PQ codes, one ADT-lookup
    distance pass, exact-rerank the top ``scan_rerank * k``, top-k. The
    passing-id list is padded to the next power of two so distinct filters
    share compiled buckets.
  * **traversal** — masked graph traversal (``core.search(node_mask=...)``):
    the full graph routes, only passing nodes are admitted; the effective
    ``list_size`` is inflated by ~1/selectivity (pow2-quantized, capped at
    ``inflate_cap``) with ``t_step`` scaled to match, and early termination
    is relaxed by ``relax_repetition`` extra stable rounds. An all-pass
    filter leaves the config untouched, so its results are bit-identical to
    the unfiltered search.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FilterConfig, SearchConfig
from repro.core.pq import compute_adt, pq_distance
from repro.core.search import (
    Corpus, SearchResult, _exact_dist, l2_normalize, next_pow2,
)

INF = jnp.float32(jnp.inf)


class FilteredSearchResult(NamedTuple):
    ids: np.ndarray             # (Q, k) int32 passing ids only, -1 padded
    dists: np.ndarray           # (Q, k) f32 accurate distances, +inf padded
    result: SearchResult        # counters (scan mode: synthesized — hops=0,
                                # pq = passing-subset size, rounds=1)
    mode: str                   # "traversal" | "scan" | "empty"
    selectivity: float          # exact passing fraction of the mask
    effective: SearchConfig     # the adapted config actually executed


def adapt_search_cfg(
    cfg: SearchConfig,
    selectivity: float,
    filter_cfg: FilterConfig,
) -> SearchConfig:
    """Masked-traversal config for a given selectivity: the candidate list
    must hold ~1/selectivity non-passing entries per admitted one, so the
    frontier inflates accordingly (pow2-quantized to bound the set of
    compiled shapes) and termination is relaxed. selectivity >= 1 returns
    ``cfg`` unchanged (the all-pass bit-identity guarantee)."""
    if selectivity >= 1.0:
        return cfg
    want = min(1.0 / max(selectivity, 1e-9), float(filter_cfg.inflate_cap))
    inflate = next_pow2(int(np.ceil(want)))
    return dataclasses.replace(
        cfg,
        list_size=cfg.list_size * inflate,
        t_step=cfg.t_step * inflate,
        repetition_rate=cfg.repetition_rate + filter_cfg.relax_repetition,
    )


def tile_node_masks(tile_ids, mask: np.ndarray) -> np.ndarray:
    """Slice a global pass mask into per-tile local masks: (P, Nt) bool over
    ``TiledCorpus.tile_ids`` (padding rows never pass). The per-channel
    bitmap slices of the shard layer — a tile whose slice is all-False can
    skip the query entirely (zero-pass tile skipping)."""
    tid = np.asarray(tile_ids)
    m = np.asarray(mask, bool)
    return (tid >= 0) & m[np.clip(tid, 0, None)]


# ---------------------------------------------------------------------------
# Brute-force PQ scan over the passing subset
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "m_rerank", "metric", "use_pq"))
def _scan_kernel(corpus: Corpus, queries, sel_ids, sel_valid,
                 k: int, m_rerank: int, metric: str, use_pq: bool):
    """One batched pass over the gathered passing rows. sel_ids (S,) int32
    (pow2-padded), sel_valid (S,) bool. Returns (ids, dists, n_acc_each)."""
    if metric == "angular":
        queries = l2_normalize(queries)
    base_sel = corpus.base[sel_ids]                     # (S, D)
    if use_pq:
        adts = jax.vmap(
            lambda q: compute_adt(q, corpus.centroids, metric)
        )(queries)
        codes_sel = corpus.codes[sel_ids]               # (S, M)
        d = jax.vmap(lambda adt: pq_distance(codes_sel, adt))(adts)
        d = jnp.where(sel_valid[None, :], d, INF)       # (Q, S)
        m = min(m_rerank, d.shape[1])
        negd, idx = jax.lax.top_k(-d, m)                # (Q, m) PQ short-list
        cand = base_sel[idx]                            # (Q, m, D)
        acc = jax.vmap(lambda q, x: _exact_dist(q, x, metric))(queries, cand)
        acc = jnp.where(jnp.isinf(negd), INF, acc)      # padded lanes stay inf
        neg2, idx2 = jax.lax.top_k(-acc, min(k, m))
        out_ids = jnp.take_along_axis(sel_ids[idx], idx2, 1)
        out_d = -neg2
        n_acc_each = jnp.isfinite(negd).sum(axis=1)
    else:
        d = jax.vmap(lambda q: _exact_dist(q, base_sel, metric))(queries)
        d = jnp.where(sel_valid[None, :], d, INF)
        neg2, idx2 = jax.lax.top_k(-d, min(k, d.shape[1]))
        out_ids = sel_ids[idx2]
        out_d = -neg2
        n_acc_each = sel_valid.sum()[None].repeat(queries.shape[0])
    out_ids = jnp.where(jnp.isinf(out_d), -1, out_ids)
    return out_ids, out_d, n_acc_each


def _pad_topk(ids: np.ndarray, dists: np.ndarray, k: int):
    got = ids.shape[1]
    if got >= k:
        return ids[:, :k], dists[:, :k]
    q = ids.shape[0]
    pid = np.full((q, k), -1, np.int32)
    pd = np.full((q, k), np.inf, np.float32)
    pid[:, :got] = ids
    pd[:, :got] = dists
    return pid, pd


def _zero_counters(nq: int):
    z = jnp.zeros((nq,), jnp.int32)
    return dict(n_hops=z, n_pq=z, n_acc=z, n_hot_hops=z, n_free_pq=z,
                rounds=z)


def scan_search(corpus: Corpus, queries: jnp.ndarray, mask: np.ndarray,
                cfg: SearchConfig, metric: str, fcfg: FilterConfig,
                selectivity: float) -> FilteredSearchResult:
    """Bitmap-driven brute-force PQ scan KERNEL over the passing subset —
    the ``scan`` strategy of a ``repro.plan.QueryPlan``."""
    pass_ids = np.nonzero(mask)[0].astype(np.int32)
    pot = next_pow2(len(pass_ids))
    sel_ids = np.zeros((pot,), np.int32)
    sel_ids[: len(pass_ids)] = pass_ids
    sel_valid = np.zeros((pot,), bool)
    sel_valid[: len(pass_ids)] = True
    m_rerank = next_pow2(max(fcfg.scan_rerank * cfg.k, cfg.k))
    use_pq = cfg.use_pq and cfg.rerank  # rank-by-PQ degenerates to exact scan
    ids, dists, n_acc = _scan_kernel(
        corpus, queries, jnp.asarray(sel_ids), jnp.asarray(sel_valid),
        cfg.k, m_rerank, metric, use_pq,
    )
    nq = queries.shape[0]
    ids, dists = _pad_topk(np.asarray(ids), np.asarray(dists), cfg.k)
    counters = _zero_counters(nq)
    counters["n_pq"] = jnp.full((nq,), len(pass_ids) if use_pq else 0,
                                jnp.int32)
    counters["n_acc"] = jnp.asarray(n_acc, jnp.int32) if use_pq else \
        jnp.full((nq,), len(pass_ids), jnp.int32)
    counters["rounds"] = jnp.ones((nq,), jnp.int32)
    res = SearchResult(ids=jnp.asarray(ids), dists=jnp.asarray(dists),
                       **counters)
    return FilteredSearchResult(ids=ids, dists=dists, result=res,
                                mode="scan", selectivity=selectivity,
                                effective=cfg)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def filtered_search(
    corpus: Corpus,
    queries,
    mask: np.ndarray,
    cfg: SearchConfig,
    metric: str = "l2",
    filter_cfg: Optional[FilterConfig] = None,
) -> FilteredSearchResult:
    """DEPRECATED entry point — the empty/scan/masked regime choice now
    lives in ``repro.plan.QueryPlanner`` (the masked-vs-scan filter
    strategy of a ``QueryPlan``); this wrapper builds a mask request with
    ``adaptive=True`` and delegates, reproducing the legacy decision and
    kernels bit-identically."""
    from repro.plan import Searcher, SearchRequest
    from repro.plan.searcher import warn_legacy

    warn_legacy("filter.filtered_search")
    s = Searcher.open(corpus, cfg=cfg, metric=metric, filter_cfg=filter_cfg)
    res = s.search(SearchRequest(queries=queries, node_mask=mask,
                                 adaptive=True))
    return res.raw
