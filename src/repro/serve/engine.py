"""Batched ANN-search serving engine — the software twin of the paper's
search-engine frontend (scheduler + N_q queues, §IV-D), rebuilt on the
query-plan layer.

Requests arrive individually; each ``submit`` compiles (or plan-cache-hits)
a ``repro.plan.QueryPlan`` and the scheduler packs requests into fixed-size
batches BY PLAN CACHE KEY (requests sharing a compiled execution strategy —
same kind, filter strategy, effective config — flush together; with uniform
filters this degenerates to plain FIFO batching, exactly the old
filter-hash behaviour).  The flush runs the plan once over the padded
bucket through the shared ``Searcher`` facade and completes futures.
Single-threaded event-loop style, deterministic.

The engine serves every target the plan layer can open — a frozen
``ProximaIndex`` (flat or tiled) or a streaming ``stream.MutableIndex``.
In streaming mode ``insert``/``delete`` interleave with ``submit``: updates
apply immediately (the delta segment is DRAM-resident), queued queries
observe every update applied before their batch flushes, and consolidation
runs *between* batches once the delta exceeds its configured fraction —
never inside one, so the compiled base search shape is stable within a
batch.

All per-feature constructor kwargs (num_tiles / shard_policy / probe_tiles
/ beam_width) are legacy sugar folded into one ``PlanConfig``; the ad-hoc
per-spec ``_filter_cache`` is gone — compiled masks live in the planner's
artifact cache, keyed by plan.

Observability (``repro.obs``): pass ``obs=Observability.on()`` (or an
``ObsConfig``) and the engine records queue-wait / end-to-end latency
histograms and a batch-occupancy gauge labeled by plan kind / filter
strategy / tenant, emits per-request ``queue-wait`` async trace spans
nested over each flush's ``batch`` > ``batch-assembly`` / ``kernel-execute``
/ ``post-process`` spans, watches the jit caches for unexpected recompiles
(budget: pow2 buckets x distinct executed plans), and — with
``nand_billing`` — bills every flushed batch through the NAND cost model
into the same registry.  The default is the shared no-op bundle: one
predictable branch per call site, no allocation, no timing.

All engine timing uses ``time.perf_counter()`` — the monotonic clock;
``time.time()`` is wall-clock and jumps under NTP step corrections, which
produced negative latencies and spurious/missed flush timeouts.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Union

import numpy as np

from repro.configs.base import PlanConfig, SearchConfig
from repro.core.index import ProximaIndex
from repro.core.search import next_pow2
from repro.filter.spec import FilterSpec
from repro.obs import KernelWatch, Observability, record_plan_execution
from repro.plan import QueryPlan, Searcher, SearchRequest
from repro.stream.mutable import MutableIndex


@dataclasses.dataclass
class Request:
    rid: int
    query: np.ndarray
    t_submit: float = 0.0
    t_done: float = 0.0
    ids: Optional[np.ndarray] = None
    dists: Optional[np.ndarray] = None
    # per-request attribute filter — requests sharing a compiled plan (the
    # spec is part of its cache key) are batched together so one compiled
    # execution serves the whole batch; None = unfiltered
    filter: Optional[FilterSpec] = None
    # the compiled strategy serving this request (assigned at submit)
    plan: Optional[QueryPlan] = None

    @property
    def latency_ms(self) -> float:
        return (self.t_done - self.t_submit) * 1e3


@dataclasses.dataclass
class EngineStats:
    """Structured serving counters — the typed record ``ServingEngine.stats``
    derives its back-compat dict from (no more hand-maintained counter dict
    to drift)."""
    batches: int = 0
    queries: int = 0
    pad_fraction: float = 0.0        # running MEAN pad share over batches
    inserts: int = 0
    deletes: int = 0
    consolidations: int = 0
    filtered_queries: int = 0
    filter_scan_batches: int = 0
    # plan_cache_hits / plan_cache_misses intentionally live on the PLANNER
    # (the component that owns the cache); ``ServingEngine.stats`` merges
    # them into the dict view at read time instead of hand-syncing fields

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ServingEngine:
    def __init__(
        self,
        index: Union[ProximaIndex, MutableIndex],
        batch_size: int = 32,
        cfg: Optional[SearchConfig] = None,
        flush_us: float = 2000.0,
        auto_consolidate: bool = True,
        num_tiles: Optional[int] = None,
        shard_policy: Optional[str] = None,
        probe_tiles: Optional[int] = None,
        beam_width: Optional[int] = None,
        attributes=None,
        plan: Optional[PlanConfig] = None,
        obs=None,
    ):
        pcfg = plan or PlanConfig()
        legacy = dict(search=cfg, num_tiles=num_tiles,
                      shard_policy=shard_policy, probe_tiles=probe_tiles,
                      beam_width=beam_width)
        pcfg = dataclasses.replace(
            pcfg, **{k: v for k, v in legacy.items() if v is not None})
        self.obs = Observability.resolve(obs)
        self.searcher = Searcher.open(index, pcfg, attributes=attributes,
                                      obs=self.obs)
        self.batch_size = batch_size
        self.flush_us = flush_us
        self.auto_consolidate = auto_consolidate
        self.queue: Deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self._next = 0
        self._stats = EngineStats()
        self._plan_keys_seen: set = set()    # recompile-budget denominator
        if self.obs.enabled:
            self.obs.install_kernel_hooks()
        # warm the compile for the full-batch bucket (smaller power-of-two
        # buckets compile lazily on first use)
        dummy = np.zeros((batch_size, self.index.dataset.dim), np.float32)
        self.searcher.search(SearchRequest(queries=dummy))
        # recompile watchdog baselined AFTER warm-up, so only serving-time
        # jit-cache growth is judged against the pow2-bucket x plan budget
        self._watch = KernelWatch(self.obs.metrics) \
            if self.obs.metrics.enabled else None

    def _bucket(self, n: int) -> int:
        """Smallest power-of-two >= n, capped at batch_size — the fixed set
        of compiled batch shapes (at most log2(batch_size)+1 executables, so
        varying queue depths never trigger a fresh jit compile)."""
        return min(next_pow2(max(n, 1)), self.batch_size)

    # -------------------------------------------- plan-layer pass-throughs
    @property
    def mutable(self) -> Optional[MutableIndex]:
        return self.searcher.mutable

    @property
    def index(self) -> ProximaIndex:
        """Current base index — always the mutable's latest after any
        consolidation (including capacity-forced ones inside insert)."""
        return self.searcher.index

    @property
    def cfg(self) -> SearchConfig:
        return self.searcher.cfg

    @property
    def metric(self) -> str:
        return self.searcher.metric

    @property
    def filter_cfg(self):
        return self.searcher.filter_cfg

    @property
    def attributes(self):
        return self.searcher.attributes

    @property
    def tiled(self):
        return self.searcher.tiled

    @property
    def corpus(self):
        return self.searcher.corpus

    @property
    def num_tiles(self) -> int:
        return self.searcher.num_tiles

    @property
    def shard_policy(self):
        return self.searcher.shard_policy

    @property
    def probe_tiles(self) -> int:
        return self.searcher.probe_tiles

    @property
    def stats(self) -> dict:
        """Back-compat dict view, derived from the structured
        ``EngineStats`` with the planner's plan-cache counters merged in
        at read time (the planner owns the cache; nothing is hand-synced)."""
        d = self._stats.as_dict()
        d.update(self.searcher.plan_cache_stats())
        return d

    # --------------------------------------------------------------- requests
    def submit(self, query: np.ndarray, filter: Optional[FilterSpec] = None,
               ) -> int:
        """Queue one query; ``filter`` (a hashable ``FilterSpec``) restricts
        results to attribute-passing nodes. The request's ``QueryPlan`` is
        compiled here (plan-cache hit for every repeated spec) and requests
        batch by its cache key."""
        rid = self._next
        self._next += 1
        if filter is not None and getattr(filter, "is_all", False):
            filter = None                 # all-pass spec == unfiltered batch
        q = np.asarray(query, np.float32)
        obs = self.obs
        with obs.tracer.span("plan-lookup", rid=rid):
            try:
                plan = self.searcher.plan(SearchRequest(queries=q,
                                                        filter=filter))
            except RuntimeError:
                # missing attribute store: accept the request and surface the
                # error at flush time, like the legacy engine did
                plan = None
        self.queue.append(Request(rid=rid, query=q,
                                  t_submit=time.perf_counter(),
                                  filter=filter, plan=plan))
        if obs.enabled:
            # queue residency is an async span: many requests overlap, so a
            # synchronous nested span on one track cannot represent it
            obs.tracer.async_begin("queue-wait", rid)
            obs.metrics.gauge("queue_depth", float(len(self.queue)))
        return rid

    def insert(self, vector: np.ndarray, attrs=None) -> int:
        """Streaming insert; returns the stable external id. Visible to every
        query flushed after this call. ``attrs`` is the new vector's
        attribute row when the index carries an attribute store."""
        if self.mutable is None:
            raise RuntimeError("engine serves a frozen index — wrap it in "
                               "stream.MutableIndex for online updates")
        before = self.mutable.stats["consolidations"]
        ext = self.mutable.insert(vector, attrs=attrs)  # may consolidate
        self._stats.consolidations += (
            self.mutable.stats["consolidations"] - before
        )
        self._stats.inserts += 1
        return ext

    def delete(self, ext_id: int) -> bool:
        """Streaming delete (tombstone). Filtered from every later flush."""
        if self.mutable is None:
            raise RuntimeError("engine serves a frozen index — wrap it in "
                               "stream.MutableIndex for online updates")
        ok = self.mutable.delete(ext_id)
        if ok:
            self._stats.deletes += 1
        return ok

    # ------------------------------------------------------------- scheduling
    def _flush_due(self) -> bool:
        """Full batch, or the OLDEST QUEUED request has waited ``flush_us``.

        The timeout is anchored to the head request's submit time, not the
        last flush: after an idle gap the first request of a new burst must
        still wait its full window for batch-mates (measuring from the last
        flush made it flush immediately in a batch of 1, defeating
        batching). An empty->nonempty enqueue restarts the clock naturally —
        the new head carries a fresh ``t_submit``."""
        if len(self.queue) >= self.batch_size:
            return True
        return (
            bool(self.queue)
            and (time.perf_counter() - self.queue[0].t_submit) * 1e6
            >= self.flush_us
        )

    def step(self, force: bool = False) -> List[Request]:
        """Run one batch if due; returns completed requests. In streaming
        mode, consolidation triggers between batches.

        Batches are homogeneous in PLAN: the flush takes the head request's
        plan cache key and gathers (in FIFO order) only requests sharing it
        — one compiled execution serves the whole batch. Other-plan
        requests keep their place at the front of the queue for the next
        flush. With uniform filters (the common case, and every unfiltered
        workload) this is plain FIFO batching."""
        if not (force and self.queue) and not self._flush_due():
            return []
        head = self.queue[0]
        plan = head.plan
        if plan is None:             # deferred planning error (e.g. filter
            plan = self.searcher.plan(  # without a store) raises HERE
                SearchRequest(queries=head.query, filter=head.filter))

        def _key(r: Request):
            return r.plan.cache_key if r.plan is not None \
                else ("unplanned", r.filter)

        key = plan.cache_key if head.plan is not None \
            else ("unplanned", head.filter)
        obs = self.obs
        with obs.tracer.span("batch", kind=plan.kind,
                             strategy=plan.strategy) as bsp:
            with obs.tracer.span("batch-assembly"):
                batch: List[Request] = []
                skipped: List[Request] = []
                while self.queue and len(batch) < self.batch_size:
                    r = self.queue.popleft()
                    (batch if _key(r) == key else skipped).append(r)
                self.queue.extendleft(reversed(skipped))
                n = len(batch)
                t_assembled = time.perf_counter()
                if obs.enabled:
                    for r in batch:
                        # the request leaves the queue here — close its
                        # async residency span and bill queue-wait
                        obs.tracer.async_end("queue-wait", r.rid)
                        obs.metrics.observe(
                            "queue_wait_ms",
                            (t_assembled - r.t_submit) * 1e3,
                            kind=plan.kind, strategy=plan.strategy,
                            tenant=plan.tenant,
                        )
                q = np.stack([r.query for r in batch])
                bucket = self._bucket(n)
                if n < bucket:  # pad to the bucket's compiled shape
                    q = np.concatenate(
                        [q, np.zeros((bucket - n, q.shape[1]), np.float32)]
                    )
            ex = self.searcher.execute(plan, q)   # kernel-execute span inside
            now = time.perf_counter()
            with obs.tracer.span("post-process"):
                ids, dists = ex.ids, ex.dists
                if plan.spec is not None:
                    self._stats.filtered_queries += n
                if plan.kind == "flat" and plan.strategy == "scan":
                    self._stats.filter_scan_batches += 1
                for i, r in enumerate(batch):
                    r.ids, r.dists, r.t_done = ids[i], dists[i], now
                    self.done[r.rid] = r
                    if obs.enabled:
                        obs.metrics.observe(
                            "request_latency_ms", r.latency_ms,
                            kind=plan.kind, strategy=plan.strategy,
                            tenant=plan.tenant,
                        )
            if obs.enabled:
                bsp.set(queries=n, bucket=bucket)
                obs.metrics.gauge("batch_occupancy", n / bucket)
                obs.metrics.observe("batch_occupancy_hist", n / bucket,
                                    kind=plan.kind)
                obs.metrics.gauge("queue_depth", float(len(self.queue)))
            if obs.nand_billing:
                with obs.tracer.span("nand-billing"):
                    from repro.plan.request import SearchResult
                    pres = SearchResult(
                        ids=ex.ids, dists=ex.dists,
                        stats=self.searcher.planner.stats_for(plan, ex),
                        plan=plan, raw=ex.raw,
                    )
                    record_plan_execution(
                        obs.metrics, pres,
                        index=self.mutable if self.mutable is not None
                        else self._index_or_none(),
                        batch_queries=n,
                    )
        # running MEAN pad fraction over all batches (a sum would grow
        # without bound and read as >100% padding after a few batches)
        b = self._stats.batches
        self._stats.pad_fraction = (
            self._stats.pad_fraction * b + (bucket - n) / bucket
        ) / (b + 1)
        self._stats.batches = b + 1
        self._stats.queries += n
        if self._watch is not None:
            self._plan_keys_seen.add(key)
            self._watch.sample()
            # the pow2-bucket contract as a LIVE assertion: at most
            # log2(batch)+1 compiled shapes per distinct executed plan
            buckets = int(math.log2(next_pow2(self.batch_size))) + 1
            self._watch.check(buckets * len(self._plan_keys_seen))
        if (
            self.auto_consolidate
            and self.mutable is not None
            and self.mutable.needs_consolidation()
        ):
            self.consolidate()
        return batch

    def _index_or_none(self):
        """Served base index, or None for raw-corpus targets (those carry no
        NAND geometry; billing then counts the batch as unbilled)."""
        try:
            idx = self.index
        except AttributeError:
            return None
        return idx

    def consolidate(self) -> None:
        """Fold the delta segment into a rebuilt base index."""
        if self.mutable is None:
            return
        self.mutable.consolidate()
        self._stats.consolidations += 1

    def drain(self) -> List[Request]:
        out = []
        while self.queue:
            out.extend(self.step(force=True))
        return out
