"""Batched ANN-search serving engine — the software twin of the paper's
search-engine frontend (scheduler + N_q queues, §IV-D).

Requests arrive individually; the scheduler packs them into fixed-size
batches (the JAX search is compiled for a fixed query-batch shape = the
ASIC's queue count) with a flush timeout, runs the compiled search, and
completes futures. Single-threaded event-loop style, deterministic.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import SearchConfig
from repro.core import search
from repro.core.index import ProximaIndex


@dataclasses.dataclass
class Request:
    rid: int
    query: np.ndarray
    t_submit: float = 0.0
    t_done: float = 0.0
    ids: Optional[np.ndarray] = None
    dists: Optional[np.ndarray] = None

    @property
    def latency_ms(self) -> float:
        return (self.t_done - self.t_submit) * 1e3


class ServingEngine:
    def __init__(
        self,
        index: ProximaIndex,
        batch_size: int = 32,
        cfg: Optional[SearchConfig] = None,
        flush_us: float = 2000.0,
    ):
        self.index = index
        self.corpus = index.corpus()
        self.cfg = cfg or index.config.search
        self.metric = index.dataset.metric
        self.batch_size = batch_size
        self.flush_us = flush_us
        self.queue: Deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self._next = 0
        self._last_flush = time.time()
        self.stats = {"batches": 0, "queries": 0, "pad_fraction": 0.0}
        # warm the compile with a dummy batch
        dummy = np.zeros((batch_size, index.dataset.dim), np.float32)
        jax.block_until_ready(
            search(self.corpus, dummy, self.cfg, self.metric).ids
        )

    def submit(self, query: np.ndarray) -> int:
        rid = self._next
        self._next += 1
        self.queue.append(Request(rid=rid, query=np.asarray(query, np.float32),
                                  t_submit=time.time()))
        return rid

    def _flush_due(self) -> bool:
        if len(self.queue) >= self.batch_size:
            return True
        return (
            bool(self.queue)
            and (time.time() - self._last_flush) * 1e6 >= self.flush_us
        )

    def step(self, force: bool = False) -> List[Request]:
        """Run one batch if due; returns completed requests."""
        if not (force and self.queue) and not self._flush_due():
            return []
        batch = [self.queue.popleft()
                 for _ in range(min(self.batch_size, len(self.queue)))]
        n = len(batch)
        q = np.stack([r.query for r in batch])
        if n < self.batch_size:  # pad to the compiled shape
            q = np.concatenate(
                [q, np.zeros((self.batch_size - n, q.shape[1]), np.float32)]
            )
        res = search(self.corpus, q, self.cfg, self.metric)
        ids = np.asarray(res.ids)
        dists = np.asarray(res.dists)
        now = time.time()
        for i, r in enumerate(batch):
            r.ids, r.dists, r.t_done = ids[i], dists[i], now
            self.done[r.rid] = r
        self.stats["batches"] += 1
        self.stats["queries"] += n
        self.stats["pad_fraction"] += (self.batch_size - n) / self.batch_size
        self._last_flush = now
        return batch

    def drain(self) -> List[Request]:
        out = []
        while self.queue:
            out.extend(self.step(force=True))
        return out
