"""Batched ANN-search serving engine — the software twin of the paper's
search-engine frontend (scheduler + N_q queues, §IV-D).

Requests arrive individually; the scheduler packs them into fixed-size
batches (the JAX search is compiled for a fixed query-batch shape = the
ASIC's queue count) with a flush timeout, runs the compiled search, and
completes futures. Single-threaded event-loop style, deterministic.

The engine serves either a frozen ``ProximaIndex`` or a streaming
``stream.MutableIndex``. In streaming mode ``insert``/``delete`` interleave
with ``submit``: updates apply immediately (the delta segment is
DRAM-resident), queued queries observe every update applied before their
batch flushes, and consolidation runs *between* batches once the delta
exceeds its configured fraction — never inside one, so the compiled base
search shape is stable within a batch.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Union

import jax
import numpy as np

from repro.configs.base import SearchConfig
from repro.core import search
from repro.core.search import next_pow2
from repro.core.index import ProximaIndex
from repro.stream.mutable import MutableIndex
from repro.stream.searcher import search_merged


@dataclasses.dataclass
class Request:
    rid: int
    query: np.ndarray
    t_submit: float = 0.0
    t_done: float = 0.0
    ids: Optional[np.ndarray] = None
    dists: Optional[np.ndarray] = None
    # per-request attribute filter (repro.filter.FilterSpec) — requests
    # sharing a spec (by hash) are batched together so one compiled masked
    # search serves the whole batch; None = unfiltered
    filter: Optional[object] = None

    @property
    def latency_ms(self) -> float:
        return (self.t_done - self.t_submit) * 1e3


class ServingEngine:
    def __init__(
        self,
        index: Union[ProximaIndex, MutableIndex],
        batch_size: int = 32,
        cfg: Optional[SearchConfig] = None,
        flush_us: float = 2000.0,
        auto_consolidate: bool = True,
        num_tiles: Optional[int] = None,
        shard_policy: Optional[str] = None,
        probe_tiles: Optional[int] = None,
        beam_width: Optional[int] = None,
        attributes=None,
    ):
        self.mutable = index if isinstance(index, MutableIndex) else None
        self._index = index.base if self.mutable else index
        self.cfg = cfg or self.index.config.search
        if beam_width is not None:
            self.cfg = dataclasses.replace(self.cfg, beam_width=beam_width)
        self.metric = self.index.dataset.metric
        self.batch_size = batch_size
        self.flush_us = flush_us
        self.auto_consolidate = auto_consolidate
        self.queue: Deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self._next = 0
        self.stats = {
            "batches": 0, "queries": 0, "pad_fraction": 0.0,
            "inserts": 0, "deletes": 0, "consolidations": 0,
            "filtered_queries": 0, "filter_scan_batches": 0,
        }
        # ----- filtered-search plumbing ------------------------------------
        # getattr: configs/indexes unpickled from pre-filter-layer caches
        from repro.configs.base import FilterConfig

        self.filter_cfg = (
            getattr(self.index.config, "filter", None) or FilterConfig()
        )
        if self.mutable is not None:
            if attributes is not None:
                if len(attributes) != self.mutable.next_ext:
                    raise ValueError(
                        f"attribute store has {len(attributes)} rows, "
                        f"mutable index has allocated "
                        f"{self.mutable.next_ext} external ids"
                    )
                self.mutable.attributes = attributes
            self.attributes = self.mutable.attributes
        else:
            if attributes is not None and \
                    len(attributes) != self._index.dataset.num_base:
                raise ValueError(
                    f"attribute store has {len(attributes)} rows, index "
                    f"has {self._index.dataset.num_base} vertices"
                )
            self.attributes = (
                attributes if attributes is not None
                else getattr(self._index, "attributes", None)
            )
        self._filter_cache: Dict[object, dict] = {}  # spec -> mask/cfg/tiles
        # ----- multi-channel (sharded) base path ---------------------------
        # getattr: configs unpickled from pre-shard-layer caches lack .shard
        from repro.configs.base import ShardConfig

        shard_cfg = getattr(self.index.config, "shard", None) or ShardConfig()
        self.probe_tiles = (
            shard_cfg.probe_tiles if probe_tiles is None else probe_tiles
        )
        self.tiled = None
        self.partition = None
        if self.mutable is not None:
            # defaults come from the MutableIndex itself (it may have been
            # tiled manually via set_num_tiles); sync back only when the
            # caller explicitly asked for a tiling, so an engine constructed
            # with defaults never clobbers the index's serving mode
            self.num_tiles = (
                self.mutable.num_tiles if num_tiles is None else num_tiles
            )
            self.shard_policy = (
                self.mutable.shard_policy if shard_policy is None
                else shard_policy
            )
            if (self.num_tiles, self.shard_policy) != (
                self.mutable.num_tiles, self.mutable.shard_policy
            ):
                self.mutable.set_num_tiles(self.num_tiles, self.shard_policy)
            self.corpus = None
        else:
            self.num_tiles = (
                shard_cfg.num_tiles if num_tiles is None else num_tiles
            )
            self.shard_policy = (
                shard_cfg.policy if shard_policy is None else shard_policy
            )
            if self.num_tiles > 1:
                self.tiled, self.partition = self._index.sharded_corpus(
                    self.num_tiles, self.shard_policy
                )
                self.corpus = None
            else:
                self.corpus = self._index.corpus()
        if self.probe_tiles and self.num_tiles > 1 and \
                self.shard_policy != "cluster":
            import warnings

            warnings.warn(
                "probe_tiles routing assumes geometry-aware tiles "
                "(shard_policy='cluster'); with hash/contiguous allocation "
                "tile centroids are near-identical and routed recall "
                "collapses", stacklevel=2,
            )
        # warm the compile for the full-batch bucket (smaller power-of-two
        # buckets compile lazily on first use)
        dummy = np.zeros((batch_size, self.index.dataset.dim), np.float32)
        self._search_batch(dummy)

    def _bucket(self, n: int) -> int:
        """Smallest power-of-two >= n, capped at batch_size — the fixed set
        of compiled batch shapes (at most log2(batch_size)+1 executables, so
        varying queue depths never trigger a fresh jit compile)."""
        return min(next_pow2(max(n, 1)), self.batch_size)

    @property
    def index(self) -> ProximaIndex:
        """Current base index — always the mutable's latest after any
        consolidation (including capacity-forced ones inside insert)."""
        return self.mutable.base if self.mutable is not None else self._index

    # ------------------------------------------------------------- search path
    def _filter_plan(self, spec) -> dict:
        """Cached per-spec plan for the frozen-index paths: compiled mask,
        adapted config, per-tile mask slices (the mutable path recomputes —
        its mask depends on the live tombstone set)."""
        plan = self._filter_cache.get(spec)
        if plan is None:
            from repro.filter import adapt_search_cfg, tile_node_masks

            if self.attributes is None:
                raise RuntimeError(
                    "filtered submit() needs an attribute store — pass "
                    "attributes= to ServingEngine or attach one to the index"
                )
            mask = self.attributes.mask(spec)
            plan = {"mask": mask, "selectivity": float(mask.mean())}
            if self.tiled is not None:
                plan["node_masks"] = tile_node_masks(self.tiled.tile_ids, mask)
                plan["cfg"] = adapt_search_cfg(
                    self.cfg, plan["selectivity"], self.filter_cfg
                )
            self._filter_cache[spec] = plan
        return plan

    def _search_batch(self, q: np.ndarray, spec=None):
        """(B, D) -> (ids, dists) through the merged, sharded or static
        path; ``spec`` routes the batch through the filtered variant."""
        if self.mutable is not None:
            res = search_merged(self.mutable, q, self.cfg,
                                probe_tiles=self.probe_tiles or None,
                                filter_spec=spec)
            return res.ids, res.dists
        if self.tiled is not None:
            from repro.shard import sharded_search

            cfg, node_masks = self.cfg, None
            if spec is not None:
                plan = self._filter_plan(spec)
                cfg, node_masks = plan["cfg"], plan["node_masks"]
            res = sharded_search(
                self.tiled, q, cfg, self.metric,
                probe_tiles=self.probe_tiles or None,
                node_masks=node_masks,
            )
            jax.block_until_ready(res.ids)
            return np.asarray(res.ids), np.asarray(res.dists)
        if spec is not None:
            from repro.filter import filtered_search

            plan = self._filter_plan(spec)
            fres = filtered_search(self.corpus, q, plan["mask"], self.cfg,
                                   self.metric, filter_cfg=self.filter_cfg)
            if fres.mode == "scan":
                self.stats["filter_scan_batches"] += 1
            return fres.ids, fres.dists
        res = search(self.corpus, q, self.cfg, self.metric)
        jax.block_until_ready(res.ids)
        return np.asarray(res.ids), np.asarray(res.dists)

    # --------------------------------------------------------------- requests
    def submit(self, query: np.ndarray, filter=None) -> int:
        """Queue one query; ``filter`` (a hashable ``FilterSpec``) restricts
        results to attribute-passing nodes. Requests batch by filter hash."""
        rid = self._next
        self._next += 1
        if filter is not None and getattr(filter, "is_all", False):
            filter = None                 # all-pass spec == unfiltered batch
        self.queue.append(Request(rid=rid, query=np.asarray(query, np.float32),
                                  t_submit=time.time(), filter=filter))
        return rid

    def insert(self, vector: np.ndarray, attrs=None) -> int:
        """Streaming insert; returns the stable external id. Visible to every
        query flushed after this call. ``attrs`` is the new vector's
        attribute row when the index carries an attribute store."""
        if self.mutable is None:
            raise RuntimeError("engine serves a frozen index — wrap it in "
                               "stream.MutableIndex for online updates")
        before = self.mutable.stats["consolidations"]
        ext = self.mutable.insert(vector, attrs=attrs)  # may consolidate
        self.stats["consolidations"] += (
            self.mutable.stats["consolidations"] - before
        )
        self.stats["inserts"] += 1
        return ext

    def delete(self, ext_id: int) -> bool:
        """Streaming delete (tombstone). Filtered from every later flush."""
        if self.mutable is None:
            raise RuntimeError("engine serves a frozen index — wrap it in "
                               "stream.MutableIndex for online updates")
        ok = self.mutable.delete(ext_id)
        if ok:
            self.stats["deletes"] += 1
        return ok

    # ------------------------------------------------------------- scheduling
    def _flush_due(self) -> bool:
        """Full batch, or the OLDEST QUEUED request has waited ``flush_us``.

        The timeout is anchored to the head request's submit time, not the
        last flush: after an idle gap the first request of a new burst must
        still wait its full window for batch-mates (measuring from the last
        flush made it flush immediately in a batch of 1, defeating
        batching). An empty->nonempty enqueue restarts the clock naturally —
        the new head carries a fresh ``t_submit``."""
        if len(self.queue) >= self.batch_size:
            return True
        return (
            bool(self.queue)
            and (time.time() - self.queue[0].t_submit) * 1e6 >= self.flush_us
        )

    def step(self, force: bool = False) -> List[Request]:
        """Run one batch if due; returns completed requests. In streaming
        mode, consolidation triggers between batches.

        Batches are homogeneous in filter: the flush takes the head
        request's ``FilterSpec`` and gathers (in FIFO order) only requests
        sharing it — one compiled masked search serves the whole batch.
        Other-filter requests keep their place at the front of the queue
        for the next flush. With uniform filters (the common case, and
        every unfiltered workload) this is plain FIFO batching."""
        if not (force and self.queue) and not self._flush_due():
            return []
        spec = self.queue[0].filter
        batch: List[Request] = []
        skipped: List[Request] = []
        while self.queue and len(batch) < self.batch_size:
            r = self.queue.popleft()
            (batch if r.filter == spec else skipped).append(r)
        self.queue.extendleft(reversed(skipped))
        n = len(batch)
        q = np.stack([r.query for r in batch])
        bucket = self._bucket(n)
        if n < bucket:  # pad to the bucket's compiled shape
            q = np.concatenate(
                [q, np.zeros((bucket - n, q.shape[1]), np.float32)]
            )
        ids, dists = self._search_batch(q, spec)
        now = time.time()
        if spec is not None:
            self.stats["filtered_queries"] += n
        for i, r in enumerate(batch):
            r.ids, r.dists, r.t_done = ids[i], dists[i], now
            self.done[r.rid] = r
        # running MEAN pad fraction over all batches (a sum would grow
        # without bound and read as >100% padding after a few batches)
        b = self.stats["batches"]
        self.stats["pad_fraction"] = (
            self.stats["pad_fraction"] * b + (bucket - n) / bucket
        ) / (b + 1)
        self.stats["batches"] = b + 1
        self.stats["queries"] += n
        if (
            self.auto_consolidate
            and self.mutable is not None
            and self.mutable.needs_consolidation()
        ):
            self.consolidate()
        return batch

    def consolidate(self) -> None:
        """Fold the delta segment into a rebuilt base index."""
        if self.mutable is None:
            return
        self.mutable.consolidate()
        self.stats["consolidations"] += 1

    def drain(self) -> List[Request]:
        out = []
        while self.queue:
            out.extend(self.step(force=True))
        return out
