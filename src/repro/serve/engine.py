"""Batched ANN-search serving engine — the software twin of the paper's
search-engine frontend (scheduler + N_q queues, §IV-D), rebuilt on the
query-plan layer.

Requests arrive individually; each ``submit`` compiles (or plan-cache-hits)
a ``repro.plan.QueryPlan`` and the scheduler packs requests into fixed-size
batches BY PLAN CACHE KEY (requests sharing a compiled execution strategy —
same kind, filter strategy, effective config — flush together; with uniform
filters this degenerates to plain FIFO batching, exactly the old
filter-hash behaviour).  The flush runs the plan once over the padded
bucket through the shared ``Searcher`` facade and completes futures.
Single-threaded event-loop style, deterministic.

The engine serves every target the plan layer can open — a frozen
``ProximaIndex`` (flat or tiled) or a streaming ``stream.MutableIndex``.
In streaming mode ``insert``/``delete`` interleave with ``submit``: updates
apply immediately (the delta segment is DRAM-resident), queued queries
observe every update applied before their batch flushes, and consolidation
runs *between* batches once the delta exceeds its configured fraction —
never inside one, so the compiled base search shape is stable within a
batch.

All per-feature constructor kwargs (num_tiles / shard_policy / probe_tiles
/ beam_width) are legacy sugar folded into one ``PlanConfig``; the ad-hoc
per-spec ``_filter_cache`` is gone — compiled masks live in the planner's
artifact cache, keyed by plan.

Observability (``repro.obs``): pass ``obs=Observability.on()`` (or an
``ObsConfig``) and the engine records queue-wait / end-to-end latency
histograms and a batch-occupancy gauge labeled by plan kind / filter
strategy / tenant, emits per-request ``queue-wait`` async trace spans
nested over each flush's ``batch`` > ``batch-assembly`` / ``kernel-execute``
/ ``post-process`` spans, watches the jit caches for unexpected recompiles
(budget: pow2 buckets x distinct executed plans), and — with
``nand_billing`` — bills every flushed batch through the NAND cost model
into the same registry.  The default is the shared no-op bundle: one
predictable branch per call site, no allocation, no timing.

All engine timing uses ``time.perf_counter()`` — the monotonic clock;
``time.time()`` is wall-clock and jumps under NTP step corrections, which
produced negative latencies and spurious/missed flush timeouts.

Continuous batching (``ServingEngine(continuous=True)``): instead of
flushing whole batches through one ``lax.while_loop``, the engine keeps a
fixed pool of ``slots`` in-flight lanes per plan cache key and advances ALL
of them one traversal round per ``step()`` call (a "tick", via the plan
layer's ``RoundSession`` over the ``core.search`` round-step kernels).
Lanes whose traversal quiesces are retired immediately — beta rerank,
delta/tombstone fusion for merged plans, NAND billing, future completion —
and their slots refill from the queue on the next tick, so no query ever
waits on another's last round.  Requests are admitted the moment a slot is
free (no flush window); plans without a round-steppable spine (tiled /
distributed fan-outs, bitmap scans) fall back to the batch-flush path
transparently.  Slot pools hold ONE fixed lane shape per plan, so the
round-step kernels compile once per (plan, slots) — the same pow2-bucket
recompile budget applies.

Streaming caveats in continuous mode: a lane traverses the base corpus (and,
when filtered, the admission mask) pinned at its session's creation, while
tombstones and the delta segment are read LIVE at retire time — deleted
vectors never surface, inserts are visible to every lane retired after them.
Consolidation rebuilds the base id space, so the engine completes all
in-flight merged lanes BEFORE consolidating (including the capacity-forced
consolidation inside ``insert``) and then re-creates their sessions against
the fresh base.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Union

import numpy as np

from repro.configs.base import PlanConfig, SearchConfig
from repro.core.index import ProximaIndex
from repro.core.search import next_pow2
from repro.filter.spec import FilterSpec
from repro.obs import (
    KernelWatch, Observability, SLOTracker, record_plan_execution,
)
from repro.plan import QueryPlan, Searcher, SearchRequest
from repro.stream.mutable import MutableIndex


@dataclasses.dataclass
class Request:
    rid: int
    query: np.ndarray
    t_submit: float = 0.0
    t_done: float = 0.0
    ids: Optional[np.ndarray] = None
    dists: Optional[np.ndarray] = None
    # per-request attribute filter — requests sharing a compiled plan (the
    # spec is part of its cache key) are batched together so one compiled
    # execution serves the whole batch; None = unfiltered
    filter: Optional[FilterSpec] = None
    # namespace slot: part of the plan cache key (tenants never co-batch)
    # and the SLO tracker's accounting key
    tenant: Optional[str] = None
    # the compiled strategy serving this request (assigned at submit)
    plan: Optional[QueryPlan] = None

    @property
    def latency_ms(self) -> float:
        return (self.t_done - self.t_submit) * 1e3


@dataclasses.dataclass
class EngineStats:
    """Structured serving counters — the typed record ``ServingEngine.stats``
    derives its back-compat dict from (no more hand-maintained counter dict
    to drift)."""
    batches: int = 0
    queries: int = 0
    pad_fraction: float = 0.0        # running MEAN pad share over batches
    inserts: int = 0
    deletes: int = 0
    consolidations: int = 0
    filtered_queries: int = 0
    filter_scan_batches: int = 0
    ticks: int = 0                   # continuous mode: round-step ticks run
    retired: int = 0                 # continuous mode: lanes retired
    fallback_batches: int = 0        # continuous mode: non-steppable plans
                                     # served through the batch-flush path
    slo_violations: int = 0          # rolling-window SLO breaches observed
                                     # (per-tenant detail in the registry's
                                     # slo_violations{tenant,slo} counters)
    # plan_cache_hits / plan_cache_misses intentionally live on the PLANNER
    # (the component that owns the cache); ``ServingEngine.stats`` merges
    # them into the dict view at read time instead of hand-syncing fields

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _SlotPool:
    """One plan's fixed pool of in-flight lanes (continuous mode).  ``state``
    is a ``core.search.SearchState`` over exactly ``len(requests)`` lanes —
    the ONE compiled shape this pool's round-step kernels ever see; free
    slots hold quiesced dummy lanes (``done=True``) so stepping them is a
    no-op."""
    session: object                          # plan.RoundSession
    requests: List[Optional[Request]]        # slot -> in-flight request
    state: object = None                     # lazily built on first admit

    @property
    def occupied(self) -> int:
        return sum(r is not None for r in self.requests)


_select_jit = None


def _select_lanes(mask: np.ndarray, new, old):
    """Per-lane select over two same-shape ``SearchState``s: lane i comes
    from ``new`` where ``mask[i]`` — the fixed-shape slot-refill primitive
    (no concatenation, no shape change, no recompile).  Jitted as one call
    for the same reason as ``_gather_rows``: per-leaf eager ``where``s cost
    a dispatch per state field."""
    global _select_jit
    import jax
    import jax.numpy as jnp

    if _select_jit is None:
        def _f(m, a, b):
            return jax.tree_util.tree_map(
                lambda x, y: jnp.where(
                    m.reshape(m.shape + (1,) * (x.ndim - 1)), x, y),
                a, b,
            )
        _select_jit = jax.jit(_f)
    return _select_jit(np.asarray(mask), new, old)


_gather_jit = None


def _gather_rows(state, rows: np.ndarray):
    """Row-gather a ``SearchState`` down to the given lanes (device-side).
    Retiring finalizes only the quiesced rows — padded to a power-of-two
    bucket so the rerank kernel compiles at log2(slots)+1 shapes per plan
    instead of reranking the whole pool on every retiring tick.  Jitted as
    ONE call: an eager per-leaf gather costs a device dispatch per state
    field, which dominated the tick."""
    global _gather_jit
    import jax

    if _gather_jit is None:
        _gather_jit = jax.jit(
            lambda s, i: jax.tree_util.tree_map(lambda a: a[i], s))
    return _gather_jit(state, rows)


def _quiet_free_lanes(state, occupied: np.ndarray):
    """Force ``done=True`` on unoccupied lanes so they never burn rounds —
    a free slot's dummy query must not traverse."""
    import jax.numpy as jnp

    m = jnp.asarray(occupied)
    lanes = state.lanes._replace(
        done=jnp.where(m, state.lanes.done, True))
    return state._replace(lanes=lanes)


class ServingEngine:
    def __init__(
        self,
        index: Union[ProximaIndex, MutableIndex],
        batch_size: int = 32,
        cfg: Optional[SearchConfig] = None,
        flush_us: float = 2000.0,
        auto_consolidate: bool = True,
        num_tiles: Optional[int] = None,
        shard_policy: Optional[str] = None,
        probe_tiles: Optional[int] = None,
        beam_width: Optional[int] = None,
        attributes=None,
        plan: Optional[PlanConfig] = None,
        obs=None,
        continuous: bool = False,
        slots: Optional[int] = None,
        nand=None,
        nand_queues: Optional[int] = None,
        slo=None,
    ):
        """``slo`` takes a ``{tenant: obs.SLOTarget}`` mapping (key ``None``
        covers untenanted traffic); completed requests then feed per-tenant
        rolling latency windows — and, with ``obs`` quality monitoring on,
        shadow-recall windows — whose breaches count into
        ``EngineStats.slo_violations`` and the registry's
        ``slo_violations{tenant,slo}`` counters."""
        pcfg = plan or PlanConfig()
        legacy = dict(search=cfg, num_tiles=num_tiles,
                      shard_policy=shard_policy, probe_tiles=probe_tiles,
                      beam_width=beam_width)
        pcfg = dataclasses.replace(
            pcfg, **{k: v for k, v in legacy.items() if v is not None})
        self.obs = Observability.resolve(obs)
        self.searcher = Searcher.open(index, pcfg, attributes=attributes,
                                      obs=self.obs)
        self.batch_size = batch_size
        self.flush_us = flush_us
        self.auto_consolidate = auto_consolidate
        self.continuous = bool(continuous)
        self.slots = int(slots) if slots else batch_size
        self.nand = nand                     # NandConfig override for billing
                                             # (e.g. double_buffer=True)
        self.nand_queues = nand_queues       # modeled scheduler queue count
                                             # (Fig. 16 N_q sweep knob)
        self.queue: Deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self._next = 0
        self._stats = EngineStats()
        self._plan_keys_seen: set = set()    # recompile-budget denominator
        self._pools: Dict[tuple, _SlotPool] = {}
        self._sessions: Dict[tuple, object] = {}   # key -> RoundSession|None
        self._plan_memo: Dict[int, tuple] = {}     # id(plan) -> (plan,
                                                   #   session, cache_key)
        self._slo = SLOTracker(self.obs.metrics, slo) if slo else None
        if self.obs.quality is not None and self._slo is not None:
            # shadow-recall samples are the only recall observations the SLO
            # windows can get — wire the monitor to feed them
            self.obs.quality.slo = self._slo
        if self.obs.enabled:
            self.obs.install_kernel_hooks()
        # warm the compile for the full-batch bucket (smaller power-of-two
        # buckets compile lazily on first use); warm-up queries are synthetic
        # — keep them out of the shadow-recall sampling stream
        dummy = np.zeros((batch_size, self.index.dataset.dim), np.float32)
        qm = self.obs.quality
        with (qm.paused() if qm is not None else contextlib.nullcontext()):
            self.searcher.search(SearchRequest(queries=dummy))
        if self.continuous:
            # warm the round-step kernels at the slot-pool shape for the
            # default (unfiltered) plan, so serving-time ticks start hot
            plan0 = self.searcher.plan(SearchRequest(queries=dummy[:1]))
            sess0 = self._session_for(plan0)
            if sess0 is not None:
                z = np.zeros((self.slots, dummy.shape[1]), np.float32)
                st = sess0.step(sess0.init(z))
                sess0.finalize(st)
        # recompile watchdog baselined AFTER warm-up, so only serving-time
        # jit-cache growth is judged against the pow2-bucket x plan budget
        self._watch = KernelWatch(self.obs.metrics) \
            if self.obs.metrics.enabled else None

    def _bucket(self, n: int) -> int:
        """Smallest power-of-two >= n, capped at batch_size — the fixed set
        of compiled batch shapes (at most log2(batch_size)+1 executables, so
        varying queue depths never trigger a fresh jit compile)."""
        return min(next_pow2(max(n, 1)), self.batch_size)

    # -------------------------------------------- plan-layer pass-throughs
    @property
    def mutable(self) -> Optional[MutableIndex]:
        return self.searcher.mutable

    @property
    def index(self) -> ProximaIndex:
        """Current base index — always the mutable's latest after any
        consolidation (including capacity-forced ones inside insert)."""
        return self.searcher.index

    @property
    def cfg(self) -> SearchConfig:
        return self.searcher.cfg

    @property
    def metric(self) -> str:
        return self.searcher.metric

    @property
    def filter_cfg(self):
        return self.searcher.filter_cfg

    @property
    def attributes(self):
        return self.searcher.attributes

    @property
    def tiled(self):
        return self.searcher.tiled

    @property
    def corpus(self):
        return self.searcher.corpus

    @property
    def num_tiles(self) -> int:
        return self.searcher.num_tiles

    @property
    def shard_policy(self):
        return self.searcher.shard_policy

    @property
    def probe_tiles(self) -> int:
        return self.searcher.probe_tiles

    @property
    def stats(self) -> dict:
        """Back-compat dict view, derived from the structured
        ``EngineStats`` with the planner's plan-cache counters merged in
        at read time (the planner owns the cache; nothing is hand-synced)."""
        d = self._stats.as_dict()
        d.update(self.searcher.plan_cache_stats())
        return d

    def slo_status(self) -> dict:
        """Per-tenant rolling-window SLO state (empty without ``slo=``)."""
        return self._slo.status() if self._slo is not None else {}

    # --------------------------------------------------------------- requests
    def submit(self, query: np.ndarray, filter: Optional[FilterSpec] = None,
               tenant: Optional[str] = None) -> int:
        """Queue one query; ``filter`` (a hashable ``FilterSpec``) restricts
        results to attribute-passing nodes. The request's ``QueryPlan`` is
        compiled here (plan-cache hit for every repeated spec) and requests
        batch by its cache key — ``tenant`` is part of that key, so tenants
        never co-batch and their latency/recall account separately (SLO
        tracking, quality labels)."""
        rid = self._next
        self._next += 1
        if filter is not None and getattr(filter, "is_all", False):
            filter = None                 # all-pass spec == unfiltered batch
        q = np.asarray(query, np.float32)
        obs = self.obs
        with obs.tracer.span("plan-lookup", rid=rid):
            try:
                plan = self.searcher.plan(SearchRequest(queries=q,
                                                        filter=filter,
                                                        tenant=tenant))
            except RuntimeError:
                # missing attribute store: accept the request and surface the
                # error at flush time, like the legacy engine did
                plan = None
        self.queue.append(Request(rid=rid, query=q,
                                  t_submit=time.perf_counter(),
                                  filter=filter, tenant=tenant, plan=plan))
        if obs.enabled:
            # queue residency is an async span: many requests overlap, so a
            # synchronous nested span on one track cannot represent it
            obs.tracer.async_begin("queue-wait", rid)
            obs.metrics.gauge("queue_depth", float(len(self.queue)))
        return rid

    def insert(self, vector: np.ndarray, attrs=None) -> int:
        """Streaming insert; returns the stable external id. Visible to every
        query flushed after this call. ``attrs`` is the new vector's
        attribute row when the index carries an attribute store."""
        if self.mutable is None:
            raise RuntimeError("engine serves a frozen index — wrap it in "
                               "stream.MutableIndex for online updates")
        if self.continuous and self.mutable.delta_full:
            # this insert WILL consolidate (delta at capacity): complete
            # in-flight merged lanes first — they traverse the base corpus
            # whose id space the consolidation is about to rebuild
            self._complete_merged_pools()
        before = self.mutable.stats["consolidations"]
        ext = self.mutable.insert(vector, attrs=attrs)  # may consolidate
        consolidated = self.mutable.stats["consolidations"] - before
        if consolidated and self.continuous:
            self._reset_merged_sessions()
        self._stats.consolidations += consolidated
        self._stats.inserts += 1
        return ext

    def delete(self, ext_id: int) -> bool:
        """Streaming delete (tombstone). Filtered from every later flush."""
        if self.mutable is None:
            raise RuntimeError("engine serves a frozen index — wrap it in "
                               "stream.MutableIndex for online updates")
        ok = self.mutable.delete(ext_id)
        if ok:
            self._stats.deletes += 1
        return ok

    # ------------------------------------------------------------- scheduling
    def _flush_due(self) -> bool:
        """Full batch, or the OLDEST QUEUED request has waited ``flush_us``.

        The timeout is anchored to the head request's submit time, not the
        last flush: after an idle gap the first request of a new burst must
        still wait its full window for batch-mates (measuring from the last
        flush made it flush immediately in a batch of 1, defeating
        batching). An empty->nonempty enqueue restarts the clock naturally —
        the new head carries a fresh ``t_submit``."""
        if len(self.queue) >= self.batch_size:
            return True
        return (
            bool(self.queue)
            and (time.perf_counter() - self.queue[0].t_submit) * 1e6
            >= self.flush_us
        )

    def step(self, force: bool = False) -> List[Request]:
        """Advance the engine; returns completed requests.

        Batch mode: run one plan-homogeneous batch if due (full bucket or
        flush timeout).  Continuous mode: one scheduler tick — admit queued
        requests into free slots, advance every in-flight lane ONE traversal
        round, retire lanes that quiesced; plans without a steppable spine
        flush through the batch path when due.  In streaming mode,
        consolidation triggers between batches/ticks."""
        if self.continuous:
            return self._tick(force)
        return self._step_batch(force)

    def _step_batch(self, force: bool = False) -> List[Request]:
        """Run one batch if due; returns completed requests.

        Batches are homogeneous in PLAN: the flush takes the head request's
        plan cache key and gathers (in FIFO order) only requests sharing it
        — one compiled execution serves the whole batch. Other-plan
        requests keep their place at the front of the queue for the next
        flush. With uniform filters (the common case, and every unfiltered
        workload) this is plain FIFO batching."""
        if not (force and self.queue) and not self._flush_due():
            return []
        head = self.queue[0]
        plan = head.plan
        if plan is None:             # deferred planning error (e.g. filter
            plan = self.searcher.plan(  # without a store) raises HERE
                SearchRequest(queries=head.query, filter=head.filter,
                              tenant=head.tenant))
            # planning succeeded after all — cache the plan back onto the
            # head and every queued same-filter request, so they batch under
            # the real cache key and are never re-planned on later flushes
            head.plan = plan
            for r in self.queue:
                if r.plan is None and r.filter == head.filter \
                        and r.tenant == head.tenant:
                    r.plan = plan

        def _key(r: Request):
            return r.plan.cache_key if r.plan is not None \
                else ("unplanned", r.filter)

        key = plan.cache_key
        obs = self.obs
        with obs.tracer.span("batch", kind=plan.kind,
                             strategy=plan.strategy) as bsp:
            with obs.tracer.span("batch-assembly"):
                batch: List[Request] = []
                skipped: List[Request] = []
                while self.queue and len(batch) < self.batch_size:
                    r = self.queue.popleft()
                    (batch if _key(r) == key else skipped).append(r)
                self.queue.extendleft(reversed(skipped))
                n = len(batch)
                t_assembled = time.perf_counter()
                if obs.enabled:
                    for r in batch:
                        # the request leaves the queue here — close its
                        # async residency span and bill queue-wait
                        obs.tracer.async_end("queue-wait", r.rid)
                        obs.metrics.observe(
                            "queue_wait_ms",
                            (t_assembled - r.t_submit) * 1e3,
                            kind=plan.kind, strategy=plan.strategy,
                            tenant=plan.tenant,
                        )
                q = np.stack([r.query for r in batch])
                bucket = self._bucket(n)
                if n < bucket:  # pad to the bucket's compiled shape
                    q = np.concatenate(
                        [q, np.zeros((bucket - n, q.shape[1]), np.float32)]
                    )
            ex = self.searcher.execute(plan, q)   # kernel-execute span inside
            now = time.perf_counter()
            with obs.tracer.span("post-process"):
                ids, dists = ex.ids, ex.dists
                if plan.spec is not None:
                    self._stats.filtered_queries += n
                if plan.kind == "flat" and plan.strategy == "scan":
                    self._stats.filter_scan_batches += 1
                for i, r in enumerate(batch):
                    r.ids, r.dists, r.t_done = ids[i], dists[i], now
                    self.done[r.rid] = r
                    if obs.enabled:
                        obs.metrics.observe(
                            "request_latency_ms", r.latency_ms,
                            kind=plan.kind, strategy=plan.strategy,
                            tenant=plan.tenant,
                        )
                    if self._slo is not None:
                        self._slo.record_latency(plan.tenant, r.latency_ms)
                if obs.quality is not None:
                    # off-path shadow-recall sampling over the batch's
                    # UNPADDED rows (also feeds the SLO recall windows)
                    obs.quality.observe(self.searcher, plan, q[:n], ids[:n])
                if self._slo is not None:
                    self._stats.slo_violations = self._slo.total_violations
            if obs.enabled:
                bsp.set(queries=n, bucket=bucket)
                obs.metrics.gauge("batch_occupancy", n / bucket)
                obs.metrics.observe("batch_occupancy_hist", n / bucket,
                                    kind=plan.kind)
                obs.metrics.gauge("queue_depth", float(len(self.queue)))
            if obs.nand_billing:
                with obs.tracer.span("nand-billing"):
                    from repro.plan.request import SearchResult
                    pres = SearchResult(
                        ids=ex.ids, dists=ex.dists,
                        stats=self.searcher.planner.stats_for(plan, ex),
                        plan=plan, raw=ex.raw,
                    )
                    record_plan_execution(
                        obs.metrics, pres,
                        index=self.mutable if self.mutable is not None
                        else self._index_or_none(),
                        nand=self.nand, batch_queries=n,
                        n_queues=self.nand_queues,
                    )
        # running MEAN pad fraction over all batches (a sum would grow
        # without bound and read as >100% padding after a few batches)
        b = self._stats.batches
        self._stats.pad_fraction = (
            self._stats.pad_fraction * b + (bucket - n) / bucket
        ) / (b + 1)
        self._stats.batches = b + 1
        self._stats.queries += n
        if self._watch is not None:
            self._plan_keys_seen.add(key)
            self._watch.sample()
            # the pow2-bucket contract as a LIVE assertion: at most
            # log2(batch)+1 compiled shapes per distinct executed plan
            buckets = int(math.log2(next_pow2(self.batch_size))) + 1
            self._watch.check(buckets * len(self._plan_keys_seen))
        if (
            self.auto_consolidate
            and self.mutable is not None
            and self.mutable.needs_consolidation()
        ):
            self.consolidate()
        return batch

    # ----------------------------------------------- continuous (tick) mode
    def _plan_entry(self, plan: Optional[QueryPlan]):
        """(session, cache_key) for a plan — None session when the plan has
        no round-steppable spine.  Memoized by plan object IDENTITY: the
        planner's plan cache hands out one ``QueryPlan`` per cache key, so
        the admission scan resolves a queued request with one dict lookup
        instead of re-hashing its config/spec tuple every tick.  The memo
        entry holds the plan itself, keeping the id stable."""
        if plan is None:
            return None, None
        entry = self._plan_memo.get(id(plan))
        if entry is None:
            key = plan.cache_key
            if key not in self._sessions:
                self._sessions[key] = \
                    self.searcher.planner.round_session(plan)
            entry = (plan, self._sessions[key], key)
            self._plan_memo[id(plan)] = entry
        return entry[1], entry[2]

    def _session_for(self, plan: Optional[QueryPlan]):
        """Cached ``RoundSession`` for a plan (None when the plan has no
        round-steppable spine — also cached, so the planner is asked once
        per cache key)."""
        return self._plan_entry(plan)[0]

    def inflight(self) -> int:
        """Lanes currently mid-traversal across every slot pool."""
        return sum(p.occupied for p in self._pools.values())

    def _admit(self, pool: _SlotPool, admissions: List[tuple]) -> None:
        """Fill freed slots: init a full-pool state for the refill queries
        and per-lane-select it into the live state (fixed shapes — one
        compiled init/step per pool, regardless of how many slots refill)."""
        dim = self.index.dataset.dim if self._index_or_none() is not None \
            else len(admissions[0][1].query)
        S = len(pool.requests)
        qmat = np.zeros((S, dim), np.float32)
        refill = np.zeros((S,), bool)
        for slot, r in admissions:
            qmat[slot] = r.query
            refill[slot] = True
            pool.requests[slot] = r
        fresh = pool.session.init(qmat)
        state = fresh if pool.state is None \
            else _select_lanes(refill, fresh, pool.state)
        occupied = np.array([r is not None for r in pool.requests])
        pool.state = _quiet_free_lanes(state, occupied)

    def _refill(self) -> None:
        """Admit queued requests into free slots, FIFO, creating slot pools
        per plan cache key on first use.  Requests whose plan is unplanned
        (deferred planning error) or not round-steppable stay queued for the
        batch-flush fallback."""
        if not self.queue:
            return
        obs = self.obs
        admitted: Dict[tuple, List[tuple]] = {}
        remaining: Deque[Request] = deque()
        now = time.perf_counter()
        # per-pool free-slot budget: a full pool rejects its requests with
        # one dict lookup (no O(slots) slot scan per queued request), so a
        # deep backlog costs the tick a cheap identity-memo pass, not
        # repeated plan-key hashing
        free = {k: len(p.requests) - p.occupied
                for k, p in self._pools.items()}
        while self.queue:
            r = self.queue.popleft()
            sess, key = self._plan_entry(r.plan)
            if sess is None:
                remaining.append(r)
                continue
            pool = self._pools.get(key)
            if pool is None:
                pool = _SlotPool(session=sess,
                                 requests=[None] * self.slots)
                self._pools[key] = pool
                free[key] = self.slots
            if free[key] <= 0:
                remaining.append(r)          # pool full — wait for retires
                continue
            taken = {s for s, _ in admitted.get(key, ())}
            slot = next((i for i, req in enumerate(pool.requests)
                         if req is None and i not in taken), None)
            if slot is None:
                remaining.append(r)
                continue
            free[key] -= 1
            admitted.setdefault(key, []).append((slot, r))
            if obs.enabled:
                obs.tracer.async_end("queue-wait", r.rid)
                obs.metrics.observe(
                    "queue_wait_ms", (now - r.t_submit) * 1e3,
                    kind=r.plan.kind, strategy=r.plan.strategy,
                    tenant=r.plan.tenant,
                )
        self.queue = remaining
        for key, admissions in admitted.items():
            self._admit(self._pools[key], admissions)
            self._plan_keys_seen.add(key)

    def _step_pool(self, pool: _SlotPool) -> List[Request]:
        """ONE round over a pool's lanes; finalize + hand back every lane
        that quiesced.  Retired batches bill through the NAND model exactly
        like flushed ones (``RoundSession.complete`` returns the same
        plan-layer result shape)."""
        obs = self.obs
        plan = pool.session.plan
        pool.state = pool.session.step(pool.state)
        if obs.convergence is not None:
            # per-round telemetry for every occupied lane — live requests
            # grow the same learned-ET dataset the off-line driver collects
            occ = [i for i, r in enumerate(pool.requests) if r is not None]
            if occ:
                pool.session.record_round(
                    obs.convergence,
                    [pool.requests[i].rid for i in occ],
                    pool.state, select=occ)
        active = pool.session.active(pool.state)
        rows = [i for i, r in enumerate(pool.requests)
                if r is not None and not active[i]]
        if not rows:
            return []
        idx = np.asarray(rows)
        bucket = next_pow2(len(rows))      # pad rows to a pow2 gather shape
        pad = np.full((bucket,), rows[0], np.int64)
        pad[: len(rows)] = rows
        core = pool.session.finalize(_gather_rows(pool.state, pad))
        core_rows = type(core)(*(np.asarray(f)[: len(rows)] for f in core))
        qrows = np.stack([pool.requests[i].query for i in rows])
        rounds = pool.session.rounds(pool.state)[idx]
        with obs.tracer.span("retire", kind=plan.kind,
                             strategy=plan.strategy, lanes=len(rows)):
            pres = pool.session.complete(qrows, core_rows)
        now = time.perf_counter()
        completed: List[Request] = []
        for j, i in enumerate(rows):
            r = pool.requests[i]
            r.ids, r.dists, r.t_done = pres.ids[j], pres.dists[j], now
            self.done[r.rid] = r
            pool.requests[i] = None
            completed.append(r)
            if obs.enabled:
                obs.metrics.observe(
                    "request_latency_ms", r.latency_ms, kind=plan.kind,
                    strategy=plan.strategy, tenant=plan.tenant,
                )
                obs.metrics.observe("rounds_in_flight", float(rounds[j]),
                                    kind=plan.kind, strategy=plan.strategy)
            if self._slo is not None:
                self._slo.record_latency(plan.tenant, r.latency_ms)
            if obs.convergence is not None:
                obs.convergence.finalize_lane(r.rid, int(rounds[j]))
        if obs.quality is not None:
            obs.quality.observe(self.searcher, plan, qrows, pres.ids)
        if self._slo is not None:
            self._stats.slo_violations = self._slo.total_violations
        if plan.spec is not None:
            self._stats.filtered_queries += len(rows)
        self._stats.retired += len(rows)
        self._stats.queries += len(rows)
        if obs.nand_billing:
            with obs.tracer.span("nand-billing"):
                record_plan_execution(
                    obs.metrics, pres,
                    index=self.mutable if self.mutable is not None
                    else self._index_or_none(),
                    nand=self.nand, batch_queries=len(rows),
                    n_queues=self.nand_queues,
                )
        return completed

    def _tick(self, force: bool = False) -> List[Request]:
        """One scheduler tick: refill free slots from the queue, advance
        every occupied pool one traversal round, retire quiesced lanes.
        Requests the round-step path cannot serve flush through the batch
        path when due (or on ``force``)."""
        obs = self.obs
        completed: List[Request] = []
        with obs.tracer.span("tick"):
            self._refill()
            for key, pool in self._pools.items():
                if pool.occupied == 0:
                    continue
                completed.extend(self._step_pool(pool))
                if obs.enabled:
                    obs.metrics.gauge("slot_occupancy",
                                      pool.occupied / len(pool.requests),
                                      kind=pool.session.plan.kind,
                                      strategy=pool.session.plan.strategy)
        self._stats.ticks += 1
        if obs.enabled:
            obs.metrics.gauge("queue_depth", float(len(self.queue)))
        # non-steppable head (tiled/distributed/scan plans, deferred
        # planning errors): serve it through the batch-flush path
        if self.queue and self._session_for(self.queue[0].plan) is None \
                and (force or self._flush_due()):
            n0 = self._stats.batches
            completed.extend(self._step_batch(force=force))
            self._stats.fallback_batches += self._stats.batches - n0
        elif self._watch is not None:
            self._watch.sample()
            # continuous pools gather-finalize at pow2 buckets up to the
            # slot count, so the budget widens to max(batch, slots)
            width = max(self.batch_size, self.slots)
            buckets = int(math.log2(next_pow2(width))) + 1
            self._watch.check(buckets * max(len(self._plan_keys_seen), 1))
        if (
            self.auto_consolidate
            and self.mutable is not None
            and self.mutable.needs_consolidation()
        ):
            self.consolidate()
        return completed

    def _complete_merged_pools(self) -> List[Request]:
        """Run every in-flight MERGED lane to completion (they traverse the
        pre-consolidation base corpus, whose id space is about to be
        rebuilt).  Retired requests land in ``done`` as usual."""
        out: List[Request] = []
        for key, pool in self._pools.items():
            if pool.session.plan.kind != "merged":
                continue
            guard = self.cfg.max_rounds + 2
            while pool.occupied and guard:
                out.extend(self._step_pool(pool))
                guard -= 1
        return out

    def _reset_merged_sessions(self) -> None:
        """Drop merged sessions + pools — they pin the pre-consolidation
        corpus/masks.  Fresh ones are created on the next admit."""
        for key in [k for k, p in self._pools.items()
                    if p.session.plan.kind == "merged"]:
            del self._pools[key]
        for key in [k for k, s in self._sessions.items()
                    if s is not None and s.plan.kind == "merged"]:
            del self._sessions[key]
        self._plan_memo = {i: e for i, e in self._plan_memo.items()
                           if e[2] in self._sessions}

    def _index_or_none(self):
        """Served base index, or None for raw-corpus targets (those carry no
        NAND geometry; billing then counts the batch as unbilled)."""
        try:
            idx = self.index
        except AttributeError:
            return None
        return idx

    def consolidate(self) -> None:
        """Fold the delta segment into a rebuilt base index.  In continuous
        mode, in-flight merged lanes complete first — their states reference
        the old base id space."""
        if self.mutable is None:
            return
        self._complete_merged_pools()
        self.mutable.consolidate()
        self._reset_merged_sessions()
        self._stats.consolidations += 1

    def drain(self, max_steps: Optional[int] = None) -> List[Request]:
        """Force-run until the queue (and, in continuous mode, every
        in-flight lane) is empty.  Bounded: a plan that cannot make progress
        raises instead of spinning forever.  The default budget is generous
        — batch mode completes >= 1 request per forced step; a continuous
        lane finishes within ``max_rounds`` ticks."""
        out: List[Request] = []
        if max_steps is None:
            pending = len(self.queue) + self.inflight()
            per = (self.cfg.max_rounds + 2) if self.continuous else 2
            max_steps = per * (pending + 1) + 16
        steps = 0
        while self.queue or (self.continuous and self.inflight()):
            if steps >= max_steps:
                raise RuntimeError(
                    f"drain() exceeded {max_steps} steps with "
                    f"{len(self.queue)} queued and {self.inflight()} "
                    "in-flight — a plan that cannot execute (or a stuck "
                    "lane) is spinning the loop"
                )
            out.extend(self.step(force=True))
            steps += 1
        return out
