"""Embedding-corpus retrieval backed by Proxima — the integration point
between the model zoo and the paper's technique.

Any architecture's encoder output can feed the index; ``EmbeddingRetriever``
takes an embedding function (e.g. a VLM backbone over patch embeddings, or
an LM's final hidden state) plus a corpus, builds the Proxima index offline,
and serves kNN queries through the batched engine.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.configs.base import (
    DatasetConfig, GraphConfig, PQConfig, ProximaConfig, SearchConfig,
)
from repro.core.dataset import Dataset, exact_knn
from repro.core.index import ProximaIndex, build_index


class EmbeddingRetriever:
    def __init__(
        self,
        embeddings: np.ndarray,          # (N, D) corpus embeddings
        metric: str = "angular",
        pq_subvectors: Optional[int] = None,
        max_degree: int = 32,
        hot_fraction: float = 0.03,
        search: Optional[SearchConfig] = None,
    ):
        n, d = embeddings.shape
        m = pq_subvectors or max(
            mm for mm in (8, 16, 25, 32) if d % mm == 0
        )
        # num_queries is a placeholder until the first query() — the true
        # batch size is only known at call time and is patched in there
        cfg = ProximaConfig(
            dataset=DatasetConfig(name="corpus", num_base=n, num_queries=1,
                                  dim=d, metric=metric),
            pq=PQConfig(num_subvectors=m, num_centroids=min(256, max(n // 4, 16))),
            graph=GraphConfig(max_degree=max_degree,
                              build_list_size=2 * max_degree),
            search=search or SearchConfig(k=10, list_size=64, t_init=16,
                                          t_step=8, repetition_rate=2,
                                          beta=1.06),
            hot_node_fraction=hot_fraction,
        )
        queries = embeddings[:1]
        ds = Dataset(
            base=np.asarray(embeddings, np.float32),
            queries=np.asarray(queries, np.float32),
            gt=exact_knn(queries, embeddings, min(10, n), metric),
            metric=metric,
            config=cfg.dataset,
        )
        self.index: ProximaIndex = build_index(cfg, dataset=ds,
                                               reorder_samples=64)

    def query(self, q: np.ndarray, k: int = 10):
        from repro.core import graph_search
        import dataclasses as dc

        qb = np.atleast_2d(np.asarray(q, np.float32))
        # keep the dataset metadata truthful for batched queries: the config
        # travels with NAND traces and checkpoint manifests, so it must
        # reflect the batch actually searched, not a build-time placeholder
        if self.index.config.dataset.num_queries != qb.shape[0]:
            ds_cfg = dc.replace(self.index.config.dataset,
                                num_queries=qb.shape[0])
            self.index.config = dc.replace(self.index.config, dataset=ds_cfg)
            self.index.dataset.config = ds_cfg
        cfg = dc.replace(self.index.config.search, k=k)
        res = graph_search(self.index.corpus(), qb, cfg,
                           self.index.dataset.metric)
        ids = np.asarray(res.ids)
        # map back to pre-reorder corpus ids
        if self.index.reordering is not None:
            ids = self.index.reordering.inv[np.clip(ids, 0, None)]
        return ids, np.asarray(res.dists)
