"""repro.serve subpackage — the batched serving engine, built on the
query-plan layer (``repro.plan.Searcher``)."""
from repro.serve.engine import EngineStats, Request, ServingEngine

__all__ = ["EngineStats", "Request", "ServingEngine"]
