"""repro.serve subpackage."""
