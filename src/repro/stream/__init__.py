"""Streaming mutable-index subsystem: online insert/delete over a frozen
Proxima base index.

  * ``delta``    — append-only in-memory segment with an incrementally
                   maintained Vamana-style graph (greedy search + robust
                   prune per insert, reverse-edge patching).
  * ``mutable``  — MutableIndex: base index + delta segment + tombstones,
                   with ``consolidate()`` merging the delta into a rebuilt
                   base (re-running reorder / hot-node / gap-encode).
  * ``searcher`` — merged search: compiled fixed-shape base search + small
                   delta search, top-k fused by accurate distance with
                   tombstone filtering.
"""
from repro.stream.delta import DeltaSegment
from repro.stream.mutable import MutableIndex
from repro.stream.searcher import (
    MergedResult, merged_search_kernel, search_merged,
)
from repro.stream.stitch import StitchResult, stitch_segments

__all__ = ["DeltaSegment", "MutableIndex", "MergedResult",
           "merged_search_kernel", "search_merged",
           "StitchResult", "stitch_segments"]
