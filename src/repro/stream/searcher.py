"""Merged search over base index + delta segment with tombstone filtering.

The compiled fixed-shape JAX base search runs untouched (same shapes it was
jitted for); the base is merely over-fetched by ``StreamConfig.base_overfetch``
candidates so tombstoned hits can be dropped without losing recall. The delta
segment is searched host-side (it is DRAM-resident and small by construction),
and the two candidate streams are fused per query by *accurate* distance —
both paths score with the same metric, so the merge is a plain top-k.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import numpy as np

from repro.configs.base import SearchConfig
from repro.core.search import SearchResult, search


class MergedResult(NamedTuple):
    ids: np.ndarray             # (Q, k) external ids, -1 padded
    dists: np.ndarray           # (Q, k) accurate distances, +inf padded
    base: SearchResult          # raw base-segment result (NAND trace input)
    delta_candidates: np.ndarray  # (Q,) delta candidates considered


def search_merged(
    mutable,
    queries: np.ndarray,
    cfg: Optional[SearchConfig] = None,
) -> MergedResult:
    cfg = cfg or mutable.base.config.search
    k = cfg.k
    k_base = min(cfg.list_size, k + mutable.stream_cfg.base_overfetch)
    base_cfg = dataclasses.replace(cfg, k=k_base) if k_base != k else cfg

    q = np.atleast_2d(np.asarray(queries, np.float32))
    res = search(mutable.corpus(), q, base_cfg, mutable.metric)
    base_ids = np.asarray(res.ids)                    # (Q, k_base) internal
    base_d = np.asarray(res.dists)

    valid = (base_ids >= 0) & np.isfinite(base_d)
    ext = mutable.ext_base[np.clip(base_ids, 0, None)]  # (Q, k_base)
    dead = mutable.tombstone_mask(ext)
    keep = valid & ~dead
    base_d = np.where(keep, base_d, np.inf)
    ext = np.where(keep, ext, -1)

    nq = q.shape[0]
    out_ids = np.full((nq, k), -1, np.int64)
    out_d = np.full((nq, k), np.inf, np.float32)
    n_delta = np.zeros((nq,), np.int32)
    delta = mutable.delta
    delta_ext = np.asarray(mutable.delta_ext, np.int64)
    for i in range(nq):
        cand_ids, cand_d = ext[i], base_d[i]
        if len(delta):
            # same tombstone slack as the base path: deleted delta vectors
            # must not crowd live ones out of the candidate set
            dl_ids, dl_d = delta.search(
                q[i], k + mutable.stream_cfg.base_overfetch
            )
            n_delta[i] = len(dl_ids)
            if len(dl_ids):
                dl_ext = delta_ext[dl_ids]
                alive = ~mutable.tombstone_mask(dl_ext)
                cand_ids = np.concatenate([cand_ids, dl_ext[alive]])
                cand_d = np.concatenate([cand_d, dl_d[alive]])
        order = np.argsort(cand_d, kind="stable")[:k]
        got = min(k, int(np.isfinite(cand_d[order]).sum()))
        out_ids[i, :got] = cand_ids[order][:got]
        out_d[i, :got] = cand_d[order][:got]
    return MergedResult(ids=out_ids, dists=out_d, base=res,
                        delta_candidates=n_delta)
