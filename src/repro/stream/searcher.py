"""Merged search over base index + delta segment with tombstone filtering.

The compiled fixed-shape JAX base search runs untouched (same shapes it was
jitted for); the base is merely over-fetched by ``StreamConfig.base_overfetch``
candidates so tombstoned hits can be dropped without losing recall. The delta
segment is searched host-side in one batched call for the whole query batch
(it is DRAM-resident and small by construction), and the two candidate
streams are fused by *accurate* distance in a single vectorized tombstone
mask + row-wise top-k — both paths score with the same metric, so the merge
is a plain argsort. Result ids are int32, matching the base path.

When the mutable index is configured with ``num_tiles > 1`` the base segment
runs channel-parallel (``shard.sharded_search`` over per-tile graphs, with
its own cross-tile merge); the delta segment ALWAYS stays a single global
structure — it models the DRAM-resident write buffer in front of the NAND
channels, not NAND-resident data.

Filtered queries (``filter_spec``): the base traversal runs under the
COMBINED filter ∧ ¬tombstone admission mask (``MutableIndex.filter_masks``)
— selectivity-adaptive on the single-tile path (masked traversal or bitmap
PQ scan), per-tile mask slices with zero-pass tile skipping on the tiled
path — and delta candidates are filtered by the same ext-id mask alongside
the tombstone check before the cross-segment merge.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Union

import numpy as np

from repro.configs.base import SearchConfig, upgrade_config
from repro.core.search import SearchResult, graph_search


class MergedResult(NamedTuple):
    ids: np.ndarray             # (Q, k) int32 external ids, -1 padded
                                # (same dtype as the base path's ids)
    dists: np.ndarray           # (Q, k) accurate distances, +inf padded
    base: Union[SearchResult, object]  # raw base result; with a tiled base
                                # this is shard.ShardedSearchResult (its
                                # .per_tile counters feed the NAND model)
    delta_candidates: np.ndarray  # (Q,) delta candidates considered
    selectivity: float = 1.0    # base admission-mask passing fraction
                                # (1.0 unfiltered) — the plan layer's
                                # billing input for merged executions
    base_mode: str = "none"     # realized base filter regime: none |
                                # traversal | scan | empty — scan's
                                # candidate stream is the passing subset
                                # itself, which the NAND pushdown billing
                                # must not discount


def merged_search_kernel(
    mutable,
    queries: np.ndarray,
    cfg: Optional[SearchConfig] = None,
    probe_tiles: Optional[int] = None,
    filter_spec=None,
) -> MergedResult:
    """Base + delta merge KERNEL — the ``merged`` execution spine of a
    ``repro.plan.QueryPlan`` (the admission mask depends on the live
    tombstone set, so the filter regime is re-decided here per call)."""
    full_cfg = upgrade_config(mutable.base.config)
    cfg = cfg or full_cfg.search
    k = cfg.k
    k_base = min(cfg.list_size, k + mutable.stream_cfg.base_overfetch)
    base_cfg = dataclasses.replace(cfg, k=k_base) if k_base != k else cfg

    base_mask = ext_mask = None
    if filter_spec is not None and not getattr(filter_spec, "is_all", False):
        base_mask, ext_mask = mutable.filter_masks(filter_spec)
    fcfg = full_cfg.filter

    q = np.atleast_2d(np.asarray(queries, np.float32))
    base_mode = "none" if base_mask is None else "traversal"
    if getattr(mutable, "num_tiles", 1) > 1:
        from repro.shard.search import sharded_search_kernel

        # tiled base: per-tile ids come back already mapped to the base
        # index's global (reordered-internal) id space, so the external-id
        # and tombstone plumbing below is identical to the single-tile path
        node_masks = None
        tiled = mutable.tiled_corpus()
        tiled_cfg = base_cfg
        if base_mask is not None:
            from repro.filter import adapt_search_cfg, tile_node_masks

            node_masks = tile_node_masks(tiled.tile_ids, base_mask)
            tiled_cfg = adapt_search_cfg(
                base_cfg, float(base_mask.mean()), fcfg
            )
        res = sharded_search_kernel(tiled, q, tiled_cfg, mutable.metric,
                                    probe_tiles=probe_tiles,
                                    node_masks=node_masks)
    elif base_mask is not None:
        from repro.plan.planner import flat_filtered_search

        # selectivity-adaptive base path (masked traversal / bitmap PQ scan)
        # through the plan layer's shared regime-decision point
        fres = flat_filtered_search(mutable.corpus(), q, base_mask, base_cfg,
                                    mutable.metric, filter_cfg=fcfg)
        base_mode, res = fres.mode, fres.result
    else:
        res = graph_search(mutable.corpus(), q, base_cfg, mutable.metric)
    out_ids, out_d, n_delta = _merge_base_delta(
        mutable, q, np.asarray(res.ids), np.asarray(res.dists), ext_mask, k
    )
    return MergedResult(
        ids=out_ids, dists=out_d, base=res, delta_candidates=n_delta,
        selectivity=1.0 if base_mask is None else float(base_mask.mean()),
        base_mode=base_mode,
    )


def _merge_base_delta(
    mutable,
    q: np.ndarray,
    base_ids: np.ndarray,
    base_d: np.ndarray,
    ext_mask,
    k: int,
):
    """Cross-segment fusion half of the merged kernel: map base-internal ids
    to external ids, drop tombstoned / non-passing hits, search the delta
    segment once for the batch, and top-k merge the two candidate streams by
    accurate distance.  Factored out of ``merged_search_kernel`` so the
    continuous-batching retire path (which produces ``base_ids``/``base_d``
    through the round-step kernels, lane by lane) can fuse retired rows
    against the live delta/tombstone state without re-running the base
    search.  Returns ``(ids, dists, delta_candidates)``."""
    valid = (base_ids >= 0) & np.isfinite(base_d)
    ext = mutable.ext_base[np.clip(base_ids, 0, None)]  # (Q, k_base)
    dead = mutable.tombstone_mask(ext)
    keep = valid & ~dead
    if ext_mask is not None:
        # belt-and-braces: the traversal already admitted only passing
        # nodes, but the combined filter ∧ tombstone mask is re-applied on
        # external ids so the merge invariant holds by construction
        keep &= ext_mask[np.clip(ext, 0, None)]
    base_d = np.where(keep, base_d, np.inf)
    ext = np.where(keep, ext, -1)

    nq = q.shape[0]
    delta = mutable.delta
    cand_ids, cand_d = ext, base_d                    # (Q, k_base)
    n_delta = np.zeros((nq,), np.int32)
    if len(delta):
        # one batched delta search for the whole query batch, with the same
        # tombstone slack as the base path: deleted delta vectors must not
        # crowd live ones out of the candidate set
        dl_ids, dl_d = delta.search_batch(
            q, k + mutable.stream_cfg.base_overfetch
        )                                             # (Q, k_delta)
        delta_ext = np.asarray(mutable.delta_ext, np.int64)
        dl_ext = np.where(
            dl_ids >= 0, delta_ext[np.clip(dl_ids, 0, None)], -1
        )
        alive = (dl_ids >= 0) & ~mutable.tombstone_mask(dl_ext)
        if ext_mask is not None:
            # same combined mask on the delta stream: deleted OR
            # non-passing delta vectors must not reach the merge
            alive &= ext_mask[np.clip(dl_ext, 0, None)]
        n_delta = (dl_ids >= 0).sum(1).astype(np.int32)
        cand_ids = np.concatenate(
            [cand_ids, np.where(alive, dl_ext, -1)], axis=1
        )
        cand_d = np.concatenate(
            [cand_d, np.where(alive, dl_d, np.inf)], axis=1
        )
    # vectorized cross-segment merge: one row-wise stable argsort, top-k
    if cand_d.shape[1] < k:                           # degenerate list_size < k
        pad = k - cand_d.shape[1]
        cand_ids = np.pad(cand_ids, ((0, 0), (0, pad)), constant_values=-1)
        cand_d = np.pad(cand_d, ((0, 0), (0, pad)), constant_values=np.inf)
    order = np.argsort(cand_d, axis=1, kind="stable")[:, :k]
    out_d = np.take_along_axis(cand_d, order, 1).astype(np.float32)
    out_ids = np.take_along_axis(cand_ids, order, 1).astype(np.int32)
    out_ids = np.where(np.isfinite(out_d), out_ids, np.int32(-1))
    return out_ids, out_d, n_delta


def search_merged(
    mutable,
    queries: np.ndarray,
    cfg: Optional[SearchConfig] = None,
    probe_tiles: Optional[int] = None,
    filter_spec=None,
) -> MergedResult:
    """DEPRECATED entry point — builds a ``repro.plan.SearchRequest`` over
    the mutable index and delegates to the ``Searcher`` facade (which calls
    ``merged_search_kernel`` with identical arguments, so results are
    bit-identical)."""
    from repro.plan import Searcher, SearchRequest
    from repro.plan.searcher import warn_legacy

    warn_legacy("stream.search_merged")
    # probe_tiles=None meant "no routing" here (the engine, not this entry
    # point, used to resolve the config default) — pin 0 to preserve that
    s = Searcher.open(mutable, cfg=cfg,
                      probe_tiles=0 if probe_tiles is None else probe_tiles)
    return s.search(SearchRequest(queries=queries, filter=filter_spec)).raw
