"""Merged search over base index + delta segment with tombstone filtering.

The compiled fixed-shape JAX base search runs untouched (same shapes it was
jitted for); the base is merely over-fetched by ``StreamConfig.base_overfetch``
candidates so tombstoned hits can be dropped without losing recall. The delta
segment is searched host-side (it is DRAM-resident and small by construction),
and the two candidate streams are fused per query by *accurate* distance —
both paths score with the same metric, so the merge is a plain top-k.

When the mutable index is configured with ``num_tiles > 1`` the base segment
runs channel-parallel (``shard.sharded_search`` over per-tile graphs, with
its own cross-tile merge); the delta segment ALWAYS stays a single global
structure — it models the DRAM-resident write buffer in front of the NAND
channels, not NAND-resident data.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Union

import numpy as np

from repro.configs.base import SearchConfig
from repro.core.search import SearchResult, search


class MergedResult(NamedTuple):
    ids: np.ndarray             # (Q, k) external ids, -1 padded
    dists: np.ndarray           # (Q, k) accurate distances, +inf padded
    base: Union[SearchResult, object]  # raw base result; with a tiled base
                                # this is shard.ShardedSearchResult (its
                                # .per_tile counters feed the NAND model)
    delta_candidates: np.ndarray  # (Q,) delta candidates considered


def search_merged(
    mutable,
    queries: np.ndarray,
    cfg: Optional[SearchConfig] = None,
    probe_tiles: Optional[int] = None,
) -> MergedResult:
    cfg = cfg or mutable.base.config.search
    k = cfg.k
    k_base = min(cfg.list_size, k + mutable.stream_cfg.base_overfetch)
    base_cfg = dataclasses.replace(cfg, k=k_base) if k_base != k else cfg

    q = np.atleast_2d(np.asarray(queries, np.float32))
    if getattr(mutable, "num_tiles", 1) > 1:
        from repro.shard import sharded_search

        # tiled base: per-tile ids come back already mapped to the base
        # index's global (reordered-internal) id space, so the external-id
        # and tombstone plumbing below is identical to the single-tile path
        res = sharded_search(mutable.tiled_corpus(), q, base_cfg,
                             mutable.metric, probe_tiles=probe_tiles)
    else:
        res = search(mutable.corpus(), q, base_cfg, mutable.metric)
    base_ids = np.asarray(res.ids)                    # (Q, k_base) internal
    base_d = np.asarray(res.dists)

    valid = (base_ids >= 0) & np.isfinite(base_d)
    ext = mutable.ext_base[np.clip(base_ids, 0, None)]  # (Q, k_base)
    dead = mutable.tombstone_mask(ext)
    keep = valid & ~dead
    base_d = np.where(keep, base_d, np.inf)
    ext = np.where(keep, ext, -1)

    nq = q.shape[0]
    out_ids = np.full((nq, k), -1, np.int64)
    out_d = np.full((nq, k), np.inf, np.float32)
    n_delta = np.zeros((nq,), np.int32)
    delta = mutable.delta
    delta_ext = np.asarray(mutable.delta_ext, np.int64)
    for i in range(nq):
        cand_ids, cand_d = ext[i], base_d[i]
        if len(delta):
            # same tombstone slack as the base path: deleted delta vectors
            # must not crowd live ones out of the candidate set
            dl_ids, dl_d = delta.search(
                q[i], k + mutable.stream_cfg.base_overfetch
            )
            n_delta[i] = len(dl_ids)
            if len(dl_ids):
                dl_ext = delta_ext[dl_ids]
                alive = ~mutable.tombstone_mask(dl_ext)
                cand_ids = np.concatenate([cand_ids, dl_ext[alive]])
                cand_d = np.concatenate([cand_d, dl_d[alive]])
        order = np.argsort(cand_d, kind="stable")[:k]
        got = min(k, int(np.isfinite(cand_d[order]).sum()))
        out_ids[i, :got] = cand_ids[order][:got]
        out_d[i, :got] = cand_d[order][:got]
    return MergedResult(ids=out_ids, dists=out_d, base=res,
                        delta_candidates=n_delta)
