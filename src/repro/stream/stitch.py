"""Cross-segment stitching: patch per-segment graphs into ONE navigable
global graph with the streaming insert machinery.

The segmented builder (``core.segmented``) emits S independent graphs over
contiguous global-id blocks — block-diagonal, mutually unreachable.  This
module replays ``stream.DeltaSegment``'s insert recipe across segment
boundaries: segments join the union one at a time, and each joining
segment's boundary ANCHORS (its entry point, a slice of its hot prefix, and
a random sample) are greedy-searched against the already-stitched union,
their neighbour lists merged with the cross-segment candidates through the
Vamana robust-prune rule, and the kept cross edges reverse-patched
(re-pruning rows that overflow ``max_degree``) — exactly
``DeltaSegment.insert`` with a whole segment playing the delta.

The greedy-search list is density-compensated (``build_list_size`` scaled by
the segment count, the same rule tile graphs use) so stitch edges span the
global geometry, not one segment's local sample.  A final BFS check repairs
any vertex the anchor edges left unreachable (NSG-style, reusing
``core.graph._ensure_connected``).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import BuildConfig, GraphConfig
from repro.core.dataset import pairwise_dist
from repro.core.graph import (
    Graph,
    _ensure_connected,
    _greedy_search_np,
    _pad_rows,
    compensated_build_cfg,
    robust_prune,
)


@dataclass
class StitchResult:
    """The stitched global graph plus the patch accounting the NAND build
    model bills (every patched row is an adjacency re-program)."""
    graph: Graph                 # GLOBAL built ids, all segments reachable
    anchors: np.ndarray          # (A,) global ids used as stitch anchors
    cross_edges: int             # directed edges between different segments
    patched_rows: int            # adjacency rows rewritten by stitching


def _segment_of(segments) -> np.ndarray:
    """(N,) global id -> segment index."""
    n = sum(s.num_vertices for s in segments)
    out = np.empty(n, np.int32)
    for i, seg in enumerate(segments):
        out[seg.start : seg.start + seg.num_vertices] = i
    return out


def _pick_anchors(seg, sample: int, rng: np.random.Generator) -> np.ndarray:
    """Boundary anchors of one segment, GLOBAL ids: the entry point first
    (every traversal crosses it), then the hot prefix (the highest-traffic
    vertices benefit most from long-range edges), then a random spread."""
    n = seg.num_vertices
    picks = [seg.graph.entry_point]
    picks += [i for i in range(seg.hot_count) if i != seg.graph.entry_point]
    if len(picks) < sample:
        rest = rng.permutation(n)
        picks += [int(i) for i in rest if int(i) not in set(picks)]
    return seg.start + np.asarray(picks[:sample], np.int64)


def stitch_segments(
    segments,
    metric: str,
    graph_cfg: GraphConfig,
    build_cfg: BuildConfig,
) -> StitchResult:
    """Stitch built segments (``core.segmented.IndexSegment``) into one
    global :class:`~repro.core.graph.Graph`."""
    num_segments = len(segments)
    n = sum(s.num_vertices for s in segments)
    r = graph_cfg.max_degree
    alpha = graph_cfg.alpha
    base = np.concatenate([s.base for s in segments])
    seg_of = _segment_of(segments)

    # block-diagonal union: per-segment adjacency offset to global ids
    adj = np.zeros((n, r), np.int32)
    deg = np.zeros((n,), np.int32)
    for seg in segments:
        lo = seg.start
        hi = lo + seg.num_vertices
        adj[lo:hi] = seg.graph.adjacency + lo
        deg[lo:hi] = seg.graph.degrees

    entry = int(segments[0].start + segments[0].graph.entry_point)
    list_size = build_cfg.stitch_list_size or compensated_build_cfg(
        graph_cfg, num_segments, n
    ).build_list_size

    patched: set = set()
    anchors_all: list = []
    rng = np.random.default_rng(graph_cfg.seed)
    # segments join the union one at a time; segment 0 seeds it.  Greedy
    # search can only reach the stitched prefix, so anchor candidates are
    # guaranteed to be cross-segment links into the union.
    for s in range(1, num_segments):
        seg = segments[s]
        anchors = _pick_anchors(seg, build_cfg.stitch_sample, rng)
        anchors_all.append(anchors)
        for a in anchors:
            a = int(a)
            scored, _ = _greedy_search_np(
                base, adj, deg, entry, base[a], metric, list_size
            )
            cross = [v for v, _ in scored if seg_of[v] != s]
            if not cross:
                continue
            row = [int(v) for v in adj[a, : deg[a]]]
            merged = np.asarray(
                list(dict.fromkeys(row + cross)), np.int64
            )
            cd = pairwise_dist(base[a : a + 1], base[merged], metric)[0]
            kept = robust_prune(merged, cd, base, metric, r, alpha)
            adj[a, : len(kept)] = kept
            deg[a] = len(kept)
            patched.add(a)
            # reverse-patch the union side (DeltaSegment._patch_reverse_edge)
            for j in kept:
                if seg_of[j] == s:
                    continue
                dj = int(deg[j])
                row_j = adj[j, :dj]
                if a in row_j:
                    continue
                if dj < r:
                    adj[j, dj] = a
                    deg[j] = dj + 1
                else:
                    merged_j = np.append(row_j, a).astype(np.int64)
                    cdj = pairwise_dist(
                        base[j : j + 1], base[merged_j], metric
                    )[0]
                    kept_j = robust_prune(
                        merged_j, cdj, base, metric, r, alpha
                    )
                    adj[j, : len(kept_j)] = kept_j
                    deg[j] = len(kept_j)
                patched.add(int(j))

    # finalize: ragged rows -> connectivity repair -> padded adjacency
    rows = [[int(v) for v in adj[i, : deg[i]]] for i in range(n)]
    before = {i: list(row) for i, row in enumerate(rows)}
    rows = _ensure_connected(rows, base, metric, entry, r, alpha)
    for i, row in enumerate(rows):
        if row != before[i]:
            patched.add(i)
    padded, degrees = _pad_rows(rows, r, n)

    cross_edges = 0
    for i in range(n):
        cross_edges += int(
            (seg_of[padded[i, : degrees[i]]] != seg_of[i]).sum()
        )
    return StitchResult(
        graph=Graph(
            adjacency=padded, degrees=degrees, entry_point=entry,
            metric=metric,
        ),
        anchors=np.concatenate(anchors_all) if anchors_all
        else np.empty((0,), np.int64),
        cross_edges=cross_edges,
        patched_rows=len(patched),
    )
