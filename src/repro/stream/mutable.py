"""MutableIndex: tombstoned deletes + delta inserts over a frozen base.

External ids are stable across the index's lifetime: the initial base corpus
owns ids ``0..N-1`` (in the base index's reordered space) and every insert
allocates the next id. Deletes mark ids in a tombstone set that the merged
search filters at rerank time; the vectors are physically dropped at the
next ``consolidate()``, which rebuilds the base ``ProximaIndex`` from all
live vectors (re-running PQ, graph build, visit-frequency reordering,
hot-node selection and gap encoding) and empties the delta segment.

Write accounting mirrors what the 3D NAND backend would see: each insert
eventually programs its raw vector + PQ code + adjacency row, and each
consolidation reprograms the whole rebuilt index — the ratio is the
subsystem's write amplification (fed to ``nand.simulator``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.base import ProximaConfig, StreamConfig
from repro.core.dataset import Dataset, exact_knn
from repro.core.index import ProximaIndex, build_index
from repro.stream.delta import DeltaSegment


class MutableIndex:
    def __init__(self, index: ProximaIndex, stream_cfg: Optional[StreamConfig] = None,
                 attributes=None):
        self.base = index
        self.stream_cfg = stream_cfg or index.config.stream
        n = index.dataset.num_base
        # filtered-search attributes, keyed by STABLE EXTERNAL id (row e =
        # attrs of ext id e) so they survive consolidation's internal-id
        # reshuffle untouched. At construction ext ids 0..N-1 coincide with
        # the base index's internal ids, so a store attached to the built
        # index seeds the table directly.
        self.attributes = (
            attributes if attributes is not None
            else getattr(index, "attributes", None)
        )
        if self.attributes is not None and len(self.attributes) != n:
            raise ValueError(
                f"attribute store has {len(self.attributes)} rows, base "
                f"corpus has {n}"
            )
        self.ext_base = np.arange(n, dtype=np.int64)   # base internal -> ext
        self.next_ext = n
        self.delta_ext: list[int] = []                 # delta local -> ext
        self._live_base: set[int] = set(range(n))      # O(1) liveness checks
        self._delta_set: set[int] = set()
        self.tombstones: set[int] = set()
        self._dead_cache: Optional[np.ndarray] = None  # sorted tombstone array
        self._corpus = None
        # multi-channel base serving: the frozen base goes tiled, the delta
        # segment stays global (it is DRAM-resident; see stream.searcher).
        # configs unpickled from pre-shard-layer caches lack .shard —
        # upgrade_config fills every missing section with its default
        from repro.configs.base import upgrade_config

        shard_cfg = upgrade_config(index.config).shard
        self.num_tiles = shard_cfg.num_tiles
        self.shard_policy = shard_cfg.policy
        self._tiled = None
        self._delta = self._new_delta()
        self.stats = {
            "inserts": 0, "deletes": 0, "consolidations": 0,
            "logical_bytes": 0.0, "consolidation_bytes": 0.0,
        }
        from repro.obs import NULL_OBS
        # observability bundle — ``Searcher.open(..., obs=...)`` and the
        # serving engine install a live one; default no-op
        self.obs = NULL_OBS

    def _new_delta(self) -> DeltaSegment:
        return DeltaSegment(
            dim=self.base.dataset.dim,
            metric=self.base.dataset.metric,
            centroids=self.base.codebook.centroids,
            graph_cfg=self.base.config.graph,
            stream_cfg=self.stream_cfg,
        )

    # ------------------------------------------------------------ properties
    @property
    def delta(self) -> DeltaSegment:
        return self._delta

    @property
    def metric(self) -> str:
        return self.base.dataset.metric

    def corpus(self):
        """Cached device-side base corpus (refreshed on consolidation)."""
        if self._corpus is None:
            self._corpus = self.base.corpus()
        return self._corpus

    def set_num_tiles(self, num_tiles: int, policy: Optional[str] = None):
        """Route the base segment through ``num_tiles`` search tiles from the
        next flush on (the delta always stays global)."""
        self.num_tiles = int(num_tiles)
        if policy is not None:
            self.shard_policy = policy
        self._tiled = None

    def tiled_corpus(self):
        """Cached per-tile base corpus; repartitioned after consolidation
        (the rebuilt base has a fresh id space and vertex set)."""
        if self._tiled is None:
            self._tiled, _ = self.base.sharded_corpus(
                self.num_tiles, self.shard_policy
            )
        return self._tiled

    def delta_fraction(self) -> float:
        return len(self._delta) / max(self.base.dataset.num_base, 1)

    def needs_consolidation(self) -> bool:
        return (
            self._delta.full
            or self.delta_fraction() >= self.stream_cfg.consolidate_fraction
        )

    @property
    def delta_full(self) -> bool:
        """True when the next ``insert`` MUST consolidate first (the delta
        segment is at capacity).  The continuous serving engine checks this
        to complete in-flight merged lanes before the base index is rebuilt
        under them."""
        return self._delta.full

    def live_count(self) -> int:
        return (
            self.base.dataset.num_base + len(self.delta_ext)
            - len(self.tombstones)
        )

    def is_live(self, ext_id: int) -> bool:
        if ext_id in self.tombstones:
            return False
        return ext_id in self._live_base or ext_id in self._delta_set

    def tombstone_mask(self, ext_ids: np.ndarray) -> np.ndarray:
        """True where ext_ids are tombstoned. The dead-id array is cached
        across calls (search_merged calls this per query in a batch)."""
        if not self.tombstones:
            return np.zeros(ext_ids.shape, bool)
        if self._dead_cache is None:
            self._dead_cache = np.fromiter(
                self.tombstones, dtype=np.int64, count=len(self.tombstones)
            )
        return np.isin(ext_ids, self._dead_cache)

    def live_vectors(self) -> tuple[np.ndarray, np.ndarray]:
        """(ext_ids, raw vectors) of the *current* corpus — the ground-truth
        population for streaming recall measurements."""
        dead_base = self.tombstone_mask(self.ext_base)
        ids = [self.ext_base[~dead_base]]
        vecs = [self.base.dataset.base[~dead_base]]
        if self.delta_ext:
            dext = np.asarray(self.delta_ext, np.int64)
            alive = ~self.tombstone_mask(dext)
            ids.append(dext[alive])
            vecs.append(self._delta.vecs[: len(self._delta)][alive])
        return np.concatenate(ids), np.concatenate(vecs).astype(np.float32)

    # ---------------------------------------------------------------- filter
    def filter_masks(self, spec) -> tuple[np.ndarray, np.ndarray]:
        """(base_mask, ext_mask) for a ``FilterSpec``: ``ext_mask`` over all
        external ids ever allocated, ``base_mask`` the combined
        filter ∧ ¬tombstone admission mask over the CURRENT base index's
        internal ids (what the masked base traversal consumes)."""
        if self.attributes is None:
            raise RuntimeError(
                "index has no attribute store — pass attributes= to "
                "MutableIndex (or attach one to the base ProximaIndex) "
                "before filtered search"
            )
        ext_mask = self.attributes.mask(spec)           # (next_ext,)
        base_mask = ext_mask[self.ext_base] & ~self.tombstone_mask(self.ext_base)
        return base_mask, ext_mask

    # -------------------------------------------------------------- mutation
    def insert(self, vec: np.ndarray, attrs=None) -> int:
        """Insert a vector (and, when the index carries an attribute store,
        its attribute row — required so filters stay total over the live
        corpus)."""
        attr_row = None
        if self.attributes is not None:
            if attrs is None:
                raise ValueError(
                    "index carries an attribute store; insert(vec, "
                    "attrs=...) must provide the new vector's attributes"
                )
            # validate BEFORE any mutation: a malformed row must not leave
            # a live vector without its attribute entry
            attr_row = self.attributes.coerce_row(attrs)
        if self._delta.full:
            self.consolidate()
        self._delta.insert(vec)
        ext = self.next_ext
        self.next_ext += 1
        self.delta_ext.append(ext)
        self._delta_set.add(ext)
        if attr_row is not None:
            row = self.attributes.append(attr_row)
            assert row == ext, "attribute rows must track external ids"
        self.stats["inserts"] += 1
        self.stats["logical_bytes"] += self._delta.logical_bytes_per_insert()
        if self.obs.enabled:
            self.obs.metrics.counter("stream_inserts")
            self.obs.metrics.gauge("delta_fraction", self.delta_fraction())
        return ext

    def delete(self, ext_id: int) -> bool:
        """Tombstone an external id; False if already dead or never existed."""
        if not self.is_live(ext_id):
            return False
        self.tombstones.add(int(ext_id))
        self._dead_cache = None
        self.stats["deletes"] += 1
        return True

    def consolidate(self, reorder_samples: int = 64) -> ProximaIndex:
        """Merge delta + base into a rebuilt single-segment index."""
        if self.obs.enabled:
            import time as _time
            t0 = _time.perf_counter()
            with self.obs.tracer.span("consolidate", cat="stream",
                                      live=self.live_count()):
                out = self._consolidate(reorder_samples)
            self.obs.metrics.observe(
                "consolidate_ms", (_time.perf_counter() - t0) * 1e3)
            self.obs.metrics.counter("stream_consolidations")
            return out
        return self._consolidate(reorder_samples)

    def _consolidate(self, reorder_samples: int = 64) -> ProximaIndex:
        ext_ids, vecs = self.live_vectors()
        from repro.configs.base import upgrade_config

        cfg = upgrade_config(self.base.config)
        new_n = int(vecs.shape[0])
        ds_cfg = dataclasses.replace(
            cfg.dataset, num_base=new_n, num_queries=1,
        )
        # keep the kNN build neighbourhood proportional to corpus density:
        # when the corpus grows past the build list size, every kNN list
        # turns purely local and the graph loses its natural long-range
        # (inter-cluster) edges — greedy search then cannot navigate out of
        # the entry point's neighbourhood and recall collapses
        graph_cfg = cfg.graph
        old_n = cfg.dataset.num_base
        if new_n > old_n:
            scaled = int(np.ceil(cfg.graph.build_list_size * new_n / old_n))
            graph_cfg = dataclasses.replace(cfg.graph, build_list_size=scaled)
        new_cfg = dataclasses.replace(cfg, dataset=ds_cfg, graph=graph_cfg)
        queries = vecs[:1]
        ds = Dataset(
            base=vecs,
            queries=queries,
            gt=exact_knn(queries, vecs, min(10, vecs.shape[0]), self.metric),
            metric=self.metric,
            config=ds_cfg,
        )
        new_index = build_index(new_cfg, dataset=ds,
                                reorder_samples=reorder_samples)
        if new_index.reordering is not None:
            self.ext_base = ext_ids[new_index.reordering.inv]
        else:
            self.ext_base = ext_ids
        self.base = new_index
        self._corpus = None
        self._tiled = None
        self._delta = self._new_delta()
        self.delta_ext = []
        self._live_base = set(int(e) for e in self.ext_base)
        self._delta_set = set()
        self.tombstones = set()
        self._dead_cache = None
        self.stats["consolidations"] += 1
        self.stats["consolidation_bytes"] += float(
            new_index.index_bytes()["total_bytes"]
        )
        return new_index

    # ------------------------------------------------------------ accounting
    def write_amplification(self) -> float:
        """NAND bytes programmed / logical bytes inserted (>= 1)."""
        logical = self.stats["logical_bytes"]
        if logical <= 0:
            return 1.0
        return (logical + self.stats["consolidation_bytes"]) / logical

    # ---------------------------------------------------------------- search
    def search(self, queries: np.ndarray, cfg=None, filter_spec=None):
        from repro.stream.searcher import merged_search_kernel

        return merged_search_kernel(self, queries, cfg,
                                    filter_spec=filter_spec)
