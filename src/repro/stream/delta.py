"""Append-only delta segment with an incrementally maintained Vamana graph.

Freshly inserted vectors live here (DRAM-resident, unlike the NAND-resident
base corpus) until ``MutableIndex.consolidate()`` folds them into a rebuilt
base index. Each insert runs the faithful Vamana update from
``core.graph.build_incremental``: greedy-search the current delta graph from
its entry point, robust-prune the visited set into the new vertex's
neighbour list, then patch reverse edges (re-pruning rows that overflow
``max_degree``). Vectors are also PQ-encoded against the *frozen* base
codebook so consolidation and the NAND write model know the exact bytes the
segment will eventually program.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import GraphConfig, StreamConfig
from repro.core.dataset import pairwise_dist
from repro.core.graph import _greedy_search_np, robust_prune


def encode_np(vecs: np.ndarray, centroids: np.ndarray) -> np.ndarray:
    """Host-side PQ encode (no JAX dispatch — called once per insert).
    vecs (B, D), centroids (M, C, dsub) -> (B, M) uint8."""
    m, _, dsub = centroids.shape
    subs = vecs.reshape(vecs.shape[0], m, dsub)
    d = ((subs[:, :, None, :] - centroids[None]) ** 2).sum(-1)  # (B, M, C)
    return np.argmin(d, axis=-1).astype(np.uint8)


class DeltaSegment:
    """In-memory mutable segment. Ids are *local* (0..count-1); the owning
    MutableIndex maps them to stable external ids."""

    def __init__(
        self,
        dim: int,
        metric: str,
        centroids: np.ndarray,          # frozen base PQ codebook (M, C, dsub)
        graph_cfg: GraphConfig,
        stream_cfg: StreamConfig,
    ):
        self.metric = metric
        self.graph_cfg = graph_cfg
        self.stream_cfg = stream_cfg
        self.centroids = centroids
        cap = stream_cfg.delta_capacity
        r = graph_cfg.max_degree
        self.vecs = np.zeros((cap, dim), np.float32)
        self.codes = np.zeros((cap, centroids.shape[0]), np.uint8)
        self.adjacency = np.zeros((cap, r), np.int32)
        self.degrees = np.zeros((cap,), np.int32)
        self.count = 0
        self.entry_point = 0

    def __len__(self) -> int:
        return self.count

    @property
    def full(self) -> bool:
        return self.count >= self.vecs.shape[0]

    # ------------------------------------------------------------- mutation
    def insert(self, vec: np.ndarray) -> int:
        """Vamana-style incremental insert; returns the local id."""
        if self.full:
            raise RuntimeError("delta segment full — consolidate first")
        v = np.asarray(vec, np.float32).reshape(-1)
        if self.metric == "angular":
            v = v / max(float(np.linalg.norm(v)), 1e-12)
        i = self.count
        self.vecs[i] = v
        self.codes[i] = encode_np(v[None], self.centroids)[0]
        r, alpha = self.graph_cfg.max_degree, self.graph_cfg.alpha
        if i > 0:
            scored, _ = _greedy_search_np(
                self.vecs, self.adjacency, self.degrees, self.entry_point,
                v, self.metric, self.stream_cfg.delta_list_size,
            )
            cand = np.asarray([u for u, _ in scored], dtype=np.int64)
            cd = np.asarray([d for _, d in scored], dtype=np.float32)
            kept = robust_prune(cand, cd, self.vecs, self.metric, r, alpha)
            self.adjacency[i, : len(kept)] = kept
            self.degrees[i] = len(kept)
            for j in kept:
                self._patch_reverse_edge(j, i)
        self.count = i + 1
        return i

    def _patch_reverse_edge(self, j: int, i: int) -> None:
        """Add edge j -> i, re-pruning row j if it overflows max_degree."""
        dj = int(self.degrees[j])
        row = self.adjacency[j, :dj]
        if i in row:
            return
        r, alpha = self.graph_cfg.max_degree, self.graph_cfg.alpha
        if dj < r:
            self.adjacency[j, dj] = i
            self.degrees[j] = dj + 1
            return
        merged = np.append(row, i).astype(np.int64)
        cd = pairwise_dist(self.vecs[j : j + 1], self.vecs[merged],
                           self.metric)[0]
        kept = robust_prune(merged, cd, self.vecs, self.metric, r, alpha)
        self.adjacency[j, : len(kept)] = kept
        self.degrees[j] = len(kept)

    # --------------------------------------------------------------- search
    def _brute_force(self) -> bool:
        """Exact scan while the segment is tiny (one shared regime switch for
        the single-query and batched paths — they must never diverge)."""
        return self.count <= self.stream_cfg.brute_force_below

    def search(self, query: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
        """Top-k over the segment by accurate distance. Brute force while the
        segment is tiny; greedy graph search once it pays off. Returns
        (local_ids, dists), both length <= k."""
        if self.count == 0:
            return (np.empty((0,), np.int32), np.empty((0,), np.float32))
        q = np.asarray(query, np.float32).reshape(-1)
        if self._brute_force() or self.count <= k:
            ids, d = self.search_batch(q[None], k)   # the one brute-force path
            got = int((ids[0] >= 0).sum())
            return ids[0, :got], d[0, :got]
        if self.metric == "angular":
            q = q / max(float(np.linalg.norm(q)), 1e-12)
        scored, _ = _greedy_search_np(
            self.vecs, self.adjacency, self.degrees, self.entry_point,
            q, self.metric, max(self.stream_cfg.delta_list_size, k),
        )
        top = scored[:k]
        return (
            np.asarray([u for u, _ in top], np.int32),
            np.asarray([d for _, d in top], np.float32),
        )

    def search_batch(self, queries: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
        """Batched top-k over the segment: (Q, k) local ids (-1 padded) and
        distances (+inf padded). The brute-force regime — the common case,
        the segment is tiny between consolidations — is one vectorized
        distance matrix over ALL queries; only the graph regime walks per
        query (a host-side greedy search has no batch form)."""
        q = np.atleast_2d(np.asarray(queries, np.float32))
        nq = q.shape[0]
        out_ids = np.full((nq, k), -1, np.int32)
        out_d = np.full((nq, k), np.inf, np.float32)
        if self.count == 0:
            return out_ids, out_d
        if self._brute_force() or self.count <= k:
            d = pairwise_dist(q, self.vecs[: self.count], self.metric)
            got = min(k, self.count)
            order = np.argsort(d, axis=1, kind="stable")[:, :got]
            out_ids[:, :got] = order.astype(np.int32)
            out_d[:, :got] = np.take_along_axis(d, order, 1).astype(np.float32)
            return out_ids, out_d
        for i in range(nq):
            ids_i, d_i = self.search(q[i], k)
            out_ids[i, : len(ids_i)] = ids_i
            out_d[i, : len(d_i)] = d_i
        return out_ids, out_d

    # ---------------------------------------------------------- accounting
    def logical_bytes_per_insert(self) -> float:
        """Bytes one insert eventually programs into NAND (same formula the
        analytic NAND update model uses)."""
        from repro.nand.simulator import logical_insert_bytes

        return logical_insert_bytes(
            dim=self.vecs.shape[1], pq_bits=8 * self.codes.shape[1],
            r_degree=self.graph_cfg.max_degree, index_bits=32,
        )
