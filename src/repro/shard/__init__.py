"""Multi-channel corpus sharding — the paper's data-allocation scheme
(§IV-E/§V) as a serving-stack layer: the corpus is partitioned into P tiles
(one per NAND channel group), each tile carries its own proximity graph and
entry point, hot nodes and PQ centroids are replicated on every tile, and a
query fans out to all tiles in parallel before a cross-tile top-k merge."""
from repro.shard.partition import (
    TiledCorpus, TilePartition, partition_index, tiles_from_segments,
)
from repro.shard.search import (
    ShardedSearchResult,
    cross_tile_merge,
    route_queries,
    sharded_search,
    sharded_search_kernel,
)

__all__ = [
    "TiledCorpus",
    "TilePartition",
    "partition_index",
    "tiles_from_segments",
    "ShardedSearchResult",
    "cross_tile_merge",
    "route_queries",
    "sharded_search",
    "sharded_search_kernel",
]
