"""Corpus partitioner: split a built ``ProximaIndex`` into P search tiles.

This is the paper's *optimized data allocation scheme* turned into an explicit
serving abstraction. Each tile models one NAND channel group and holds:

  * a **partition** of the cold vertices (contiguous / hash / cluster-aware
    assignment — the allocation trade-off of §IV-E),
  * a **replica** of the hot nodes (global ids ``< hot_count`` after
    visit-frequency reordering) and of the PQ centroids — the paper
    replicates exactly the high-traffic data so every channel serves it from
    a local read,
  * its **own proximity graph** over the tile's vertex set with a per-tile
    entry point (each channel runs the unmodified Algorithm-1 engine against
    purely local addresses; no cross-channel fetch on the traversal path).

Tiles are padded to a common vertex count so the per-tile search fan-out is a
single fixed-shape JAX program over a leading tile axis. Padding rows are
unreachable (no real vertex links to them) and carry ``tile_ids == -1``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import GraphConfig
from repro.core.graph import build_graph, compensated_build_cfg

POLICIES = ("contiguous", "hash", "cluster")


class TiledCorpus(NamedTuple):
    """Device-side stacked per-tile search structures (leading axis = tile).

    ``adjacency``/``codes``/``base`` are tile-local; ``tile_ids`` maps local
    row -> global id in the built index's (reordered) space, -1 for padding.
    ``centroids`` is the replicated global PQ codebook. ``hot_counts[p]``
    vertices at the head of every tile are the replicated hot nodes.
    """
    adjacency: jnp.ndarray      # (P, Nt, R) int32, tile-local ids
    codes: jnp.ndarray          # (P, Nt, M) uint8
    base: jnp.ndarray           # (P, Nt, D) f32 (normalized for angular)
    centroids: jnp.ndarray      # (M, C, dsub) f32 — replicated
    entry_points: jnp.ndarray   # (P,) int32 tile-local entry vertex
    hot_counts: jnp.ndarray     # (P,) int32 replicated-hot prefix length
    tile_ids: jnp.ndarray       # (P, Nt) int32 local -> global, -1 padding
    tile_centroids: jnp.ndarray # (P, D) f32 mean of each tile's cold
                                # vectors — the query router's coarse index

    @property
    def num_tiles(self) -> int:
        return self.adjacency.shape[0]


@dataclass
class TilePartition:
    """Host-side partition metadata (benchmark / accounting view)."""
    policy: str
    num_tiles: int
    hot_count: int                    # replicated prefix (global ids < this)
    tile_of_cold: np.ndarray          # (N - hot_count,) tile of each cold id
    tile_sizes: np.ndarray            # (P,) vertices per tile incl. replicas

    @property
    def imbalance(self) -> float:
        """max/mean tile size — 1.0 is perfectly balanced."""
        return float(self.tile_sizes.max() / max(self.tile_sizes.mean(), 1))

    def replicated_fraction(self, num_vertices: int) -> float:
        """Extra storage from hot-node replication, relative to the corpus."""
        extra = (self.num_tiles - 1) * self.hot_count
        return extra / max(num_vertices, 1)


def _kmeans_labels(x: np.ndarray, k: int, seed: int, iters: int = 8) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    cent = x[rng.choice(n, size=min(k, n), replace=False)].astype(np.float64)
    labels = np.zeros(n, np.int64)
    for _ in range(iters):
        d = (
            (x * x).sum(-1)[:, None] - 2.0 * x @ cent.T
            + (cent * cent).sum(-1)[None, :]
        )
        labels = d.argmin(1)
        for c in range(len(cent)):
            m = labels == c
            if m.any():
                cent[c] = x[m].mean(0)
    return labels


def assign_cold(
    base_cold: np.ndarray,
    num_tiles: int,
    policy: str,
    seed: int = 0,
) -> np.ndarray:
    """(Nc,) tile index for every cold vertex, by allocation policy.

    * ``contiguous`` — blocks of consecutive (visit-frequency-ordered) ids;
      preserves locality of the reordering, cheapest to program.
    * ``hash`` — round-robin ``i % P``; the paper's core-level address
      interleaving, best static load balance.
    * ``cluster`` — k-means clusters greedily bin-packed onto tiles; keeps
      geometric neighbourhoods on one channel so per-tile graphs stay dense.
    """
    nc = base_cold.shape[0]
    if policy == "contiguous":
        return np.minimum(
            np.arange(nc) * num_tiles // max(nc, 1), num_tiles - 1
        ).astype(np.int32)
    if policy == "hash":
        return (np.arange(nc) % num_tiles).astype(np.int32)
    if policy == "cluster":
        k = min(max(4 * num_tiles, num_tiles), max(nc, 1))
        labels = _kmeans_labels(base_cold.astype(np.float64), k, seed)
        sizes = np.bincount(labels, minlength=k)
        tile_of_cluster = np.zeros(k, np.int32)
        load = np.zeros(num_tiles, np.int64)
        for c in np.argsort(-sizes):          # big clusters first
            t = int(load.argmin())
            tile_of_cluster[c] = t
            load[t] += sizes[c]
        return tile_of_cluster[labels]
    raise ValueError(f"unknown shard policy {policy!r}; choose from {POLICIES}")


def _is_segment_built(index) -> bool:
    """Duck-type a ``core.segmented.SegmentedIndex`` (per-segment graphs +
    shared codebook, no single flat graph)."""
    return hasattr(index, "segments") and hasattr(index, "codebook") \
        and not hasattr(index, "graph")


def tiles_from_segments(seg_index) -> tuple[TiledCorpus, TilePartition]:
    """Direct-to-tile emission: every built segment IS a channel tile.

    The segmented builder already produced exactly what a tile needs — a
    local-id proximity graph, reordered codes/base, an entry point, a
    centroid — so sharded serving skips the build-flat-then-repartition
    detour (and its per-tile graph REBUILD) entirely.  Segment centroids
    become ``tile_centroids``, the router's IVF-style coarse index.

    Per-segment hot prefixes surface as ``hot_counts`` (hot-hit accounting
    inside each tile) but are NOT replicas: every global id lives on exactly
    one tile, so ``TilePartition.hot_count`` — the replicated-prefix length —
    is 0 and the cross-tile merge's duplicate masking is a no-op.
    """
    segs = seg_index.segments
    p_tiles = len(segs)
    metric = seg_index.metric
    nt = max(s.num_vertices for s in segs)
    r = segs[0].graph.max_degree
    m = segs[0].codes.shape[1]
    d = segs[0].base.shape[1]

    adjacency = np.zeros((p_tiles, nt, r), np.int32)
    codes = np.zeros((p_tiles, nt, m), np.uint8)
    base = np.zeros((p_tiles, nt, d), np.float32)
    tile_ids = np.full((p_tiles, nt), -1, np.int32)
    entries = np.zeros((p_tiles,), np.int32)
    hot_counts = np.zeros((p_tiles,), np.int32)
    tile_cents = np.zeros((p_tiles, d), np.float32)
    tile_of = np.empty((seg_index.num_base,), np.int32)

    for p, seg in enumerate(segs):
        k = seg.num_vertices
        sb = seg.base
        if metric == "angular":
            sb = sb / np.maximum(
                np.linalg.norm(sb, axis=-1, keepdims=True), 1e-12
            )
        adjacency[p, :k] = seg.graph.adjacency
        codes[p, :k] = seg.codes
        base[p, :k] = sb
        tile_ids[p, :k] = seg.start + np.arange(k, dtype=np.int32)
        entries[p] = seg.graph.entry_point
        hot_counts[p] = seg.hot_count
        tile_cents[p] = seg.centroid
        tile_of[seg.start : seg.start + k] = p

    part = TilePartition(
        policy="segments", num_tiles=p_tiles, hot_count=0,
        tile_of_cold=tile_of,
        tile_sizes=np.asarray([s.num_vertices for s in segs], np.int64),
    )
    tiled = TiledCorpus(
        adjacency=jnp.asarray(adjacency),
        codes=jnp.asarray(codes),
        base=jnp.asarray(base),
        centroids=jnp.asarray(seg_index.codebook.centroids),
        entry_points=jnp.asarray(entries),
        hot_counts=jnp.asarray(hot_counts),
        tile_ids=jnp.asarray(tile_ids),
        tile_centroids=jnp.asarray(tile_cents),
    )
    return tiled, part


def partition_index(
    index,
    num_tiles: int | None = None,
    policy: str = "contiguous",
    replicate_hot: bool = True,
    from_segments: bool = False,
) -> tuple[TiledCorpus, TilePartition]:
    """Split a built ``ProximaIndex`` into ``num_tiles`` search tiles.

    Per-tile graphs are rebuilt over each tile's vertex set (hot replicas +
    cold partition) with the index's graph config — the offline cost of the
    channel layout, analogous to the paper's graph-data preloading phase.
    ``num_tiles == 1`` reuses the index's own graph unchanged, so the
    single-tile path is bit-identical to ``index.corpus()``.

    A segment-built index (``core.segmented.SegmentedIndex``, or
    ``from_segments=True``) takes the direct-emission path instead: its
    segments become the tiles verbatim (:func:`tiles_from_segments`), no
    rebuild, ``num_tiles``/``policy`` ignored.
    """
    if from_segments or _is_segment_built(index):
        return tiles_from_segments(index)
    if num_tiles is None:
        raise ValueError("num_tiles is required for a flat ProximaIndex")
    if num_tiles < 1:
        raise ValueError("num_tiles must be >= 1")
    n = index.dataset.num_base
    hot = int(index.hot_count) if replicate_hot else 0
    search_base = index._search_base()        # normalized for angular
    metric = index.dataset.metric

    if num_tiles == 1:
        part = TilePartition(
            policy=policy, num_tiles=1, hot_count=hot,
            tile_of_cold=np.zeros(n - hot, np.int32),
            tile_sizes=np.asarray([n], np.int64),
        )
        tiled = TiledCorpus(
            adjacency=jnp.asarray(index.graph.adjacency)[None],
            codes=jnp.asarray(index.codes)[None],
            base=jnp.asarray(search_base)[None],
            centroids=jnp.asarray(index.codebook.centroids),
            entry_points=jnp.asarray([index.graph.entry_point], jnp.int32),
            hot_counts=jnp.asarray([hot], jnp.int32),
            tile_ids=jnp.asarray(np.arange(n, dtype=np.int32))[None],
            tile_centroids=jnp.asarray(
                search_base.mean(0, keepdims=True), jnp.float32
            ),
        )
        return tiled, part

    cold_ids = np.arange(hot, n)
    # cluster on the SEARCH geometry (normalized for angular) so the tiles,
    # the router centroids and the per-tile searches agree on distances
    tile_of_cold = assign_cold(
        search_base[hot:], num_tiles, policy,
        seed=index.config.dataset.seed,
    )
    tiles_global: List[np.ndarray] = []
    for p in range(num_tiles):
        ids = np.concatenate([
            np.arange(hot, dtype=np.int64),          # replicated hot prefix
            cold_ids[tile_of_cold == p],
        ])
        tiles_global.append(ids)
    sizes = np.asarray([len(t) for t in tiles_global], np.int64)
    if sizes.min() < 2:
        raise ValueError(
            f"num_tiles={num_tiles} with policy={policy!r} leaves a tile "
            f"with {int(sizes.min())} vertices (sizes {sizes.tolist()}); "
            "reduce num_tiles or pick a different policy"
        )
    nt = int(sizes.max())

    r = index.graph.max_degree
    m = index.codes.shape[1]
    d = search_base.shape[1]
    adjacency = np.zeros((num_tiles, nt, r), np.int32)
    codes = np.zeros((num_tiles, nt, m), np.uint8)
    base = np.zeros((num_tiles, nt, d), np.float32)
    tile_ids = np.full((num_tiles, nt), -1, np.int32)
    entries = np.zeros((num_tiles,), np.int32)
    tile_cents = np.zeros((num_tiles, d), np.float32)

    # Density compensation (the inverse of MutableIndex.consolidate's rule);
    # shared with the segmented builder — see core.graph.compensated_build_cfg.
    graph_cfg: GraphConfig = index.config.graph
    for p, ids in enumerate(tiles_global):
        k = len(ids)
        # the k//4 floor covers the cluster policy, whose tiles keep whole
        # geometric clusters at full density: there the P-scaled list can
        # still sit inside one cluster, so tie the neighbourhood to the tile
        # size itself to guarantee inter-cluster reach
        tile_cfg = compensated_build_cfg(graph_cfg, num_tiles, k, floor=k // 4)
        # rebuild the tile's proximity graph over its own vertex set; the
        # graph lives in tile-local ids so the unmodified search engine
        # never emits a cross-channel address
        g = build_graph(index.dataset.base[ids], tile_cfg, metric)
        adjacency[p, :k] = g.adjacency
        entries[p] = g.entry_point
        codes[p, :k] = index.codes[ids]
        base[p, :k] = search_base[ids]
        tile_ids[p, :k] = ids
        # router centroid over the tile's OWN (cold) vertices — replicated
        # hot nodes live everywhere and would wash the centroids together
        own = ids[hot:] if k > hot else ids
        tile_cents[p] = search_base[own].mean(0)

    part = TilePartition(
        policy=policy, num_tiles=num_tiles, hot_count=hot,
        tile_of_cold=tile_of_cold.astype(np.int32), tile_sizes=sizes,
    )
    tiled = TiledCorpus(
        adjacency=jnp.asarray(adjacency),
        codes=jnp.asarray(codes),
        base=jnp.asarray(base),
        centroids=jnp.asarray(index.codebook.centroids),
        entry_points=jnp.asarray(entries),
        hot_counts=jnp.asarray(
            np.full((num_tiles,), hot, np.int32)
        ),
        tile_ids=jnp.asarray(tile_ids),
        tile_centroids=jnp.asarray(tile_cents),
    )
    return tiled, part
