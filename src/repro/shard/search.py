"""Per-tile search fan-out + cross-tile top-k merge.

A query batch is broadcast to every tile; each tile runs the UNMODIFIED
fixed-shape Algorithm-1 engine (``core.search.search``) against its local
graph/codes/base — this is the channel-parallel dataflow: P independent
while-loop searches over identical shapes, vmapped over the leading tile
axis. Tile-local result ids are mapped to global ids through ``tile_ids``
and the P*k candidate streams are fused per query by accurate distance —
through the Pallas bitonic network when ``cfg.use_pallas`` (the ASIC's
shared Bitonic Sorter doing one extra merge pass), else ``lax.top_k``.

Replicated hot nodes surface from several tiles with bit-identical
distances (same base row, same arithmetic); the merge masks those
duplicates before ranking so they cannot crowd the top-k.

Per-tile traversal counters are preserved with their tile axis in
``ShardedSearchResult.per_tile`` — that is the per-channel workload the
NAND simulator consumes (``nand.simulator.simulate_sharded``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SearchConfig
from repro.core.search import (
    Corpus, SearchResult, empty_search_result, graph_search, next_pow2,
)
from repro.shard.partition import TiledCorpus

_obs = None     # Observability bundle (repro.obs) or None — module-wide hook


def set_observability(obs) -> None:
    """Install (or clear) the channel-observability sink — per-flush tile
    load imbalance and skipped-lane counts (``Observability.
    install_kernel_hooks`` wires this alongside the Pallas op hooks)."""
    global _obs
    _obs = obs if obs is not None and getattr(obs, "enabled", False) else None


def _record_channel_stats(res: "ShardedSearchResult") -> None:
    """Per-tile work distribution into the registry (straggler accounting —
    the host-side twin of ``nand.simulate_sharded``'s load_imbalance).
    Forces a device sync on the counters, so it only runs when the hook is
    installed."""
    hops = np.asarray(res.per_tile.n_hops)           # (P, Q)
    per_tile = hops.sum(axis=1).astype(float)        # total work per channel
    mean = per_tile.mean()
    m = _obs.metrics
    m.gauge("tile_load_imbalance",
            float(per_tile.max() / mean) if mean > 0 else 1.0)
    probed = np.asarray(res.probed)
    m.counter("tile_lanes_skipped", float((~probed).sum()))
    m.counter("tile_lanes_served", float(probed.sum()))


class ShardedSearchResult(NamedTuple):
    ids: jnp.ndarray            # (Q, k) int32 GLOBAL ids, -1 padded
    dists: jnp.ndarray          # (Q, k) f32 accurate distances, +inf padded
    per_tile: SearchResult      # every field with a leading (P, ...) tile axis
    probed: jnp.ndarray         # (P, Q) bool — which channels served which
                                # query (all-True under full fan-out)

    @property
    def num_tiles(self) -> int:
        return self.per_tile.ids.shape[0]


def cross_tile_merge(
    ids: jnp.ndarray,           # (Q, C) global candidate ids, -1 invalid
    dists: jnp.ndarray,         # (Q, C) accurate distances
    k: int,
    use_pallas: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fuse per-tile candidate streams into a global top-k per query.

    Duplicate ids (hot-node replicas found by several tiles) keep only their
    first occurrence; invalid and duplicate slots rank as +inf and come back
    as id -1.
    """
    q, c = ids.shape
    eq = ids[:, :, None] == ids[:, None, :]
    lower = jnp.tril(jnp.ones((c, c), bool), k=-1)
    dup = (eq & lower[None]).any(-1)
    key = jnp.where(dup | (ids < 0), jnp.inf, dists)
    if use_pallas:
        from repro.kernels import ops

        pot = next_pow2(c)
        keys = jnp.pad(key, ((0, 0), (0, pot - c)), constant_values=jnp.inf)
        pos = jnp.broadcast_to(
            jnp.pad(jnp.arange(c, dtype=jnp.int32), (0, pot - c)), (q, pot)
        )
        sk, sp = ops.bitonic_sort_pairs(keys, pos)
        out_d, perm = sk[:, :k], sp[:, :k]
        out_ids = jnp.take_along_axis(ids, perm, 1)
    else:
        neg, idx = jax.lax.top_k(-key, k)
        out_d = -neg
        out_ids = jnp.take_along_axis(ids, idx, 1)
    out_ids = jnp.where(jnp.isinf(out_d), -1, out_ids)
    return out_ids, out_d


def _fan_out(tiled: TiledCorpus, queries, cfg: SearchConfig, metric: str,
             use_vmap: bool, node_masks=None) -> SearchResult:
    """Run ``search`` on every tile; results get a leading (P,) axis.
    ``node_masks`` (P, Nt) bool — the filter subsystem's per-tile bitmap
    slices: each tile admits only its passing vertices, and a tile whose
    slice is all-False is skipped outright (zero-pass tile skipping: the
    channel never sees the query)."""
    corpus = Corpus(
        adjacency=tiled.adjacency, codes=tiled.codes, base=tiled.base,
        centroids=tiled.centroids, entry_point=tiled.entry_points,
        hot_count=tiled.hot_counts,
    )
    if use_vmap and node_masks is None:
        axes = Corpus(adjacency=0, codes=0, base=0, centroids=None,
                      entry_point=0, hot_count=0)
        return jax.vmap(
            lambda c, q: graph_search(c, q, cfg, metric), in_axes=(axes, None)
        )(corpus, queries)
    # unrolled fan-out: identical shapes across tiles -> one compiled
    # executable reused P times, and tiles early-terminate independently
    # (the vmapped while_loop cannot; Pallas kernels also skip the extra
    # batching axis this way). Masked fan-out is always unrolled — that is
    # what makes the per-tile zero-pass skip a host-side decision.
    per = []
    for p in range(tiled.num_tiles):
        mask_p = None if node_masks is None else np.asarray(node_masks[p])
        if mask_p is not None and not mask_p.any():
            per.append(empty_search_result(queries.shape[0], cfg.k))
            continue
        per.append(graph_search(
            Corpus(
                adjacency=tiled.adjacency[p], codes=tiled.codes[p],
                base=tiled.base[p], centroids=tiled.centroids,
                entry_point=tiled.entry_points[p],
                hot_count=tiled.hot_counts[p],
            ),
            queries, cfg, metric,
            node_mask=None if mask_p is None else jnp.asarray(mask_p),
        ))
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)


def route_queries(tiled: TiledCorpus, queries: jnp.ndarray,
                  probe_tiles: int, metric: str = "l2") -> jnp.ndarray:
    """(P, Q) bool — the ``probe_tiles`` tiles whose cold-vertex centroid is
    nearest each query. The coarse router in front of the channels (IVF-
    style nprobe); only meaningful with geometry-aware allocation
    (``policy="cluster"``), where a query's neighbours concentrate on few
    tiles."""
    if metric == "angular":
        from repro.core.search import l2_normalize

        queries = l2_normalize(queries)
        cents = l2_normalize(tiled.tile_centroids)
        d = -(queries @ cents.T)                       # (Q, P)
    elif metric == "ip":
        d = -(queries @ tiled.tile_centroids.T)
    else:
        diff = queries[:, None, :] - tiled.tile_centroids[None]
        d = (diff * diff).sum(-1)
    p = tiled.tile_centroids.shape[0]
    nprobe = max(1, min(int(probe_tiles), p))
    _, idx = jax.lax.top_k(-d, nprobe)                 # (Q, nprobe)
    mask = jnp.zeros((queries.shape[0], p), bool)
    mask = mask.at[jnp.arange(queries.shape[0])[:, None], idx].set(True)
    return mask.T                                      # (P, Q)


def sharded_search_kernel(
    tiled: TiledCorpus,
    queries,
    cfg: SearchConfig,
    metric: str = "l2",
    use_vmap: bool | None = None,
    probe_tiles: int | None = None,
    node_masks=None,
) -> ShardedSearchResult:
    """Channel-parallel Proxima search KERNEL: fan out over tiles, merge
    top-k — the ``tiled`` execution spine of a ``repro.plan.QueryPlan``.

    ``use_vmap`` selects the fan-out style; by default the Pallas kernel
    path uses the unrolled loop (kernels stay at their compiled rank) and
    the jnp path vmaps over the tile axis.

    ``probe_tiles`` enables the coarse query router: each query is served
    by only its nearest tiles, the rest of the channels skip it (their
    candidates are masked from the merge and their counters are zeroed for
    that query). Full fan-out (None or 0) trades total work for recall;
    routed probing is what lets throughput scale with the channel count.

    ``node_masks`` (P, Nt) bool — filtered search: per-tile slices of a
    global pass mask (``filter.tile_node_masks``). Tiles whose slice has no
    passing vertex are skipped entirely (zero-pass tile skipping) and
    excluded from the merge like unprobed channels.
    """
    queries = jnp.asarray(np.atleast_2d(np.asarray(queries, np.float32)))
    if use_vmap is None:
        use_vmap = not cfg.use_pallas
    per = _fan_out(tiled, queries, cfg, metric, use_vmap,
                   node_masks=node_masks)
    nt = tiled.num_tiles
    # probe_tiles in {None, 0} -> full fan-out (0 is ShardConfig's default
    # "routing off" value, so config values can be passed straight through)
    if probe_tiles and probe_tiles < nt:
        probed = route_queries(tiled, queries, probe_tiles, metric)
        # a skipped (channel, query) lane did no work: zero its counters so
        # the NAND traces bill only the probed channels
        zeroed = {
            f: jnp.where(probed, getattr(per, f), 0)
            for f in ("n_hops", "n_pq", "n_acc", "n_hot_hops", "n_free_pq",
                      "rounds")
        }
        per = per._replace(**zeroed)
    else:
        probed = jnp.ones((nt, queries.shape[0]), bool)
    if node_masks is not None:
        # zero-pass channels served nothing (their counters are already
        # zero); mark them unprobed so the merge treats them like skipped
        # lanes
        active = jnp.asarray(np.asarray(node_masks, bool).any(axis=1))
        probed = probed & active[:, None]

    # tile-local -> global ids (pads and invalid lanes -> -1)
    gids = jax.vmap(
        lambda tid, ids: jnp.where(
            ids >= 0, tid[jnp.clip(ids, 0, tid.shape[0] - 1)], jnp.int32(-1)
        )
    )(tiled.tile_ids, per.ids)                  # (P, Q, k)
    gids = jnp.where(probed[:, :, None], gids, -1)

    p, q, k = gids.shape
    cand_ids = jnp.transpose(gids, (1, 0, 2)).reshape(q, p * k)
    cand_d = jnp.transpose(per.dists, (1, 0, 2)).reshape(q, p * k)
    cand_d = jnp.where(cand_ids >= 0, cand_d, jnp.inf)
    out_ids, out_d = cross_tile_merge(cand_ids, cand_d, cfg.k,
                                      use_pallas=cfg.use_pallas)
    res = ShardedSearchResult(ids=out_ids, dists=out_d, per_tile=per,
                              probed=probed)
    if _obs is not None:
        _record_channel_stats(res)
    return res


def sharded_search(
    tiled: TiledCorpus,
    queries,
    cfg: SearchConfig,
    metric: str = "l2",
    use_vmap: bool | None = None,
    probe_tiles: int | None = None,
    node_masks=None,
) -> ShardedSearchResult:
    """DEPRECATED entry point — builds a ``repro.plan.SearchRequest`` over
    the tiled target and delegates to the ``Searcher`` facade (which calls
    ``sharded_search_kernel`` with identical arguments, so results are
    bit-identical).  ``node_masks`` are applied verbatim — config
    adaptation stays the caller's job, exactly the legacy semantics."""
    from repro.plan import Searcher, SearchRequest
    from repro.plan.searcher import warn_legacy

    warn_legacy("shard.sharded_search")
    s = Searcher.open(tiled, cfg=cfg, metric=metric, use_vmap=use_vmap,
                      probe_tiles=probe_tiles)
    res = s.search(SearchRequest(queries=queries, node_mask=node_masks))
    return res.raw
