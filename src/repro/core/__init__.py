"""Proxima core: the paper's algorithmic contribution (Algorithm 1 + §III/§IV-E
data-layout optimizations) as composable JAX modules."""
from repro.core.dataset import (
    ArraySegmentSource, Dataset, SyntheticSegmentSource, exact_knn,
    exact_knn_stream, make_dataset, recall_at_k, recall_hits,
    recall_hits_per_query,
)
from repro.core.index import ProximaIndex, build_index, build_index_monolithic
from repro.core.segmented import (
    IndexSegment, SegmentedIndex, build_segmented,
)
from repro.core.search import (
    Corpus, SearchResult, SearchState, finalize_search, graph_search,
    graph_search_step, graph_search_stepped, init_search_state, search,
    search_reference, search_state_active,
)

__all__ = [
    "graph_search",
    "Dataset",
    "exact_knn",
    "make_dataset",
    "recall_at_k",
    "recall_hits",
    "recall_hits_per_query",
    "ProximaIndex",
    "build_index",
    "build_index_monolithic",
    "build_segmented",
    "SegmentedIndex",
    "IndexSegment",
    "ArraySegmentSource",
    "SyntheticSegmentSource",
    "exact_knn_stream",
    "Corpus",
    "SearchResult",
    "SearchState",
    "init_search_state",
    "graph_search_step",
    "graph_search_stepped",
    "finalize_search",
    "search_state_active",
    "search",
    "search_reference",
]
