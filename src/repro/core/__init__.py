"""Proxima core: the paper's algorithmic contribution (Algorithm 1 + §III/§IV-E
data-layout optimizations) as composable JAX modules."""
from repro.core.dataset import Dataset, exact_knn, make_dataset, recall_at_k
from repro.core.index import ProximaIndex, build_index
from repro.core.search import (
    Corpus, SearchResult, graph_search, search, search_reference,
)

__all__ = [
    "graph_search",
    "Dataset",
    "exact_knn",
    "make_dataset",
    "recall_at_k",
    "ProximaIndex",
    "build_index",
    "Corpus",
    "SearchResult",
    "search",
    "search_reference",
]
