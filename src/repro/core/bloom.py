"""Bloom-filter visited set (paper §IV-D).

The ASIC uses a 12 kB SRAM bit array with 8 lightweight hashes (SeaHash) for a
false-positive rate < 0.02% at ~8000 insertions. The TPU-native equivalent is
a packed uint32 bit array carried through the search loop; hashing is
multiplicative (Knuth/SeaHash-style mixers) with up to 8 odd constants —
pure integer ALU ops, fully vectorized.

Functional API (JAX): state in, state out. OR-scatter is emulated with an
idempotent add: per hash plane we sort by target bit position, zero out
duplicate contributions, and add only bits not already present
(``add = bit & ~current``) — exact OR semantics under jit.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# 8 odd multiplicative constants (golden-ratio family, like SeaHash's mixers)
_HASH_MULTS = np.array(
    [
        0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F,
        0x165667B1, 0xD3A2646D, 0xFD7046C5, 0xB55A4F09,
    ],
    dtype=np.uint32,
)


def bloom_init(num_bits: int) -> jnp.ndarray:
    """num_bits must be a power of two (mask-based modulo)."""
    assert num_bits & (num_bits - 1) == 0, "num_bits must be a power of 2"
    return jnp.zeros(num_bits // 32, dtype=jnp.uint32)


def _hash_positions(ids: jnp.ndarray, num_bits: int, num_hashes: int) -> jnp.ndarray:
    """(K,) integer ids -> (K, H) bit positions in [0, num_bits)."""
    x = ids.astype(jnp.uint32)[:, None]
    mults = jnp.asarray(_HASH_MULTS[:num_hashes])[None, :]
    h = x * mults
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x2C1B3C6D)
    h = h ^ (h >> 12)
    return (h & jnp.uint32(num_bits - 1)).astype(jnp.int32)


@partial(jax.jit, static_argnames=("num_hashes",))
def insert(
    bits: jnp.ndarray, ids: jnp.ndarray, mask: jnp.ndarray, num_hashes: int = 8
) -> jnp.ndarray:
    """Insert ``ids`` where ``mask`` is True; returns the new bit array."""
    num_bits = bits.shape[0] * 32
    pos = _hash_positions(ids, num_bits, num_hashes)             # (K, H)
    word = (pos >> 5).astype(jnp.int32)
    bitv = jnp.left_shift(jnp.uint32(1), (pos & 31).astype(jnp.uint32))
    bitv = jnp.where(mask[:, None], bitv, jnp.uint32(0))
    out = bits
    for h in range(num_hashes):                                  # static loop
        k = pos[:, h]
        order = jnp.argsort(k)
        ks = k[order]
        bs = bitv[order, h]
        ws = word[order, h]
        firsts = jnp.concatenate([jnp.array([True]), ks[1:] != ks[:-1]])
        bs = jnp.where(firsts, bs, jnp.uint32(0))                # dedupe plane
        add = bs & ~out[ws]                                      # OR via add
        out = out.at[ws].add(add)
    return out


@partial(jax.jit, static_argnames=("num_hashes",))
def contains(bits: jnp.ndarray, ids: jnp.ndarray, num_hashes: int = 8) -> jnp.ndarray:
    """(K,) bool — True if id *may* have been inserted (no false negatives)."""
    num_bits = bits.shape[0] * 32
    pos = _hash_positions(ids, num_bits, num_hashes)
    word = pos >> 5
    bit = jnp.left_shift(jnp.uint32(1), (pos & 31).astype(jnp.uint32))
    return ((bits[word] & bit) != 0).all(axis=1)


def false_positive_rate(num_bits: int, num_hashes: int, num_inserted: int) -> float:
    """Analytic FPR (paper §IV-D): (1 - e^{-kn/m})^k."""
    k, m, n = num_hashes, num_bits, num_inserted
    return (1.0 - math.exp(-k * n / m)) ** k
