"""Product quantization (paper §III-B, Fig. 5-b).

Vectors are split into M subvectors; each subvector is quantized to one of C
k-means centroids. At query time an Asymmetric Distance Table ADT[m, c] holds
the partial distance between query subvector m and centroid c; the PQ distance
of a database point is the sum of M table lookups (Eq. 3).

Codebook training is host-side (offline, like the paper's k-means); encoding,
ADT construction and distance evaluation are JAX (the hot path — Pallas
kernels in ``repro.kernels`` implement the latter two for TPU).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PQConfig


@dataclass
class PQCodebook:
    centroids: np.ndarray   # (M, C, dsub) float32
    metric: str

    @property
    def num_subvectors(self) -> int:
        return self.centroids.shape[0]

    @property
    def num_centroids(self) -> int:
        return self.centroids.shape[1]

    @property
    def dim(self) -> int:
        return self.centroids.shape[0] * self.centroids.shape[2]

    @property
    def code_bits(self) -> int:
        return self.num_subvectors * int(np.ceil(np.log2(self.num_centroids)))


def _split(x: jnp.ndarray, m: int) -> jnp.ndarray:
    """(..., D) -> (..., M, dsub)."""
    return x.reshape(*x.shape[:-1], m, x.shape[-1] // m)


# ---------------------------------------------------------------------------
# Training (host-side Lloyd k-means, vmapped over subspaces)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("iters",))
def _kmeans_one(sub: jnp.ndarray, init: jnp.ndarray, iters: int) -> jnp.ndarray:
    """Lloyd iterations for one subspace. sub: (N, dsub), init: (C, dsub)."""

    def step(cent, _):
        d = (
            (sub * sub).sum(-1)[:, None]
            - 2.0 * sub @ cent.T
            + (cent * cent).sum(-1)[None, :]
        )
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, cent.shape[0], dtype=sub.dtype)
        counts = onehot.sum(0)
        sums = onehot.T @ sub
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1)[:, None], cent)
        return new, None

    cent, _ = jax.lax.scan(step, init, None, length=iters)
    return cent


def train_pq(data: np.ndarray, cfg: PQConfig, metric: str = "l2") -> PQCodebook:
    n, d = data.shape
    m, c = cfg.num_subvectors, cfg.num_centroids
    if d % m != 0:
        raise ValueError(f"dim {d} not divisible by M={m}")
    rng = np.random.default_rng(cfg.seed)
    x = np.asarray(data, np.float32)
    if metric == "angular":
        x = x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    subs = x.reshape(n, m, d // m).transpose(1, 0, 2)          # (M, N, dsub)
    init_idx = np.stack(
        [rng.choice(n, size=min(c, n), replace=n < c) for _ in range(m)]
    )
    init = subs[np.arange(m)[:, None], init_idx]               # (M, C, dsub)
    cents = jax.vmap(lambda s, i: _kmeans_one(s, i, cfg.kmeans_iters))(
        jnp.asarray(subs), jnp.asarray(init)
    )
    return PQCodebook(centroids=np.asarray(cents), metric=metric)


# ---------------------------------------------------------------------------
# Encoding / ADT / distance (JAX reference; Pallas kernels mirror these)
# ---------------------------------------------------------------------------

@jax.jit
def encode(data: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """(N, D) -> (N, M) uint8 codes (nearest centroid per subspace)."""
    m = centroids.shape[0]
    subs = _split(data, m)                                     # (N, M, dsub)
    d = (
        (subs * subs).sum(-1)[..., None]
        - 2.0 * jnp.einsum("nmd,mcd->nmc", subs, centroids)
        + (centroids * centroids).sum(-1)[None]
    )
    return jnp.argmin(d, axis=-1).astype(jnp.uint8)


@partial(jax.jit, static_argnames=("metric",))
def compute_adt(query: jnp.ndarray, centroids: jnp.ndarray, metric: str = "l2") -> jnp.ndarray:
    """Asymmetric distance table for one query: (M, C).

    l2: ADT[m,c] = ||q_m - cent[m,c]||^2  (sum = squared L2 to the decode)
    ip/angular: ADT[m,c] = -<q_m, cent[m,c]>  (sum = -inner product; angular
    assumes inputs were normalized before PQ training/encoding)
    """
    m = centroids.shape[0]
    qs = _split(query, m)                                      # (M, dsub)
    if metric == "l2":
        return (
            (qs * qs).sum(-1)[:, None]
            - 2.0 * jnp.einsum("md,mcd->mc", qs, centroids)
            + (centroids * centroids).sum(-1)
        )
    return -jnp.einsum("md,mcd->mc", qs, centroids)


@jax.jit
def pq_distance(codes: jnp.ndarray, adt: jnp.ndarray) -> jnp.ndarray:
    """Eq. (3): sum of M ADT lookups. codes (N, M) uint8, adt (M, C) -> (N,)."""
    m = adt.shape[0]
    return adt[jnp.arange(m)[None, :], codes.astype(jnp.int32)].sum(-1)


def decode(codes: np.ndarray, centroids: np.ndarray) -> np.ndarray:
    """Reconstruct approximate vectors from codes (host-side helper)."""
    m, _, dsub = centroids.shape
    out = centroids[np.arange(m)[None, :], codes.astype(np.int64)]  # (N, M, dsub)
    return out.reshape(codes.shape[0], m * dsub)


def calibrate_beta(
    codebook: PQCodebook,
    codes: np.ndarray,
    base: np.ndarray,
    rng: np.random.Generator,
    num_samples: int = 256,
    num_targets: int = 512,
    quantile: float = 0.99,
) -> float:
    """Empirical PQ error ratio beta (paper §III-C: 99% of PQ distances are
    within beta x of accurate distances; SIFT/32B codes -> beta ~= 1.06).

    Samples base vectors as queries, compares PQ vs accurate distances and
    returns the ``quantile`` of accurate/PQ ratio (>=1 means PQ
    underestimates; we guard both sides by taking max(ratio, 1/ratio)).
    """
    from repro.core.dataset import pairwise_dist

    n = base.shape[0]
    qi = rng.choice(n, size=min(num_samples, n), replace=False)
    ti = rng.choice(n, size=min(num_targets, n), replace=False)
    q = base[qi]
    if codebook.metric == "angular":
        q = q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    acc = pairwise_dist(q, base[ti], codebook.metric)          # (S, T)
    cents = jnp.asarray(codebook.centroids)
    adts = jax.vmap(lambda qq: compute_adt(qq, cents, codebook.metric))(jnp.asarray(q))
    sub_codes = jnp.asarray(codes[ti])
    approx = jax.vmap(lambda a: pq_distance(sub_codes, a))(adts)  # (S, T)
    approx = np.asarray(approx)
    # shift to positive for ratio stability (ip/angular distances are negative)
    shift = min(acc.min(), approx.min())
    acc_s = acc - shift + 1e-3
    app_s = approx - shift + 1e-3
    ratio = np.maximum(acc_s / app_s, app_s / acc_s)
    return float(np.quantile(ratio, quantile))
