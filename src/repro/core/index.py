"""End-to-end Proxima index construction pipeline.

dataset -> PQ codebook/codes -> proximity graph -> visit-frequency reordering
-> hot-node selection -> gap encoding -> device Corpus.

This is the offline "graph data preloading" phase of the paper (§IV-B); the
resulting ``ProximaIndex`` carries both the host-side artifacts (for the NAND
model and benchmarks) and the device-side ``Corpus`` (for JAX search).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ProximaConfig
from repro.core import pq as pq_mod
from repro.core.dataset import Dataset, make_dataset
from repro.core.gap_encoding import GapEncodedGraph, gap_encode
from repro.core.graph import Graph, build_graph
from repro.core.reorder import (
    Reordering,
    remap_ground_truth,
    reorder_segment,
)
from repro.core.search import Corpus, l2_normalize


@dataclass
class ProximaIndex:
    config: ProximaConfig
    dataset: Dataset                 # arrays in *reordered* id space
    graph: Graph
    codebook: pq_mod.PQCodebook
    codes: np.ndarray                # (N, M) uint8, reordered
    gap: Optional[GapEncodedGraph]
    reordering: Optional[Reordering]
    calibrated_beta: float
    # per-node attribute store for the filtered-search subsystem, keyed by
    # the CURRENT (reordered) internal ids; attach via
    # ``repro.filter.attach_attributes`` (workload data, not built here)
    attributes: Optional[object] = None

    @property
    def hot_count(self) -> int:
        return self.reordering.hot_count if self.reordering else 0

    def corpus(self) -> Corpus:
        """Device-side search structures."""
        return Corpus(
            adjacency=jnp.asarray(self.graph.adjacency),
            codes=jnp.asarray(self.codes),
            base=jnp.asarray(self._search_base()),
            centroids=jnp.asarray(self.codebook.centroids),
            entry_point=jnp.int32(self.graph.entry_point),
            hot_count=jnp.int32(self.hot_count),
        )

    def _search_base(self) -> np.ndarray:
        b = self.dataset.base
        if self.dataset.metric == "angular":
            b = l2_normalize(b, np)
        return b

    def sharded_corpus(self, num_tiles: Optional[int] = None,
                       policy: Optional[str] = None,
                       replicate_hot: Optional[bool] = None):
        """Partition this index into P search tiles (one per NAND channel
        group) for the channel-parallel serving path; see ``repro.shard``.
        Defaults come from ``config.shard``. Returns (TiledCorpus,
        TilePartition)."""
        from repro.configs.base import upgrade_config
        from repro.shard import partition_index

        # configs unpickled from pre-shard-layer caches lack .shard;
        # upgrade_config fills every missing section with its default
        sc = upgrade_config(self.config).shard
        return partition_index(
            self,
            num_tiles=sc.num_tiles if num_tiles is None else num_tiles,
            policy=sc.policy if policy is None else policy,
            replicate_hot=(
                sc.replicate_hot if replicate_hot is None else replicate_hot
            ),
        )

    def index_bytes(self) -> dict:
        """Storage accounting (paper Challenge 3 / §III-E)."""
        n, r = self.graph.adjacency.shape
        raw = self.dataset.base.nbytes
        idx_raw = n * r * 4
        idx_gap = self.gap.encoded_bytes if self.gap else idx_raw
        pq_bytes = self.codes.nbytes
        hot_extra = self.hot_count * r * self.codes.shape[1]  # repeated PQ codes
        return {
            "raw_bytes": raw,
            "index_bytes_uncompressed": idx_raw,
            "index_bytes_gap": idx_gap,
            "pq_bytes": pq_bytes,
            "hot_repetition_bytes": hot_extra,
            "total_bytes": raw + idx_gap + pq_bytes + hot_extra,
        }


def build_index_monolithic(
    cfg: ProximaConfig,
    dataset: Optional[Dataset] = None,
    graph_method: str = "knn_prune",
    reorder_samples: int = 128,
    calibrate: bool = False,
) -> ProximaIndex:
    """Legacy single-pass pipeline: the WHOLE corpus is resident (base,
    graph, codes) throughout the build.  Kept as the independent reference
    implementation the CI equivalence suite compares the segmented builder's
    single-segment path against (tests/test_segmented.py); production code
    should call :func:`build_index` or ``repro.core.segmented.
    build_segmented``."""
    ds = dataset if dataset is not None else make_dataset(cfg.dataset)
    metric = ds.metric

    # --- PQ (paper §III-B: search-time only; graph built on full precision)
    codebook = pq_mod.train_pq(ds.base, cfg.pq, metric)
    enc_in = ds.base
    if metric == "angular":
        enc_in = enc_in / np.maximum(np.linalg.norm(enc_in, axis=-1, keepdims=True), 1e-12)
    codes = np.asarray(pq_mod.encode(jnp.asarray(enc_in), jnp.asarray(codebook.centroids)))

    # --- graph on full-precision coordinates
    graph = build_graph(ds.base, cfg.graph, metric, method=graph_method)

    # --- reordering + hot nodes (§IV-E); enc_in is permuted ALONGSIDE
    # base/codes — it feeds calibrate_beta below, which indexes codes and
    # enc_in by the same row
    reordering = None
    if cfg.hot_node_fraction > 0:
        graph, new_base, enc_in, codes, reordering = reorder_segment(
            graph, ds.base, enc_in, codes, codebook.centroids, cfg.search,
            metric, cfg.hot_node_fraction, num_samples=reorder_samples,
            seed=cfg.dataset.seed,
        )
        ds = Dataset(
            base=new_base,
            queries=ds.queries,
            gt=remap_ground_truth(reordering, ds.gt),
            metric=ds.metric,
            config=ds.config,
        )

    # --- gap encoding (§III-E)
    gap = gap_encode(graph.adjacency) if cfg.gap_encode else None

    beta = cfg.search.beta
    if calibrate:
        rng = np.random.default_rng(cfg.dataset.seed)
        beta = pq_mod.calibrate_beta(codebook, codes, enc_in, rng)

    return ProximaIndex(
        config=cfg,
        dataset=ds,
        graph=graph,
        codebook=codebook,
        codes=codes,
        gap=gap,
        reordering=reordering,
        calibrated_beta=beta,
    )


def build_index(
    cfg: ProximaConfig,
    dataset: Optional[Dataset] = None,
    graph_method: str = "knn_prune",
    reorder_samples: int = 128,
    calibrate: bool = False,
) -> ProximaIndex:
    """Build a flat index — a thin SINGLE-SEGMENT wrapper over the segmented
    out-of-core builder (``repro.core.segmented.build_segmented``), bit-
    identical to :func:`build_index_monolithic` (same adjacency, codes,
    reordering, beta; enforced by tests/test_segmented.py).  For corpora
    larger than host memory, call ``build_segmented`` with
    ``BuildConfig.segment_size > 0`` directly."""
    from repro.core.segmented import build_segmented

    return build_segmented(
        cfg,
        dataset=dataset,
        graph_method=graph_method,
        reorder_samples=reorder_samples,
        calibrate=calibrate,
        segment_size=0,                 # one segment == the legacy pipeline
    ).to_flat()
