"""Synthetic ANN corpora + exact ground truth.

No network access is available offline, so SIFT/GLOVE/DEEP are stood in for by
synthetic corpora with controllable cluster structure:

  * ``sift-like``  — Gaussian mixture in R^128, L2 metric (local clusters,
    like SIFT descriptors).
  * ``glove-like`` — heavy-tailed directions on the sphere, angular metric
    (high hubness — the hard case the paper calls out: GLOVE needs 6-8x more
    distance computations at equal recall).
  * ``deep-like``  — PCA-style anisotropic Gaussian, inner-product metric.

Ground truth is exact brute-force kNN computed in chunks.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import DatasetConfig


@dataclass
class Dataset:
    base: np.ndarray      # (N, D) float32
    queries: np.ndarray   # (Q, D) float32
    gt: np.ndarray        # (Q, k_gt) int32 exact nearest neighbours
    metric: str
    config: DatasetConfig

    @property
    def num_base(self) -> int:
        return self.base.shape[0]

    @property
    def dim(self) -> int:
        return self.base.shape[1]

    def as_source(self, segment_size: int = 0) -> "ArraySegmentSource":
        """View this (host-resident) corpus as a segment stream for the
        out-of-core builder; ``segment_size == 0`` -> one segment."""
        return ArraySegmentSource(self.base, segment_size)


def _normalize(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def pairwise_dist(q: np.ndarray, x: np.ndarray, metric: str) -> np.ndarray:
    """(Q, N) distances; smaller is closer for every metric."""
    if metric == "l2":
        # squared L2 (monotone in L2; matches PQ table construction)
        q2 = (q * q).sum(-1, keepdims=True)
        x2 = (x * x).sum(-1)
        return q2 + x2[None, :] - 2.0 * q @ x.T
    if metric == "ip":
        return -(q @ x.T)
    if metric == "angular":
        return -(_normalize(q) @ _normalize(x).T)
    raise ValueError(f"unknown metric {metric!r}")


def exact_knn(
    queries: np.ndarray, base: np.ndarray, k: int, metric: str, chunk: int = 512
) -> np.ndarray:
    k = min(k, base.shape[0])   # a tiny corpus (e.g. a sharp filter's
                                # passing subset) caps the answer size
    out = np.empty((queries.shape[0], k), dtype=np.int32)
    for s in range(0, queries.shape[0], chunk):
        d = pairwise_dist(queries[s : s + chunk], base, metric)
        if k < d.shape[1]:
            idx = np.argpartition(d, k, axis=1)[:, :k]
        else:                   # argpartition needs kth < n; full sort below
            idx = np.broadcast_to(np.arange(k), d.shape[:1] + (k,))
        row = np.take_along_axis(d, idx, axis=1)
        order = np.argsort(row, axis=1, kind="stable")
        out[s : s + chunk] = np.take_along_axis(idx, order, axis=1)
    return out


class ArraySegmentSource:
    """Fixed-size segment view over a host-resident array — the trivial
    ``SegmentSource``.  The segmented builder (``repro.core.segmented``)
    consumes any object with this four-member surface (``num_base``,
    ``dim``, ``num_segments``, ``segment(s)``); out-of-core sources (e.g.
    :class:`SyntheticSegmentSource`) generate each segment on demand so
    nothing larger than one segment is ever resident."""

    def __init__(self, base: np.ndarray, segment_size: int = 0):
        self.base = base
        self.segment_size = segment_size if segment_size > 0 else base.shape[0]

    @property
    def num_base(self) -> int:
        return self.base.shape[0]

    @property
    def dim(self) -> int:
        return self.base.shape[1]

    @property
    def num_segments(self) -> int:
        return max(1, -(-self.num_base // self.segment_size))

    def bounds(self, s: int) -> tuple[int, int]:
        lo = s * self.segment_size
        return lo, min(lo + self.segment_size, self.num_base)

    def segment(self, s: int) -> np.ndarray:
        lo, hi = self.bounds(s)
        return self.base[lo:hi]

    def __iter__(self):
        for s in range(self.num_segments):
            yield self.segment(s)


class SyntheticSegmentSource:
    """Out-of-core synthetic corpus: segment ``s`` is a pure function of
    ``(config, s)`` — a per-segment RNG stream seeded ``(seed, s)`` draws the
    cluster assignments and noise — so iteration is restartable, order-
    independent, and only the (num_clusters, dim) centre matrix plus ONE
    segment is ever resident.  Gaussian-mixture (sift-like) geometry only;
    queries come from the same mixture via :meth:`queries`."""

    def __init__(self, cfg: DatasetConfig, segment_size: int):
        if segment_size <= 0:
            raise ValueError("SyntheticSegmentSource needs segment_size > 0")
        self.config = cfg
        self.segment_size = segment_size
        self.metric = cfg.metric if cfg.metric else "l2"
        rng = np.random.default_rng(cfg.seed)
        self.centers = rng.standard_normal(
            (cfg.num_clusters, cfg.dim)
        ).astype(np.float32)

    @property
    def num_base(self) -> int:
        return self.config.num_base

    @property
    def dim(self) -> int:
        return self.config.dim

    @property
    def num_segments(self) -> int:
        return max(1, -(-self.num_base // self.segment_size))

    def bounds(self, s: int) -> tuple[int, int]:
        lo = s * self.segment_size
        return lo, min(lo + self.segment_size, self.num_base)

    def segment(self, s: int) -> np.ndarray:
        cfg = self.config
        lo, hi = self.bounds(s)
        rng = np.random.default_rng((cfg.seed, s))
        assign = rng.integers(0, cfg.num_clusters, size=hi - lo)
        noise = cfg.cluster_std * rng.standard_normal((hi - lo, cfg.dim))
        return (self.centers[assign] + noise).astype(np.float32)

    def __iter__(self):
        for s in range(self.num_segments):
            yield self.segment(s)

    def queries(self, num_queries: int) -> np.ndarray:
        cfg = self.config
        rng = np.random.default_rng((cfg.seed, -1))
        qa = rng.integers(0, cfg.num_clusters, size=num_queries)
        noise = cfg.cluster_std * rng.standard_normal((num_queries, cfg.dim))
        return (self.centers[qa] + noise).astype(np.float32)


def exact_knn_stream(
    queries: np.ndarray, source, k: int, metric: str
) -> np.ndarray:
    """Exact kNN against a segment source without materializing the corpus:
    per-segment brute-force top-k (global ids) merged across segments.  The
    streaming twin of :func:`exact_knn` — identical answers on an
    ``ArraySegmentSource`` over the same base."""
    k = min(k, source.num_base)
    nq = queries.shape[0]
    best_ids = np.full((nq, k), -1, np.int64)
    best_d = np.full((nq, k), np.inf, np.float64)
    for s in range(source.num_segments):
        seg = source.segment(s)
        lo, _ = source.bounds(s)
        ks = min(k, seg.shape[0])
        ids = exact_knn(queries, seg, ks, metric).astype(np.int64) + lo
        d = np.take_along_axis(
            pairwise_dist(queries, seg, metric).astype(np.float64),
            ids - lo, axis=1,
        )
        cat_ids = np.concatenate([best_ids, ids], axis=1)
        cat_d = np.concatenate([best_d, d], axis=1)
        cat_d = np.where(cat_ids < 0, np.inf, cat_d)
        order = np.argsort(cat_d, axis=1, kind="stable")[:, :k]
        best_ids = np.take_along_axis(cat_ids, order, axis=1)
        best_d = np.take_along_axis(cat_d, order, axis=1)
    return best_ids.astype(np.int32)


def make_dataset(cfg: DatasetConfig, k_gt: int = 100) -> Dataset:
    rng = np.random.default_rng(cfg.seed)
    n, d, q = cfg.num_base, cfg.dim, cfg.num_queries

    if cfg.name.startswith("glove"):
        # heavy-tailed directions: cluster centres on sphere, power-law sizes
        centers = _normalize(rng.standard_normal((cfg.num_clusters, d)))
        weights = 1.0 / np.arange(1, cfg.num_clusters + 1) ** 0.8
        weights /= weights.sum()
        assign = rng.choice(cfg.num_clusters, size=n, p=weights)
        base = _normalize(centers[assign] + cfg.cluster_std * rng.standard_normal((n, d)))
        qa = rng.choice(cfg.num_clusters, size=q, p=weights)
        queries = _normalize(centers[qa] + cfg.cluster_std * rng.standard_normal((q, d)))
        metric = "angular"
    elif cfg.name.startswith("deep"):
        scales = np.exp(-np.linspace(0.0, 3.0, d))  # anisotropic spectrum
        centers = rng.standard_normal((cfg.num_clusters, d)) * scales
        assign = rng.integers(0, cfg.num_clusters, size=n)
        base = (centers[assign] + cfg.cluster_std * rng.standard_normal((n, d)) * scales)
        qa = rng.integers(0, cfg.num_clusters, size=q)
        queries = centers[qa] + cfg.cluster_std * rng.standard_normal((q, d)) * scales
        metric = "ip"
    else:  # sift-like
        centers = rng.standard_normal((cfg.num_clusters, d))
        assign = rng.integers(0, cfg.num_clusters, size=n)
        base = centers[assign] + cfg.cluster_std * rng.standard_normal((n, d))
        qa = rng.integers(0, cfg.num_clusters, size=q)
        queries = centers[qa] + cfg.cluster_std * rng.standard_normal((q, d))
        metric = cfg.metric if cfg.metric else "l2"

    base = base.astype(np.float32)
    queries = queries.astype(np.float32)
    gt = exact_knn(queries, base, min(k_gt, n), metric)
    return Dataset(base=base, queries=queries, gt=gt, metric=metric, config=cfg)


def recall_hits_per_query(pred: np.ndarray, gt: np.ndarray) -> np.ndarray:
    """(Q,) per-row |pred∩gt| — the primitive :func:`recall_at_k` and the
    shadow-recall estimator (``obs.quality.QualityMonitor``) both build on.
    Negative ids (the -1 padding short result lists carry) never match."""
    out = np.zeros(pred.shape[0], np.int64)
    for i, (p, g) in enumerate(zip(pred, gt)):
        out[i] = len(set(int(x) for x in p if x >= 0)
                     & set(int(x) for x in g if x >= 0))
    return out


def recall_hits(pred: np.ndarray, gt: np.ndarray) -> int:
    """Row-wise |pred∩gt| summed over queries."""
    return int(recall_hits_per_query(pred, gt).sum())


def recall_at_k(pred: np.ndarray, gt: np.ndarray, k: int) -> float:
    """Paper Eq. (2): |pred∩gt|/k averaged over queries."""
    return recall_hits(pred[:, :k], gt[:, :k]) / (pred.shape[0] * k)
