"""Synthetic ANN corpora + exact ground truth.

No network access is available offline, so SIFT/GLOVE/DEEP are stood in for by
synthetic corpora with controllable cluster structure:

  * ``sift-like``  — Gaussian mixture in R^128, L2 metric (local clusters,
    like SIFT descriptors).
  * ``glove-like`` — heavy-tailed directions on the sphere, angular metric
    (high hubness — the hard case the paper calls out: GLOVE needs 6-8x more
    distance computations at equal recall).
  * ``deep-like``  — PCA-style anisotropic Gaussian, inner-product metric.

Ground truth is exact brute-force kNN computed in chunks.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import DatasetConfig


@dataclass
class Dataset:
    base: np.ndarray      # (N, D) float32
    queries: np.ndarray   # (Q, D) float32
    gt: np.ndarray        # (Q, k_gt) int32 exact nearest neighbours
    metric: str
    config: DatasetConfig

    @property
    def num_base(self) -> int:
        return self.base.shape[0]

    @property
    def dim(self) -> int:
        return self.base.shape[1]


def _normalize(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def pairwise_dist(q: np.ndarray, x: np.ndarray, metric: str) -> np.ndarray:
    """(Q, N) distances; smaller is closer for every metric."""
    if metric == "l2":
        # squared L2 (monotone in L2; matches PQ table construction)
        q2 = (q * q).sum(-1, keepdims=True)
        x2 = (x * x).sum(-1)
        return q2 + x2[None, :] - 2.0 * q @ x.T
    if metric == "ip":
        return -(q @ x.T)
    if metric == "angular":
        return -(_normalize(q) @ _normalize(x).T)
    raise ValueError(f"unknown metric {metric!r}")


def exact_knn(
    queries: np.ndarray, base: np.ndarray, k: int, metric: str, chunk: int = 512
) -> np.ndarray:
    k = min(k, base.shape[0])   # a tiny corpus (e.g. a sharp filter's
                                # passing subset) caps the answer size
    out = np.empty((queries.shape[0], k), dtype=np.int32)
    for s in range(0, queries.shape[0], chunk):
        d = pairwise_dist(queries[s : s + chunk], base, metric)
        if k < d.shape[1]:
            idx = np.argpartition(d, k, axis=1)[:, :k]
        else:                   # argpartition needs kth < n; full sort below
            idx = np.broadcast_to(np.arange(k), d.shape[:1] + (k,))
        row = np.take_along_axis(d, idx, axis=1)
        order = np.argsort(row, axis=1, kind="stable")
        out[s : s + chunk] = np.take_along_axis(idx, order, axis=1)
    return out


def make_dataset(cfg: DatasetConfig, k_gt: int = 100) -> Dataset:
    rng = np.random.default_rng(cfg.seed)
    n, d, q = cfg.num_base, cfg.dim, cfg.num_queries

    if cfg.name.startswith("glove"):
        # heavy-tailed directions: cluster centres on sphere, power-law sizes
        centers = _normalize(rng.standard_normal((cfg.num_clusters, d)))
        weights = 1.0 / np.arange(1, cfg.num_clusters + 1) ** 0.8
        weights /= weights.sum()
        assign = rng.choice(cfg.num_clusters, size=n, p=weights)
        base = _normalize(centers[assign] + cfg.cluster_std * rng.standard_normal((n, d)))
        qa = rng.choice(cfg.num_clusters, size=q, p=weights)
        queries = _normalize(centers[qa] + cfg.cluster_std * rng.standard_normal((q, d)))
        metric = "angular"
    elif cfg.name.startswith("deep"):
        scales = np.exp(-np.linspace(0.0, 3.0, d))  # anisotropic spectrum
        centers = rng.standard_normal((cfg.num_clusters, d)) * scales
        assign = rng.integers(0, cfg.num_clusters, size=n)
        base = (centers[assign] + cfg.cluster_std * rng.standard_normal((n, d)) * scales)
        qa = rng.integers(0, cfg.num_clusters, size=q)
        queries = centers[qa] + cfg.cluster_std * rng.standard_normal((q, d)) * scales
        metric = "ip"
    else:  # sift-like
        centers = rng.standard_normal((cfg.num_clusters, d))
        assign = rng.integers(0, cfg.num_clusters, size=n)
        base = centers[assign] + cfg.cluster_std * rng.standard_normal((n, d))
        qa = rng.integers(0, cfg.num_clusters, size=q)
        queries = centers[qa] + cfg.cluster_std * rng.standard_normal((q, d))
        metric = cfg.metric if cfg.metric else "l2"

    base = base.astype(np.float32)
    queries = queries.astype(np.float32)
    gt = exact_knn(queries, base, min(k_gt, n), metric)
    return Dataset(base=base, queries=queries, gt=gt, metric=metric, config=cfg)


def recall_at_k(pred: np.ndarray, gt: np.ndarray, k: int) -> float:
    """Paper Eq. (2): |pred∩gt|/k averaged over queries."""
    hits = 0
    for p, g in zip(pred[:, :k], gt[:, :k]):
        hits += len(set(int(i) for i in p if i >= 0) & set(int(i) for i in g))
    return hits / (pred.shape[0] * k)
