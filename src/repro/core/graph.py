"""Proximity-graph construction (DiskANN/Vamana-style).

The paper builds graphs with existing tools (HNSW / DiskANN / NSG, §III-A) and
contributes only the *search*; we therefore implement a standard Vamana-style
builder with the RRND (alpha) robust-prune rule so the search layer has
faithful graphs to traverse.

Two builders:
  * ``build_knn_prune``  (default) — exact kNN graph (chunked brute force) +
    alpha robust prune + reverse edges.  Deterministic and fast at the scales
    this container supports; closely approximates incremental Vamana quality.
  * ``build_incremental`` — faithful Vamana: insert points one at a time,
    greedy-search from the medoid, robust-prune the visited set. Slower;
    used by tests on small N to validate the fast builder.

Adjacency is a dense (N, R) int32 array padded by repeating the last valid
neighbour (duplicates are filtered by the visited set during search), matching
the paper's "nodes with degree < R are padded to R to align address".
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from repro.configs.base import GraphConfig
from repro.core.dataset import pairwise_dist


@dataclass
class Graph:
    adjacency: np.ndarray   # (N, R) int32, padded
    degrees: np.ndarray     # (N,) int32 true degrees
    entry_point: int
    metric: str

    @property
    def num_vertices(self) -> int:
        return self.adjacency.shape[0]

    @property
    def max_degree(self) -> int:
        return self.adjacency.shape[1]


def _pad_rows(rows, r, n):
    adj = np.empty((n, r), dtype=np.int32)
    deg = np.empty((n,), dtype=np.int32)
    for i, row in enumerate(rows):
        row = list(dict.fromkeys(int(v) for v in row if v != i))[:r]
        if not row:
            row = [(i + 1) % n]
        deg[i] = len(row)
        adj[i, : len(row)] = row
        adj[i, len(row):] = row[-1]  # pad with last valid neighbour
    return adj, deg


def compensated_build_cfg(
    cfg: GraphConfig, factor: int, n: int, floor: int = 0
) -> GraphConfig:
    """THE density-compensation rule, shared by the tile partitioner
    (``shard.partition_index``), the segmented builder and the cross-segment
    stitcher: a graph built over a 1/``factor`` sample of every cluster sees
    intra-cluster gaps grow by ~``factor``, so a kNN list of the global size
    turns purely local and loses the long-range edges greedy search needs.
    Scaling the build neighbourhood by ``factor`` (with an optional
    ``floor``, capped at ``n - 1``) keeps navigability at the global level
    (measured: contiguous halves drop to ~0.69 greedy recall at the global
    build_list_size and recover to ~0.95+ when scaled)."""
    if factor <= 1 and floor <= 0:
        return cfg
    return dataclasses.replace(
        cfg,
        build_list_size=min(
            max(cfg.build_list_size * max(factor, 1), floor),
            max(n - 1, 1),
        ),
    )


def medoid(base: np.ndarray, metric: str, sample: int = 4096, seed: int = 0) -> int:
    rng = np.random.default_rng(seed)
    n = base.shape[0]
    idx = rng.choice(n, size=min(sample, n), replace=False)
    centroid = base.mean(0, keepdims=True)
    d = pairwise_dist(centroid, base[idx], metric)[0]
    return int(idx[np.argmin(d)])


def robust_prune(
    cand_ids: np.ndarray,
    cand_dists: np.ndarray,
    base: np.ndarray,
    metric: str,
    r: int,
    alpha: float,
) -> list:
    """Vamana RRND rule: greedily keep the closest candidate p, discard any
    remaining candidate x with alpha * dist(p, x) <= dist(query, x)."""
    order = np.argsort(cand_dists, kind="stable")
    ids = cand_ids[order]
    dists = cand_dists[order]
    kept: list = []
    alive = np.ones(len(ids), dtype=bool)
    for i in range(len(ids)):
        if not alive[i]:
            continue
        p = int(ids[i])
        kept.append(p)
        if len(kept) >= r:
            break
        rest = np.where(alive)[0]
        rest = rest[rest > i]
        if rest.size:
            d_p = pairwise_dist(base[p : p + 1], base[ids[rest]], metric)[0]
            alive[rest[alpha * d_p <= dists[rest]]] = False
    return kept


def _ensure_connected(
    rows: list, base: np.ndarray, metric: str, entry: int, r: int, alpha: float
) -> list:
    """NSG-style connectivity repair: BFS from the entry point; every orphan
    component is stitched to the reached set through its member closest to
    the dataset centroid, linked bidirectionally to its nearest reached node.
    Guarantees every vertex is reachable from the entry point."""
    from collections import deque

    n = len(rows)
    centroid = base.mean(0, keepdims=True)
    d_centroid = pairwise_dist(centroid, base, metric)[0]

    def reachable() -> np.ndarray:
        reached = np.zeros(n, dtype=bool)
        reached[entry] = True
        dq = deque([entry])
        while dq:
            v = dq.popleft()
            for u in rows[v]:
                if not reached[u]:
                    reached[u] = True
                    dq.append(u)
        return reached

    protected: set = set()  # stitch edges are preferentially kept
    max_iters = 4 * n + 16
    for _ in range(max_iters):
        reached = reachable()
        if reached.all():
            return rows
        orphans = np.where(~reached)[0]
        u = int(orphans[np.argmin(d_centroid[orphans])])
        ridx = np.where(reached)[0]
        d = pairwise_dist(base[u : u + 1], base[ridx], metric)[0]
        # pick the nearest reached node with a free or unprotected slot —
        # protected (stitch) edges are NEVER evicted, which makes progress
        # monotone: a reached node can never become unreachable again
        w = None
        for cand in ridx[np.argsort(d)]:
            cand = int(cand)
            if len(rows[cand]) < r or any(
                (cand, e) not in protected for e in rows[cand]
            ):
                w = cand
                break
        if w is None:  # pathological: every reached row fully protected
            raise RuntimeError("connectivity repair exhausted slots")
        for a, b in ((w, u), (u, w)):
            if b in rows[a]:
                continue
            if len(rows[a]) < r:
                rows[a].append(b)
            else:
                da = pairwise_dist(base[a : a + 1], base[rows[a]], metric)[0]
                evictable = [
                    j for j in range(len(rows[a]))
                    if (a, rows[a][j]) not in protected
                ]
                if not evictable:
                    # defensive (unreachable given the w selection above):
                    # front-insert so _pad_rows truncation keeps the stitch
                    rows[a].insert(0, b)
                else:
                    j = max(evictable, key=lambda j: da[j])
                    rows[a][j] = b
            protected.add((a, b))
    raise RuntimeError("connectivity repair did not converge")


def build_knn_prune(base: np.ndarray, cfg: GraphConfig, metric: str) -> Graph:
    n = base.shape[0]
    r = cfg.max_degree
    k = min(cfg.build_list_size, n - 1)
    rng = np.random.default_rng(cfg.seed)

    # exact kNN lists, chunked
    knn = np.empty((n, k), dtype=np.int32)
    knn_d = np.empty((n, k), dtype=np.float32)
    chunk = max(1, int(2e8 // max(n, 1)))
    for s in range(0, n, chunk):
        d = pairwise_dist(base[s : s + chunk], base, metric)
        for j in range(d.shape[0]):
            d[j, s + j] = np.inf  # exclude self
        idx = np.argpartition(d, k, axis=1)[:, :k].astype(np.int32)
        row = np.take_along_axis(d, idx, axis=1)
        order = np.argsort(row, axis=1, kind="stable")
        knn[s : s + chunk] = np.take_along_axis(idx, order, axis=1)
        knn_d[s : s + chunk] = np.take_along_axis(row, order, axis=1)

    # alpha-prune each kNN list
    rows = []
    for i in range(n):
        rows.append(robust_prune(knn[i], knn_d[i], base, metric, r, cfg.alpha))

    # add reverse edges (re-pruning overflow rows), long-range shortcuts
    rev: list = [[] for _ in range(n)]
    for i, row in enumerate(rows):
        for j in row:
            rev[j].append(i)
    for i in range(n):
        merged = list(dict.fromkeys(rows[i] + rev[i]))
        if len(merged) > r:
            cd = pairwise_dist(base[i : i + 1], base[merged], metric)[0]
            merged = robust_prune(np.asarray(merged), cd, base, metric, r, cfg.alpha)
        rows[i] = merged

    entry = medoid(base, metric, seed=cfg.seed)
    rows = _ensure_connected(rows, base, metric, entry, r, cfg.alpha)
    adj, deg = _pad_rows(rows, r, n)
    return Graph(adjacency=adj, degrees=deg, entry_point=entry, metric=metric)


def _greedy_search_np(
    base, adj, deg, entry, query, metric, list_size
):
    """Plain best-first search (HNSW/DiskANN inner loop) returning the visited
    set with distances — used by the incremental builder and as the accurate
    traversal baseline."""
    import heapq

    d0 = float(pairwise_dist(query[None], base[entry : entry + 1], metric)[0, 0])
    cand = [(d0, entry)]           # min-heap of unexpanded
    best: dict = {entry: d0}       # id -> dist of everything scored
    expanded = set()
    worst = d0
    while cand:
        d, v = heapq.heappop(cand)
        topl = sorted(best.values())[: list_size]
        if d > topl[-1] and len(best) >= list_size:
            break
        if v in expanded:
            continue
        expanded.add(v)
        neigh = [int(u) for u in adj[v, : deg[v]] if int(u) not in best]
        neigh = list(dict.fromkeys(neigh))
        if not neigh:
            continue
        nd = pairwise_dist(query[None], base[neigh], metric)[0]
        for u, du in zip(neigh, nd):
            best[u] = float(du)
            heapq.heappush(cand, (float(du), u))
    order = sorted(best.items(), key=lambda kv: kv[1])
    return order, expanded


def build_incremental(base: np.ndarray, cfg: GraphConfig, metric: str) -> Graph:
    n = base.shape[0]
    r = cfg.max_degree
    rng = np.random.default_rng(cfg.seed)
    start = medoid(base, metric, seed=cfg.seed)
    rows: list = [[] for _ in range(n)]
    # bootstrap: random initial edges
    for i in range(n):
        rows[i] = [int(v) for v in rng.choice(n, size=min(4, n - 1), replace=False) if v != i]
    adj, deg = _pad_rows(rows, r, n)
    order = rng.permutation(n)
    for i in order:
        scored, _ = _greedy_search_np(base, adj, deg, start, base[i], metric, cfg.build_list_size)
        cand = np.asarray([v for v, _ in scored if v != i], dtype=np.int64)
        cd = np.asarray([d for v, d in scored if v != i], dtype=np.float32)
        kept = robust_prune(cand, cd, base, metric, r, cfg.alpha)
        rows[i] = kept
        for j in kept:  # reverse edges with overflow re-prune
            if i not in rows[j]:
                rows[j].append(i)
                if len(rows[j]) > r:
                    cj = pairwise_dist(base[j : j + 1], base[rows[j]], metric)[0]
                    rows[j] = robust_prune(np.asarray(rows[j]), cj, base, metric, r, cfg.alpha)
        adj, deg = _pad_rows(rows, r, n)
    return Graph(adjacency=adj, degrees=deg, entry_point=start, metric=metric)


def build_graph(base: np.ndarray, cfg: GraphConfig, metric: str, method: str = "knn_prune") -> Graph:
    if method == "knn_prune":
        return build_knn_prune(base, cfg, metric)
    if method == "incremental":
        return build_incremental(base, cfg, metric)
    raise ValueError(f"unknown graph build method {method!r}")
