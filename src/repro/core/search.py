"""Proxima graph search — Algorithm 1 of the paper, as a fixed-shape JAX
program (vmapped over the query batch = the ASIC's N_q search queues).

Per traversal round (one iteration of the ``lax.while_loop``):
  1. pop the E best unevaluated candidates from the sorted list (Alg.1 l.4;
     E = ``SearchConfig.beam_width``, the beam-parallel generalization —
     the E adjacency fetches of one round are independent NAND page reads
     issued to parallel planes/channels, §IV-D dataflow)
  2. fetch their E*R neighbours in one indexed gather, dedup the combined
     set, Bloom-filter already-visited ones                    (l.6, §IV-B)
  3. PQ-distance all fresh ones via the ADT in one batch       (l.7)
  4. one (L + E*R) merge + sort, keep top L                    (l.10)
  5. if the top-T entries are all evaluated: rerank top T with accurate
     distances (cached), check early termination (r stable rounds), then
     grow T by T_step                                          (l.11-16)
Post-loop: beta-margin rerank of every candidate whose PQ distance is within
beta of the T-th candidate's, then return top-k by accurate distance (l.19-22).

Filtered traversal (``node_mask``, the ``repro.filter`` subsystem): a (N,)
boolean pass mask restricts *result admission*, never routing — non-passing
nodes still enter the candidate list and route the traversal exactly as
before, but only mask-passing nodes count for the early-termination top-k,
the beta-margin rerank threshold (taken at the T-th *passing* candidate) and
the final top-k. With an all-true mask every selection reduces to the
unfiltered arithmetic, so an all-pass filter is bit-identical to
``node_mask=None`` at every beam width.

Counters (per query) feed the NAND performance model and the memory-traffic
benchmarks: hops (index fetches = expansions, up to E per round), pq (code
fetches + LUT distance computations), acc (raw-vector fetches), hot_hops /
free_pq (hot-node repetition hits), rounds (serial traversal rounds — the
critical-path length; hops/rounds is the realized beam parallelism).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SearchConfig, upgrade_config
from repro.core import bloom
from repro.core.pq import compute_adt, pq_distance

INF = jnp.float32(jnp.inf)


class Corpus(NamedTuple):
    """Device-resident search structures (one NAND tile's worth)."""
    adjacency: jnp.ndarray      # (N, R) int32 padded
    codes: jnp.ndarray          # (N, M) uint8 PQ codes
    base: jnp.ndarray           # (N, D) f32 raw vectors (rerank path)
    centroids: jnp.ndarray      # (M, C, dsub) f32 PQ codebook
    entry_point: jnp.ndarray    # () int32
    hot_count: jnp.ndarray      # () int32 — ids < hot_count are "hot nodes"


class SearchResult(NamedTuple):
    ids: jnp.ndarray            # (Q, k) int32
    dists: jnp.ndarray          # (Q, k) f32 accurate distances
    n_hops: jnp.ndarray         # (Q,) expansions (index fetches)
    n_pq: jnp.ndarray           # (Q,) PQ distance computations
    n_acc: jnp.ndarray          # (Q,) accurate distance computations
    n_hot_hops: jnp.ndarray     # (Q,) expansions that hit a hot node
    n_free_pq: jnp.ndarray      # (Q,) PQ fetches covered by hot-node pages
    rounds: jnp.ndarray         # (Q,) traversal rounds


class _State(NamedTuple):
    ids: jnp.ndarray            # (L,) int32, -1 padding, sorted by dist
    dists: jnp.ndarray          # (L,) f32 traversal (PQ) distances
    acc: jnp.ndarray            # (L,) f32 accurate distances, +inf if unknown
    evaluated: jnp.ndarray      # (L,) bool
    bits: jnp.ndarray           # (W,) uint32 Bloom filter
    t: jnp.ndarray              # () int32 dynamic list size
    prev_topk: jnp.ndarray      # (k,) int32 last reranked top-k (sorted ids)
    stable: jnp.ndarray         # () int32 consecutive stable rounds
    done: jnp.ndarray           # () bool
    n_hops: jnp.ndarray
    n_pq: jnp.ndarray
    n_acc: jnp.ndarray
    n_hot: jnp.ndarray
    n_free: jnp.ndarray
    rounds: jnp.ndarray


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1) — bitonic networks and compiled
    batch buckets all pad to this."""
    return 1 << max(n - 1, 0).bit_length()


def empty_search_result(nq: int, k: int) -> SearchResult:
    """A no-work result batch: -1 ids, +inf distances, zeroed counters —
    what a skipped channel (zero-pass tile) or an empty-filter query batch
    contributes."""
    z = jnp.zeros((nq,), jnp.int32)
    return SearchResult(
        ids=jnp.full((nq, k), -1, jnp.int32),
        dists=jnp.full((nq, k), jnp.inf, jnp.float32),
        n_hops=z, n_pq=z, n_acc=z, n_hot_hops=z, n_free_pq=z, rounds=z,
    )


def l2_normalize(x, xp=jnp):
    """Unit-normalize rows — THE angular-metric normalization, shared by the
    JAX search, the reference oracle and the index's device-corpus export
    (``xp`` selects numpy for host-side callers)."""
    return x / xp.maximum(xp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def _exact_dist(q, x, metric: str):
    """q (D,), x (K, D) -> (K,). Angular assumes pre-normalized inputs.
    Operator-only arithmetic: works identically on jnp (traced search) and
    np (reference oracle) inputs — the single exact-distance path."""
    if metric == "l2":
        diff = x - q[None, :]
        return (diff * diff).sum(-1)
    return -(x @ q)


def _dedup_round(neighbors: jnp.ndarray) -> jnp.ndarray:
    """Mask duplicates within one fetched neighbour row (padding repeats)."""
    r = neighbors.shape[0]
    eq = neighbors[None, :] == neighbors[:, None]
    lower = jnp.tril(jnp.ones((r, r), bool), k=-1)
    return ~(eq & lower).any(axis=1)


def _merge_sort_topl(ids, dists, acc, evaluated, n_ids, n_dists):
    """Merge L existing + R new candidates, sort by dist, keep top L."""
    l = ids.shape[0]
    all_ids = jnp.concatenate([ids, n_ids])
    all_d = jnp.concatenate([dists, n_dists])
    all_acc = jnp.concatenate([acc, jnp.full(n_ids.shape, INF)])
    all_ev = jnp.concatenate([evaluated, jnp.zeros(n_ids.shape, bool)])
    order = jnp.argsort(all_d, stable=True)
    return (
        all_ids[order][:l],
        all_d[order][:l],
        all_acc[order][:l],
        all_ev[order][:l],
    )


def _topk_ids_by(ids, key, k):
    """ids of the k smallest keys, returned sorted by id for set comparison."""
    _, idx = jax.lax.top_k(-key, k)
    got = ids[idx]
    return jnp.sort(got)


def _merge_sort_topl_bitonic(ids, dists, acc, evaluated, n_ids, n_dists):
    """Kernel-path variant of ``_merge_sort_topl``: the merged (L+R) list is
    sorted by the Pallas bitonic network (the ASIC's shared Bitonic Sorter),
    carrying the position index as payload; other payloads follow by gather."""
    from repro.kernels import ops

    l = ids.shape[0]
    all_ids = jnp.concatenate([ids, n_ids])
    all_d = jnp.concatenate([dists, n_dists])
    all_acc = jnp.concatenate([acc, jnp.full(n_ids.shape, INF)])
    all_ev = jnp.concatenate([evaluated, jnp.zeros(n_ids.shape, bool)])
    total = all_d.shape[0]
    pot = next_pow2(total)
    keys = jnp.pad(all_d, (0, pot - total), constant_values=jnp.inf)
    pos = jnp.pad(jnp.arange(total, dtype=jnp.int32), (0, pot - total),
                  constant_values=0)
    # NOTE: bitonic is not stable; +inf-keyed entries are interchangeable
    # (all carry id=-1), so only exact finite-key ties can reorder.
    _, perm = ops.bitonic_sort_pairs(keys[None], pos[None])
    perm = perm[0, :l]
    return all_ids[perm], all_d[perm], all_acc[perm], all_ev[perm]


def _passes_of(ids, node_mask):
    """Valid AND mask-passing, elementwise (-1 slots never pass). With
    ``node_mask=None`` this is plain validity — the unfiltered path."""
    valid = ids >= 0
    if node_mask is None:
        return valid
    return valid & node_mask[jnp.maximum(ids, 0)]


def _build_adts(corpus: Corpus, queries: jnp.ndarray, cfg: SearchConfig,
                metric: str) -> jnp.ndarray:
    """Batched ADT construction (Pallas pq_adt kernel path) — shared by the
    while_loop kernel and ``init_search_state``."""
    if not cfg.use_pq:
        return jnp.zeros((queries.shape[0], 1, 1), jnp.float32)
    if cfg.use_pallas:
        from repro.kernels import ops

        return ops.pq_adt(queries, corpus.centroids, metric)
    return jax.vmap(lambda q: compute_adt(q, corpus.centroids, metric))(
        queries
    )


def _round_fns(corpus: Corpus, cfg: SearchConfig, metric: str,
               bloom_bits: int, num_hashes: int, node_mask):
    """THE traversal round, factored out of the ``lax.while_loop``: returns
    ``(init_one, cond, body)`` per-query functions.  ``graph_search`` wraps
    them back into a while_loop and ``graph_search_step`` applies exactly one
    guarded round — both paths trace the SAME functions, which is what makes
    the round-step path bit-identical to the while_loop kernel (enforced by
    the round-step equivalence suite in tests/test_plan.py).

    ``cond`` is also the vmap batching rule for while_loop: jax lowers a
    vmapped while_loop to "loop while any(cond), select(cond, body(s), s)
    per lane" — so one ``graph_search_step`` application IS one iteration of
    the vmapped loop, and iterating it until no lane is active reproduces
    the loop's fixpoint exactly (extra steps on a finished batch are
    no-ops)."""
    cfg = upgrade_config(cfg)    # pre-beam pickled configs: fill defaults
    L, k = cfg.list_size, cfg.k
    R = corpus.adjacency.shape[1]
    # beam wider than the candidate list can never pop more than L entries
    E = min(max(int(cfg.beam_width), 1), L)
    use_pq, do_et = cfg.use_pq, cfg.early_termination
    t_init = cfg.t_init if do_et else L
    t_step = cfg.t_step if do_et else L
    merge = _merge_sort_topl_bitonic if cfg.use_pallas else _merge_sort_topl

    def tdist(q, adt, ids):
        if use_pq:
            if cfg.use_pallas:
                from repro.kernels import ops

                return ops.pq_lookup(corpus.codes[ids], adt)
            return pq_distance(corpus.codes[ids], adt)
        return _exact_dist(q, corpus.base[ids], metric)

    def init_one(q, adt):
        ep = corpus.entry_point
        d0 = tdist(q, adt, ep[None])[0]
        ids0 = jnp.full((L,), -1, jnp.int32).at[0].set(ep)
        dists0 = jnp.full((L,), INF).at[0].set(d0)
        acc0 = jnp.full((L,), INF)
        if not use_pq:
            acc0 = acc0.at[0].set(d0)
        bits0 = bloom.bloom_init(bloom_bits)
        bits0 = bloom.insert(bits0, ep[None], jnp.ones((1,), bool), num_hashes)

        return _State(
            ids=ids0, dists=dists0, acc=acc0,
            evaluated=jnp.zeros((L,), bool), bits=bits0,
            t=jnp.int32(min(t_init, L)),
            prev_topk=jnp.full((k,), -2, jnp.int32),
            stable=jnp.int32(0), done=jnp.bool_(False),
            n_hops=jnp.int32(0), n_pq=jnp.int32(1 if use_pq else 0),
            n_acc=jnp.int32(0 if use_pq else 1),
            n_hot=jnp.int32(0), n_free=jnp.int32(0), rounds=jnp.int32(0),
        )

    def cond(s: _State):
        return (~s.done) & (s.rounds < cfg.max_rounds)

    def body(q, adt, s: _State):
        valid = s.ids >= 0
        unev = valid & ~s.evaluated
        n_unev = unev.sum()
        has_unev = unev.any()
        # positions of unevaluated entries in list (distance) order: a
        # stable sort of ~unev floats them to the front, so sel[:E] are
        # the E best unevaluated candidates — the round's beam. E == 1
        # keeps the original O(L) argmax instead of the O(L log L) sort.
        if E == 1:
            sel = jnp.argmax(unev)[None]               # (1,)
        else:
            sel = jnp.argsort(~unev, stable=True)[:E]  # (E,) distinct
        sel_valid = jnp.arange(E) < n_unev             # (E,)
        vs = jnp.where(sel_valid, s.ids[sel], 0)       # (E,) beam ids

        # ---- expand the beam: one E-row adjacency gather ---------------
        neigh = corpus.adjacency[vs].reshape(E * R)    # (E*R,)
        fresh = _dedup_round(neigh) & ~bloom.contains(s.bits, neigh, num_hashes)
        fresh = fresh & jnp.repeat(sel_valid, R)
        nd = tdist(q, adt, neigh)                      # one batched call
        nd = jnp.where(fresh, nd, INF)
        bits = bloom.insert(s.bits, neigh, fresh, num_hashes)
        evaluated = s.evaluated.at[sel].set(s.evaluated[sel] | sel_valid)
        n_new = fresh.sum()
        is_hot = (vs < corpus.hot_count) & sel_valid   # (E,)
        ids, dists, acc, evaluated = merge(
            s.ids, s.dists, s.acc, evaluated,
            jnp.where(fresh, neigh, -1).astype(jnp.int32), nd,
        )

        # ---- top-T evaluated? -> rerank + early-termination ------------
        valid = ids >= 0
        pl = _passes_of(ids, node_mask)
        in_t = (jnp.arange(L) < s.t) & valid
        all_eval = jnp.where(in_t.any(), (~in_t | evaluated).all(), False)

        # only passing candidates are admitted to the reranked top-k
        # (non-passing ones still route; in_t implies valid, so with no
        # mask in_t & pl == in_t and this is the unfiltered arithmetic)
        need = in_t & pl & jnp.isinf(acc)
        acc_new = _exact_dist(q, corpus.base[jnp.maximum(ids, 0)], metric)
        acc2 = jnp.where(need & all_eval, acc_new, acc)
        n_acc_new = jnp.where(all_eval, need.sum(), 0)
        if use_pq:
            rerank_key = jnp.where(in_t & pl, acc2, INF)
        else:
            acc2 = jnp.where(valid, dists, INF)
            rerank_key = jnp.where(in_t & pl, acc2, INF)
        new_topk = _topk_ids_by(ids, rerank_key, k)
        same = (new_topk == s.prev_topk).all()
        stable = jnp.where(all_eval, jnp.where(same, s.stable + 1, 1), s.stable)
        prev_topk = jnp.where(all_eval, new_topk, s.prev_topk)
        t = jnp.where(all_eval, s.t + t_step, s.t)

        terminated = do_et & all_eval & (stable >= cfg.repetition_rate)
        exhausted = ~has_unev
        overflow = t > L
        done = terminated | exhausted | overflow

        hot_new = (fresh.reshape(E, R) & is_hot[:, None]).sum()
        new = _State(
            ids=ids, dists=dists, acc=acc2, evaluated=evaluated, bits=bits,
            t=jnp.minimum(t, L), prev_topk=prev_topk, stable=stable,
            done=done,
            n_hops=s.n_hops + jnp.minimum(n_unev, E).astype(jnp.int32),
            n_pq=s.n_pq + (n_new if use_pq else 0),
            n_acc=s.n_acc + n_acc_new + (0 if use_pq else n_new),
            n_hot=s.n_hot + is_hot.sum().astype(jnp.int32),
            n_free=s.n_free + hot_new,
            rounds=s.rounds + 1,
        )
        # lanes that were already done keep their state (vmap-safety)
        return jax.tree_util.tree_map(
            lambda a, b: jnp.where(s.done, a, b), s, new
        )

    return init_one, cond, body


@partial(
    jax.jit,
    static_argnames=("cfg", "metric", "bloom_bits", "num_hashes"),
)
def graph_search(
    corpus: Corpus,
    queries: jnp.ndarray,
    cfg: SearchConfig,
    metric: str = "l2",
    bloom_bits: int = 1 << 17,
    num_hashes: int = 8,
    node_mask: jnp.ndarray | None = None,
) -> SearchResult:
    """Batched Proxima traversal KERNEL. queries: (Q, D). ``node_mask`` (N,)
    bool, if given, admits only passing nodes to the result set (filtered
    search — see the module docstring).

    This is the innermost compiled engine every ``repro.plan.QueryPlan``
    composes (flat, masked, per-tile fan-out, merged base segment); call it
    through ``repro.plan.Searcher`` unless you are writing a kernel.  The
    round-stepped decomposition of the same traversal —
    ``init_search_state`` / ``graph_search_step`` / ``finalize_search`` —
    serves the continuous-batching engine and is bit-identical to this
    while_loop at every round count."""
    if metric == "angular":
        queries = l2_normalize(queries)
    adts = _build_adts(corpus, queries, cfg, metric)
    init_one, cond, body = _round_fns(corpus, cfg, metric, bloom_bits,
                                      num_hashes, node_mask)

    def one_query(q, adt):
        return jax.lax.while_loop(
            cond, lambda s: body(q, adt, s), init_one(q, adt)
        )

    s = jax.vmap(one_query)(queries, adts)
    return _finalize_batch(corpus, cfg, metric, node_mask, queries, s)


def _finalize_batch(corpus: Corpus, cfg: SearchConfig, metric: str,
                    node_mask, queries: jnp.ndarray, s: _State) -> SearchResult:
    """Post-loop beta-margin rerank + top-k extraction over a BATCHED lane
    state (Alg.1 l.19-22) — shared verbatim by the while_loop kernel and the
    round-step path's ``finalize_search``."""
    L, k = cfg.list_size, cfg.k
    # ---- final beta rerank, batched (Alg.1 l.19-21; Pallas l2_rerank) ------
    valid = s.ids >= 0                                       # (Q, L)
    pass_l = _passes_of(s.ids, node_mask)                    # (Q, L)
    if node_mask is None:
        t_idx = jnp.clip(s.t, 1, L) - 1
        d_t = jnp.take_along_axis(s.dists, t_idx[:, None], 1)[:, 0]
        thr = d_t + (cfg.beta - 1.0) * jnp.abs(d_t)          # sign-safe margin
    else:
        # margin anchor = the T-th PASSING candidate's distance. The list
        # is distance-sorted with all valid entries a prefix, so with an
        # all-true mask "T-th passing" is exactly position T-1 (or the +inf
        # padding when fewer than T are valid) — bit-identical to the
        # unfiltered read above.
        rank = jnp.cumsum(pass_l, axis=1)                    # (Q, L)
        tt = jnp.clip(s.t, 1, L)
        is_t = pass_l & (rank == tt[:, None])
        d_t = jnp.where(is_t, s.dists, -INF).max(axis=1)
        d_t = jnp.where(rank[:, -1] >= tt, d_t, INF)
        # inf anchor (fewer than T passing): rerank every passing candidate
        # — guarded, since beta == 1.0 would turn inf + 0*inf into NaN and
        # silently drop all results
        thr = jnp.where(jnp.isinf(d_t), INF,
                        d_t + (cfg.beta - 1.0) * jnp.abs(d_t))
    if cfg.use_pq and cfg.rerank:
        need = pass_l & (s.dists <= thr[:, None]) & jnp.isinf(s.acc)
        cand = corpus.base[jnp.maximum(s.ids, 0)]            # (Q, L, D)
        if cfg.use_pallas:
            from repro.kernels import ops

            acc_new = ops.l2_rerank(queries, cand, metric)
        else:
            acc_new = jax.vmap(lambda q, x: _exact_dist(q, x, metric))(
                queries, cand
            )
        acc = jnp.where(need, acc_new, s.acc)
        n_acc = s.n_acc + need.sum(axis=1)
    else:
        # no rerank (rank by PQ) / accurate traversal (dists are accurate)
        acc = jnp.where(valid, s.dists, INF)
        n_acc = s.n_acc
    key = jnp.where(pass_l, acc, INF)
    neg, idx = jax.lax.top_k(-key, k)
    out_ids = jnp.take_along_axis(s.ids, idx, 1)
    if node_mask is not None:
        # a filter can leave fewer than k admissible candidates: such slots
        # carry +inf keys and must come back as explicit -1 padding
        out_ids = jnp.where(jnp.isinf(neg), -1, out_ids)
    return SearchResult(
        ids=out_ids, dists=-neg, n_hops=s.n_hops, n_pq=s.n_pq, n_acc=n_acc,
        n_hot_hops=s.n_hot, n_free_pq=s.n_free, rounds=s.rounds,
    )


# ---------------------------------------------------------------------------
# Round-stepped traversal — the continuous-batching kernel surface
# ---------------------------------------------------------------------------
# ``graph_search`` runs every lane to its fixpoint inside one while_loop; the
# three kernels below expose the SAME traversal one round at a time so an
# iteration-level scheduler (repro.serve.ServingEngine(continuous=True)) can
# retire finished lanes and refill their slots between rounds:
#
#     state = init_search_state(corpus, queries, cfg, ...)
#     while search_state_active(state, cfg).any():
#         state = graph_search_step(corpus, state, cfg, ...)   # ONE round
#     res = finalize_search(corpus, state, cfg, ...)           # beta rerank
#
# All three are jit-compiled with fixed shapes (Q lanes x list_size) and built
# from the same ``_round_fns``/``_finalize_batch`` pieces as ``graph_search``,
# so iterating the step to quiescence is bit-identical to the while_loop (a
# vmapped while_loop lowers to exactly this select-guarded step).


class SearchState(NamedTuple):
    """Mid-traversal snapshot of a batch of lanes.  ``queries`` are already
    metric-normalized and ``adts`` are the per-lane PQ lookup tables — both
    loop-invariant, carried here so ``graph_search_step`` is a pure
    State -> State function.  A lane is live while
    ``search_state_active(state, cfg)`` holds; rows may be swapped between
    two states with ``jnp.where`` (slot refill) because every leaf's leading
    axis is the lane axis."""

    queries: jnp.ndarray  # (Q, D) normalized query vectors
    adts: jnp.ndarray     # (Q, M, K) ADT lookup tables ((Q,1,1) when !use_pq)
    lanes: _State         # batched per-lane traversal state


@partial(
    jax.jit,
    static_argnames=("cfg", "metric", "bloom_bits", "num_hashes"),
)
def init_search_state(
    corpus: Corpus,
    queries: jnp.ndarray,
    cfg: SearchConfig,
    metric: str = "l2",
    bloom_bits: int = 1 << 17,
    num_hashes: int = 8,
    node_mask: jnp.ndarray | None = None,
) -> SearchState:
    """Round 0 of the traversal for a (Q, D) query batch: normalize, build
    ADTs, seed every lane at the entry point.  ``node_mask`` only matters in
    later rounds but is accepted here for signature symmetry."""
    if metric == "angular":
        queries = l2_normalize(queries)
    adts = _build_adts(corpus, queries, cfg, metric)
    init_one, _, _ = _round_fns(corpus, cfg, metric, bloom_bits, num_hashes,
                                node_mask)
    lanes = jax.vmap(init_one)(queries, adts)
    return SearchState(queries=queries, adts=adts, lanes=lanes)


@partial(
    jax.jit,
    static_argnames=("cfg", "metric", "bloom_bits", "num_hashes"),
)
def graph_search_step(
    corpus: Corpus,
    state: SearchState,
    cfg: SearchConfig,
    metric: str = "l2",
    bloom_bits: int = 1 << 17,
    num_hashes: int = 8,
    node_mask: jnp.ndarray | None = None,
) -> SearchState:
    """ONE traversal round over every lane (vmapped, fixed shapes).  Inactive
    lanes — done, or at ``max_rounds`` — pass through unchanged, exactly like
    the select-guarded iteration a vmapped while_loop lowers to, so stepping
    an all-quiet batch is a no-op and stepping until quiet reproduces
    ``graph_search`` bit-for-bit."""
    _, cond, body = _round_fns(corpus, cfg, metric, bloom_bits, num_hashes,
                               node_mask)

    def step_one(q, adt, s):
        active = cond(s)
        new = body(q, adt, s)
        return jax.tree_util.tree_map(
            lambda a, b: jnp.where(active, b, a), s, new
        )

    lanes = jax.vmap(step_one)(state.queries, state.adts, state.lanes)
    return state._replace(lanes=lanes)


def search_state_active(state: SearchState, cfg: SearchConfig) -> jnp.ndarray:
    """(Q,) bool — lanes that still have rounds to run.  This is the
    while_loop's cond applied batchwise; host code should ``.any()`` it to
    decide whether another ``graph_search_step`` is needed."""
    return (~state.lanes.done) & (state.lanes.rounds < cfg.max_rounds)


@partial(jax.jit, static_argnames=("cfg", "metric"))
def finalize_search(
    corpus: Corpus,
    state: SearchState,
    cfg: SearchConfig,
    metric: str = "l2",
    node_mask: jnp.ndarray | None = None,
) -> SearchResult:
    """Post-traversal beta-margin rerank + top-k (Alg.1 l.19-22) over lanes
    that have quiesced — the same ``_finalize_batch`` the while_loop kernel
    runs.  Queries inside ``state`` are already normalized; do NOT pass them
    through ``init_search_state`` twice."""
    return _finalize_batch(corpus, cfg, metric, node_mask,
                           state.queries, state.lanes)


def graph_search_stepped(
    corpus: Corpus,
    queries: jnp.ndarray,
    cfg: SearchConfig,
    metric: str = "l2",
    bloom_bits: int = 1 << 17,
    num_hashes: int = 8,
    node_mask: jnp.ndarray | None = None,
) -> SearchResult:
    """Host-side driver: iterate ``graph_search_step`` to quiescence, then
    finalize.  Semantically (bit-for-bit) equivalent to ``graph_search`` —
    the equivalence suite in tests/test_plan.py pins this; useful as a
    reference for schedulers and for testing the step kernels."""
    state = init_search_state(corpus, queries, cfg, metric, bloom_bits,
                              num_hashes, node_mask)
    while bool(search_state_active(state, cfg).any()):
        state = graph_search_step(corpus, state, cfg, metric, bloom_bits,
                                  num_hashes, node_mask)
    return finalize_search(corpus, state, cfg, metric, node_mask)


def search(
    corpus: Corpus,
    queries,
    cfg: SearchConfig,
    metric: str = "l2",
    bloom_bits: int = 1 << 17,
    num_hashes: int = 8,
    node_mask=None,
) -> SearchResult:
    """DEPRECATED entry point — builds a ``repro.plan.SearchRequest`` and
    delegates to the ``Searcher`` facade (which dispatches back to the
    ``graph_search`` kernel above with identical arguments, so results are
    bit-identical).  ``node_mask`` is passed verbatim to the traversal —
    no selectivity adaptation, exactly the legacy semantics.

    Under an active JAX trace (this name used to be jit-wrapped, so callers
    could compose it inside jit/vmap) the wrapper forwards straight to the
    kernel — the plan layer is host-side and cannot consume tracers."""
    leaves = jax.tree_util.tree_leaves((corpus, queries, node_mask))
    if any(isinstance(x, jax.core.Tracer) for x in leaves):
        return graph_search(corpus, queries, cfg, metric, bloom_bits,
                            num_hashes, node_mask=node_mask)

    from repro.plan import Searcher, SearchRequest
    from repro.plan.searcher import warn_legacy

    warn_legacy("core.search")
    s = Searcher.open(corpus, cfg=cfg, metric=metric, bloom_bits=bloom_bits,
                      num_hashes=num_hashes)
    res = s.search(SearchRequest(queries=queries, node_mask=node_mask,
                                 adaptive=False))
    return res.raw if node_mask is None else res.raw.result


# jit-cache introspection rides along so compile-count regression tests keep
# observing the kernel through the legacy name
if hasattr(graph_search, "_cache_size"):
    search._cache_size = graph_search._cache_size


def jit_cache_sizes() -> dict:
    """Executable-cache entry counts of the stack's jitted kernels — the
    recompile detector's input (``repro.obs.KernelWatch``).  Empty when the
    jax build exposes no ``_cache_size`` introspection."""
    out = {}
    for name, fn in (
        ("graph_search", graph_search),
        ("init_search_state", init_search_state),
        ("graph_search_step", graph_search_step),
        ("finalize_search", finalize_search),
    ):
        if hasattr(fn, "_cache_size"):
            out[name] = int(fn._cache_size())
    return out


# ---------------------------------------------------------------------------
# NumPy reference (direct Algorithm-1 transliteration) — the test oracle
# ---------------------------------------------------------------------------

def search_reference(
    adjacency: np.ndarray,
    degrees: np.ndarray,
    codes: np.ndarray,
    base: np.ndarray,
    centroids: np.ndarray,
    entry: int,
    query: np.ndarray,
    cfg: SearchConfig,
    metric: str = "l2",
    hot_count: int = 0,
    trace: np.ndarray | None = None,
    node_mask: np.ndarray | None = None,
):
    """Single-query Python loop implementation of Algorithm 1 with an exact
    visited set (no Bloom false positives). Returns (ids, dists, counters).
    Honours ``cfg.beam_width``: each round pops the E best unevaluated
    candidates and expands them together, deduplicating the combined
    neighbour set in beam order (first occurrence wins) — the same wavefront
    the JAX engine issues, so counters stay comparable at every E.
    If ``trace`` is given, expansion counts are accumulated into it
    (visit-frequency histogram for graph reordering, §IV-E).
    ``node_mask`` mirrors the JAX engine's filtered admission: non-passing
    nodes route but are excluded from the reranked top-k, the beta-margin
    anchor (T-th passing candidate) and the returned results."""
    if metric == "angular":
        # same single normalization point as the JAX path (idempotent if the
        # caller already normalized, as build_index's tracing does); base
        # rows are normalized per fetched slice, never the whole corpus
        query = l2_normalize(query, np)

    def _rows(ids):
        rows = base[ids]
        return l2_normalize(rows, np) if metric == "angular" else rows

    m = centroids.shape[0]
    if cfg.use_pq:
        adt = np.asarray(compute_adt(jnp.asarray(query), jnp.asarray(centroids), metric))

        def tdist(ids):
            return adt[np.arange(m)[None, :], codes[ids].astype(np.int64)].sum(-1)
    else:
        def tdist(ids):
            return _exact_dist(query, _rows(ids), metric)

    def adist(ids):
        return _exact_dist(query, _rows(ids), metric)

    cfg = upgrade_config(cfg)    # pre-beam pickled configs: fill defaults
    L, k = cfg.list_size, cfg.k
    E = max(int(cfg.beam_width), 1)

    def _pass(u: int) -> bool:
        return node_mask is None or bool(node_mask[u])

    counters = {"hops": 0, "pq": 0, "acc": 0, "hot": 0, "free": 0, "rounds": 0}
    d0 = float(tdist(np.asarray([entry]))[0])
    counters["pq" if cfg.use_pq else "acc"] += 1
    lst = [(d0, int(entry))]        # sorted (dist, id)
    visited = {int(entry)}
    evaluated = set()
    acc_cache = {}
    t = cfg.t_init if cfg.early_termination else L
    t_step = cfg.t_step if cfg.early_termination else L
    prev_topk = None
    stable = 0
    while counters["rounds"] < cfg.max_rounds:
        counters["rounds"] += 1
        unev = [(d, v) for d, v in lst if v not in evaluated]
        if not unev:
            break
        beam = [v for _, v in unev[:E]]           # E best unevaluated
        fresh: list[int] = []                     # beam-order, deduped
        fresh_owner_hot: list[bool] = []
        for v in beam:
            evaluated.add(v)
            counters["hops"] += 1
            if trace is not None:
                trace[v] += 1
            is_hot = v < hot_count
            if is_hot:
                counters["hot"] += 1
            neigh = [int(u) for u in adjacency[v, : degrees[v]]]
            for u in dict.fromkeys(neigh):
                if u not in visited:
                    visited.add(u)                # first occurrence owns u
                    fresh.append(u)
                    fresh_owner_hot.append(is_hot)
        if fresh:
            nd = tdist(np.asarray(fresh))
            counters["pq" if cfg.use_pq else "acc"] += len(fresh)
            counters["free"] += sum(fresh_owner_hot)
            for u, du in zip(fresh, nd):
                lst.append((float(du), u))
            lst.sort(key=lambda x: (x[0], ))
            lst = lst[:L]
        top_t = lst[: min(t, len(lst))]
        if top_t and all(v2 in evaluated for _, v2 in top_t):
            # only mask-passing candidates are admitted to the reranked
            # top-k (non-passing ones still route the traversal)
            ids_t = [v2 for _, v2 in top_t if _pass(v2)]
            fresh = [u for u in ids_t if u not in acc_cache]
            if cfg.use_pq and fresh:
                for u, du in zip(fresh, adist(np.asarray(fresh))):
                    acc_cache[u] = float(du)
                counters["acc"] += len(fresh)
            if not cfg.use_pq:
                for dd, u in top_t:
                    if _pass(u):
                        acc_cache[u] = dd
            topk = tuple(sorted(
                [u for u in ids_t][: len(ids_t)],
                key=lambda u: acc_cache[u],
            )[:k])
            topk = tuple(sorted(topk))
            if topk == prev_topk:
                stable += 1
            else:
                stable = 1
            prev_topk = topk
            if cfg.early_termination and stable >= cfg.repetition_rate:
                break
            t += t_step
            if t > L:
                break
    # final beta rerank (filtered: margin anchored at the T-th PASSING entry)
    if node_mask is None:
        t_idx = min(max(t, 1), len(lst)) - 1
        d_t = lst[t_idx][0]
        thr = d_t + (cfg.beta - 1.0) * abs(d_t)
    else:
        pass_list = [d for d, u in lst if _pass(u)]
        tt = max(t, 1)
        d_t = pass_list[tt - 1] if len(pass_list) >= tt else np.inf
        # same beta==1.0 NaN guard as the JAX engine's masked anchor
        thr = np.inf if np.isinf(d_t) else d_t + (cfg.beta - 1.0) * abs(d_t)
    if cfg.use_pq and cfg.rerank:
        need = [u for d, u in lst
                if d <= thr and _pass(u) and u not in acc_cache]
        if need:
            for u, du in zip(need, adist(np.asarray(need))):
                acc_cache[u] = float(du)
            counters["acc"] += len(need)
        scored = sorted(
            ((u, d) for u, d in acc_cache.items() if _pass(u)),
            key=lambda kv: kv[1],
        )
    else:
        scored = sorted(((u, d) for d, u in lst if _pass(u)),
                        key=lambda kv: kv[1])
    ids = np.asarray([u for u, _ in scored[:k]], dtype=np.int32)
    ds = np.asarray([d for _, d in scored[:k]], dtype=np.float32)
    if len(ids) < k:
        ids = np.pad(ids, (0, k - len(ids)), constant_values=-1)
        ds = np.pad(ds, (0, k - len(ds)), constant_values=np.inf)
    return ids, ds, counters
