"""Distributed Proxima search — the paper's NAND-tile/search-engine split
mapped onto a TPU mesh with ``shard_map``.

Mapping (DESIGN.md §2/§5):
  * mesh axis ``data``  = NAND cores: the corpus (adjacency, PQ codes, raw
    vectors) is sharded round-robin — vertex i lives on shard ``i % P`` at
    local row ``i // P`` (paper §IV-E "core-level round-robin address
    mapping ... data with consecutive indices are assigned to consecutive
    cores").
  * mesh axis ``model`` = search queues (N_q): the query batch is sharded so
    each model-group runs an independent search engine.
  * hot nodes (ids < hot_count, after visit-frequency reordering) are
    REPLICATED on every shard — the paper's hot-node repetition, which here
    converts remote fetches into local reads.

Two execution modes (the §Perf baseline/optimized pair):
  * ``mode="fetch"`` — DiskANN-on-a-host style: the search engine psum-gathers
    the PQ *codes* of the frontier from the owning shards, then computes
    distances locally. Collective payload per round: (Q, R, M) uint8 codes
    + (Q, R) int32 adjacency.
  * ``mode="nsp"``   — the paper's near-storage insight: each shard computes
    distances for the frontier ids it OWNS and only the (Q, R) float32
    distances are reduced. Collective payload shrinks by ~M bytes/4 per
    entry (8x for M=32) — compute moves to the data.

Both modes return bit-identical results (tested); only the collective bytes
differ, which the roofline analysis measures.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SearchConfig, upgrade_config
from repro.core import bloom
from repro.core.pq import compute_adt, pq_distance
from repro.core.search import (
    INF,
    _dedup_round,
    _exact_dist,
    _merge_sort_topl,
    _topk_ids_by,
)


class ShardedCorpus(NamedTuple):
    """Host-side container of round-robin-sharded corpus arrays.

    Sharded arrays have a leading shard axis of size P:
      adjacency (P, N/P, R), codes (P, N/P, M), base (P, N/P, D).
    Replicated: centroids, hot_* (hot-node repetition replicas), entry.
    """
    adjacency: jnp.ndarray
    codes: jnp.ndarray
    base: jnp.ndarray
    centroids: jnp.ndarray
    hot_adjacency: jnp.ndarray   # (H, R) replicated
    hot_codes: jnp.ndarray       # (H, M)
    hot_base: jnp.ndarray        # (H, D)
    entry_point: jnp.ndarray
    hot_count: jnp.ndarray       # () int32 == H
    num_vertices: int
    num_shards: int


def shard_corpus(
    adjacency: np.ndarray,
    codes: np.ndarray,
    base: np.ndarray,
    centroids: np.ndarray,
    entry_point: int,
    hot_count: int,
    num_shards: int,
) -> ShardedCorpus:
    """Round-robin partition: vertex i -> (shard i % P, local row i // P)."""
    n = adjacency.shape[0]
    pad = (-n) % num_shards
    if pad:
        adjacency = np.concatenate([adjacency, np.zeros((pad, adjacency.shape[1]), adjacency.dtype)])
        codes = np.concatenate([codes, np.zeros((pad, codes.shape[1]), codes.dtype)])
        base = np.concatenate([base, np.zeros((pad, base.shape[1]), base.dtype)])
    npad = n + pad
    order = np.arange(npad).reshape(npad // num_shards, num_shards).T  # (P, N/P)
    h = max(int(hot_count), 1)
    return ShardedCorpus(
        adjacency=jnp.asarray(adjacency[order]),
        codes=jnp.asarray(codes[order]),
        base=jnp.asarray(base[order]),
        centroids=jnp.asarray(centroids),
        hot_adjacency=jnp.asarray(adjacency[:h]),
        hot_codes=jnp.asarray(codes[:h]),
        hot_base=jnp.asarray(base[:h]),
        entry_point=jnp.int32(entry_point),
        hot_count=jnp.int32(hot_count),
        num_vertices=n,
        num_shards=num_shards,
    )


def _owned_rows(arr_local, ids, shard_idx, p):
    """Gather rows for global ids from this shard's slice; zeros elsewhere.
    arr_local: (N/P, W); ids: (K,) -> (K, W) with zeros for non-owned."""
    owner = ids % p
    local = ids // p
    rows = arr_local[jnp.clip(local, 0, arr_local.shape[0] - 1)]
    mine = (owner == shard_idx) & (ids >= 0)
    return jnp.where(mine[:, None], rows, jnp.zeros_like(rows))


@partial(
    jax.jit,
    static_argnames=("cfg", "metric", "mode", "mesh", "data_axis",
                     "queue_axis", "bloom_bits", "num_hashes"),
)
def distributed_search_kernel(
    corpus: ShardedCorpus,
    queries: jnp.ndarray,
    cfg: SearchConfig,
    metric: str = "l2",
    mode: str = "nsp",
    mesh: Mesh | None = None,
    data_axis: str = "data",
    queue_axis: str = "model",
    bloom_bits: int = 1 << 17,
    num_hashes: int = 8,
):
    """Batched distributed search KERNEL — the ``distributed`` execution
    spine of a ``repro.plan.QueryPlan``. queries (Q, D) sharded over
    ``queue_axis``; corpus sharded over ``data_axis``. Returns (ids, dists)
    of shape (Q, k).
    """
    assert mesh is not None
    cfg = upgrade_config(cfg)    # pre-beam pickled configs: fill defaults
    if metric == "angular":
        queries = queries / jnp.maximum(
            jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-12
        )

    L, k = cfg.list_size, cfg.k
    R = corpus.adjacency.shape[2]
    M = corpus.codes.shape[2]
    p = corpus.num_shards
    # beam-parallel traversal (core.search semantics): E expansions per
    # round — one (Qb, E*R) collective wave instead of E serial rounds
    E = min(max(int(cfg.beam_width), 1), L)
    use_pq = cfg.use_pq
    t_init = cfg.t_init if cfg.early_termination else L
    t_step = cfg.t_step if cfg.early_termination else L

    def engine(adj_l, codes_l, base_l, cents, hot_adj, hot_codes, hot_base,
               entry, hot_count, q_block):
        """Runs on one device: full search engine for its query slice, with
        psum-served fetches from the data shards."""
        adj_l, codes_l, base_l = adj_l[0], codes_l[0], base_l[0]
        shard_idx = jax.lax.axis_index(data_axis)

        def fetch_adjacency(v):
            """(Qb,) vertex ids -> (Qb, R) neighbour ids via masked psum,
            hot rows served from the local replica."""
            cold = _owned_rows(adj_l, v, shard_idx, p)
            cold = jax.lax.psum(cold, data_axis)
            hot = hot_adj[jnp.clip(v, 0, hot_adj.shape[0] - 1)]
            return jnp.where((v < hot_count)[:, None], hot, cold)

        def score(ids2d, adts, qb):
            """(Qb, R) ids -> (Qb, R) traversal distances."""
            flat = ids2d.reshape(-1)
            if use_pq:
                if mode == "nsp":
                    # distances computed at the owning shard, psum-merged
                    def one(idv, adt):
                        cold_codes = _owned_rows(codes_l, idv, shard_idx, p)
                        d = pq_distance(cold_codes, adt)
                        mine = (idv % p == shard_idx) & (idv >= 0)
                        return jnp.where(mine, d, 0.0)
                    d = jax.vmap(one)(ids2d, adts)
                    d = jax.lax.psum(d, data_axis)
                    hot_d = jax.vmap(
                        lambda idv, adt: pq_distance(
                            hot_codes[jnp.clip(idv, 0, hot_codes.shape[0] - 1)], adt
                        )
                    )(ids2d, adts)
                    return jnp.where(ids2d < hot_count, hot_d, d)
                # fetch mode: ship the codes, compute at the engine
                cold = _owned_rows(codes_l.astype(jnp.int32), flat, shard_idx, p)
                cold = jax.lax.psum(cold, data_axis).astype(jnp.uint8)
                hot = hot_codes[jnp.clip(flat, 0, hot_codes.shape[0] - 1)]
                codes = jnp.where(
                    (flat < hot_count)[:, None], hot, cold
                ).reshape(*ids2d.shape, M)
                return jax.vmap(pq_distance)(codes, adts)
            # accurate traversal: always NSP-style (ship distances)
            def one(idv, qq):
                rows = _owned_rows(base_l, idv, shard_idx, p)
                d = _exact_dist(qq, rows, metric)
                mine = (idv % p == shard_idx) & (idv >= 0)
                return jnp.where(mine, d, 0.0)
            d = jax.lax.psum(jax.vmap(one)(ids2d, qb), data_axis)
            hot_d = jax.vmap(
                lambda idv, qq: _exact_dist(
                    qq, hot_base[jnp.clip(idv, 0, hot_base.shape[0] - 1)], metric
                )
            )(ids2d, qb)
            return jnp.where(ids2d < hot_count, hot_d, d)

        def fetch_base(ids2d, qb):
            """Accurate distances for rerank: NSP-style psum of distances."""
            def one(idv, qq):
                rows = _owned_rows(base_l, idv, shard_idx, p)
                d = _exact_dist(qq, rows, metric)
                mine = (idv % p == shard_idx) & (idv >= 0)
                return jnp.where(mine, d, 0.0)
            d = jax.lax.psum(jax.vmap(one)(ids2d, qb), data_axis)
            hot_d = jax.vmap(
                lambda idv, qq: _exact_dist(
                    qq, hot_base[jnp.clip(idv, 0, hot_base.shape[0] - 1)], metric
                )
            )(ids2d, qb)
            return jnp.where(ids2d < hot_count, hot_d, d)

        qb = q_block  # (Qb, D)
        nq = qb.shape[0]
        if use_pq:
            adts = jax.vmap(lambda qq: compute_adt(qq, cents, metric))(qb)
        else:
            adts = jnp.zeros((nq, 1, 1))

        d0 = score(jnp.broadcast_to(entry[None, None], (nq, 1)), adts, qb)[:, 0]
        ids0 = jnp.full((nq, L), -1, jnp.int32).at[:, 0].set(entry)
        dists0 = jnp.full((nq, L), INF).at[:, 0].set(d0)
        acc0 = jnp.full((nq, L), INF)
        if not use_pq:
            acc0 = acc0.at[:, 0].set(d0)
        bits0 = jnp.zeros((nq, bloom_bits // 32), jnp.uint32)
        bits0 = jax.vmap(
            lambda b: bloom.insert(b, entry[None], jnp.ones((1,), bool), num_hashes)
        )(bits0)

        state = dict(
            ids=ids0, dists=dists0, acc=acc0,
            evaluated=jnp.zeros((nq, L), bool), bits=bits0,
            t=jnp.full((nq,), min(t_init, L), jnp.int32),
            prev=jnp.full((nq, k), -2, jnp.int32),
            stable=jnp.zeros((nq,), jnp.int32),
            done=jnp.zeros((nq,), bool),
            rounds=jnp.int32(0),
        )

        def cond(s):
            return (~s["done"].all()) & (s["rounds"] < cfg.max_rounds)

        def body(s):
            valid = s["ids"] >= 0
            unev = valid & ~s["evaluated"]
            has = unev.any(axis=1)
            # per-query beam: positions of the E best unevaluated entries
            # (argmax fast path at E=1, like core.search)
            if E == 1:
                sel = jnp.argmax(unev, axis=1)[:, None]            # (Qb, 1)
            else:
                sel = jnp.argsort(~unev, axis=1, stable=True)[:, :E]
            sel_valid = jnp.arange(E)[None, :] < unev.sum(axis=1)[:, None]
            vs = jnp.where(
                sel_valid, jnp.take_along_axis(s["ids"], sel, 1), 0
            )                                                      # (Qb, E)

            neigh = fetch_adjacency(vs.reshape(-1)).reshape(nq, E * R)
            fresh = jax.vmap(_dedup_round)(neigh)
            fresh &= ~jax.vmap(lambda b, n_: bloom.contains(b, n_, num_hashes))(s["bits"], neigh)
            fresh &= jnp.repeat(sel_valid, R, axis=1)
            nd = jnp.where(fresh, score(neigh, adts, qb), INF)  # collective
            bits = jax.vmap(lambda b, n_, m_: bloom.insert(b, n_, m_, num_hashes))(
                s["bits"], neigh, fresh
            )
            evaluated = s["evaluated"].at[jnp.arange(nq)[:, None], sel].set(
                jnp.take_along_axis(s["evaluated"], sel, 1) | sel_valid
            )
            ids, dists, acc, evaluated = jax.vmap(_merge_sort_topl)(
                s["ids"], s["dists"], s["acc"], evaluated,
                jnp.where(fresh, neigh, -1).astype(jnp.int32), nd,
            )

            valid = ids >= 0
            in_t = (jnp.arange(L)[None, :] < s["t"][:, None]) & valid
            all_eval = in_t.any(1) & (~in_t | evaluated).all(1)
            need = in_t & jnp.isinf(acc)
            acc_new = fetch_base(jnp.maximum(ids, 0), qb)     # collective
            acc2 = jnp.where(need & all_eval[:, None], acc_new, acc)
            if not use_pq:
                acc2 = jnp.where(valid, dists, INF)
            rkey = jnp.where(in_t, acc2, INF)
            new_topk = jax.vmap(lambda i_, k_: _topk_ids_by(i_, k_, k))(ids, rkey)
            same = (new_topk == s["prev"]).all(1)
            stable = jnp.where(all_eval, jnp.where(same, s["stable"] + 1, 1), s["stable"])
            prev = jnp.where(all_eval[:, None], new_topk, s["prev"])
            t = jnp.where(all_eval, s["t"] + t_step, s["t"])
            term = cfg.early_termination & all_eval & (stable >= cfg.repetition_rate)
            done = term | ~has | (t > L)

            new = dict(
                ids=ids, dists=dists, acc=acc2, evaluated=evaluated, bits=bits,
                t=jnp.minimum(t, L), prev=prev, stable=stable,
                done=s["done"] | done, rounds=s["rounds"] + 1,
            )
            # frozen lanes keep their state
            out = {}
            for key in new:
                if key == "rounds":
                    out[key] = new[key]
                    continue
                oldv, newv = s[key], new[key]
                d_ = s["done"]
                while d_.ndim < newv.ndim:
                    d_ = d_[..., None]
                out[key] = jnp.where(d_, oldv, newv)
            return out

        s = jax.lax.while_loop(cond, body, state)

        valid = s["ids"] >= 0
        t_idx = jnp.clip(s["t"], 1, L) - 1
        d_t = jnp.take_along_axis(s["dists"], t_idx[:, None], 1)[:, 0]
        thr = d_t + (cfg.beta - 1.0) * jnp.abs(d_t)
        if use_pq and cfg.rerank:
            need = valid & (s["dists"] <= thr[:, None]) & jnp.isinf(s["acc"])
            acc_new = fetch_base(jnp.maximum(s["ids"], 0), qb)
            acc = jnp.where(need, acc_new, s["acc"])
        else:
            # no rerank (rank by traversal distance) / accurate traversal
            acc = jnp.where(valid, s["dists"], INF)
        key_ = jnp.where(valid, acc, INF)
        neg, idx = jax.lax.top_k(-key_, k)
        out_ids = jnp.take_along_axis(s["ids"], idx, 1)
        return out_ids, -neg

    pspec_sharded = P(data_axis, None, None)
    pspec_rep = P()
    q_spec = P(queue_axis, None)
    fn = shard_map(
        engine,
        mesh=mesh,
        in_specs=(
            pspec_sharded, pspec_sharded, pspec_sharded,  # adjacency/codes/base
            pspec_rep, pspec_rep, pspec_rep, pspec_rep,   # centroids + hot_*
            pspec_rep, pspec_rep,                         # entry, hot_count
            q_spec,                                       # queries
        ),
        out_specs=(q_spec, q_spec),
        check_rep=False,
    )
    return fn(
        corpus.adjacency, corpus.codes, corpus.base,
        corpus.centroids, corpus.hot_adjacency, corpus.hot_codes,
        corpus.hot_base, corpus.entry_point, corpus.hot_count, queries,
    )


def distributed_search(
    corpus: ShardedCorpus,
    queries: jnp.ndarray,
    cfg: SearchConfig,
    metric: str = "l2",
    mode: str = "nsp",
    mesh: Mesh | None = None,
    data_axis: str = "data",
    queue_axis: str = "model",
    bloom_bits: int = 1 << 17,
    num_hashes: int = 8,
):
    """DEPRECATED entry point — builds a ``repro.plan.SearchRequest`` over
    the mesh target and delegates to the ``Searcher`` facade (which calls
    ``distributed_search_kernel`` with identical arguments, so results are
    bit-identical). Use ``distributed_search_kernel`` directly for
    ``.lower``/AOT workflows."""
    from repro.plan import Searcher, SearchRequest
    from repro.plan.searcher import warn_legacy

    warn_legacy("core.distributed_search")
    s = Searcher.open(corpus, cfg=cfg, metric=metric, mesh=mesh, mode=mode,
                      data_axis=data_axis, queue_axis=queue_axis,
                      bloom_bits=bloom_bits, num_hashes=num_hashes)
    return s.search(SearchRequest(queries=queries)).raw
