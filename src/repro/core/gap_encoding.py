"""Gap encoding for adjacency lists (paper §III-E, Fig. 5-a).

Per row: sort neighbour ids ascending, keep the first absolute, store the
rest as deltas to the previous id. The whole graph uses one fixed bit width
b = max(bits(first ids), bits(max delta)) so address arithmetic stays trivial
(paper: "each page uses the same bit length"). Rows are bit-packed into a
flat uint64-backed little-endian bitstream.

The paper reports 20-26 bit widths on 1M-100M graphs -> >=19-37% compression
vs uniform 32-bit; ``compression_ratio`` reproduces that number.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class GapEncodedGraph:
    bits: np.ndarray        # packed little-endian bitstream, uint64 words
    bit_width: int          # fixed width b for every stored value
    num_vertices: int
    max_degree: int         # R — every row padded to R entries

    @property
    def encoded_bytes(self) -> int:
        return self.num_vertices * self.max_degree * self.bit_width // 8

    @property
    def raw_bytes(self) -> int:
        return self.num_vertices * self.max_degree * 4

    @property
    def compression_ratio(self) -> float:
        return 1.0 - (self.num_vertices * self.max_degree * self.bit_width) / (
            self.num_vertices * self.max_degree * 32
        )


def _sorted_padded(adj: np.ndarray) -> np.ndarray:
    """Sort each row ascending. Padding (repeated last neighbour) sorts into
    place as duplicates; deltas for duplicates are 0 — free to encode."""
    return np.sort(adj.astype(np.int64), axis=1)


def gap_encode(adj: np.ndarray) -> GapEncodedGraph:
    n, r = adj.shape
    s = _sorted_padded(adj)
    deltas = np.empty_like(s)
    deltas[:, 0] = s[:, 0]
    deltas[:, 1:] = s[:, 1:] - s[:, :-1]
    assert (deltas >= 0).all()
    max_val = int(deltas.max()) if deltas.size else 0
    bit_width = max(1, int(max_val).bit_length())

    flat = deltas.reshape(-1).astype(np.uint64)
    total_bits = flat.size * bit_width
    words = np.zeros((total_bits + 63) // 64 + 1, dtype=np.uint64)
    positions = np.arange(flat.size, dtype=np.uint64) * np.uint64(bit_width)
    word_idx = positions >> np.uint64(6)
    bit_off = positions & np.uint64(63)
    lo = (flat << bit_off) & np.uint64(0xFFFFFFFFFFFFFFFF)
    # contribution spilling into the next word
    shift_hi = np.uint64(64) - bit_off
    hi = np.where(bit_off > 0, flat >> shift_hi, np.uint64(0))
    np.bitwise_or.at(words, word_idx.astype(np.int64), lo)
    np.bitwise_or.at(words, word_idx.astype(np.int64) + 1, hi)
    return GapEncodedGraph(bits=words, bit_width=bit_width, num_vertices=n, max_degree=r)


def gap_decode(enc: GapEncodedGraph) -> np.ndarray:
    n, r, b = enc.num_vertices, enc.max_degree, enc.bit_width
    count = n * r
    positions = np.arange(count, dtype=np.uint64) * np.uint64(b)
    word_idx = (positions >> np.uint64(6)).astype(np.int64)
    bit_off = positions & np.uint64(63)
    lo = enc.bits[word_idx] >> bit_off
    shift_hi = np.uint64(64) - bit_off
    hi = np.where(bit_off > 0, enc.bits[word_idx + 1] << shift_hi, np.uint64(0))
    mask = (np.uint64(1) << np.uint64(b)) - np.uint64(1) if b < 64 else np.uint64(0xFFFFFFFFFFFFFFFF)
    vals = ((lo | hi) & mask).reshape(n, r).astype(np.int64)
    out = np.cumsum(vals, axis=1)
    return out.astype(np.int32)


def gap_stats(adj: np.ndarray) -> dict:
    enc = gap_encode(adj)
    return {
        "bit_width": enc.bit_width,
        "raw_bytes": enc.raw_bytes,
        "encoded_bytes": enc.encoded_bytes,
        "compression_ratio": enc.compression_ratio,
    }
