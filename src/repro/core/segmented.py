"""Segmented out-of-core index build — the billion-scale blocker breaker.

``core.index.build_index`` materializes the whole corpus (full-precision
base, full n-squared kNN temporaries, full graph) in host memory, so corpus
size is bounded by ONE host's RAM.  ``build_segmented`` consumes the corpus
as a stream of fixed-size segments instead:

    pass 1   reservoir-sample the stream  ->  ONE shared PQ codebook
             (bounded by ``BuildConfig.codebook_sample`` rows)
    pass 2   per segment: PQ-encode -> proximity graph (density-compensated
             ``build_list_size``, see ``core.graph.compensated_build_cfg``)
             -> visit-frequency reordering -> gap encoding.  The expensive
             temporaries (the kNN distance matrix is O(n_seg * n)) are
             bounded by the SEGMENT, not the corpus.
    stitch   cross-segment boundary patching through the streaming insert
             machinery (``repro.stream.stitch``) -> one navigable global
             graph for flat serving.
    emit     segments ARE channel tiles (``shard.tiles_from_segments``) with
             segment centroids as IVF-style routing metadata — sharded
             serving no longer takes the build-flat-then-repartition detour.

A single-segment build is bit-identical to the legacy monolithic pipeline
(``build_index_monolithic``): same codebook (the reservoir is bypassed — one
segment is already fully resident), same graph config (compensation factor
1 is the identity), same reorder trace seed, same beta.  ``build_index`` is
the thin wrapper ``build_segmented(...).to_flat()``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ProximaConfig, upgrade_config
from repro.core import pq as pq_mod
from repro.core.dataset import Dataset, make_dataset
from repro.core.gap_encoding import GapEncodedGraph, gap_encode
from repro.core.graph import Graph, build_graph, compensated_build_cfg
from repro.core.index import ProximaIndex
from repro.core.reorder import Reordering, reorder_segment


@dataclass
class IndexSegment:
    """One built segment: a self-contained mini-index over the contiguous
    global-id block ``[start, start + num_vertices)``.  The graph lives in
    LOCAL (segment-reordered) ids — exactly what a channel tile serves."""
    start: int                          # global id offset of this block
    graph: Graph                        # local ids, reordered within segment
    base: np.ndarray                    # (n_s, D) f32, reordered
    codes: np.ndarray                   # (n_s, M) uint8, reordered
    gap: Optional[GapEncodedGraph]
    reordering: Optional[Reordering]    # source-local -> built-local
    centroid: np.ndarray                # (D,) mean in SEARCH geometry —
                                        # the router's coarse index entry

    @property
    def num_vertices(self) -> int:
        return self.base.shape[0]

    @property
    def hot_count(self) -> int:
        return self.reordering.hot_count if self.reordering else 0


@dataclass
class SegmentedIndex:
    """A segment-built index: shared codebook + per-segment mini-indexes +
    (multi-segment only) the cross-stitched global graph.  Serve it tiled
    via :meth:`tiled_corpus` / ``plan.Searcher.open``, or flatten with
    :meth:`to_flat` for the legacy single-corpus paths."""
    config: ProximaConfig
    codebook: pq_mod.PQCodebook
    segments: List[IndexSegment]
    metric: str
    calibrated_beta: float
    stitch: Optional[object] = None     # stream.stitch.StitchResult (S > 1)
    dataset: Optional[Dataset] = None   # queries/gt in SOURCE id space
    graph_method: str = "knn_prune"

    @property
    def num_segments(self) -> int:
        return len(self.segments)

    @property
    def num_base(self) -> int:
        return sum(s.num_vertices for s in self.segments)

    # ------------------------------------------------------------- routing
    def segment_centroids(self) -> np.ndarray:
        """(S, D) routing metadata: each segment's centroid in search
        geometry — the IVF-style coarse index ``shard.route_queries``
        selects entry tiles with."""
        return np.stack([s.centroid for s in self.segments]).astype(np.float32)

    def global_perm(self) -> np.ndarray:
        """(N,) source global id -> built global id.  Segments keep their
        contiguous block; the per-segment visit-frequency reordering
        permutes WITHIN the block."""
        perm = np.empty(self.num_base, np.int32)
        for seg in self.segments:
            n = seg.num_vertices
            local = seg.reordering.perm if seg.reordering is not None \
                else np.arange(n, dtype=np.int32)
            perm[seg.start : seg.start + n] = seg.start + local
        return perm

    # ------------------------------------------------------------ emission
    def to_flat(self) -> ProximaIndex:
        """Flatten to a legacy ``ProximaIndex``.  Single segment: the exact
        monolithic artifacts (graph/codes/reordering/beta bit-identical).
        Multi-segment: the stitched global graph over the concatenated
        blocks (per-segment hot prefixes are NOT a global hot prefix, so
        ``reordering`` is None and ``hot_count`` is 0 — serve multi-segment
        builds tiled to keep hot-node accounting)."""
        cfg = self.config
        if self.num_segments == 1:
            seg = self.segments[0]
            ds = self._flat_dataset(seg.base, seg.reordering)
            return ProximaIndex(
                config=cfg, dataset=ds, graph=seg.graph,
                codebook=self.codebook, codes=seg.codes, gap=seg.gap,
                reordering=seg.reordering,
                calibrated_beta=self.calibrated_beta,
            )
        if self.stitch is None:
            raise ValueError(
                "multi-segment index was built without stitching — cannot "
                "flatten to a navigable single graph"
            )
        base = np.concatenate([s.base for s in self.segments])
        codes = np.concatenate([s.codes for s in self.segments])
        graph = self.stitch.graph
        gap = gap_encode(graph.adjacency) if cfg.gap_encode else None
        ds = self._flat_dataset(base, None, perm=self.global_perm())
        return ProximaIndex(
            config=cfg, dataset=ds, graph=graph, codebook=self.codebook,
            codes=codes, gap=gap, reordering=None,
            calibrated_beta=self.calibrated_beta,
        )

    def _flat_dataset(self, base, reordering, perm=None) -> Dataset:
        from repro.core.reorder import remap_ground_truth

        if self.dataset is None:
            d = base.shape[1]
            return Dataset(
                base=base, queries=np.zeros((0, d), np.float32),
                gt=np.zeros((0, 1), np.int32), metric=self.metric,
                config=self.config.dataset,
            )
        gt = self.dataset.gt
        if reordering is not None:
            gt = remap_ground_truth(reordering, gt)
        elif perm is not None:
            gt = perm[gt]
        return Dataset(
            base=base, queries=self.dataset.queries, gt=gt,
            metric=self.dataset.metric, config=self.dataset.config,
        )

    def tiled_corpus(self):
        """Direct-to-tile emission: (TiledCorpus, TilePartition) with one
        tile per segment — see ``shard.tiles_from_segments``."""
        from repro.shard import tiles_from_segments

        return tiles_from_segments(self)

    # ---------------------------------------------------------- accounting
    def index_bytes(self) -> dict:
        """Per-segment storage accounting plus corpus totals — the same
        categories as ``ProximaIndex.index_bytes`` with a ``per_segment``
        breakdown; single-segment totals equal the flat build's exactly."""
        per = []
        for seg in self.segments:
            n, r = seg.graph.adjacency.shape
            idx_raw = n * r * 4
            idx_gap = seg.gap.encoded_bytes if seg.gap else idx_raw
            pq_bytes = seg.codes.nbytes
            hot_extra = seg.hot_count * r * seg.codes.shape[1]
            per.append({
                "raw_bytes": seg.base.nbytes,
                "index_bytes_uncompressed": idx_raw,
                "index_bytes_gap": idx_gap,
                "pq_bytes": pq_bytes,
                "hot_repetition_bytes": hot_extra,
                "total_bytes": seg.base.nbytes + idx_gap + pq_bytes + hot_extra,
            })
        totals = {k: sum(p[k] for p in per) for k in per[0]}
        totals["per_segment"] = per
        return totals

    def build_trace(self, index_bits: int = 32):
        """Build-time NAND workload: per-segment program volume plus the
        adjacency rows stitching re-programmed (the build-side write
        amplification) — feed to ``nand.simulate_build``."""
        from repro.nand.simulator import BuildTrace

        return BuildTrace(
            segment_sizes=tuple(s.num_vertices for s in self.segments),
            stitched_rows=self.stitch.patched_rows if self.stitch else 0,
            dim=self.segments[0].base.shape[1],
            r_degree=self.config.graph.max_degree,
            index_bits=index_bits,
            pq_bits=8 * self.segments[0].codes.shape[1],
        )


def reservoir_sample(source, cap: int, seed: int = 0) -> np.ndarray:
    """Algorithm-R over a segment stream: a uniform sample of
    ``min(cap, N)`` rows in one pass with O(cap) memory.  Vectorized per
    segment — replacement indices are drawn for a whole segment at once and
    applied in order (NumPy fancy assignment is last-write-wins), which is
    exactly the sequential algorithm."""
    rng = np.random.default_rng(seed)
    cap = min(cap, source.num_base)
    buf = np.empty((cap, source.dim), np.float32)
    seen = 0
    for seg in source:
        seg = np.asarray(seg, np.float32)
        m = seg.shape[0]
        take = min(max(cap - seen, 0), m)
        if take:
            buf[seen : seen + take] = seg[:take]
        if take < m:
            rest = seg[take:]
            pos = seen + take + np.arange(rest.shape[0])
            j = rng.integers(0, pos + 1)
            keep = j < cap
            buf[j[keep]] = rest[keep]
        seen += m
    return buf


def _build_segment(
    start: int,
    seg_base: np.ndarray,
    codebook: pq_mod.PQCodebook,
    cfg: ProximaConfig,
    metric: str,
    num_segments: int,
    seg_idx: int,
    graph_method: str,
    reorder_samples: int,
) -> tuple:
    """The monolithic pipeline applied to ONE segment (encode -> graph ->
    reorder -> gap); with ``num_segments == 1`` every step degenerates to
    the legacy build exactly.  Returns ``(IndexSegment, enc_in)`` — the
    (reordered) encoder input is only kept when the caller calibrates."""
    enc_in = seg_base
    if metric == "angular":
        enc_in = enc_in / np.maximum(
            np.linalg.norm(enc_in, axis=-1, keepdims=True), 1e-12
        )
    codes = np.asarray(
        pq_mod.encode(jnp.asarray(enc_in), jnp.asarray(codebook.centroids))
    )

    # each segment holds a 1/S sample of every cluster -> compensate the
    # build neighbourhood (identity for a single segment)
    gcfg = compensated_build_cfg(cfg.graph, num_segments, seg_base.shape[0])
    graph = build_graph(seg_base, gcfg, metric, method=graph_method)

    reordering = None
    if cfg.hot_node_fraction > 0:
        # segment 0 keeps the legacy trace seed (single-segment bit-
        # identity); later segments decorrelate their trace samples
        seed = cfg.dataset.seed + (seg_idx if num_segments > 1 else 0)
        graph, seg_base, enc_in, codes, reordering = reorder_segment(
            graph, seg_base, enc_in, codes, codebook.centroids, cfg.search,
            metric, cfg.hot_node_fraction, num_samples=reorder_samples,
            seed=seed,
        )

    gap = gap_encode(graph.adjacency) if cfg.gap_encode else None
    cent_in = enc_in if metric == "angular" else seg_base
    seg = IndexSegment(
        start=start, graph=graph, base=seg_base, codes=codes, gap=gap,
        reordering=reordering,
        centroid=cent_in.mean(0).astype(np.float32),
    )
    return seg, enc_in


def build_segmented(
    cfg: ProximaConfig,
    source=None,
    dataset: Optional[Dataset] = None,
    graph_method: str = "knn_prune",
    reorder_samples: int = 128,
    calibrate: bool = False,
    segment_size: Optional[int] = None,
) -> SegmentedIndex:
    """Build a :class:`SegmentedIndex` from a segment ``source`` (any object
    with ``num_base``/``dim``/``num_segments``/``segment(s)``/``bounds(s)``,
    e.g. ``core.dataset.ArraySegmentSource`` or ``SyntheticSegmentSource``).

    With no ``source``, the ``dataset`` (or ``make_dataset(cfg.dataset)``)
    is viewed through ``Dataset.as_source``; ``segment_size`` overrides
    ``cfg.build.segment_size`` (0 -> one segment, the legacy pipeline)."""
    bc = upgrade_config(cfg).build
    ds = dataset
    if source is None:
        if ds is None:
            ds = make_dataset(cfg.dataset)
        sz = bc.segment_size if segment_size is None else segment_size
        source = ds.as_source(sz)
    metric = ds.metric if ds is not None else (
        getattr(source, "metric", None) or cfg.dataset.metric or "l2"
    )
    num_segments = source.num_segments

    # --- pass 1: shared PQ codebook on a bounded reservoir sample.  ONE
    # segment is already fully resident, so the reservoir is bypassed and
    # the codebook is trained on exactly the legacy input.
    if num_segments == 1:
        sample = np.asarray(source.segment(0), np.float32)
    else:
        sample = reservoir_sample(source, bc.codebook_sample, cfg.pq.seed)
    codebook = pq_mod.train_pq(sample, cfg.pq, metric)
    del sample

    # --- pass 2: per-segment encode/graph/reorder/gap
    segments: List[IndexSegment] = []
    enc_ins: List[np.ndarray] = []
    for s in range(num_segments):
        seg_base = np.asarray(source.segment(s), np.float32)
        lo, _ = source.bounds(s)
        seg, enc_in = _build_segment(
            lo, seg_base, codebook, cfg, metric, num_segments, s,
            graph_method, reorder_samples,
        )
        segments.append(seg)
        if calibrate:
            enc_ins.append(enc_in)

    # --- cross-segment stitching (streaming insert machinery)
    stitch = None
    if num_segments > 1:
        from repro.stream.stitch import stitch_segments

        stitch = stitch_segments(segments, metric, cfg.graph, bc)

    beta = cfg.search.beta
    if calibrate:
        rng = np.random.default_rng(cfg.dataset.seed)
        codes_all = segments[0].codes if num_segments == 1 \
            else np.concatenate([g.codes for g in segments])
        enc_all = enc_ins[0] if num_segments == 1 \
            else np.concatenate(enc_ins)
        beta = pq_mod.calibrate_beta(codebook, codes_all, enc_all, rng)

    return SegmentedIndex(
        config=cfg, codebook=codebook, segments=segments, metric=metric,
        calibrated_beta=beta, stitch=stitch, dataset=ds,
        graph_method=graph_method,
    )
