"""Graph index reordering + hot-node selection (paper §IV-E, Fig. 10-a).

Vertices are renumbered by descending visit frequency, measured by tracing
searches over randomly sampled base vectors (exactly the paper's procedure:
"the calculation of vertices' visiting frequency is based on the graph search
trace from the randomly sampled base data"). After reordering, the entry
point has index 0 and the hottest ``hot_fraction`` of nodes occupy the lowest
ids — the search layer and the NAND model both treat ``id < hot_count`` as a
hot-node-repetition hit (NN indices + neighbours' PQ codes co-located).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import SearchConfig
from repro.core.graph import Graph


@dataclass
class Reordering:
    perm: np.ndarray        # old id -> new id
    inv: np.ndarray         # new id -> old id
    hot_count: int


def trace_visit_frequency(
    graph: Graph,
    base: np.ndarray,
    codes: np.ndarray,
    centroids: np.ndarray,
    cfg: SearchConfig,
    metric: str,
    num_samples: int = 128,
    seed: int = 0,
) -> np.ndarray:
    """Expansion-frequency histogram from sampled-base-vector searches."""
    from repro.core.search import search_reference

    rng = np.random.default_rng(seed)
    n = graph.num_vertices
    freq = np.zeros(n, dtype=np.int64)
    sample = rng.choice(n, size=min(num_samples, n), replace=False)
    for qi in sample:
        _, _, counters = search_reference(
            graph.adjacency, graph.degrees, codes, base, centroids,
            graph.entry_point, base[qi], cfg, metric,
            trace=freq,
        )
    return freq


def reorder_graph(
    graph: Graph, freq: np.ndarray, hot_fraction: float
) -> tuple[Graph, Reordering]:
    """Renumber vertices by descending visit frequency; entry point -> 0."""
    n = graph.num_vertices
    # entry point must stay hottest (it is visited by every query)
    key = freq.astype(np.float64).copy()
    key[graph.entry_point] = np.inf
    order = np.argsort(-key, kind="stable")       # new id -> old id
    inv = order.astype(np.int32)
    perm = np.empty(n, dtype=np.int32)            # old id -> new id
    perm[order] = np.arange(n, dtype=np.int32)
    new_adj = perm[graph.adjacency[inv]]          # remap rows + contents
    new_deg = graph.degrees[inv]
    hot_count = int(np.ceil(hot_fraction * n)) if hot_fraction > 0 else 0
    g2 = Graph(
        adjacency=new_adj.astype(np.int32),
        degrees=new_deg.astype(np.int32),
        entry_point=int(perm[graph.entry_point]),
        metric=graph.metric,
    )
    return g2, Reordering(perm=perm, inv=inv, hot_count=hot_count)


def reorder_segment(
    graph: Graph,
    base: np.ndarray,
    enc_in: np.ndarray,
    codes: np.ndarray,
    centroids: np.ndarray,
    cfg: SearchConfig,
    metric: str,
    hot_fraction: float,
    num_samples: int = 128,
    seed: int = 0,
) -> tuple:
    """Trace -> renumber -> permute EVERY row-aligned array of one built
    segment (base, the encoder input, and the PQ codes together — permuting
    a subset is exactly the row-misalignment bug ``calibrate_beta`` used to
    hit).  Shared by the monolithic pipeline (one segment = the corpus) and
    the segmented builder.  Returns ``(graph, base, enc_in, codes,
    Reordering)``."""
    freq = trace_visit_frequency(
        graph, enc_in, codes, centroids, cfg, metric,
        num_samples=num_samples, seed=seed,
    )
    graph, reord = reorder_graph(graph, freq, hot_fraction)
    base, enc_in, codes = apply_reordering(reord, base, enc_in, codes)
    return graph, base, enc_in, codes, reord


def apply_reordering(reord: Reordering, *arrays: np.ndarray) -> tuple:
    """Permute data arrays (base, codes, ...) into the new id space."""
    return tuple(a[reord.inv] for a in arrays)


def remap_ground_truth(reord: Reordering, gt: np.ndarray) -> np.ndarray:
    return reord.perm[gt]
