"""IVF-PQ baseline (the paper's non-graph comparison, FAISS-IVF in Fig. 11).

Classic inverted-file index: a coarse k-means quantizer partitions the corpus
into nlist buckets; at query time the nprobe nearest buckets are scanned and
candidates are scored with PQ (optionally on residuals, as FAISS IVFPQ does).
No reranking by default — reproducing the paper's observation that lossy PQ
compression saturates recall around 80-90% while graph+rerank keeps climbing.

The scan is the batched PQ-scoring hot spot and routes through the Pallas
kernels (pq_adt + pq_lookup) when ``use_pallas=True``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PQConfig
from repro.core import pq as pq_mod
from repro.core.dataset import pairwise_dist


@dataclass
class IVFIndex:
    coarse_centroids: np.ndarray   # (nlist, D)
    lists: np.ndarray              # (nlist, max_len) int32, -1 padded
    list_codes: np.ndarray         # (nlist, max_len, M) uint8
    codebook: pq_mod.PQCodebook
    residual: bool
    metric: str


def build_ivf(
    base: np.ndarray,
    pq_cfg: PQConfig,
    metric: str = "l2",
    nlist: int = 64,
    residual: bool = True,
    seed: int = 0,
) -> IVFIndex:
    rng = np.random.default_rng(seed)
    x = np.asarray(base, np.float32)
    if metric == "angular":
        x = x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    n = x.shape[0]
    # coarse k-means
    init = x[rng.choice(n, size=nlist, replace=False)]
    cent = jnp.asarray(init)
    xs = jnp.asarray(x)
    for _ in range(10):
        d = (
            (xs * xs).sum(-1)[:, None]
            - 2.0 * xs @ cent.T
            + (cent * cent).sum(-1)[None, :]
        )
        assign = jnp.argmin(d, axis=1)
        oh = jax.nn.one_hot(assign, nlist, dtype=xs.dtype)
        counts = oh.sum(0)
        cent = jnp.where(
            counts[:, None] > 0, (oh.T @ xs) / jnp.maximum(counts, 1)[:, None], cent
        )
    cent = np.asarray(cent)
    assign = np.asarray(assign)

    enc_input = x - cent[assign] if residual else x
    codebook = pq_mod.train_pq(enc_input, pq_cfg, "l2" if residual else metric)
    codes = np.asarray(
        pq_mod.encode(jnp.asarray(enc_input), jnp.asarray(codebook.centroids))
    )

    max_len = int(np.bincount(assign, minlength=nlist).max())
    lists = np.full((nlist, max_len), -1, np.int32)
    list_codes = np.zeros((nlist, max_len, codes.shape[1]), np.uint8)
    fill = np.zeros(nlist, np.int64)
    for i, a in enumerate(assign):
        lists[a, fill[a]] = i
        list_codes[a, fill[a]] = codes[i]
        fill[a] += 1
    return IVFIndex(
        coarse_centroids=cent, lists=lists, list_codes=list_codes,
        codebook=codebook, residual=residual, metric=metric,
    )


def search_ivf(
    index: IVFIndex,
    queries: np.ndarray,
    k: int,
    nprobe: int = 8,
    use_pallas: bool = False,
):
    """Returns (ids (Q,k), dists (Q,k), n_pq_scored (Q,))."""
    q = np.asarray(queries, np.float32)
    if index.metric == "angular":
        q = q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    d_coarse = pairwise_dist(q, index.coarse_centroids, index.metric)
    probes = np.argsort(d_coarse, axis=1)[:, :nprobe]           # (Q, nprobe)

    cents = jnp.asarray(index.codebook.centroids)
    lists = jnp.asarray(index.lists)
    list_codes = jnp.asarray(index.list_codes)
    metric = "l2" if index.residual else index.metric
    coarse = jnp.asarray(index.coarse_centroids)

    @partial(jax.jit, static_argnames=())
    def score_one(qq, probe_rows):
        cand_ids = lists[probe_rows].reshape(-1)                # (nprobe*max,)
        cand_codes = list_codes[probe_rows].reshape(-1, list_codes.shape[-1])
        if index.residual:
            # ADT per probed list against the query residual
            res = qq[None, :] - coarse[probe_rows]              # (nprobe, D)
            adts = jax.vmap(
                lambda r: pq_mod.compute_adt(r, cents, metric)
            )(res)                                              # (nprobe, M, C)
            per_list = list_codes[probe_rows]                   # (nprobe, max, M)
            d = jax.vmap(lambda c, a: pq_mod.pq_distance(c, a))(per_list, adts)
            d = d.reshape(-1)
        else:
            adt = pq_mod.compute_adt(qq, cents, metric)
            d = pq_mod.pq_distance(cand_codes, adt)
        d = jnp.where(cand_ids >= 0, d, jnp.inf)
        neg, idx = jax.lax.top_k(-d, k)
        return cand_ids[idx], -neg, (cand_ids >= 0).sum()

    if use_pallas:
        from repro.kernels import ops

        def score_one_pallas(qq, probe_rows):
            cand_ids = lists[probe_rows].reshape(-1)
            cand_codes = list_codes[probe_rows].reshape(-1, list_codes.shape[-1])
            if index.residual:
                res = qq[None, :] - coarse[probe_rows]
                adts = ops.pq_adt(res, cents, metric)
                per_list = list_codes[probe_rows]
                d = jnp.stack(
                    [ops.pq_lookup(per_list[i], adts[i]) for i in range(probe_rows.shape[0])]
                ).reshape(-1)
            else:
                adt = ops.pq_adt(qq[None], cents, metric)[0]
                d = ops.pq_lookup(cand_codes, adt)
            d = jnp.where(cand_ids >= 0, d, jnp.inf)
            neg, idx = jax.lax.top_k(-d, k)
            return cand_ids[idx], -neg, (cand_ids >= 0).sum()

        score_one = score_one_pallas

    out_ids, out_d, out_n = [], [], []
    qj = jnp.asarray(q)
    pj = jnp.asarray(probes)
    for i in range(q.shape[0]):
        ids, ds, nn = score_one(qj[i], pj[i])
        out_ids.append(np.asarray(ids))
        out_d.append(np.asarray(ds))
        out_n.append(int(nn))
    return np.stack(out_ids), np.stack(out_d), np.asarray(out_n)
