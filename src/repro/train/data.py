"""Synthetic sharded data pipeline.

Stateless and step-seeded: ``batch_for_step(step)`` is a pure function of
(seed, step), so checkpoint/restart and elastic re-meshing resume the exact
token stream with NO pipeline state in the checkpoint — the fault-tolerance
story (DESIGN.md §5) leans on this.

The synthetic LM task mixes three learnable structures so a ~100M model shows
a real loss curve in a few hundred steps:
  * Zipf-distributed unigrams (learnable bias toward frequent tokens)
  * first-order Markov chains with banded transitions (learnable bigrams)
  * periodic copy patterns (induction-head-style repetition)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    copy_period: int = 64
    frontend_tokens: int = 0
    frontend_dim: int = 0
    family: str = "dense"


def _tokens_for_step(cfg: DataConfig, step: int) -> np.ndarray:
    rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    veff = min(v, 50000)
    # zipf unigrams
    ranks = np.arange(1, veff + 1, dtype=np.float64)
    probs = ranks ** -cfg.zipf_a
    probs /= probs.sum()
    toks = rng.choice(veff, size=(b, s), p=probs)
    # markov band: with p=0.5 next token = prev + small delta (mod veff)
    deltas = rng.integers(-4, 5, size=(b, s))
    markov = (np.roll(toks, 1, axis=1) + deltas) % veff
    use_markov = rng.random((b, s)) < 0.5
    toks = np.where(use_markov, markov, toks)
    # periodic copy: second half of each period repeats the first half
    p = cfg.copy_period
    if s >= 2 * p:
        idx = np.arange(s)
        phase = idx % (2 * p)
        src = idx - p
        copy_mask = (phase >= p) & (src >= 0)
        toks[:, copy_mask] = toks[:, src[copy_mask]]
    return toks.astype(np.int32)


def batch_for_step(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    toks = _tokens_for_step(cfg, step)
    batch: Dict[str, np.ndarray] = {
        "tokens": toks[:, :-1].copy(),
        "labels": toks[:, 1:].copy(),
    }
    if cfg.family == "vlm":
        rng = np.random.default_rng(np.uint64(cfg.seed * 7 + step))
        batch["frontend"] = rng.standard_normal(
            (cfg.global_batch, cfg.frontend_tokens, cfg.frontend_dim)
        ).astype(np.float32)
    elif cfg.family == "encdec":
        rng = np.random.default_rng(np.uint64(cfg.seed * 7 + step))
        batch["frontend"] = rng.standard_normal(
            (cfg.global_batch, cfg.seq_len - 1, cfg.frontend_dim)
        ).astype(np.float32)
    return batch


def device_put_batch(batch, mesh, sharding):
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}
