"""AdamW optimizer + LR schedules, pure JAX (no optax dependency).

Moments are fp32 and inherit the parameter sharding (elementwise ops under
jit/GSPMD keep the operand sharding), so with the FSDP rules in
``repro.distributed.sharding`` the optimizer state is fully ZeRO-sharded.
Gradient clipping is by global norm (fp32 accumulation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1

    def schedule(self, step: jnp.ndarray) -> jnp.ndarray:
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(self.warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (s - self.warmup_steps) / max(self.total_steps - self.warmup_steps, 1),
            0.0, 1.0,
        )
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        ratio = self.min_lr_ratio + (1 - self.min_lr_ratio) * cos
        return self.lr * warm * ratio

    def init(self, params) -> AdamWState:
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree_util.tree_map(jnp.copy, zeros))

    def apply(
        self, grads, state: AdamWState, params
    ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        # NOTE: square-sum per leaf, NOT jnp.vdot — vdot ravels the sharded
        # tensor to 1-D, which GSPMD cannot shard (involuntary full
        # rematerialization: a replicated fp32 copy of every gradient).
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g)) for g in jax.tree_util.tree_leaves(g32))
        )
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        lr = self.schedule(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, n):
            g = g * scale
            m2 = self.b1 * m + (1 - self.b1) * g
            n2 = self.b2 * n + (1 - self.b2) * g * g
            mhat = m2 / b1c
            nhat = n2 / b2c
            delta = mhat / (jnp.sqrt(nhat) + self.eps)
            decay = self.weight_decay if p.ndim >= 2 else 0.0
            p32 = p.astype(jnp.float32)
            p2 = p32 - lr * (delta + decay * p32)
            return p2.astype(p.dtype), m2, n2

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(g32)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_n = tdef.flatten_up_to(state.nu)
        out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_m, flat_n)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_n = tdef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step=step, mu=new_m, nu=new_n), {
            "grad_norm": gnorm, "lr": lr,
        }
