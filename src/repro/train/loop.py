"""Train-step factory: microbatched gradient accumulation, GSPMD sharding,
donated buffers — the production training path used by launch/train.py and
the dry-run.

``make_train_step`` builds a jit'd function
    (train_state, batch) -> (train_state, metrics)
with in/out shardings resolved from the logical specs, gradient accumulation
over ``microbatches`` (lax.scan, fp32 accumulators), and remat already
applied per block inside the model.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shard_lib
from repro.models.model import Model
from repro.train.optimizer import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(model: Model, optimizer: AdamW, rng) -> Tuple[TrainState, Any]:
    params, specs = model.init(rng)
    opt = optimizer.init(params)
    return TrainState(params=params, opt=opt), specs


def state_shardings(specs, state: Any, mesh: Mesh):
    """NamedSharding pytree for a TrainState (moments mirror params)."""
    p_sh = shard_lib.param_shardings(specs, state.params, mesh)
    return TrainState(
        params=p_sh,
        opt=AdamWState(
            step=NamedSharding(mesh, P()),
            mu=jax.tree_util.tree_map(
                lambda a, s: s, state.opt.mu, p_sh
            ),
            nu=jax.tree_util.tree_map(lambda a, s: s, state.opt.nu, p_sh),
        ),
    )


def make_train_step(
    model: Model,
    optimizer: AdamW,
    mesh: Mesh,
    microbatches: int = 1,
    donate: bool = True,
    param_shardings: Any = None,
):
    """Returns (train_step, batch_sharding). ``param_shardings``: optional
    NamedSharding pytree matching params — applied to the fp32 gradient
    accumulator so it stays ZeRO-sharded across the microbatch scan (without
    it GSPMD replicates the accumulator: 268 GB/device for a 67B model)."""
    bspec = shard_lib.batch_spec(mesh)
    bshard = NamedSharding(mesh, bspec)

    def constrain_grads(g):
        if param_shardings is None:
            return g
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, g, param_shardings
        )

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)

            def acc_step(carry, mb):
                gsum, lsum = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb
                )
                gsum = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(jnp.float32), gsum, g
                )
                return (constrain_grads(gsum), lsum + loss), None

            zeros = constrain_grads(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            ))
            (gsum, lsum), _ = jax.lax.scan(acc_step, (zeros, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch
            )
            grads = constrain_grads(grads)
        new_params, new_opt, om = optimizer.apply(grads, state.opt, state.params)
        metrics = {"loss": loss, **om}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step, bshard


def jit_train_step(train_step, state_sh, batch_sh, donate: bool = True):
    return jax.jit(
        train_step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, NamedSharding(batch_sh.mesh, P())),
        donate_argnums=(0,) if donate else (),
    )


def make_serve_step(model: Model, mesh: Mesh, seq_shard: bool = False):
    """Returns a decode_step closure suitable for jit with explicit cache
    shardings (launch/dryrun.py lowers this for decode cells)."""

    def serve_step(params, cache, tokens):
        logits, cache2 = model.decode_step(params, cache, tokens)
        return logits, cache2

    return serve_step
