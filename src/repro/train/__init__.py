"""repro.train subpackage."""
