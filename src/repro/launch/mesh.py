"""Production mesh construction.

Single pod:  (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh with Auto axis types (tests / small-scale runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def chips(mesh: Mesh) -> int:
    return mesh.devices.size
