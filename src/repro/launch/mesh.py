"""Production mesh construction.

Single pod:  (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto
    AxisType = None


def _make(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh with Auto axis types (tests / small-scale runs)."""
    return _make(tuple(shape), tuple(axes))


def chips(mesh: Mesh) -> int:
    return mesh.devices.size
