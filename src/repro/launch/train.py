"""Training launcher: fault-tolerant LM training on synthetic data.

    PYTHONPATH=src python -m repro.launch.train \
        --arch stablelm-1.6b --smoke --steps 100 --batch 8 --seq 129 \
        --ckpt-dir /tmp/ckpt

``--smoke`` uses the reduced config (CPU-feasible); omit it on real hardware
to train the full architecture. ``--params-millions`` builds a custom-width
dense model instead (e.g. 100 for the ~100M example).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ModelConfig
from repro.distributed.fault import FaultConfig, FaultTolerantLoop
from repro.launch.mesh import make_mesh
from repro.models.model import build_model
from repro.train.data import DataConfig, batch_for_step
from repro.train.loop import init_train_state, make_train_step
from repro.train.optimizer import AdamW


def custom_dense_config(params_millions: float, vocab: int = 32768) -> ModelConfig:
    """A dense config sized to roughly the requested parameter count."""
    # params ~ 12 L d^2 + 2 V d ; fix L = max(8, d/64), solve d numerically
    import numpy as np

    target = params_millions * 1e6
    d = 256
    while True:
        L = max(8, d // 64)
        n = 12 * L * d * d + 2 * vocab * d
        if n >= target or d >= 8192:
            break
        d += 64
    return ModelConfig(
        name=f"dense-{params_millions:.0f}m", family="dense",
        num_layers=max(8, d // 64), d_model=d, num_heads=max(d // 64, 2),
        num_kv_heads=max(d // 64, 2), d_ff=4 * d, vocab_size=vocab,
        max_position=4096,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--params-millions", type=float, default=0.0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=129)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.params_millions > 0:
        cfg = custom_dense_config(args.params_millions)
    elif args.smoke:
        cfg = get_smoke_config(args.arch)
    else:
        cfg = get_config(args.arch)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M")

    model = build_model(cfg, q_chunk=max(args.seq - 1, 64))
    opt = AdamW(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                total_steps=args.steps)
    state, specs = init_train_state(model, opt, jax.random.PRNGKey(0))
    mesh = make_mesh((1, 1), ("data", "model"))
    ts, _ = make_train_step(model, opt, mesh, microbatches=args.microbatches)
    ts = jax.jit(ts, donate_argnums=(0,))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, copy_period=16,
                      family=cfg.family,
                      frontend_tokens=cfg.frontend_tokens,
                      frontend_dim=cfg.frontend_dim)

    def step_fn(st, step):
        batch = {k: jnp.asarray(v) for k, v in batch_for_step(dcfg, step).items()}
        st, m = ts(st, batch)
        return st, {k: float(v) for k, v in m.items()}

    t0 = time.time()

    def on_metrics(step, m):
        if step % args.log_every == 0:
            dt = time.time() - t0
            tok = step * args.batch * (args.seq - 1)
            print(f"step {step:5d} loss {m['loss']:.4f} "
                  f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} "
                  f"({tok/dt:.0f} tok/s)", flush=True)

    if args.ckpt_dir:
        loop = FaultTolerantLoop(
            step_fn, state,
            FaultConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        )
        loop.try_resume()
        loop.run(args.steps - loop.step, on_metrics=on_metrics)
    else:
        for step in range(args.steps):
            state, m = step_fn(state, step)
            on_metrics(step + 1, m)


if __name__ == "__main__":
    main()
