"""repro.launch subpackage."""
