"""Serving launcher: batched Proxima ANN query serving (the paper's workload).

    PYTHONPATH=src python -m repro.launch.serve --num-base 4000 --queries 256
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.base import (
    DatasetConfig, GraphConfig, PQConfig, ProximaConfig, SearchConfig,
)
from repro.core import build_index, recall_at_k
from repro.serve.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-base", type=int, default=4000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--arrival-qps", type=float, default=0.0,
                    help="simulated request arrival rate (0 = closed loop)")
    args = ap.parse_args()

    cfg = ProximaConfig(
        dataset=DatasetConfig(name="sift-like", num_base=args.num_base,
                              num_queries=args.queries, dim=args.dim,
                              num_clusters=32, cluster_std=0.35, seed=0),
        pq=PQConfig(num_subvectors=32 if args.dim % 32 == 0 else 16,
                    num_centroids=128),
        graph=GraphConfig(max_degree=24, build_list_size=48),
        search=SearchConfig(k=args.k, list_size=64, t_init=16, t_step=8,
                            repetition_rate=2, beta=1.06),
        hot_node_fraction=0.03,
    )
    print("building index ...", flush=True)
    t0 = time.time()
    idx = build_index(cfg, reorder_samples=64)
    print(f"index built in {time.time()-t0:.1f}s "
          f"(gap {idx.gap.bit_width}b, {idx.gap.compression_ratio:.0%} saved; "
          f"hot {idx.hot_count} nodes)")

    eng = ServingEngine(idx, batch_size=args.batch_size)
    queries = idx.dataset.queries
    t0 = time.time()
    for i in range(queries.shape[0]):
        eng.submit(queries[i])
        if args.arrival_qps > 0:
            time.sleep(1.0 / args.arrival_qps)
        eng.step()
    done = list(eng.done.values()) + eng.drain()
    dt = time.time() - t0
    done = sorted(eng.done.values(), key=lambda r: r.rid)
    lats = np.asarray([r.latency_ms for r in done])
    ids = np.stack([r.ids for r in done])
    rec = recall_at_k(ids, idx.dataset.gt, args.k)
    print(f"served {len(done)} queries in {dt:.2f}s -> {len(done)/dt:.0f} QPS")
    print(f"latency p50 {np.percentile(lats,50):.1f}ms "
          f"p99 {np.percentile(lats,99):.1f}ms | recall@{args.k} {rec:.3f} | "
          f"batches {eng.stats['batches']}")


if __name__ == "__main__":
    main()
