import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell
on the production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh both --out results/dryrun.json

Per cell this builds the abstract train/serve step (ShapeDtypeStruct only —
no real allocation), jit-lowers it with explicit in/out shardings, compiles,
and records:
  * memory_analysis (bytes per device: argument/output/temp/peak)
  * cost_analysis (FLOPs, bytes accessed)
  * collective bytes parsed from the compiled HLO (roofline/analysis.py)
Results are written incrementally, so interrupted runs resume.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shard_lib
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model, build_model, input_specs
from repro.roofline import analysis as roofline
from repro.train.loop import TrainState, make_train_step
from repro.train.optimizer import AdamW


def model_init_specs(model: Model):
    """Logical specs are static metadata: evaluate init abstractly but pull
    the spec pytree out via closure (init returns (params, specs))."""
    holder = {}

    def make():
        params, specs = model.init(jax.random.PRNGKey(0))
        holder["specs"] = specs
        return params

    params_shape = jax.eval_shape(make)
    return params_shape, holder["specs"]


# train cells whose saved-activation stacks exceed v5e HBM without
# sequence-parallel residual sharding (see EXPERIMENTS.md §Perf iteration 2)
SEQ_PARALLEL_TRAIN = {
    "mistral-nemo-12b", "granite-34b", "deepseek-67b", "mixtral-8x22b",
    "falcon-mamba-7b", "zamba2-1.2b",
}

# per-arch MoE dispatch-buffer layout (EXPERIMENTS.md §Perf M4/M5):
# few-expert models prefer the data-sharded dispatch buffer; many-expert
# models do better with GSPMD's expert-dim strategy
MOE_DISPATCH_HINT = {"mixtral-8x22b": True, "granite-moe-3b-a800m": False}

# prefill cells whose single-shot buffers exceed HBM -> segmented prefill
# (EXPERIMENTS.md §Perf P1); vlm/encdec keep the single-shot path.
# deepseek-67b is EXCLUDED: chunking regressed it (51 vs 40 GB — the
# cache-resident attention rematerializes fp32 copies; refuted, see log)
CHUNKED_PREFILL = {
    "granite-moe-3b-a800m", "mixtral-8x22b", "zamba2-1.2b",
}
CHUNKED_PREFILL_SEG = 4096


def _cell_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Per-device microbatch of ~1 sequence for train cells (memory-safe
    default at 4k seq; the §Perf log sweeps this knob)."""
    bsz = int(np.prod([mesh.shape[a] for a in shard_lib.batch_axes(mesh)]))
    if shape.kind != "train":
        return 1
    per_dev = max(shape.global_batch // bsz, 1)
    return per_dev


def lower_cell(
    arch: str,
    shape: ShapeConfig,
    mesh,
    model_kw: Optional[Dict[str, Any]] = None,
    microbatches: Optional[int] = None,
) -> Dict[str, Any]:
    """Lower + compile one cell; returns the result record."""
    cfg = get_config(arch)
    model_kw = dict(model_kw or {})
    if shape.kind == "train" and arch in SEQ_PARALLEL_TRAIN:
        model_kw.setdefault("seq_parallel", True)
    if arch in MOE_DISPATCH_HINT:
        model_kw.setdefault("moe_dispatch_hint", MOE_DISPATCH_HINT[arch])
    model = build_model(cfg, **model_kw)
    optimizer = AdamW()
    t0 = time.time()
    mb_used = 1
    kv_bytes_local = 0.0

    params_shape, specs = model_init_specs(model)
    p_sh = shard_lib.param_shardings(specs, params_shape, mesh)
    batch = input_specs(cfg, shape)
    baxes = shard_lib.batch_axes(mesh)
    bshard = NamedSharding(mesh, P(baxes))
    batch_sh = {k: NamedSharding(mesh, P(baxes, *([None] * (len(v.shape) - 1))))
                for k, v in batch.items()}

    with mesh, shard_lib.activation_hints(mesh):
        if shape.kind == "train":
            mb = mb_used = microbatches or _cell_microbatches(cfg, shape, mesh)
            state_shape = jax.eval_shape(
                lambda p: TrainState(params=p, opt=optimizer.init(p)),
                params_shape,
            )
            from repro.train.loop import state_shardings
            st_sh = state_shardings(specs, state_shape, mesh)
            train_step, _ = make_train_step(model, optimizer, mesh,
                                            microbatches=mb,
                                            param_shardings=st_sh.params)
            fn = jax.jit(
                train_step,
                in_shardings=(st_sh, batch_sh),
                out_shardings=(st_sh, NamedSharding(mesh, P())),
                donate_argnums=(0,),
            )
            lowered = fn.lower(state_shape, batch)
            tokens = shape.global_batch * shape.seq_len
            model_flops = roofline.train_model_flops(cfg.active_param_count(), tokens)
        elif shape.kind == "prefill":
            if arch in CHUNKED_PREFILL:
                def prefill_step(params, b):
                    return model.prefill_chunked(
                        params, b, seg_len=CHUNKED_PREFILL_SEG,
                        max_len=shape.seq_len + 8,
                    )
            else:
                def prefill_step(params, b):
                    logits, cache = model.prefill(
                        params, b, max_len=shape.seq_len + 8
                    )
                    return logits, cache

            cache_shape = jax.eval_shape(
                lambda p, b: prefill_step(p, b)[1], params_shape, batch
            )
            c_sh = shard_lib.cache_shardings(mesh, cache_shape, cfg)
            vshard = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
            fn = jax.jit(
                prefill_step,
                in_shardings=(p_sh, batch_sh),
                out_shardings=(NamedSharding(mesh, P(baxes, None, vshard)), c_sh),
            )
            lowered = fn.lower(params_shape, batch)
            tokens = shape.global_batch * shape.seq_len
            model_flops = roofline.decode_model_flops(cfg.active_param_count(), tokens)
        else:  # decode
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len)
            )
            cache_shape = cache_shape._replace(length=jax.ShapeDtypeStruct((), jnp.int32))
            if cfg.family == "encdec":
                d = cfg.d_model
                cache_shape = cache_shape._replace(
                    enc_out=jax.ShapeDtypeStruct(
                        (shape.global_batch, shape.seq_len, d), jnp.dtype(cfg.dtype)
                    )
                )
            c_sh = shard_lib.cache_shardings(mesh, cache_shape, cfg)
            tok_sh = NamedSharding(
                mesh,
                P(baxes if shape.global_batch % int(np.prod([mesh.shape[a] for a in baxes])) == 0 else None, None),
            )

            def serve_step(params, cache, tokens):
                return model.decode_step(params, cache, tokens)

            vshard = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
            fn = jax.jit(
                serve_step,
                in_shardings=(p_sh, c_sh, tok_sh),
                out_shardings=(
                    NamedSharding(mesh, P(tok_sh.spec[0], None, vshard)), c_sh
                ),
                donate_argnums=(1,),
            )
            tok_shape = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            lowered = fn.lower(params_shape, cache_shape, tok_shape)
            model_flops = roofline.decode_model_flops(
                cfg.active_param_count(), shape.global_batch
            )
            kv_bytes_local = sum(
                float(np.prod(l.shape)) * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(cache_shape)
                if hasattr(l, "shape") and l.shape
            ) / mesh.devices.size

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_rec = {}
    for attr in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        try:
            mem_rec[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    hlo_text = compiled.as_text()
    hbm = roofline.analytic_hbm_bytes(
        cfg, shape, mesh, microbatches=mb_used, kv_cache_bytes=kv_bytes_local
    )
    rl = roofline.analyze(compiled, chips=mesh.devices.size,
                          model_flops=model_flops, hlo_text=hlo_text,
                          hbm_bytes_per_device=hbm)
    return {
        "arch": arch,
        "shape": shape.name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": int(mesh.devices.size),
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem_rec,
        "roofline": rl.to_dict(),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results: Dict[str, Any] = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "x".join(str(s) for s in mesh.devices.shape)
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                shape = SHAPES[shape_name]
                key = f"{arch}|{shape_name}|{mesh_name}"
                if key in results and results[key].get("status") == "ok":
                    print(f"[skip] {key}")
                    continue
                if shape_name == "long_500k" and not cfg.subquadratic:
                    results[key] = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": "skipped",
                        "reason": "full quadratic attention at 500k (DESIGN.md §4)",
                    }
                    _write(args.out, results)
                    print(f"[skipped-by-design] {key}")
                    continue
                print(f"[lower] {key} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape, mesh)
                    results[key] = rec
                    rl = rec["roofline"]
                    print(
                        f"  ok  compile={rec['compile_s']}s "
                        f"flops={rl['flops']:.3e} coll={rl['coll_bytes']:.3e} "
                        f"bottleneck={rl['bottleneck']}", flush=True,
                    )
                except Exception as e:
                    results[key] = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-2000:],
                    }
                    print(f"  ERROR {type(e).__name__}: {str(e)[:300]}", flush=True)
                _write(args.out, results)


def _write(path: str, results) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1)
    os.replace(tmp, path)


if __name__ == "__main__":
    main()
