"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_adt_ref(queries: jnp.ndarray, centroids: jnp.ndarray, metric: str = "l2") -> jnp.ndarray:
    """(Q, D), (M, C, dsub) -> (Q, M, C)."""
    m, c, dsub = centroids.shape
    qs = queries.reshape(queries.shape[0], m, dsub)
    if metric == "l2":
        diff = qs[:, :, None, :] - centroids[None]
        return (diff * diff).sum(-1)
    return -jnp.einsum("qmd,mcd->qmc", qs, centroids)


def pq_lookup_ref(codes: jnp.ndarray, adt: jnp.ndarray) -> jnp.ndarray:
    """(N, M) uint8, (M, C) -> (N,)."""
    m = adt.shape[0]
    return adt[jnp.arange(m)[None, :], codes.astype(jnp.int32)].sum(-1)


def bitonic_sort_pairs_ref(keys: jnp.ndarray, vals: jnp.ndarray):
    """(Q, L) -> row-wise ascending sort carrying vals."""
    order = jnp.argsort(keys, axis=1, stable=True)
    return jnp.take_along_axis(keys, order, 1), jnp.take_along_axis(vals, order, 1)


def l2_rerank_ref(queries: jnp.ndarray, candidates: jnp.ndarray, metric: str = "l2") -> jnp.ndarray:
    """(Q, D), (Q, K, D) -> (Q, K)."""
    dot = jnp.einsum("qd,qkd->qk", queries, candidates)
    if metric == "l2":
        return (
            (queries * queries).sum(-1)[:, None]
            - 2.0 * dot
            + (candidates * candidates).sum(-1)
        )
    return -dot
