"""Pallas TPU kernel: Asymmetric Distance Table construction (paper §IV-D,
"PQ Module" — the ASIC uses 32 FP16 MACs to fill the C x M table; here the
table is built on the VPU/MXU from VMEM-resident codebook tiles).

For a query batch (Q, M, dsub) and codebook (M, C, dsub):
    l2:  ADT[q, m, c] = sum_d (query[q,m,d] - cent[m,c,d])^2
    ip:  ADT[q, m, c] = -sum_d  query[q,m,d] * cent[m,c,d]

Tiling: grid over (query blocks, subspace blocks); each program holds a
(QB, MB, dsub) query tile and a (MB, C, dsub) codebook tile in VMEM and emits
a (QB, MB, C) ADT tile. With C=256 the lane dimension is aligned; dsub is
small (2-16) so the reduction runs on the VPU. VMEM footprint per program:
MB*C*dsub*4 + QB*MB*C*4 bytes — e.g. MB=8, QB=8, C=256, dsub=4: ~0.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adt_kernel(q_ref, cent_ref, out_ref, *, metric: str):
    q = q_ref[...]           # (QB, MB, dsub)
    c = cent_ref[...]        # (MB, C, dsub)
    if metric == "l2":
        diff = q[:, :, None, :] - c[None, :, :, :]      # (QB, MB, C, dsub)
        out_ref[...] = (diff * diff).sum(-1)
    else:  # ip / angular (pre-normalized)
        prod = q[:, :, None, :] * c[None, :, :, :]
        out_ref[...] = -prod.sum(-1)


@functools.partial(
    jax.jit, static_argnames=("metric", "q_block", "m_block", "interpret")
)
def pq_adt(
    queries: jnp.ndarray,      # (Q, D) float32
    centroids: jnp.ndarray,    # (M, C, dsub) float32
    metric: str = "l2",
    q_block: int = 8,
    m_block: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns (Q, M, C) float32 ADTs."""
    m, c, dsub = centroids.shape
    q = queries.shape[0]
    if m_block == 0:
        m_block = m
    assert q % q_block == 0 and m % m_block == 0
    qs = queries.reshape(q, m, dsub)
    grid = (q // q_block, m // m_block)
    return pl.pallas_call(
        functools.partial(_adt_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_block, m_block, dsub), lambda i, j: (i, j, 0)),
            pl.BlockSpec((m_block, c, dsub), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((q_block, m_block, c), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((q, m, c), jnp.float32),
        interpret=interpret,
    )(qs, centroids)
