"""Pallas TPU kernel: PQ distance evaluation, Eq. (3) of the paper.

The ASIC's per-queue "Distance Computation Module" does M SRAM lookups + an
M-term accumulation per candidate. TPUs have no efficient VMEM gather, but
they have an MXU — so the lookup is re-expressed as a ONE-HOT MATMUL
(DESIGN.md §2, hardware adaptation):

    dist[n] = sum_m ADT[m, codes[n, m]]
            = onehot(codes)[n, :] . vec(ADT)      with onehot in {0,1}^(M*C)

The one-hot block is built in-register from a broadcasted iota comparison —
it never exists in HBM. Per grid step the kernel holds a (NB, M) code tile,
the full (M, C) ADT and the (NB, M, C) one-hot in VMEM:
NB=128, M=32, C=256 -> 128*8192*4 B = 4 MB (fits v5e's 16 MB VMEM twice over
for double buffering). The contraction is a (NB, M*C) x (M*C, 1) matvec on
the MXU with f32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lookup_kernel(codes_ref, adt_ref, out_ref):
    codes = codes_ref[...].astype(jnp.int32)        # (NB, M)
    adt = adt_ref[...]                              # (M, C)
    m, c = adt.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], m, c), 2)
    onehot = (codes[:, :, None] == iota).astype(jnp.float32)   # in-register
    flat = onehot.reshape(codes.shape[0], m * c)
    out_ref[...] = jax.lax.dot_general(
        flat, adt.reshape(m * c, 1),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]


@functools.partial(jax.jit, static_argnames=("n_block", "interpret"))
def pq_lookup(
    codes: jnp.ndarray,   # (N, M) uint8
    adt: jnp.ndarray,     # (M, C) float32
    n_block: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns (N,) float32 PQ distances."""
    n, m = codes.shape
    _, c = adt.shape
    pad = (-n) % n_block
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    np_ = n + pad
    out = pl.pallas_call(
        _lookup_kernel,
        grid=(np_ // n_block,),
        in_specs=[
            pl.BlockSpec((n_block, m), lambda i: (i, 0)),
            pl.BlockSpec((m, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=interpret,
    )(codes, adt)
    return out[:n]
