"""Jit'd public wrappers for the Pallas kernels.

On this container (CPU) the kernels execute in ``interpret=True`` mode, which
runs the kernel bodies in Python for correctness validation; on a real TPU
set ``REPRO_PALLAS_INTERPRET=0`` (or pass interpret=False) to compile them to
Mosaic. ``use_kernels()`` gates whether the search layer routes through the
Pallas path or the pure-jnp reference path.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bitonic_topk import bitonic_sort_pairs as _bitonic
from repro.kernels.l2_rerank import l2_rerank as _l2_rerank
from repro.kernels.pq_adt import pq_adt as _pq_adt
from repro.kernels.pq_lookup import pq_lookup as _pq_lookup


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def pq_adt(queries, centroids, metric="l2", interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    q = queries.shape[0]
    q_block = 8 if q % 8 == 0 else (4 if q % 4 == 0 else 1)
    return _pq_adt(queries, centroids, metric=metric, q_block=q_block, interpret=interpret)


def pq_lookup(codes, adt, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _pq_lookup(codes, adt, interpret=interpret)


def bitonic_sort_pairs(keys, vals, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _bitonic(keys, vals, interpret=interpret)


def l2_rerank(queries, candidates, metric="l2", interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _l2_rerank(queries, candidates, metric=metric, interpret=interpret)


# re-export oracles for convenience
pq_adt_ref = ref.pq_adt_ref
pq_lookup_ref = ref.pq_lookup_ref
bitonic_sort_pairs_ref = ref.bitonic_sort_pairs_ref
l2_rerank_ref = ref.l2_rerank_ref
