"""Jit'd public wrappers for the Pallas kernels.

On this container (CPU) the kernels execute in ``interpret=True`` mode, which
runs the kernel bodies in Python for correctness validation; on a real TPU
set ``REPRO_PALLAS_INTERPRET=0`` (or pass interpret=False) to compile them to
Mosaic. ``use_kernels()`` gates whether the search layer routes through the
Pallas path or the pure-jnp reference path.

Observability (``repro.obs``): ``set_observability`` points a module-level
hook at a registry; each wrapper then reports

* ``kernel_wall_ms{kernel=...}`` — wall time of EAGER calls (timed around a
  ``block_until_ready``, so it is realized device time, not dispatch time);
* ``kernel_traces{kernel=...}`` — one count each time the wrapper body runs
  under an active JAX trace.  These wrappers are called from inside jitted
  engines (``graph_search``), so every increment is one (re)trace of the
  enclosing kernel — the Pallas-side recompile-detector signal
  (``obs.KernelWatch`` covers the jit-cache side).

The hook defaults to None and every wrapper checks it with one branch —
zero cost when observability is off.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bitonic_topk import bitonic_sort_pairs as _bitonic
from repro.kernels.l2_rerank import l2_rerank as _l2_rerank
from repro.kernels.pq_adt import pq_adt as _pq_adt
from repro.kernels.pq_lookup import pq_lookup as _pq_lookup

_obs = None     # Observability bundle (repro.obs) or None — module-wide hook


def set_observability(obs) -> None:
    """Install (or clear, with None) the kernel instrumentation sink.
    Usually called via ``Observability.install_kernel_hooks()``."""
    global _obs
    _obs = obs if obs is not None and getattr(obs, "enabled", False) else None


def _instrumented(name: str, operands, fn):
    """Run ``fn`` with wall-time / retrace accounting when the hook is set."""
    if _obs is None:
        return fn()
    if any(isinstance(x, jax.core.Tracer) for x in operands):
        # inside an enclosing jit trace: timing is meaningless, but the
        # trace itself is the (re)compile event worth counting
        _obs.metrics.counter("kernel_traces", kernel=name)
        return fn()
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    _obs.metrics.observe("kernel_wall_ms", (time.perf_counter() - t0) * 1e3,
                         kernel=name)
    _obs.metrics.counter("kernel_calls", kernel=name)
    return out


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def pq_adt(queries, centroids, metric="l2", interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    q = queries.shape[0]
    q_block = 8 if q % 8 == 0 else (4 if q % 4 == 0 else 1)
    return _instrumented(
        "pq_adt", (queries, centroids),
        lambda: _pq_adt(queries, centroids, metric=metric, q_block=q_block,
                        interpret=interpret),
    )


def pq_lookup(codes, adt, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _instrumented(
        "pq_lookup", (codes, adt),
        lambda: _pq_lookup(codes, adt, interpret=interpret),
    )


def bitonic_sort_pairs(keys, vals, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _instrumented(
        "bitonic_sort_pairs", (keys, vals),
        lambda: _bitonic(keys, vals, interpret=interpret),
    )


def l2_rerank(queries, candidates, metric="l2", interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _instrumented(
        "l2_rerank", (queries, candidates),
        lambda: _l2_rerank(queries, candidates, metric=metric,
                           interpret=interpret),
    )


# re-export oracles for convenience
pq_adt_ref = ref.pq_adt_ref
pq_lookup_ref = ref.pq_lookup_ref
bitonic_sort_pairs_ref = ref.bitonic_sort_pairs_ref
l2_rerank_ref = ref.l2_rerank_ref
