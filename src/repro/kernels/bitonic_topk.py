"""Pallas TPU kernel: batched bitonic sort of (distance, id) pairs —
the paper's shared 256-point Bitonic Sorter (§IV-D), which sorts the merged
candidate list each traversal round in constant 2*log2(N)^2/... stages.

The network is expressed with reshape-based compare-exchange so every stage
is a full-width vector op (VPU-friendly, no scatter): for stride j, the array
is viewed as (..., L/(2j), 2, j) and the two halves are min/max-combined with
a per-block direction flag. Ids travel with their keys via ``where`` on the
same predicate. All stages of one (QB, L) tile run in VMEM in a single
program — L=256: QB*L*8 B = 16 kB per tile at QB=8.

Ascending order; pad with +inf keys to a power of two before calling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_stages(keys: jnp.ndarray, vals: jnp.ndarray):
    """Full bitonic sorting network on the last axis (power-of-two length)."""
    q, l = keys.shape
    n_stages = l.bit_length() - 1
    for k_stage in range(1, n_stages + 1):
        block = 1 << k_stage
        for j_pow in range(k_stage - 1, -1, -1):
            j = 1 << j_pow
            k2 = keys.reshape(q, l // (2 * j), 2, j)
            v2 = vals.reshape(q, l // (2 * j), 2, j)
            lo_k, hi_k = k2[:, :, 0, :], k2[:, :, 1, :]
            lo_v, hi_v = v2[:, :, 0, :], v2[:, :, 1, :]
            # direction: ascending if the enclosing 2^k block index is even
            blk_idx = jax.lax.broadcasted_iota(
                jnp.int32, (q, l // (2 * j), j), 1
            )
            asc = ((blk_idx * 2 * j) // block) % 2 == 0
            swap = jnp.where(asc, lo_k > hi_k, lo_k < hi_k)
            new_lo_k = jnp.where(swap, hi_k, lo_k)
            new_hi_k = jnp.where(swap, lo_k, hi_k)
            new_lo_v = jnp.where(swap, hi_v, lo_v)
            new_hi_v = jnp.where(swap, lo_v, hi_v)
            keys = jnp.stack([new_lo_k, new_hi_k], axis=2).reshape(q, l)
            vals = jnp.stack([new_lo_v, new_hi_v], axis=2).reshape(q, l)
    return keys, vals


def _sort_kernel(keys_ref, vals_ref, out_k_ref, out_v_ref):
    keys, vals = _bitonic_stages(keys_ref[...], vals_ref[...])
    out_k_ref[...] = keys
    out_v_ref[...] = vals


@functools.partial(jax.jit, static_argnames=("q_block", "interpret"))
def bitonic_sort_pairs(
    keys: jnp.ndarray,    # (Q, L) float32 — L must be a power of two
    vals: jnp.ndarray,    # (Q, L) int32 payload
    q_block: int = 8,
    interpret: bool = True,
):
    """Sort each row ascending by key, carrying vals. Returns (keys, vals)."""
    q, l = keys.shape
    assert l & (l - 1) == 0, "row length must be a power of two"
    pad = (-q) % q_block
    if pad:
        keys = jnp.pad(keys, ((0, pad), (0, 0)), constant_values=jnp.inf)
        vals = jnp.pad(vals, ((0, pad), (0, 0)), constant_values=-1)
    qp = q + pad
    out_k, out_v = pl.pallas_call(
        _sort_kernel,
        grid=(qp // q_block,),
        in_specs=[
            pl.BlockSpec((q_block, l), lambda i: (i, 0)),
            pl.BlockSpec((q_block, l), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((q_block, l), lambda i: (i, 0)),
            pl.BlockSpec((q_block, l), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, l), keys.dtype),
            jax.ShapeDtypeStruct((qp, l), vals.dtype),
        ],
        interpret=interpret,
    )(keys, vals)
    return out_k[:q], out_v[:q]
