"""Pallas TPU kernel: accurate-distance reranking (paper §III-C / Alg.1
l.12+19 — the "accurate distance" path of the Distance Computation Module).

Given a query batch (Q, D) and per-query gathered candidate vectors
(Q, K, D), emit (Q, K) exact distances:

    l2: ||q||^2 - 2 q.x + ||x||^2      ip/angular: -q.x

The q.x contraction is a (K, D) x (D, 1) MXU matvec per query tile. Tiling:
grid over (query, candidate-block); VMEM per program = KB*D*4 + D*4 bytes
(K=128, D=128 -> 64 kB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rerank_kernel(q_ref, x_ref, out_ref, *, metric: str):
    q = q_ref[...]            # (1, D)
    x = x_ref[...][0]         # (KB, D)
    dot = jax.lax.dot_general(
        x, q.reshape(-1, 1),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]
    if metric == "l2":
        out_ref[...] = (
            (q * q).sum() - 2.0 * dot + (x * x).sum(axis=1)
        )[None, :]
    else:
        out_ref[...] = (-dot)[None, :]


@functools.partial(jax.jit, static_argnames=("metric", "k_block", "interpret"))
def l2_rerank(
    queries: jnp.ndarray,      # (Q, D)
    candidates: jnp.ndarray,   # (Q, K, D) gathered candidate vectors
    metric: str = "l2",
    k_block: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns (Q, K) accurate distances."""
    q, k, d = candidates.shape
    if k_block == 0:
        k_block = k
    assert k % k_block == 0
    return pl.pallas_call(
        functools.partial(_rerank_kernel, metric=metric),
        grid=(q, k // k_block),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, k_block, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, k_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, k), jnp.float32),
        interpret=interpret,
    )(queries, candidates)
