"""Unified query-plan layer: one ``Searcher`` facade + ``QueryPlanner``
replacing the five parallel search entry points (``core.search``,
``filter.filtered_search``, ``shard.sharded_search``,
``stream.search_merged``, ``core.distributed_search`` — all kept as thin
deprecated wrappers that build a ``SearchRequest`` and delegate here).

    request -> QueryPlanner.plan -> QueryPlan -> kernels -> SearchResult
                                                (stats + NAND trace handle)
"""
from repro.configs.base import PlanConfig
from repro.plan.planner import (
    Execution,
    IndexCapabilities,
    QueryPlan,
    QueryPlanner,
)
from repro.plan.request import SearchRequest, SearchResult, SearchStats
from repro.plan.rounds import RoundSession
from repro.plan.searcher import (
    Searcher,
    validate_attribute_store,
    warn_legacy,
)

__all__ = [
    "Execution",
    "IndexCapabilities",
    "PlanConfig",
    "QueryPlan",
    "QueryPlanner",
    "RoundSession",
    "SearchRequest",
    "SearchResult",
    "SearchStats",
    "Searcher",
    "validate_attribute_store",
    "warn_legacy",
]
