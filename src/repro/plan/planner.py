"""QueryPlanner — request + index capabilities -> executable ``QueryPlan``.

The planner inspects what the index can do (static vs mutable, tiled vs
flat, attribute store present, device mesh size) and what the request asks
for (filter selectivity, beam width, per-request overrides) and compiles a
``QueryPlan``: a hashable strategy record naming the composition of existing
kernels that serves the request —

  * ``kind``      — which execution spine: ``flat`` (one compiled Algorithm-1
    engine), ``tiled`` (per-channel fan-out + cross-tile merge), ``merged``
    (base + DRAM delta segment with tombstone fusion), ``distributed``
    (shard_map collectives over a device mesh);
  * ``strategy``  — where the filter runs: ``none``, ``masked`` traversal
    (inflated frontier), bitmap PQ ``scan``, ``empty`` short-circuit, or
    ``adaptive`` (mutable targets — the admission mask depends on the live
    tombstone set, so the regime is re-decided at execute time exactly as
    the legacy merged path did);
  * the *effective* ``SearchConfig`` actually executed (selectivity-adapted
    for masked traversal), the routing fan-in (``probe_tiles``), and the
    billing facts the NAND model reads off the plan (``selectivity``,
    ``attr_bits``, ``pushdown``).

``plan.cache_key`` is the batching identity: two requests with the same key
execute the same compiled composition, which is what lets ``ServingEngine``
batch by plan instead of by ad-hoc filter hash.  Compiled artifacts (pass
masks, per-tile bitmap slices) are planner-cached per key — the replacement
for the engine's old ``_filter_cache``.

Every plan is bit-identical to the legacy entry point it replaces: the
executor calls the SAME kernels with the SAME arguments the five old paths
did (see tests/test_plan.py for the enforced equivalence matrix).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import numpy as np

from repro.configs.base import (
    FilterConfig, PlanConfig, SearchConfig, upgrade_config,
)
from repro.filter.spec import FilterSpec
from repro.obs import NULL_OBS, Observability
from repro.plan.request import SearchRequest, SearchStats


@dataclasses.dataclass(frozen=True)
class IndexCapabilities:
    """What the opened index supports — the planner's input alongside the
    request (derived once by ``Searcher.open``)."""
    kind: str                        # flat | tiled | merged | distributed
    mutable: bool = False
    tiled: bool = False
    num_tiles: int = 1
    has_attributes: bool = False
    mesh_devices: int = 0            # device count (distributed targets)
    segments: int = 0                # >0: segment-built index served through
                                     # direct-emitted tiles (one per build
                                     # segment), with segment centroids as
                                     # the router's coarse index


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """One executable strategy: the composition of kernels serving a
    request.  Frozen and hashable — ``cache_key`` is the serving layer's
    batching identity and the artifact-cache key."""
    kind: str                        # flat | tiled | merged | distributed
    strategy: str                    # none | masked | scan | empty | adaptive
    cfg: SearchConfig                # EFFECTIVE config executed (adapted)
    metric: str
    spec: Optional[FilterSpec] = None
    selectivity: float = 1.0         # exact passing fraction (static targets)
    probe_tiles: int = 0             # 0 -> full fan-out
    num_tiles: int = 1
    attr_bits: int = 0               # spare-area word the NAND model bills
    pushdown: bool = True            # predicate evaluated inside the tile
    tenant: Optional[str] = None     # namespace slot — part of the cache
                                     # key, so tenants never co-batch (the
                                     # multi-tenancy roadmap item's hook)
    mask_token: int = 0              # >0: plan built from a caller mask, not
                                     # a spec (legacy wrappers) — keeps the
                                     # artifact cache collision-free

    @property
    def cache_key(self) -> tuple:
        """Batching/artifact identity — everything that selects a distinct
        compiled execution (selectivity is derived from ``spec``, so it is
        deliberately absent)."""
        return (self.kind, self.strategy, self.metric, self.cfg, self.spec,
                self.probe_tiles, self.tenant, self.mask_token)


class Execution(NamedTuple):
    """Internal executor reply: host arrays + the raw kernel result + the
    counter source the stats/billing layers read."""
    ids: np.ndarray
    dists: np.ndarray
    raw: Any
    counters: Any                    # core SearchResult-like (or None)
    selectivity: float
    delta_candidates: float


def _mean_counters(res) -> dict:
    """Per-query mean counters from a core ``SearchResult`` (a sharded
    result's (P, Q) counters are summed across the tile axis first — the
    total cross-channel work per query, same convention as the NAND
    traces)."""
    if res is None:
        return {}
    per = res.per_tile if hasattr(res, "per_tile") else res
    agg = (lambda x: float(np.asarray(x).sum(0).mean())) \
        if np.asarray(per.n_hops).ndim > 1 else \
        (lambda x: float(np.asarray(x).mean()))
    return dict(
        hops=agg(per.n_hops), pq=agg(per.n_pq), acc=agg(per.n_acc),
        hot_hops=agg(per.n_hot_hops), free_pq=agg(per.n_free_pq),
        rounds=agg(per.rounds),
    )


def flat_filtered_search(corpus, queries, mask, cfg: SearchConfig,
                         metric: str, filter_cfg: Optional[FilterConfig] = None):
    """Selectivity-adaptive filtered search over a flat corpus through a
    one-off plan — the SINGLE regime-decision point, shared by the
    ``filter.filtered_search`` wrapper (via ``Searcher``) and the merged
    base-segment path (``stream.searcher``).  Returns the
    ``FilteredSearchResult`` the legacy path produced, bit-identically."""
    fcfg = filter_cfg or FilterConfig()
    pc = PlanConfig(search=cfg, filter=fcfg)
    planner = QueryPlanner(
        capabilities=IndexCapabilities(kind="flat"), cfg=cfg, metric=metric,
        filter_cfg=fcfg, plan_cfg=pc, corpus=corpus,
    )
    request = SearchRequest(queries=queries, node_mask=mask, adaptive=True)
    return planner.execute(planner.plan(request), queries).raw


class QueryPlanner:
    """Compiles ``SearchRequest`` -> ``QueryPlan`` and executes plans over
    one opened target.  Owns the plan cache (hit/miss counters feed the
    serving stats and ``benchmarks/planner_bench``) and the per-plan
    artifact cache (compiled masks / per-tile bitmap slices)."""

    def __init__(
        self,
        *,
        capabilities: IndexCapabilities,
        cfg: SearchConfig,
        metric: str,
        filter_cfg: FilterConfig,
        plan_cfg: PlanConfig,
        corpus=None,
        tiled=None,
        mutable=None,
        dcorpus=None,
        mesh=None,
        attributes=None,
        probe_tiles: int = 0,
        obs: Optional[Observability] = None,
    ):
        self.capabilities = capabilities
        self.cfg = cfg
        self.metric = metric
        self.filter_cfg = filter_cfg
        self.plan_cfg = plan_cfg
        self.corpus = corpus
        self.tiled = tiled
        self.mutable = mutable
        self.dcorpus = dcorpus
        self.mesh = mesh
        self.attributes = attributes
        self.probe_tiles = int(probe_tiles or 0)
        self._plan_cache: Dict[tuple, QueryPlan] = {}
        self._mask_cache: Dict[FilterSpec, np.ndarray] = {}
        self._artifacts: Dict[tuple, dict] = {}
        self._mask_tokens = 0
        self.plan_cache_hits = 0
        self.plan_cache_misses = 0
        self.obs = obs or NULL_OBS

    # ------------------------------------------------------------- planning
    def plan(self, request: SearchRequest) -> QueryPlan:
        """Compile (or fetch from the plan cache) the strategy serving
        ``request``.  Mask-escape-hatch requests are compiled fresh — the
        mask has no hashable identity."""
        if request.node_mask is not None:
            return self._plan_for_mask(request)
        spec = request.filter
        if spec is not None and getattr(spec, "is_all", False):
            spec = None              # all-pass spec == unfiltered plan
        key = (spec, request.k, request.override_items(),
               request.probe_tiles, request.tenant)
        cached = self._plan_cache.get(key)
        if cached is not None:
            self.plan_cache_hits += 1
            if self.obs.enabled:
                self.obs.metrics.counter("plan_cache_hits",
                                         tenant=request.tenant)
            return cached
        self.plan_cache_misses += 1
        plan = self._compile(spec, request)
        self._plan_cache[key] = plan
        if self.obs.enabled:
            self.obs.metrics.counter("plan_cache_misses",
                                     tenant=request.tenant)
            self.obs.metrics.counter("plans_compiled", kind=plan.kind,
                                     strategy=plan.strategy,
                                     tenant=request.tenant)
        return plan

    def _effective_cfg(self, request: SearchRequest) -> SearchConfig:
        cfg = self.cfg
        if request.k is not None and request.k != cfg.k:
            cfg = dataclasses.replace(cfg, k=int(request.k))
        items = request.override_items()
        if items:
            cfg = dataclasses.replace(cfg, **dict(items))
        return cfg

    def _resolved_probe(self, request: SearchRequest) -> int:
        p = self.probe_tiles if request.probe_tiles is None \
            else int(request.probe_tiles)
        return int(p or 0)

    def _mask_for(self, spec: FilterSpec) -> np.ndarray:
        mask = self._mask_cache.get(spec)
        if mask is None:
            if self.attributes is None:
                raise RuntimeError(
                    "filtered search needs an attribute store — pass "
                    "attributes= to Searcher.open / ServingEngine or attach "
                    "one to the index"
                )
            mask = np.asarray(self.attributes.mask(spec), bool)
            self._mask_cache[spec] = mask
        return mask

    def _filter_strategy(self, mask: np.ndarray, k: int) -> Tuple[str, float]:
        """The selectivity regime switch — the exact ``filtered_search``
        decision, now owned by the planner."""
        n = mask.size
        n_pass = int(mask.sum())
        sel = n_pass / max(n, 1)
        if n_pass == 0:
            return "empty", 0.0
        if sel <= self.filter_cfg.brute_force_selectivity or n_pass <= k:
            return "scan", sel
        return "masked", sel

    def _attr_bits(self) -> int:
        if self.attributes is not None:
            return int(self.attributes.attr_bits)
        return int(self.filter_cfg.attr_bits)

    def _compile(self, spec: Optional[FilterSpec],
                 request: SearchRequest) -> QueryPlan:
        from repro.filter.traversal import adapt_search_cfg, tile_node_masks

        cfg = self._effective_cfg(request)
        probe = self._resolved_probe(request)
        caps = self.capabilities
        common = dict(metric=self.metric, probe_tiles=probe,
                      num_tiles=caps.num_tiles, tenant=request.tenant,
                      pushdown=bool(self.filter_cfg.pushdown))
        if caps.kind == "distributed":
            if spec is not None:
                raise NotImplementedError(
                    "the distributed (device-mesh) path has no filtered "
                    "traversal — drop the filter or open a flat/tiled target"
                )
            return QueryPlan(kind="distributed", strategy="none", cfg=cfg,
                             **common)
        if caps.mutable:
            # the admission mask depends on the live tombstone set, so the
            # regime is re-decided inside the merged kernel at execute time
            strategy = "none" if spec is None else "adaptive"
            return QueryPlan(kind="merged", strategy=strategy, cfg=cfg,
                             spec=spec,
                             attr_bits=self._attr_bits() if spec else 0,
                             **common)
        if caps.tiled:
            if spec is None:
                return QueryPlan(kind="tiled", strategy="none", cfg=cfg,
                                 **common)
            mask = self._mask_for(spec)
            sel = float(mask.mean())
            eff = adapt_search_cfg(cfg, sel, self.filter_cfg)
            plan = QueryPlan(kind="tiled", strategy="masked", cfg=eff,
                             spec=spec, selectivity=sel,
                             attr_bits=self._attr_bits(), **common)
            self._artifacts[plan.cache_key] = {
                "mask": mask,
                "node_masks": tile_node_masks(self.tiled.tile_ids, mask),
            }
            return plan
        # ---- flat ----------------------------------------------------------
        if spec is None:
            return QueryPlan(kind="flat", strategy="none", cfg=cfg, **common)
        mask = self._mask_for(spec)
        strategy, sel = self._filter_strategy(mask, cfg.k)
        eff = adapt_search_cfg(cfg, sel, self.filter_cfg) \
            if strategy == "masked" else cfg
        plan = QueryPlan(kind="flat", strategy=strategy, cfg=eff, spec=spec,
                         selectivity=sel, attr_bits=self._attr_bits(),
                         **common)
        self._artifacts[plan.cache_key] = {"mask": mask}
        return plan

    def _plan_for_mask(self, request: SearchRequest) -> QueryPlan:
        """Plans for caller-precompiled masks — what the deprecated wrappers
        delegate through.  ``adaptive`` selects ``filtered_search``
        semantics (regime switch + config adaptation) vs the verbatim
        ``core.search(node_mask=...)`` traversal."""
        from repro.filter.traversal import adapt_search_cfg

        cfg = self._effective_cfg(request)
        probe = self._resolved_probe(request)
        caps = self.capabilities
        self._mask_tokens += 1
        token = self._mask_tokens
        common = dict(metric=self.metric, probe_tiles=probe,
                      num_tiles=caps.num_tiles, tenant=request.tenant,
                      mask_token=token, attr_bits=self._attr_bits(),
                      pushdown=bool(self.filter_cfg.pushdown))
        if caps.kind == "tiled":
            # per-tile slices, applied verbatim (legacy sharded_search
            # leaves config adaptation to its caller)
            node_masks = np.asarray(request.node_mask, bool)
            plan = QueryPlan(kind="tiled", strategy="masked", cfg=cfg,
                             selectivity=float(node_masks.mean()), **common)
            self._artifacts[plan.cache_key] = {"node_masks": node_masks}
            return plan
        if caps.kind != "flat":
            raise NotImplementedError(
                "precompiled node masks apply to flat or tiled targets only "
                f"(target is {caps.kind}); use FilterSpec requests instead"
            )
        mask = np.asarray(request.node_mask, bool)
        if not request.adaptive:
            plan = QueryPlan(kind="flat", strategy="masked", cfg=cfg,
                             selectivity=float(mask.mean()), **common)
            self._artifacts[plan.cache_key] = {"mask": mask}
            return plan
        strategy, sel = self._filter_strategy(mask, cfg.k)
        eff = adapt_search_cfg(cfg, sel, self.filter_cfg) \
            if strategy == "masked" else cfg
        plan = QueryPlan(kind="flat", strategy=strategy, cfg=eff,
                         selectivity=sel, **common)
        self._artifacts[plan.cache_key] = {"mask": mask}
        return plan

    # -------------------------------------------------------- round stepping
    def round_session(self, plan: QueryPlan):
        """The round-steppable form of ``plan`` (a ``repro.plan.rounds.
        RoundSession``), or ``None`` when the plan has no per-round spine —
        tiled / distributed fan-outs, bitmap scans, empty short-circuits,
        one-shot mask-token plans — in which case callers fall back to
        whole-batch ``execute``.  Merged plans re-decide the live filter
        regime here (exactly like the merged kernel does at execute time)
        and are steppable only when it resolves to masked traversal on a
        single-tile base."""
        from repro.plan.rounds import RoundSession

        pc = self.plan_cfg
        if plan.kind == "flat" and not plan.mask_token:
            if plan.strategy == "none":
                return RoundSession(
                    planner=self, plan=plan, corpus=self.corpus, cfg=plan.cfg,
                    metric=self.metric, bloom_bits=pc.bloom_bits,
                    num_hashes=pc.num_hashes,
                )
            if plan.strategy == "masked":
                art = self._artifacts.get(plan.cache_key) or {}
                mask = art.get("mask")
                if mask is None:
                    return None
                return RoundSession(
                    planner=self, plan=plan, corpus=self.corpus, cfg=plan.cfg,
                    metric=self.metric, bloom_bits=pc.bloom_bits,
                    num_hashes=pc.num_hashes, node_mask=mask,
                    selectivity=plan.selectivity,
                )
            return None
        if plan.kind == "merged":
            mut = self.mutable
            if mut is None or getattr(mut, "num_tiles", 1) > 1:
                return None
            k = plan.cfg.k
            k_base = min(plan.cfg.list_size,
                         k + mut.stream_cfg.base_overfetch)
            base_cfg = dataclasses.replace(plan.cfg, k=k_base) \
                if k_base != k else plan.cfg
            # the merged kernel calls graph_search with ITS defaults (the
            # flat_filtered_search planner likewise uses a default
            # PlanConfig), so merged sessions must too — bit-identity
            common = dict(planner=self, plan=plan, metric=mut.metric,
                          bloom_bits=1 << 17, num_hashes=8, mutable=mut)
            if plan.strategy == "none":
                return RoundSession(corpus=mut.corpus(), cfg=base_cfg,
                                    **common)
            # adaptive: combined filter ∧ ¬tombstone admission masks against
            # the LIVE tombstone set, regime re-decided like the kernel does
            fcfg = upgrade_config(mut.base.config).filter
            base_mask, ext_mask = mut.filter_masks(plan.spec)
            base_mask = np.asarray(base_mask, bool)
            n_pass = int(base_mask.sum())
            sel = n_pass / max(base_mask.size, 1)
            if n_pass == 0 or sel <= fcfg.brute_force_selectivity \
                    or n_pass <= base_cfg.k:
                return None          # scan / empty regimes: not steppable
            from repro.filter.traversal import adapt_search_cfg

            eff = adapt_search_cfg(base_cfg, sel, fcfg)
            return RoundSession(corpus=mut.corpus(), cfg=eff,
                                node_mask=base_mask, ext_mask=ext_mask,
                                selectivity=sel, base_mode="traversal",
                                **common)
        return None

    def _artifacts_for(self, plan: QueryPlan) -> dict:
        """Compiled artifacts for a plan.  Spec-keyed plans keep theirs
        cached (the engine re-executes them every flush); mask-token plans
        are ONE-SHOT — the caller-supplied mask has no durable identity, so
        its artifacts are popped here to keep a long-lived planner from
        accumulating one (N,) mask per legacy-wrapper call."""
        if plan.mask_token:
            return self._artifacts.pop(plan.cache_key, {})
        return self._artifacts.get(plan.cache_key, {})

    # ------------------------------------------------------------ execution
    def execute(self, plan: QueryPlan, queries) -> Execution:
        """Run one compiled plan over a query batch — dispatching to the
        SAME kernels, with the SAME arguments, as the legacy entry point the
        plan replaces (the bit-identity contract).

        With observability enabled the dispatch is wrapped in a
        ``kernel-execute`` span and billed into ``kernel_execute_ms``
        (labeled by plan kind / filter strategy / tenant); the traversal
        rounds inside the compiled while_loop are not individually
        observable, so the span carries the whole device execution."""
        obs = self.obs
        if not obs.enabled:
            return self._execute_plan(plan, queries)
        import time as _time

        t0 = _time.perf_counter()
        with obs.tracer.span("kernel-execute", kind=plan.kind,
                             strategy=plan.strategy) as sp:
            ex = self._execute_plan(plan, queries)
            sp.set(queries=int(np.atleast_2d(np.asarray(ex.ids)).shape[0]))
        obs.metrics.observe(
            "kernel_execute_ms", (_time.perf_counter() - t0) * 1e3,
            kind=plan.kind, strategy=plan.strategy, tenant=plan.tenant,
        )
        obs.metrics.counter("kernel_executions", kind=plan.kind,
                            strategy=plan.strategy, tenant=plan.tenant)
        return ex

    def _execute_plan(self, plan: QueryPlan, queries) -> Execution:
        import jax
        import jax.numpy as jnp

        if plan.kind == "distributed":
            from repro.core.distributed import distributed_search_kernel

            pc = self.plan_cfg
            ids, dists = distributed_search_kernel(
                self.dcorpus, queries, plan.cfg, self.metric, pc.mode,
                mesh=self.mesh, data_axis=pc.data_axis,
                queue_axis=pc.queue_axis, bloom_bits=pc.bloom_bits,
                num_hashes=pc.num_hashes,
            )
            return Execution(ids=np.asarray(ids), dists=np.asarray(dists),
                             raw=(ids, dists), counters=None,
                             selectivity=1.0, delta_candidates=0.0)

        q_np = np.atleast_2d(np.asarray(queries, np.float32))
        if plan.kind == "merged":
            from repro.stream.searcher import merged_search_kernel

            res = merged_search_kernel(
                self.mutable, q_np, plan.cfg,
                probe_tiles=plan.probe_tiles or None, filter_spec=plan.spec,
            )
            return Execution(ids=res.ids, dists=res.dists, raw=res,
                             counters=res.base, selectivity=res.selectivity,
                             delta_candidates=float(
                                 np.asarray(res.delta_candidates).mean()),
                             )
        if plan.kind == "tiled":
            from repro.shard.search import sharded_search_kernel

            node_masks = None
            if plan.strategy == "masked":
                node_masks = self._artifacts_for(plan)["node_masks"]
            res = sharded_search_kernel(
                self.tiled, q_np, plan.cfg, self.metric,
                use_vmap=self.plan_cfg.use_vmap,
                probe_tiles=plan.probe_tiles or None, node_masks=node_masks,
            )
            jax.block_until_ready(res.ids)
            return Execution(ids=np.asarray(res.ids),
                             dists=np.asarray(res.dists), raw=res,
                             counters=res, selectivity=plan.selectivity,
                             delta_candidates=0.0)

        # ---- flat ----------------------------------------------------------
        from repro.core.search import empty_search_result, graph_search
        from repro.filter.traversal import FilteredSearchResult, scan_search

        pc = self.plan_cfg
        if plan.strategy == "none":
            res = graph_search(self.corpus, q_np, plan.cfg, self.metric,
                               pc.bloom_bits, pc.num_hashes)
            jax.block_until_ready(res.ids)
            return Execution(ids=np.asarray(res.ids),
                             dists=np.asarray(res.dists), raw=res,
                             counters=res, selectivity=1.0,
                             delta_candidates=0.0)
        nq = q_np.shape[0]
        if plan.strategy == "empty":
            core = empty_search_result(nq, plan.cfg.k)
            fres = FilteredSearchResult(
                ids=np.asarray(core.ids), dists=np.asarray(core.dists),
                result=core, mode="empty", selectivity=0.0, effective=plan.cfg,
            )
        elif plan.strategy == "scan":
            mask = self._artifacts_for(plan)["mask"]
            fres = scan_search(self.corpus, jnp.asarray(q_np), mask,
                               plan.cfg, self.metric, self.filter_cfg,
                               plan.selectivity)
        else:                        # masked traversal, plan.cfg pre-adapted
            mask = self._artifacts_for(plan)["mask"]
            res = graph_search(self.corpus, jnp.asarray(q_np), plan.cfg,
                               self.metric, pc.bloom_bits, pc.num_hashes,
                               node_mask=jnp.asarray(mask))
            fres = FilteredSearchResult(
                ids=np.asarray(res.ids), dists=np.asarray(res.dists),
                result=res, mode="traversal", selectivity=plan.selectivity,
                effective=plan.cfg,
            )
        return Execution(ids=fres.ids, dists=fres.dists, raw=fres,
                         counters=fres.result, selectivity=fres.selectivity,
                         delta_candidates=0.0)

    # ----------------------------------------------------------------- stats
    def stats_for(self, plan: QueryPlan, execution: Execution) -> SearchStats:
        counters = _mean_counters(execution.counters)
        return SearchStats(
            queries=int(np.atleast_2d(execution.ids).shape[0]),
            k=plan.cfg.k, kind=plan.kind, strategy=plan.strategy,
            selectivity=float(execution.selectivity),
            delta_candidates=float(execution.delta_candidates),
            beam_width=int(upgrade_config(plan.cfg).beam_width),
            num_tiles=plan.num_tiles, **counters,
        )
