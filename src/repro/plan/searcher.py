"""``Searcher`` — the one supported host-side query API.

NDSEARCH and the computational-storage ANN platform of Kim et al. both hide
their accelerators behind a single query facade with an internal scheduler
picking the execution strategy; ``Searcher`` is that facade for this stack::

    s = Searcher.open(index, num_tiles=4, probe_tiles=2)
    res = s.search(SearchRequest(queries=q, k=10,
                                 filter=FilterSpec.eq("category", 3)))
    res.ids, res.dists            # (Q, k) numpy
    res.stats.as_dict()           # structured SearchStats
    res.plan                      # the executed QueryPlan (billing handle)

``open`` accepts every target the five legacy entry points used to take —
a built ``ProximaIndex``, a streaming ``stream.MutableIndex``, a raw device
``core.search.Corpus``, a partitioned ``shard.TiledCorpus``, or a
round-robin ``core.distributed.ShardedCorpus`` plus device mesh — resolves
a :class:`repro.configs.base.PlanConfig` against the index's own config,
and hands planning/execution to :class:`QueryPlanner`.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

import numpy as np

from repro.configs.base import (
    FilterConfig, PlanConfig, SearchConfig,
)
from repro.obs import Observability
from repro.plan.planner import (
    Execution, IndexCapabilities, QueryPlan, QueryPlanner,
)
from repro.plan.request import SearchRequest, SearchResult

# legacy entry points that already warned this process — benchmark/serving
# loops hammer the deprecated wrappers thousands of times, and one warning
# per entry point is signal where one per call is stderr spam
_warned_legacy: set = set()


def warn_legacy(old: str, new: str = "repro.plan.Searcher.search") -> None:
    """One DeprecationWarning per legacy ENTRY POINT per process — the five
    pre-plan entry points are kept as thin wrappers that build a request and
    delegate.  ``reset_legacy_warnings`` re-arms them (tests)."""
    if old in _warned_legacy:
        return
    _warned_legacy.add(old)
    warnings.warn(
        f"{old} is a deprecated entry point kept for compatibility; build a "
        f"SearchRequest and call {new} instead (see README 'query plan "
        f"layer')",
        DeprecationWarning, stacklevel=3,
    )


def reset_legacy_warnings() -> None:
    """Re-arm every deduplicated deprecation warning (test helper)."""
    _warned_legacy.clear()


def validate_attribute_store(store, expected_rows: int, owner: str):
    """THE attribute-store/corpus length check, shared by ``Searcher.open``
    and ``ServingEngine`` (it used to be copy-pasted per engine branch).
    Returns the store for chaining; ``None`` passes through."""
    if store is not None and len(store) != expected_rows:
        raise ValueError(
            f"attribute store has {len(store)} rows, {owner} has "
            f"{expected_rows}"
        )
    return store


class Searcher:
    """Facade over one opened search target.  Use :meth:`open`."""

    def __init__(self, *, planner: QueryPlanner, plan_cfg: PlanConfig,
                 index=None, num_tiles: int = 1,
                 shard_policy: Optional[str] = None):
        self.planner = planner
        self.plan_cfg = plan_cfg
        self._index = index
        self.num_tiles = num_tiles
        self.shard_policy = shard_policy

    # --------------------------------------------------------------- opening
    @classmethod
    def open(cls, index, plan: Optional[PlanConfig] = None, *,
             cfg: Optional[SearchConfig] = None,
             metric: Optional[str] = None,
             attributes=None,
             num_tiles: Optional[int] = None,
             shard_policy: Optional[str] = None,
             probe_tiles: Optional[int] = None,
             beam_width: Optional[int] = None,
             filter_cfg: Optional[FilterConfig] = None,
             bloom_bits: Optional[int] = None,
             num_hashes: Optional[int] = None,
             use_vmap: Optional[bool] = None,
             mesh=None,
             mode: Optional[str] = None,
             data_axis: Optional[str] = None,
             queue_axis: Optional[str] = None,
             obs=None) -> "Searcher":
        """Open a search target.  Keyword arguments override the matching
        ``PlanConfig`` fields; unset fields defer to the index's own
        ``ProximaConfig`` sections, so ``Searcher.open(index)`` reproduces
        the index's configured serving mode exactly.

        ``obs`` takes an :class:`repro.obs.Observability` bundle (or an
        ``ObsConfig``); the planner then bills plan-cache traffic and wraps
        kernel execution in spans/histograms.  ``None`` (default) keeps the
        shared no-op bundle — zero overhead."""
        pc = plan or PlanConfig()
        obs = Observability.resolve(obs)
        kw = dict(search=cfg, num_tiles=num_tiles, shard_policy=shard_policy,
                  probe_tiles=probe_tiles, beam_width=beam_width,
                  filter=filter_cfg, bloom_bits=bloom_bits,
                  num_hashes=num_hashes, use_vmap=use_vmap, mode=mode,
                  data_axis=data_axis, queue_axis=queue_axis)
        pc = dataclasses.replace(
            pc, **{k: v for k, v in kw.items() if v is not None})

        from repro.core.search import Corpus

        if mesh is not None or _is_sharded_corpus(index):
            return cls._open_distributed(index, pc, metric, mesh, obs)
        if _is_mutable(index):
            return cls._open_mutable(index, pc, metric, attributes, obs)
        if isinstance(index, Corpus):
            return cls._open_corpus(index, pc, metric, attributes, obs)
        if _is_tiled(index):
            return cls._open_tiled(index, pc, metric, attributes, obs)
        if _is_segmented(index):
            return cls._open_segmented(index, pc, metric, attributes, obs)
        return cls._open_index(index, pc, metric, attributes, obs)

    # -- target-specific constructors (mirror the legacy engine branches) ----
    @classmethod
    def _resolve_cfg(cls, pc: PlanConfig, default: SearchConfig):
        scfg = pc.search or default
        if pc.beam_width is not None:
            scfg = dataclasses.replace(scfg, beam_width=pc.beam_width)
        return scfg

    @staticmethod
    def _probe_warning(probe_tiles: int, num_tiles: int, policy) -> None:
        if probe_tiles and num_tiles > 1 and policy != "cluster":
            warnings.warn(
                "probe_tiles routing assumes geometry-aware tiles "
                "(shard_policy='cluster'); with hash/contiguous allocation "
                "tile centroids are near-identical and routed recall "
                "collapses", stacklevel=3,
            )

    @classmethod
    def _open_index(cls, index, pc, metric, attributes, obs):
        from repro.configs.base import upgrade_config

        # pre-shard/filter-era pickled configs lack whole sections; upgrade
        # once at the boundary, then read fields directly
        cfg_full = upgrade_config(index.config)
        scfg = cls._resolve_cfg(pc, cfg_full.search)
        metric = metric or index.dataset.metric
        fcfg = pc.filter or cfg_full.filter
        shard_cfg = cfg_full.shard
        n_tiles = shard_cfg.num_tiles if pc.num_tiles is None else pc.num_tiles
        policy = shard_cfg.policy if pc.shard_policy is None \
            else pc.shard_policy
        probe = shard_cfg.probe_tiles if pc.probe_tiles is None \
            else pc.probe_tiles
        attributes = validate_attribute_store(
            attributes, index.dataset.num_base, "index"
        ) if attributes is not None else getattr(index, "attributes", None)
        tiled = corpus = None
        if n_tiles > 1:
            tiled, _ = index.sharded_corpus(n_tiles, policy)
        else:
            corpus = index.corpus()
        cls._probe_warning(probe, n_tiles, policy)
        caps = IndexCapabilities(
            kind="tiled" if tiled is not None else "flat",
            tiled=tiled is not None, num_tiles=n_tiles,
            has_attributes=attributes is not None,
        )
        planner = QueryPlanner(
            capabilities=caps, cfg=scfg, metric=metric, filter_cfg=fcfg,
            plan_cfg=pc, corpus=corpus, tiled=tiled, attributes=attributes,
            probe_tiles=probe, obs=obs,
        )
        return cls(planner=planner, plan_cfg=pc, index=index,
                   num_tiles=n_tiles, shard_policy=policy)

    @classmethod
    def _open_mutable(cls, mutable, pc, metric, attributes, obs):
        from repro.configs.base import upgrade_config

        base = mutable.base
        cfg_full = upgrade_config(base.config)
        scfg = cls._resolve_cfg(pc, cfg_full.search)
        metric = metric or base.dataset.metric
        fcfg = pc.filter or cfg_full.filter
        shard_cfg = cfg_full.shard
        probe = shard_cfg.probe_tiles if pc.probe_tiles is None \
            else pc.probe_tiles
        if attributes is not None:
            validate_attribute_store(
                attributes, mutable.next_ext,
                "mutable index (allocated external ids)",
            )
            mutable.attributes = attributes
        # tiling defaults come from the MutableIndex itself (it may have
        # been tiled manually); sync back only on an explicit request so an
        # opener with defaults never clobbers the index's serving mode
        n_tiles = mutable.num_tiles if pc.num_tiles is None else pc.num_tiles
        policy = mutable.shard_policy if pc.shard_policy is None \
            else pc.shard_policy
        if (n_tiles, policy) != (mutable.num_tiles, mutable.shard_policy):
            mutable.set_num_tiles(n_tiles, policy)
        cls._probe_warning(probe, n_tiles, policy)
        caps = IndexCapabilities(
            kind="merged", mutable=True, tiled=n_tiles > 1,
            num_tiles=n_tiles,
            has_attributes=mutable.attributes is not None,
        )
        planner = QueryPlanner(
            capabilities=caps, cfg=scfg, metric=metric, filter_cfg=fcfg,
            plan_cfg=pc, mutable=mutable, attributes=mutable.attributes,
            probe_tiles=probe, obs=obs,
        )
        if obs.enabled:
            mutable.obs = obs      # stream path: insert/consolidate spans
        return cls(planner=planner, plan_cfg=pc, index=mutable,
                   num_tiles=n_tiles, shard_policy=policy)

    @classmethod
    def _open_corpus(cls, corpus, pc, metric, attributes, obs):
        scfg = cls._resolve_cfg(pc, pc.search or SearchConfig())
        caps = IndexCapabilities(kind="flat",
                                 has_attributes=attributes is not None)
        planner = QueryPlanner(
            capabilities=caps, cfg=scfg, metric=metric or "l2",
            filter_cfg=pc.filter or FilterConfig(), plan_cfg=pc,
            corpus=corpus, attributes=attributes, obs=obs,
        )
        return cls(planner=planner, plan_cfg=pc)

    @classmethod
    def _open_tiled(cls, tiled, pc, metric, attributes, obs):
        scfg = cls._resolve_cfg(pc, pc.search or SearchConfig())
        probe = pc.probe_tiles or 0
        caps = IndexCapabilities(kind="tiled", tiled=True,
                                 num_tiles=tiled.num_tiles,
                                 has_attributes=attributes is not None)
        planner = QueryPlanner(
            capabilities=caps, cfg=scfg, metric=metric or "l2",
            filter_cfg=pc.filter or FilterConfig(), plan_cfg=pc,
            tiled=tiled, attributes=attributes, probe_tiles=probe, obs=obs,
        )
        return cls(planner=planner, plan_cfg=pc,
                   num_tiles=tiled.num_tiles)

    @classmethod
    def _open_segmented(cls, seg_index, pc, metric, attributes, obs):
        """A segment-built index (``core.segmented.SegmentedIndex``) is
        tiled-capable BY CONSTRUCTION: its segments are emitted as tiles
        directly (``shard.tiles_from_segments`` — no repartition, no per-
        tile graph rebuild) and its segment centroids are the router's
        coarse index, so ``probe_tiles`` routing works out of the box."""
        from repro.configs.base import upgrade_config

        cfg_full = upgrade_config(seg_index.config)
        scfg = cls._resolve_cfg(pc, cfg_full.search)
        metric = metric or seg_index.metric
        fcfg = pc.filter or cfg_full.filter
        probe = cfg_full.shard.probe_tiles if pc.probe_tiles is None \
            else pc.probe_tiles
        attributes = validate_attribute_store(
            attributes, seg_index.num_base, "segmented index")
        tiled, _ = seg_index.tiled_corpus()
        n_segments = seg_index.num_segments
        caps = IndexCapabilities(
            kind="tiled", tiled=True, num_tiles=n_segments,
            has_attributes=attributes is not None, segments=n_segments,
        )
        planner = QueryPlanner(
            capabilities=caps, cfg=scfg, metric=metric, filter_cfg=fcfg,
            plan_cfg=pc, tiled=tiled, attributes=attributes,
            probe_tiles=probe, obs=obs,
        )
        return cls(planner=planner, plan_cfg=pc, index=seg_index,
                   num_tiles=n_segments, shard_policy="segments")

    @classmethod
    def _open_distributed(cls, dcorpus, pc, metric, mesh, obs):
        if mesh is None:
            raise ValueError("distributed targets need mesh=")
        scfg = cls._resolve_cfg(pc, pc.search or SearchConfig())
        caps = IndexCapabilities(
            kind="distributed", mesh_devices=int(mesh.size),
            num_tiles=getattr(dcorpus, "num_shards", 1),
        )
        planner = QueryPlanner(
            capabilities=caps, cfg=scfg, metric=metric or "l2",
            filter_cfg=pc.filter or FilterConfig(), plan_cfg=pc,
            dcorpus=dcorpus, mesh=mesh, obs=obs,
        )
        return cls(planner=planner, plan_cfg=pc,
                   num_tiles=getattr(dcorpus, "num_shards", 1))

    # -------------------------------------------------------------- querying
    def plan(self, request: SearchRequest) -> QueryPlan:
        return self.planner.plan(request)

    def execute(self, plan: QueryPlan, queries) -> Execution:
        """Run a precompiled plan over a (possibly padded) query batch —
        the serving engine's batch-flush path."""
        return self.planner.execute(plan, queries)

    def search(self, request: SearchRequest) -> SearchResult:
        """Plan + execute one request.  The only supported entry point."""
        plan = self.planner.plan(request)
        ex = self.planner.execute(plan, request.queries)
        res = SearchResult(ids=ex.ids, dists=ex.dists,
                           stats=self.planner.stats_for(plan, ex),
                           plan=plan, raw=ex.raw)
        qm = self.obs.quality
        if qm is not None:
            # shadow-recall sampling (off-path exact-oracle replay); the
            # engine's flush/retire paths feed the monitor themselves since
            # they execute plans directly
            qm.observe(self, plan, request.queries, res.ids)
        return res

    def round_session(self, plan: QueryPlan):
        """Steppable session for a plan (``None`` when the plan has no
        round-steppable spine) — planner pass-through, the continuous
        engine's and the convergence-telemetry driver's entry point."""
        return self.planner.round_session(plan)

    # ------------------------------------------------------- quality oracle
    def shadow_ground_truth(self, plan: QueryPlan, queries):
        """Exact-oracle neighbor ids for a query batch under ``plan``, in the
        plan's own result-id space — the shadow-recall estimator's ground
        truth (``obs.quality.QualityMonitor``).

        The oracle population is exactly what the plan searched: for merged
        plans the LIVE external corpus (``MutableIndex.live_vectors`` —
        tombstoned vectors excluded, delta inserts included; filtered via the
        live ``ext_mask``), for masked/scan plans the attribute-passing
        subset of the base, otherwise the full base.  Returns ``(Q, k')``
        int64 with ``k' = min(plan.cfg.k, population)`` (``k' = 0`` when
        nothing passes), or ``None`` where no oracle is resolvable —
        distributed fan-outs, legacy caller-mask plans (the one-shot mask is
        not durable), and raw tiled corpora with no backing dataset."""
        from repro.core.dataset import exact_knn

        if plan.kind == "distributed" or plan.mask_token:
            return None
        q = np.atleast_2d(np.asarray(queries, np.float32))
        k = int(plan.cfg.k)
        if plan.kind == "merged":
            mut = self.planner.mutable
            ext_ids, vecs = mut.live_vectors()
            if plan.spec is not None:
                _, ext_mask = mut.filter_masks(plan.spec)
                keep = np.asarray(ext_mask, bool)[ext_ids]
                ext_ids, vecs = ext_ids[keep], vecs[keep]
            if ext_ids.size == 0:
                return np.empty((q.shape[0], 0), np.int64)
            nn = exact_knn(q, vecs, k, mut.metric)   # caps k at |population|
            return ext_ids[nn].astype(np.int64)
        base = self._oracle_base()
        if base is None:
            return None
        if plan.spec is not None:
            mask = np.asarray(self.planner._mask_for(plan.spec), bool)
            pids = np.nonzero(mask)[0]
            if pids.size == 0:
                return np.empty((q.shape[0], 0), np.int64)
            nn = exact_knn(q, base[pids], k, self.metric)
            return pids[nn].astype(np.int64)
        return exact_knn(q, base, k, self.metric).astype(np.int64)

    def _oracle_base(self):
        """Base vectors in the target's internal (reordered) id space, or
        ``None`` when the opened target carries no raw vectors."""
        idx = self._index
        ds = getattr(idx, "dataset", None) if idx is not None else None
        if ds is not None:
            return np.asarray(ds.base, np.float32)
        if self.planner.corpus is not None:
            return np.asarray(self.planner.corpus.base, np.float32)
        return None

    # ------------------------------------------------------------ inspection
    @property
    def cfg(self) -> SearchConfig:
        return self.planner.cfg

    @property
    def metric(self) -> str:
        return self.planner.metric

    @property
    def filter_cfg(self) -> FilterConfig:
        return self.planner.filter_cfg

    @property
    def capabilities(self) -> IndexCapabilities:
        return self.planner.capabilities

    @property
    def mutable(self):
        return self.planner.mutable

    @property
    def corpus(self):
        return self.planner.corpus

    @property
    def tiled(self):
        return self.planner.tiled

    @property
    def attributes(self):
        return self.planner.attributes

    @property
    def probe_tiles(self) -> int:
        return self.planner.probe_tiles

    @property
    def obs(self) -> Observability:
        return self.planner.obs

    @property
    def index(self):
        """Current base index — the mutable's latest after consolidation."""
        if self.planner.mutable is not None:
            return self.planner.mutable.base
        return self._index

    def plan_cache_stats(self) -> dict:
        return {"plan_cache_hits": self.planner.plan_cache_hits,
                "plan_cache_misses": self.planner.plan_cache_misses}


def _is_mutable(obj) -> bool:
    return hasattr(obj, "delta") and hasattr(obj, "tombstones") \
        and hasattr(obj, "base")


def _is_tiled(obj) -> bool:
    return hasattr(obj, "tile_ids") and hasattr(obj, "entry_points")


def _is_sharded_corpus(obj) -> bool:
    return hasattr(obj, "num_shards") and hasattr(obj, "hot_adjacency")


def _is_segmented(obj) -> bool:
    """Segment-built index: per-segment mini-indexes + shared codebook,
    no single flat graph (``core.segmented.SegmentedIndex``)."""
    return hasattr(obj, "segments") and hasattr(obj, "codebook") \
        and not hasattr(obj, "graph")
