"""Round-stepped plan execution — the continuous-batching bridge between the
plan layer and the ``core.search`` step kernels.

A :class:`RoundSession` is the steppable form of one compiled ``QueryPlan``:
where ``QueryPlanner.execute`` runs the plan's whole traversal inside one
``lax.while_loop``, a session exposes the SAME traversal one round at a time
(``init`` / ``step`` / ``active`` / ``finalize``) so an iteration-level
scheduler (``ServingEngine(continuous=True)``) can retire finished lanes and
refill their slots between rounds.  ``complete`` then applies the plan's
post-processing (filtered-result wrapping, or the merged path's delta /
tombstone fusion) to a retired lane batch, producing the same plan-layer
``SearchResult`` the batch executor returns — bit-identically, which is what
lets the round-step equivalence suite compare the two paths end to end.

Not every plan has a round-steppable spine.  Sessions exist for:

  * ``flat``/``none``      — the plain Algorithm-1 traversal;
  * ``flat``/``masked``    — masked traversal with the planner-cached mask;
  * ``merged``/``none``    — the single-tile base traversal stepped, with
    ``stream.searcher._merge_base_delta`` fusing delta candidates and
    tombstones at retire time (delta/tombstone state is read LIVE at retire;
    the base admission mask is pinned at session creation);
  * ``merged``/``adaptive`` — ditto, when the live regime decision resolves
    to masked traversal.

``tiled``/``distributed`` fan-outs, bitmap ``scan``s and ``empty``
short-circuits have no per-round structure; ``QueryPlanner.round_session``
returns ``None`` for them and callers fall back to whole-batch ``execute``.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from repro.configs.base import SearchConfig


class RoundSession:
    """Steppable execution of one ``QueryPlan``.  Create via
    ``QueryPlanner.round_session(plan)``; all lane batches passed to
    ``init``/``step`` must share one shape ``(Q, D)`` — the fixed slot-pool
    shape — so the step kernel compiles once per (plan, Q)."""

    def __init__(
        self,
        *,
        planner,
        plan,
        corpus,
        cfg: SearchConfig,
        metric: str,
        bloom_bits: int,
        num_hashes: int,
        node_mask: Optional[np.ndarray] = None,
        mutable=None,
        ext_mask: Optional[np.ndarray] = None,
        selectivity: float = 1.0,
        base_mode: str = "none",
    ):
        import jax.numpy as jnp

        self.planner = planner
        self.plan = plan
        self.corpus = corpus
        self.cfg = cfg                  # EFFECTIVE traversal config (merged
                                        # sessions: base over-fetch k applied)
        self.metric = metric
        self.bloom_bits = int(bloom_bits)
        self.num_hashes = int(num_hashes)
        self._mask = None if node_mask is None else jnp.asarray(node_mask, bool)
        self.mutable = mutable
        self.ext_mask = ext_mask
        self.selectivity = float(selectivity)
        self.base_mode = base_mode

    # ------------------------------------------------------------- stepping
    def init(self, queries):
        """Round 0 for a (Q, D) batch -> ``core.search.SearchState``."""
        import jax.numpy as jnp

        from repro.core.search import init_search_state

        q = jnp.asarray(np.atleast_2d(np.asarray(queries, np.float32)))
        return init_search_state(self.corpus, q, self.cfg, self.metric,
                                 self.bloom_bits, self.num_hashes, self._mask)

    def step(self, state):
        """ONE traversal round over every lane; quiet lanes pass through."""
        from repro.core.search import graph_search_step

        return graph_search_step(self.corpus, state, self.cfg, self.metric,
                                 self.bloom_bits, self.num_hashes, self._mask)

    def active(self, state) -> np.ndarray:
        """(Q,) bool host array — lanes with rounds still to run."""
        from repro.core.search import search_state_active

        return np.asarray(search_state_active(state, self.cfg))

    def rounds(self, state) -> np.ndarray:
        """(Q,) int host array — rounds each lane has executed so far."""
        return np.asarray(state.lanes.rounds)

    def finalize(self, state):
        """Beta rerank + top-k over the batch -> core ``SearchResult``."""
        from repro.core.search import finalize_search

        return finalize_search(self.corpus, state, self.cfg, self.metric,
                               self._mask)

    def record_round(self, log, qids, state, select=None) -> None:
        """Append one per-round telemetry record per (selected) lane to an
        ``obs.convergence.ConvergenceLog`` — the engine's tick path and the
        off-line dataset driver (``obs.convergence.trace_session``) share
        this so the feature extraction has one owner (the session knows the
        effective k)."""
        log.record_lanes(qids, state, int(self.cfg.k), select=select)

    # -------------------------------------------------------------- retire
    def complete(self, queries, core_res):
        """Post-process a finalized lane batch into the plan-layer
        ``SearchResult`` the batch executor would have returned for the same
        queries: wrap filtered results, or (merged plans) fuse the base
        candidates with the LIVE delta segment and tombstone set.  The reply
        feeds ``obs.record_plan_execution`` unchanged — retired batches bill
        exactly like flushed ones."""
        from repro.plan.planner import Execution
        from repro.plan.request import SearchResult as PlanSearchResult

        plan = self.plan
        if plan.kind == "merged":
            from repro.stream.searcher import MergedResult, _merge_base_delta

            q_np = np.atleast_2d(np.asarray(queries, np.float32))
            ext_mask = self.ext_mask
            if plan.spec is not None:
                # the external-id mask is re-derived LIVE: vectors inserted
                # after session creation extend the id space (the pinned
                # mask would be short) and their attribute rows must filter
                # the delta stream; only the base traversal's admission
                # mask stays pinned for the lane's flight
                _, ext_mask = self.mutable.filter_masks(plan.spec)
            ids, dists, n_delta = _merge_base_delta(
                self.mutable, q_np, np.asarray(core_res.ids),
                np.asarray(core_res.dists), ext_mask, plan.cfg.k,
            )
            raw: Any = MergedResult(
                ids=ids, dists=dists, base=core_res,
                delta_candidates=n_delta, selectivity=self.selectivity,
                base_mode=self.base_mode,
            )
            ex = Execution(ids=ids, dists=dists, raw=raw, counters=core_res,
                           selectivity=self.selectivity,
                           delta_candidates=float(np.asarray(n_delta).mean()))
        elif plan.strategy == "masked":
            from repro.filter.traversal import FilteredSearchResult

            raw = FilteredSearchResult(
                ids=np.asarray(core_res.ids), dists=np.asarray(core_res.dists),
                result=core_res, mode="traversal",
                selectivity=plan.selectivity, effective=plan.cfg,
            )
            ex = Execution(ids=raw.ids, dists=raw.dists, raw=raw,
                           counters=core_res, selectivity=plan.selectivity,
                           delta_candidates=0.0)
        else:
            ex = Execution(ids=np.asarray(core_res.ids),
                           dists=np.asarray(core_res.dists), raw=core_res,
                           counters=core_res, selectivity=1.0,
                           delta_candidates=0.0)
        stats = self.planner.stats_for(plan, ex)
        return PlanSearchResult(ids=ex.ids, dists=ex.dists, stats=stats,
                                plan=plan, raw=ex.raw)
