"""Typed request/result envelope of the query-plan layer.

One logical operation — route a query batch through PQ-approximate graph
traversal with early termination, billed against the NAND channel model —
used to be reachable through five parallel entry points with incompatible
signatures.  ``SearchRequest`` is the single request shape they all reduce
to, ``SearchResult`` the single reply (numpy ids/dists plus a structured
``SearchStats`` instead of ad-hoc stats dicts, and the raw kernel result for
NAND billing via ``nand.simulator.trace_from_plan_execution``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Tuple

from repro.filter.spec import FilterSpec


@dataclasses.dataclass
class SearchRequest:
    """One search call against a ``Searcher``.

    ``queries`` is a ``(Q, D)`` (or single ``(D,)``) float array.  ``k``
    defaults to the searcher's configured ``SearchConfig.k``.  ``filter`` is
    a hashable :class:`repro.filter.FilterSpec` — the typed replacement for
    the untyped per-path filter arguments.  ``overrides`` are per-request
    ``SearchConfig`` field overrides (e.g. ``{"beam_width": 4}``) applied on
    top of the searcher's base config; together with ``filter`` they define
    the request's plan-cache identity.  ``tenant`` is the namespace slot the
    multi-tenancy roadmap item composes against (recorded on the plan,
    unused by single-tenant execution).

    ``node_mask`` is the legacy escape hatch: a caller-precompiled admission
    mask in the target's native form ((N,) bool for a flat corpus, (P, Nt)
    per-tile slices for a tiled one).  The deprecated wrappers use it to
    delegate without an attribute store; ``adaptive`` selects whether the
    selectivity regimes (scan / inflated masked traversal — the
    ``filtered_search`` semantics) apply to it, or the mask is passed to the
    traversal verbatim (the ``core.search(node_mask=...)`` semantics).
    """
    queries: Any
    k: Optional[int] = None
    filter: Optional[FilterSpec] = None
    tenant: Optional[str] = None
    overrides: Any = ()
    probe_tiles: Optional[int] = None
    # legacy-wrapper escape hatch (see class docstring)
    node_mask: Optional[Any] = None
    adaptive: bool = True

    def override_items(self) -> Tuple[Tuple[str, Any], ...]:
        """Overrides as a sorted, hashable tuple (the plan-cache key part)."""
        if isinstance(self.overrides, Mapping):
            return tuple(sorted(self.overrides.items()))
        return tuple(self.overrides)


@dataclasses.dataclass(frozen=True)
class SearchStats:
    """Structured per-execution search statistics — the typed replacement
    for the ad-hoc stats dicts the five legacy paths each rolled by hand.
    Counters are per-query means over the batch (tiled executions sum the
    per-channel counters first, so they carry the TOTAL work a query costs
    across all channels — same convention as the NAND workload traces)."""
    queries: int = 0                 # batch size executed
    k: int = 0
    kind: str = "flat"               # flat | tiled | merged | distributed
    strategy: str = "none"           # none | masked | scan | empty | adaptive
    selectivity: float = 1.0         # passing fraction (1.0 unfiltered)
    hops: float = 0.0                # vertex expansions (index fetches)
    pq: float = 0.0                  # PQ distance computations
    acc: float = 0.0                 # accurate distance computations
    hot_hops: float = 0.0            # expansions served by hot-node replicas
    free_pq: float = 0.0             # PQ fetches covered by hot pages
    rounds: float = 0.0              # serial traversal rounds
    delta_candidates: float = 0.0    # delta-segment candidates (merged path)
    beam_width: int = 1              # nominal E executed
    num_tiles: int = 1

    def as_dict(self) -> dict:
        """Back-compat accessor: the dict shape legacy stats consumers read."""
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SearchResult:
    """Plan-layer search reply.

    ``ids``/``dists`` are host numpy ``(Q, k)`` arrays (-1 / +inf padded
    where a filter admits fewer than k candidates).  ``stats`` is the
    structured counter record; ``plan`` the executed :class:`QueryPlan`
    (its strategy/selectivity/beam fields drive NAND billing); ``raw`` the
    untouched kernel result (``core.search.SearchResult``,
    ``filter.FilteredSearchResult``, ``shard.ShardedSearchResult``,
    ``stream.MergedResult`` or a distributed ``(ids, dists)`` pair) — the
    optional workload-trace handle
    ``nand.simulator.trace_from_plan_execution`` consumes.
    """
    ids: Any
    dists: Any
    stats: SearchStats
    plan: Any
    raw: Any
